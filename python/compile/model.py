"""L2: the served model — a Llama-architecture transformer with batch-LoRA.

This is the compute graph the Rust coordinator executes through PJRT. It is
written once in JAX (calling the L1 Pallas kernels for every LoRA-adapted
projection) and lowered AOT by ``aot.py`` to HLO text. Python never runs on
the request path.

Entry points (each becomes one or more HLO artifacts):

  prefill      [1, T] tokens + adapter slot  -> last-token logits, last
               hidden state (for the adapter router head), per-request KV rows
  decode_step  [B] tokens, per-request positions + adapter slots, batched KV
               cache -> next-token logits, updated cache. One fused HLO; the
               whole token loop lives in Rust.
  inject_row   writes a prefill's KV rows into row ``b`` of the batched
               decode cache (device-side, no host roundtrip of the cache).
  router_head  hidden state -> adapter confidence scores (§3.2: the router is
               the shared base model plus one Linear layer, so the marginal
               cost of adaptive adapter selection ≈ one prompt decode).

Weights are *inputs*, not constants: ``aot.py`` writes ``weights.bin`` +
manifest and the Rust runtime uploads them once at startup. The LoRA banks
(``a_bank``/``b_bank``) are also inputs — the Rust memory manager rewrites a
bank slot when the adapter cache loads/evicts an adapter (§3.3).

Architecture: RMSNorm, RoPE, MHA, SwiGLU — Llama-family, matching the
paper's served models (Llama3.1/3.2, OpenELM), scaled to run for real on the
CPU PJRT client (see DESIGN.md §Substitutions).
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from compile.kernels.batch_lora import batch_lora, lora_delta_multi


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape configuration. Every field is baked into the HLO."""

    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 688
    max_seq: int = 256
    rope_theta: float = 10000.0
    # LoRA: number of resident bank slots (= memory-pool size at L3) and rank.
    n_slots: int = 8
    lora_rank: int = 16
    # Adapter-router head width (scores for up to this many adapters; L3 maps
    # logical adapter ids onto head outputs).
    n_router_outputs: int = 64
    # Decode batch width (= max concurrent generation slots on the real
    # backend; the γ knob of Table 14 for the PJRT path).
    decode_batch: int = 8

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def lora_scale(self) -> float:
        # Conventional alpha = 2 * rank.
        return 2.0

    def weight_specs(self):
        """Ordered (name, shape) list — the wire format of weights.bin."""
        c = self
        return [
            ("embed", (c.vocab, c.d_model)),
            ("wq", (c.n_layers, c.d_model, c.d_model)),
            ("wk", (c.n_layers, c.d_model, c.d_model)),
            ("wv", (c.n_layers, c.d_model, c.d_model)),
            ("wo", (c.n_layers, c.d_model, c.d_model)),
            ("w_gate", (c.n_layers, c.d_ff, c.d_model)),
            ("w_up", (c.n_layers, c.d_ff, c.d_model)),
            ("w_down", (c.n_layers, c.d_model, c.d_ff)),
            ("rms_attn", (c.n_layers, c.d_model)),
            ("rms_ffn", (c.n_layers, c.d_model)),
            ("rms_final", (c.d_model,)),
            ("lm_head", (c.vocab, c.d_model)),
            ("router_w", (c.n_router_outputs, c.d_model)),
        ]

    def bank_specs(self):
        """LoRA bank shapes. Axis 1 indexes the adapted projection (q,k,v,o)."""
        c = self
        return [
            ("a_bank", (c.n_layers, 4, c.n_slots, c.lora_rank, c.d_model)),
            ("b_bank", (c.n_layers, 4, c.n_slots, c.d_model, c.lora_rank)),
        ]

    def cache_shape(self, batch: int):
        """KV cache layout: [n_layers, batch, max_seq, n_heads, head_dim]."""
        c = self
        return (c.n_layers, batch, c.max_seq, c.n_heads, c.head_dim)


def init_weights(cfg: ModelConfig, seed: int = 0):
    """Deterministic synthetic weights (scaled for stable activations)."""
    out = {}
    key = jax.random.PRNGKey(seed)
    for name, shape in cfg.weight_specs():
        key, sub = jax.random.split(key)
        if name.startswith("rms"):
            w = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            w = jax.random.normal(sub, shape, jnp.float32) / math.sqrt(fan_in)
        out[name] = w
    return out


def init_banks(cfg: ModelConfig, seed: int = 1):
    """Synthetic LoRA banks. B is near-zero-scaled like a fresh LoRA init."""
    key_a, key_b = jax.random.split(jax.random.PRNGKey(seed))
    (na, sa), (nb, sb) = cfg.bank_specs()
    a = jax.random.normal(key_a, sa, jnp.float32) / math.sqrt(cfg.d_model)
    b = jax.random.normal(key_b, sb, jnp.float32) * 0.01
    return {na: a, nb: b}


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rms_norm(x, gamma, eps=1e-5):
    """Root-mean-square layer norm over the feature axis."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


def rope_angles(cfg: ModelConfig, positions):
    """RoPE cos/sin tables for int32 positions of any shape -> (+[hd/2])."""
    half = cfg.head_dim // 2
    inv_freq = cfg.rope_theta ** (
        -jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """Rotate pairs (x[..., :half], x[..., half:]) by the position angle.

    x: [..., n_heads, head_dim]; cos/sin broadcast over the head axis.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def _proj(x, w, banks, layer, proj_idx, idx, cfg):
    """LoRA-adapted projection via the L1 batch-LoRA kernel.

    x: [N, d]; w: [d_out, d]; idx: [N] adapter slot per row.
    """
    return batch_lora(
        x,
        w,
        banks["a_bank"][layer, proj_idx],
        banks["b_bank"][layer, proj_idx],
        idx,
        scale=cfg.lora_scale / cfg.lora_rank,
    )


def _proj_qkv(x, weights, banks, layer, idx, cfg):
    """Fused q,k,v projection (§Perf): one base GEMM over the concatenated
    weights + one multi-projection batch-LoRA kernel, instead of three
    separate pallas calls. Semantically identical to three `_proj` calls
    (asserted by the pytest oracle check).
    """
    n = x.shape[0]
    w3 = jnp.concatenate(
        [weights["wq"][layer], weights["wk"][layer], weights["wv"][layer]],
        axis=0,
    )  # [3·d_out, d_in]
    base = jnp.dot(x, w3.T, preferred_element_type=jnp.float32).astype(x.dtype)
    a3 = banks["a_bank"][layer, 0:3]  # [3, slots, r, d]
    b3 = banks["b_bank"][layer, 0:3]
    delta = lora_delta_multi(x, a3, b3, idx)  # [n, 3, d_out]
    scale = cfg.lora_scale / cfg.lora_rank
    out = base + scale * delta.reshape(n, 3 * cfg.d_model)
    q, k, v = jnp.split(out, 3, axis=-1)
    return q, k, v


def ffn(x, w_gate, w_up, w_down):
    """SwiGLU feed-forward (base weights only; LoRA targets attention)."""
    g = jax.nn.silu(x @ w_gate.T)
    u = x @ w_up.T
    return (g * u) @ w_down.T


# ---------------------------------------------------------------------------
# Prefill: one request, full prompt
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, weights, banks, tokens, adapter_slot):
    """Process a whole prompt for one request.

    Args:
      tokens:       [1, T] int32 prompt ids, right-padded with 0 to the
                    bucket length T. The causal mask keeps pad positions from
                    influencing real ones; L3 reads row ``true_len - 1`` of
                    the outputs and decode's visibility mask never exposes
                    the polluted cache rows ≥ true_len (each is overwritten
                    by a decode step before it becomes visible).
      adapter_slot: [1] int32 bank slot for this request's adapter.

    Returns:
      logits  [T, vocab]   — per-position next-token logits,
      hidden  [T, d_model] — per-position final hidden state (router input),
      k_rows  [n_layers, 1, max_seq, n_heads, head_dim],
      v_rows  same shape.
    """
    t = tokens.shape[1]
    x = weights["embed"][tokens[0]]  # [T, d]
    positions = jnp.arange(t, dtype=jnp.int32)
    cos, sin = rope_angles(cfg, positions)
    idx = jnp.broadcast_to(adapter_slot, (t,)).astype(jnp.int32)
    causal = jnp.tril(jnp.ones((t, t), jnp.bool_))

    k_rows = []
    v_rows = []
    for layer in range(cfg.n_layers):
        h = rms_norm(x, weights["rms_attn"][layer])
        # NOTE §Perf: the fused `_proj_qkv` variant was measured SLOWER on
        # the interpret/CPU path (nested 2-D grid loops beat 3 flat loops,
        # 31→35 ms/step; see EXPERIMENTS.md) — kept for real-TPU lowering
        # experiments, not used here.
        q = _proj(h, weights["wq"][layer], banks, layer, 0, idx, cfg)
        k = _proj(h, weights["wk"][layer], banks, layer, 1, idx, cfg)
        v = _proj(h, weights["wv"][layer], banks, layer, 2, idx, cfg)
        q = q.reshape(t, cfg.n_heads, cfg.head_dim)
        k = k.reshape(t, cfg.n_heads, cfg.head_dim)
        v = v.reshape(t, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

        scores = jnp.einsum("qhd,khd->hqk", q, k) / math.sqrt(cfg.head_dim)
        scores = jnp.where(causal[None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("hqk,khd->qhd", probs, v).reshape(t, cfg.d_model)
        attn = _proj(attn, weights["wo"][layer], banks, layer, 3, idx, cfg)
        x = x + attn

        h = rms_norm(x, weights["rms_ffn"][layer])
        x = x + ffn(
            h,
            weights["w_gate"][layer],
            weights["w_up"][layer],
            weights["w_down"][layer],
        )

        pad = cfg.max_seq - t
        k_rows.append(jnp.pad(k, ((0, pad), (0, 0), (0, 0)))[None])
        v_rows.append(jnp.pad(v, ((0, pad), (0, 0), (0, 0)))[None])

    hidden = rms_norm(x, weights["rms_final"])  # [T, d]
    logits = hidden @ weights["lm_head"].T  # [T, vocab]
    return (
        logits,
        hidden,
        jnp.stack(k_rows, axis=0),
        jnp.stack(v_rows, axis=0),
    )


# ---------------------------------------------------------------------------
# Decode: one token for the whole slot batch
# ---------------------------------------------------------------------------


def decode_step(cfg, weights, banks, tokens, positions, adapter_slots,
                k_cache, v_cache):
    """One generation step for the batched decode slots.

    Args:
      tokens:        [B] int32 current token per slot (0 for idle rows).
      positions:     [B] int32 write position per slot (idle rows: 0).
      adapter_slots: [B] int32 bank slot per row.
      k_cache/v_cache: [n_layers, B, max_seq, n_heads, head_dim].

    Returns:
      logits [B, vocab], k_cache', v_cache'.

    Idle rows still burn FLOPs — that is exactly what a fixed-slot static
    batch does on the real system; L3 masks their outputs.
    """
    b = tokens.shape[0]
    x = weights["embed"][tokens]  # [B, d]
    cos, sin = rope_angles(cfg, positions)  # [B, hd/2]
    idx = adapter_slots.astype(jnp.int32)
    pos_grid = jnp.arange(cfg.max_seq, dtype=jnp.int32)  # [S]
    visible = pos_grid[None, :] <= positions[:, None]  # [B, S]

    new_k = k_cache
    new_v = v_cache
    for layer in range(cfg.n_layers):
        h = rms_norm(x, weights["rms_attn"][layer])
        # NOTE §Perf: the fused `_proj_qkv` variant was measured SLOWER on
        # the interpret/CPU path (nested 2-D grid loops beat 3 flat loops,
        # 31→35 ms/step; see EXPERIMENTS.md) — kept for real-TPU lowering
        # experiments, not used here.
        q = _proj(h, weights["wq"][layer], banks, layer, 0, idx, cfg)
        k = _proj(h, weights["wk"][layer], banks, layer, 1, idx, cfg)
        v = _proj(h, weights["wv"][layer], banks, layer, 2, idx, cfg)
        q = q.reshape(b, cfg.n_heads, cfg.head_dim)
        k = k.reshape(b, cfg.n_heads, cfg.head_dim)
        v = v.reshape(b, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

        # Scatter this step's K/V into each row's ``positions[row]`` slot.
        def write(cache_l, val):
            def one(row_cache, row_val, row_pos):
                return jax.lax.dynamic_update_slice(
                    row_cache, row_val[None], (row_pos, 0, 0)
                )

            return jax.vmap(one)(cache_l, val, positions)

        k_l = write(new_k[layer], k)  # [B, S, h, hd]
        v_l = write(new_v[layer], v)
        new_k = new_k.at[layer].set(k_l)
        new_v = new_v.at[layer].set(v_l)

        scores = jnp.einsum("bhd,bshd->bhs", q, k_l) / math.sqrt(cfg.head_dim)
        scores = jnp.where(visible[:, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhs,bshd->bhd", probs, v_l).reshape(b, cfg.d_model)
        attn = _proj(attn, weights["wo"][layer], banks, layer, 3, idx, cfg)
        x = x + attn

        h = rms_norm(x, weights["rms_ffn"][layer])
        x = x + ffn(
            h,
            weights["w_gate"][layer],
            weights["w_up"][layer],
            weights["w_down"][layer],
        )

    hidden = rms_norm(x, weights["rms_final"])
    logits = hidden @ weights["lm_head"].T
    return logits, new_k, new_v


# ---------------------------------------------------------------------------
# Cache row injection + router head
# ---------------------------------------------------------------------------


def inject_row(k_cache, v_cache, k_rows, v_rows, row):
    """Write a prefill's KV rows into batch row ``row`` of the decode cache.

    k_cache: [L, B, S, h, hd]; k_rows: [L, 1, S, h, hd]; row: [] int32.
    Runs device-side so the multi-MB cache never crosses to the host.
    """
    zero = jnp.int32(0)
    start = (zero, row.astype(jnp.int32), zero, zero, zero)
    return (
        jax.lax.dynamic_update_slice(k_cache, k_rows, start),
        jax.lax.dynamic_update_slice(v_cache, v_rows, start),
    )


def router_head(weights, hidden):
    """Adapter-router scores (§3.2): sigmoid(hidden @ W_router^T).

    hidden: [1, d_model] — prefill's last hidden state, so running the router
    costs one Linear layer on top of compute the server already did.
    """
    return jax.nn.sigmoid(hidden @ weights["router_w"].T)
