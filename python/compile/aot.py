"""AOT lowering driver: JAX model -> HLO text artifacts + weights binary.

Run once at build time (``make artifacts``). Outputs, under ``artifacts/``:

  manifest.json        artifact index: files, parameter/output shapes, model
                       config — everything the Rust runtime needs to load and
                       call the executables without importing Python.
  weights.bin          flat little-endian f32 weight arrays (manifest order).
  prefill_t{T}.hlo.txt one per prompt-length bucket.
  decode_b{B}.hlo.txt  the batched decode step.
  inject_row.hlo.txt   device-side KV-row injection.
  router_head.hlo.txt  adapter-router scores from a prefill hidden state.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as m

PREFILL_BUCKETS = (8, 16, 32, 64, 128)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_entry(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


class Lowerer:
    """Lowers each entry point with an explicit, manifest-recorded signature."""

    def __init__(self, cfg: m.ModelConfig, out_dir: str):
        self.cfg = cfg
        self.out = out_dir
        # router_w is only used by router_head; jax.jit would DCE it from the
        # other entry points' signatures, so it is excluded explicitly and the
        # manifest stays an exact mirror of each artifact's HLO parameters.
        self.weight_names = [
            n for n, _ in cfg.weight_specs() if n != "router_w"
        ]
        self.bank_names = [n for n, _ in cfg.bank_specs()]
        self.artifacts = []

    def _weight_params(self):
        return [
            _param_entry(n, s)
            for n, s in self.cfg.weight_specs()
            if n != "router_w"
        ]

    def _bank_params(self):
        return [_param_entry(n, s) for n, s in self.cfg.bank_specs()]

    def lower(self, name, fn, params, outputs):
        """Trace ``fn`` against the manifest signature and dump HLO text."""
        specs = [
            _spec(
                tuple(p["shape"]),
                jnp.int32 if p["dtype"] == "i32" else jnp.float32,
            )
            for p in params
        ]
        lowered = jax.jit(fn).lower(*specs)
        path = os.path.join(self.out, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        self.artifacts.append(
            {
                "name": name,
                "file": os.path.basename(path),
                "params": params,
                "outputs": outputs,
            }
        )
        print(f"  {name}: {len(params)} params -> {path} ({len(text)} chars)")

    def lower_prefill(self, t):
        cfg = self.cfg
        names = self.weight_names

        def fn(*args):
            weights = dict(zip(names, args[: len(names)]))
            banks = dict(zip(self.bank_names, args[len(names):len(names) + 2]))
            tokens, slot = args[len(names) + 2], args[len(names) + 3]
            return m.prefill(cfg, weights, banks, tokens, slot)

        params = (
            self._weight_params()
            + self._bank_params()
            + [
                _param_entry("tokens", (1, t), "i32"),
                _param_entry("adapter_slot", (1,), "i32"),
            ]
        )
        cache = cfg.cache_shape(1)
        outputs = [
            _param_entry("logits", (t, cfg.vocab)),
            _param_entry("hidden", (t, cfg.d_model)),
            _param_entry("k_rows", cache),
            _param_entry("v_rows", cache),
        ]
        self.lower(f"prefill_t{t}", fn, params, outputs)

    def lower_decode(self):
        cfg = self.cfg
        names = self.weight_names
        b = cfg.decode_batch
        cache = cfg.cache_shape(b)

        def fn(*args):
            weights = dict(zip(names, args[: len(names)]))
            banks = dict(zip(self.bank_names, args[len(names):len(names) + 2]))
            tokens, positions, slots, k_cache, v_cache = args[len(names) + 2:]
            return m.decode_step(
                cfg, weights, banks, tokens, positions, slots, k_cache, v_cache
            )

        params = (
            self._weight_params()
            + self._bank_params()
            + [
                _param_entry("tokens", (b,), "i32"),
                _param_entry("positions", (b,), "i32"),
                _param_entry("adapter_slots", (b,), "i32"),
                _param_entry("k_cache", cache),
                _param_entry("v_cache", cache),
            ]
        )
        outputs = [
            _param_entry("logits", (b, cfg.vocab)),
            _param_entry("k_cache", cache),
            _param_entry("v_cache", cache),
        ]
        self.lower(f"decode_b{b}", fn, params, outputs)

    def lower_inject(self):
        cfg = self.cfg
        b = cfg.decode_batch
        cache = cfg.cache_shape(b)
        row = cfg.cache_shape(1)
        params = [
            _param_entry("k_cache", cache),
            _param_entry("v_cache", cache),
            _param_entry("k_rows", row),
            _param_entry("v_rows", row),
            _param_entry("row", (), "i32"),
        ]
        outputs = [_param_entry("k_cache", cache), _param_entry("v_cache", cache)]
        self.lower("inject_row", m.inject_row, params, outputs)

    def lower_router(self):
        cfg = self.cfg

        def fn(router_w, hidden):
            return (m.router_head({"router_w": router_w}, hidden),)

        params = [
            _param_entry("router_w", (cfg.n_router_outputs, cfg.d_model)),
            _param_entry("hidden", (1, cfg.d_model)),
        ]
        outputs = [_param_entry("scores", (1, cfg.n_router_outputs))]
        self.lower("router_head", fn, params, outputs)


def write_weights(cfg: m.ModelConfig, out_dir: str, seed: int):
    """weights.bin: manifest-ordered flat little-endian f32 arrays."""
    weights = m.init_weights(cfg, seed)
    banks = m.init_banks(cfg, seed + 1)
    entries = []
    offset = 0
    path = os.path.join(out_dir, "weights.bin")
    with open(path, "wb") as f:
        for name, shape in cfg.weight_specs() + cfg.bank_specs():
            arr = np.asarray(weights.get(name, banks.get(name)), np.float32)
            assert tuple(arr.shape) == tuple(shape), name
            raw = arr.astype("<f4").tobytes()
            f.write(raw)
            entries.append(
                {"name": name, "shape": list(shape), "offset": offset,
                 "nbytes": len(raw)}
            )
            offset += len(raw)
    print(f"  weights.bin: {offset / 1e6:.1f} MB")
    return entries


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--prefill-buckets", type=str, default=None,
                    help="comma-separated prompt-length buckets")
    args = ap.parse_args()

    cfg = m.ModelConfig()
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
    if args.n_layers:
        overrides["n_layers"] = args.n_layers
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    buckets = (
        tuple(int(x) for x in args.prefill_buckets.split(","))
        if args.prefill_buckets
        else PREFILL_BUCKETS
    )

    os.makedirs(args.out, exist_ok=True)
    low = Lowerer(cfg, args.out)
    print(f"lowering model cfg={cfg}")
    for t in buckets:
        low.lower_prefill(t)
    low.lower_decode()
    low.lower_inject()
    low.lower_router()
    weight_entries = write_weights(cfg, args.out, args.seed)

    manifest = {
        "config": dataclasses.asdict(cfg),
        "prefill_buckets": list(buckets),
        "weights_file": "weights.bin",
        "weights": weight_entries,
        "artifacts": low.artifacts,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  manifest.json: {len(low.artifacts)} artifacts")


if __name__ == "__main__":
    main()
