"""Pallas kernels for EdgeLoRA's Batch LoRA Inference (§3.4).

The paper's CUDA formulation (Punica-style BGMV: one threadblock per request
gathers its adapter and runs a small GEMM) is rethought for the TPU execution
model (see DESIGN.md §Hardware-Adaptation):

  * the per-request adapter *gather* becomes a **scalar-prefetched BlockSpec
    index map**: the grid iterates over the batch, and the block index of the
    adapter bank operand is ``idx[i]`` — Pallas/Mosaic turns that into the
    HBM→VMEM DMA schedule that CUDA expressed with threadblocks;
  * the small per-request GEMV targets the MXU; ranks are padded to the MXU
    lane width at AOT time (L3 keeps banks pre-padded, so there is no runtime
    cost);
  * consecutive grid steps with the same ``idx[i]`` reuse the VMEM-resident
    adapter block — which is why the Rust batcher sorts requests by adapter
    id before building a batch (u-batch grouping at L3).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO so the Rust
runtime can run the same artifact. Real-TPU efficiency is estimated
analytically in EXPERIMENTS.md §Perf.

Kernel inventory
----------------
  bgmv_shrink(x, a_bank, idx)        -> v = A_idx @ x           [B,r]
  bgmv_expand(v, b_bank, idx)        -> y = B_idx @ v           [B,d_out]
  lora_delta(x, a_bank, b_bank, idx) -> y = B_idx (A_idx x)     fused, one
                                        HBM roundtrip instead of two
  batch_lora(...)                    -> x @ W^T + scale * delta  (full §3.4
                                        projection; base GEMM left to XLA,
                                        which fuses it with neighbours)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# interpret=True is mandatory for the CPU-PJRT AOT path; see module docstring.
INTERPRET = True


def _shrink_kernel(idx_ref, x_ref, a_ref, o_ref):
    """One grid step: v[i] = A[idx[i]] @ x[i].

    ``a_ref`` already holds the idx[i]-th adapter block in VMEM courtesy of
    the scalar-prefetch index map — the kernel body never sees the gather.
    """
    del idx_ref  # consumed by the index maps, not the body
    x = x_ref[0, :]                       # [d]
    a = a_ref[0, :, :]                    # [r, d]
    o_ref[0, :] = jnp.dot(a, x, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def _expand_kernel(idx_ref, v_ref, b_ref, o_ref):
    """One grid step: y[i] = B[idx[i]] @ v[i]."""
    del idx_ref
    v = v_ref[0, :]                       # [r]
    b = b_ref[0, :, :]                    # [d_out, r]
    o_ref[0, :] = jnp.dot(b, v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def _fused_kernel(idx_ref, x_ref, a_ref, b_ref, o_ref):
    """One grid step: y[i] = B[idx[i]] @ (A[idx[i]] @ x[i]).

    Keeps the rank-r intermediate in VMEM/registers; saves writing v to HBM
    and reading it back (the shrink→expand roundtrip).
    """
    del idx_ref
    x = x_ref[0, :]
    a = a_ref[0, :, :]
    b = b_ref[0, :, :]
    v = jnp.dot(a, x, preferred_element_type=jnp.float32)
    o_ref[0, :] = jnp.dot(
        b, v.astype(b.dtype), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _fused_multi_kernel(idx_ref, x_ref, a_ref, b_ref, o_ref):
    """Grid step (i, p): y[i, p] = B[p, idx[i]] @ (A[p, idx[i]] @ x[i]).

    The multi-projection variant: one pallas_call covers all projections
    that share the same input activation (q, k, v), cutting kernel-dispatch
    count — the dominant decode-step cost on the interpret/CPU path
    (EXPERIMENTS.md §Perf) — and letting consecutive grid steps reuse the
    VMEM-resident x row across projections on real hardware.
    """
    del idx_ref
    x = x_ref[0, :]
    a = a_ref[0, 0, :, :]
    b = b_ref[0, 0, :, :]
    v = jnp.dot(a, x, preferred_element_type=jnp.float32)
    o_ref[0, 0, :] = jnp.dot(
        b, v.astype(b.dtype), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def lora_delta_multi(x, a_banks, b_banks, idx):
    """Fused deltas for P projections sharing input x.

    Args:
      x:       [B, d_in].
      a_banks: [P, L, r, d_in]   (stacked per-projection A banks).
      b_banks: [P, L, d_out, r].
      idx:     [B] int32.

    Returns:
      [B, P, d_out].
    """
    batch, d_in = x.shape
    n_proj, _, r, d_in2 = a_banks.shape
    _, _, d_out, _ = b_banks.shape
    assert d_in == d_in2
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(batch, n_proj),
        in_specs=[
            pl.BlockSpec((1, d_in), lambda i, p, idx_ref: (i, 0)),
            pl.BlockSpec(
                (1, 1, r, d_in), lambda i, p, idx_ref: (p, idx_ref[i], 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, d_out, r), lambda i, p, idx_ref: (p, idx_ref[i], 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, d_out), lambda i, p, idx_ref: (i, p, 0)),
    )
    return pl.pallas_call(
        _fused_multi_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, n_proj, d_out), x.dtype),
        interpret=INTERPRET,
    )(idx, x, a_banks, b_banks)


def _bank_spec_3d(dim1, dim2):
    """BlockSpec selecting adapter block ``idx[i]`` of a [L, dim1, dim2] bank.

    The index map receives (grid position i, prefetched idx ref) and returns
    the *block* coordinates — (idx[i], 0, 0) with a (1, dim1, dim2) block is
    exactly "DMA adapter idx[i] into VMEM".
    """
    return pl.BlockSpec((1, dim1, dim2), lambda i, idx_ref: (idx_ref[i], 0, 0))


def _row_spec(width):
    """BlockSpec selecting row i of a [B, width] operand."""
    return pl.BlockSpec((1, width), lambda i, idx_ref: (i, 0))


def bgmv_shrink(x, a_bank, idx):
    """v[i] = A[idx[i]] @ x[i]  — batched gather matrix-vector, down proj.

    Args:
      x:      [B, d] activations.
      a_bank: [L, r, d] adapter-A bank.
      idx:    [B] int32 adapter slot per request.

    Returns:
      [B, r] with x.dtype.
    """
    batch, d = x.shape
    _, r, d2 = a_bank.shape
    assert d == d2, f"x feature dim {d} != bank dim {d2}"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(batch,),
        in_specs=[_row_spec(d), _bank_spec_3d(r, d)],
        out_specs=_row_spec(r),
    )
    return pl.pallas_call(
        _shrink_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, r), x.dtype),
        interpret=INTERPRET,
    )(idx, x, a_bank)


def bgmv_expand(v, b_bank, idx):
    """y[i] = B[idx[i]] @ v[i]  — batched gather matrix-vector, up proj.

    Args:
      v:      [B, r] down-projected activations.
      b_bank: [L, d_out, r] adapter-B bank.
      idx:    [B] int32 adapter slot per request.

    Returns:
      [B, d_out] with v.dtype.
    """
    batch, r = v.shape
    _, d_out, r2 = b_bank.shape
    assert r == r2, f"v rank dim {r} != bank rank {r2}"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(batch,),
        in_specs=[_row_spec(r), _bank_spec_3d(d_out, r)],
        out_specs=_row_spec(d_out),
    )
    return pl.pallas_call(
        _expand_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, d_out), v.dtype),
        interpret=INTERPRET,
    )(idx, v, b_bank)


def lora_delta(x, a_bank, b_bank, idx):
    """Fused y[i] = B[idx[i]] @ (A[idx[i]] @ x[i]).

    Args:
      x:      [B, d_in] activations.
      a_bank: [L, r, d_in].
      b_bank: [L, d_out, r].
      idx:    [B] int32.

    Returns:
      [B, d_out] with x.dtype.
    """
    batch, d_in = x.shape
    n_slots, r, d_in2 = a_bank.shape
    n_slots2, d_out, r2 = b_bank.shape
    assert d_in == d_in2 and r == r2 and n_slots == n_slots2
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(batch,),
        in_specs=[
            _row_spec(d_in),
            _bank_spec_3d(r, d_in),
            _bank_spec_3d(d_out, r),
        ],
        out_specs=_row_spec(d_out),
    )
    return pl.pallas_call(
        _fused_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, d_out), x.dtype),
        interpret=INTERPRET,
    )(idx, x, a_bank, b_bank)


@functools.partial(jax.named_call, name="batch_lora")
def batch_lora(x, w, a_bank, b_bank, idx, scale=1.0, fused=True):
    """Full §3.4 projection: y_i = W x_i + scale · B_{a(i)} A_{a(i)} x_i.

    The dense base GEMM ``x @ W^T`` is deliberately expressed in plain jnp so
    XLA fuses it with surrounding ops; only the irregular gathered part runs
    in Pallas.

    Args:
      x:      [B, d_in].
      w:      [d_out, d_in] frozen base weight.
      a_bank: [L, r, d_in].
      b_bank: [L, d_out, r].
      idx:    [B] int32 adapter slot per request.
      scale:  LoRA scaling (alpha / r).
      fused:  use the fused shrink+expand kernel (default) or the two-kernel
              pipeline (kept for ablation).

    Returns:
      [B, d_out].
    """
    base = jnp.dot(x, w.T, preferred_element_type=jnp.float32).astype(x.dtype)
    if fused:
        delta = lora_delta(x, a_bank, b_bank, idx)
    else:
        v = bgmv_shrink(x, a_bank, idx)
        delta = bgmv_expand(v, b_bank, idx)
    return base + scale * delta
