"""Pure-jnp oracles for the batch-LoRA kernels.

These are the correctness ground truth for the Pallas kernels in
``batch_lora.py``. They implement §3.4 of the EdgeLoRA paper literally:

    y_i = W x_i + B_{a(i)} A_{a(i)} x_i

where ``a(i)`` is the adapter slot assigned to request ``i``. No Pallas, no
tricks — just gathers and einsums — so pytest can assert_allclose the kernels
against them across shapes and dtypes.
"""

import jax.numpy as jnp


def bgmv_shrink_ref(x, a_bank, idx):
    """v[i] = A[idx[i]] @ x[i].

    Args:
      x:      [B, d]    activations.
      a_bank: [L, r, d] LoRA-A bank (one slot per cached adapter).
      idx:    [B]       int32 adapter-slot index per request.

    Returns:
      [B, r] down-projected activations.
    """
    a = a_bank[idx]  # [B, r, d]
    return jnp.einsum("brd,bd->br", a, x)


def bgmv_expand_ref(v, b_bank, idx):
    """y[i] = B[idx[i]] @ v[i].

    Args:
      v:      [B, r]    down-projected activations.
      b_bank: [L, d, r] LoRA-B bank.
      idx:    [B]       int32 adapter-slot index per request.

    Returns:
      [B, d] up-projected LoRA deltas.
    """
    b = b_bank[idx]  # [B, d, r]
    return jnp.einsum("bdr,br->bd", b, v)


def batch_lora_ref(x, w, a_bank, b_bank, idx, scale=1.0):
    """Full batch-LoRA projection: y = x @ W^T + scale * B_a A_a x.

    ``w`` is [d_out, d_in] (row-major weight as in a Linear layer);
    ``a_bank`` is [L, r, d_in], ``b_bank`` is [L, d_out, r].
    """
    base = x @ w.T
    v = bgmv_shrink_ref(x, a_bank, idx)
    delta = bgmv_expand_ref(v, b_bank, idx)
    return base + scale * delta


def grouped_batch_lora_ref(x, w, a_bank, b_bank, idx, scale=1.0):
    """Reference for the u-batch (grouped) execution order of §3.4.

    Semantically identical to ``batch_lora_ref`` but computed the way the
    paper describes it: requests are gathered into per-adapter groups, each
    group's LoRA GEMM runs over the whole sub-batch at once, and results are
    scattered back to their original positions. Used by the tests to prove
    gather/scatter is a bijection (ordering invariance of the u-batch plan).
    Not jittable (data-dependent grouping) — oracle only.
    """
    import numpy as np

    base = x @ w.T
    out = np.zeros(base.shape, dtype=np.asarray(base).dtype)
    idx_np = np.asarray(idx)
    x_np = np.asarray(x)
    for slot in np.unique(idx_np):
        mask = idx_np == slot
        xs = x_np[mask]                          # gather the u-batch
        v = xs @ np.asarray(a_bank[slot]).T      # [g, r]
        delta = v @ np.asarray(b_bank[slot]).T   # [g, d_out]
        out[mask] = delta                        # scatter back
    return base + scale * jnp.asarray(out)
