"""L1 correctness: Pallas batch-LoRA kernels vs the pure-jnp oracle.

This is the core correctness signal for the compute layer: every kernel that
ends up inside the AOT artifacts is asserted allclose against ``ref.py``
across shapes, dtypes, ranks and adapter-assignment patterns (hypothesis
drives the sweep).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is not part of every offline environment; only the property
# sweep below is gated on it — the deterministic kernel tests always run.
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from compile.kernels import batch_lora as bl
from compile.kernels import ref


def _mk(batch, d_in, d_out, rank, n_slots, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (batch, d_in), dtype)
    w = jax.random.normal(ks[1], (d_out, d_in), dtype) / np.sqrt(d_in)
    a = jax.random.normal(ks[2], (n_slots, rank, d_in), dtype) / np.sqrt(d_in)
    b = jax.random.normal(ks[3], (n_slots, d_out, rank), dtype) * 0.1
    idx = jax.random.randint(ks[4], (batch,), 0, n_slots, jnp.int32)
    return x, w, a, b, idx


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


class TestBgmvShrink:
    def test_basic(self):
        x, _, a, _, idx = _mk(6, 64, 64, 8, 4, jnp.float32)
        got = bl.bgmv_shrink(x, a, idx)
        want = ref.bgmv_shrink_ref(x, a, idx)
        np.testing.assert_allclose(got, want, **TOL[jnp.float32])

    def test_single_row(self):
        x, _, a, _, idx = _mk(1, 32, 32, 4, 2, jnp.float32, seed=3)
        np.testing.assert_allclose(
            bl.bgmv_shrink(x, a, idx),
            ref.bgmv_shrink_ref(x, a, idx),
            **TOL[jnp.float32],
        )

    def test_all_same_slot(self):
        x, _, a, _, _ = _mk(8, 32, 32, 8, 4, jnp.float32, seed=4)
        idx = jnp.full((8,), 2, jnp.int32)
        np.testing.assert_allclose(
            bl.bgmv_shrink(x, a, idx),
            ref.bgmv_shrink_ref(x, a, idx),
            **TOL[jnp.float32],
        )

    def test_jit_composes(self):
        x, _, a, _, idx = _mk(4, 32, 32, 8, 4, jnp.float32, seed=5)
        got = jax.jit(bl.bgmv_shrink)(x, a, idx)
        np.testing.assert_allclose(
            got, ref.bgmv_shrink_ref(x, a, idx), **TOL[jnp.float32]
        )


class TestBgmvExpand:
    def test_basic(self):
        _, _, _, b, idx = _mk(6, 64, 96, 8, 4, jnp.float32, seed=1)
        v = jax.random.normal(jax.random.PRNGKey(9), (6, 8), jnp.float32)
        np.testing.assert_allclose(
            bl.bgmv_expand(v, b, idx),
            ref.bgmv_expand_ref(v, b, idx),
            **TOL[jnp.float32],
        )

    def test_rectangular_out(self):
        _, _, _, b, idx = _mk(3, 16, 128, 4, 5, jnp.float32, seed=2)
        v = jax.random.normal(jax.random.PRNGKey(8), (3, 4), jnp.float32)
        np.testing.assert_allclose(
            bl.bgmv_expand(v, b, idx),
            ref.bgmv_expand_ref(v, b, idx),
            **TOL[jnp.float32],
        )


class TestFused:
    def test_matches_two_kernel_pipeline(self):
        x, _, a, b, idx = _mk(7, 48, 48, 8, 4, jnp.float32, seed=6)
        fused = bl.lora_delta(x, a, b, idx)
        v = bl.bgmv_shrink(x, a, idx)
        two = bl.bgmv_expand(v, b, idx)
        np.testing.assert_allclose(fused, two, rtol=1e-5, atol=1e-5)

    def test_matches_ref(self):
        x, _, a, b, idx = _mk(7, 48, 80, 8, 4, jnp.float32, seed=7)
        want = ref.bgmv_expand_ref(ref.bgmv_shrink_ref(x, a, idx), b, idx)
        np.testing.assert_allclose(
            bl.lora_delta(x, a, b, idx), want, rtol=2e-5, atol=2e-5
        )


class TestBatchLora:
    @pytest.mark.parametrize("fused", [True, False])
    def test_full_projection(self, fused):
        x, w, a, b, idx = _mk(5, 64, 64, 16, 4, jnp.float32, seed=10)
        got = bl.batch_lora(x, w, a, b, idx, scale=0.125, fused=fused)
        want = ref.batch_lora_ref(x, w, a, b, idx, scale=0.125)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_matches_grouped_ubatch_order(self):
        """§3.4: gather→group-GEMM→scatter must equal per-row computation."""
        x, w, a, b, idx = _mk(9, 32, 32, 8, 3, jnp.float32, seed=11)
        got = bl.batch_lora(x, w, a, b, idx, scale=1.0)
        want = ref.grouped_batch_lora_ref(x, w, a, b, idx, scale=1.0)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_zero_scale_is_base_only(self):
        x, w, a, b, idx = _mk(4, 32, 32, 8, 3, jnp.float32, seed=12)
        got = bl.batch_lora(x, w, a, b, idx, scale=0.0)
        np.testing.assert_allclose(got, x @ w.T, rtol=2e-5, atol=2e-5)

    def test_permutation_equivariance(self):
        """Permuting the batch permutes the output identically (the scatter
        of the u-batch plan is a bijection)."""
        x, w, a, b, idx = _mk(8, 32, 32, 8, 4, jnp.float32, seed=13)
        perm = jnp.array([3, 1, 7, 0, 5, 2, 6, 4])
        y = bl.batch_lora(x, w, a, b, idx)
        y_perm = bl.batch_lora(x[perm], w, a, b, idx[perm])
        np.testing.assert_allclose(y[perm], y_perm, rtol=2e-5, atol=2e-5)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        batch=st.integers(1, 9),
        d=st.sampled_from([16, 32, 64, 128]),
        rank=st.sampled_from([4, 8, 16, 32]),
        n_slots=st.integers(1, 6),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_sweep_f32(batch, d, rank, n_slots, seed):
        """Property: kernels == oracle over the (B, d, r, L) shape lattice."""
        x, w, a, b, idx = _mk(batch, d, d, rank, n_slots, jnp.float32, seed)
        got = bl.batch_lora(x, w, a, b, idx, scale=2.0 / rank)
        want = ref.batch_lora_ref(x, w, a, b, idx, scale=2.0 / rank)
        np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        batch=st.integers(1, 6),
        rank=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_bf16(batch, rank, seed):
        """bfloat16 path stays within bf16 tolerance of the f32 oracle."""
        x, w, a, b, idx = _mk(batch, 64, 64, rank, 4, jnp.bfloat16, seed)
        got = bl.batch_lora(x, w, a, b, idx).astype(jnp.float32)
        want = ref.batch_lora_ref(
            x.astype(jnp.float32),
            w.astype(jnp.float32),
            a.astype(jnp.float32),
            b.astype(jnp.float32),
            idx,
        )
        np.testing.assert_allclose(got, want, **TOL[jnp.bfloat16])

    @settings(max_examples=15, deadline=None)
    @given(
        data=st.data(),
        batch=st.integers(2, 8),
    )
    def test_hypothesis_adapter_assignment_patterns(data, batch):
        """Property: any adapter assignment (incl. degenerate all-same and
        all-distinct) matches the grouped u-batch oracle."""
        n_slots = data.draw(st.integers(1, 4))
        idx_list = data.draw(
            st.lists(st.integers(0, n_slots - 1), min_size=batch, max_size=batch)
        )
        x, w, a, b, _ = _mk(batch, 32, 32, 8, n_slots, jnp.float32, seed=42)
        idx = jnp.array(idx_list, jnp.int32)
        got = bl.batch_lora(x, w, a, b, idx)
        want = ref.grouped_batch_lora_ref(x, w, a, b, idx)
        np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)

else:

    @pytest.mark.skip(reason="hypothesis not installed — property sweep only")
    def test_hypothesis_property_sweep():
        """Placeholder so the skipped sweep stays visible in reports."""


class TestLoraDeltaMulti:
    """The multi-projection fused kernel (kept for real-TPU lowering; see
    EXPERIMENTS.md §Perf) must match the per-projection oracle."""

    def test_matches_per_projection_ref(self):
        P, B, d, r, L = 3, 5, 32, 8, 4
        ks = jax.random.split(jax.random.PRNGKey(21), 4)
        x = jax.random.normal(ks[0], (B, d), jnp.float32)
        a = jax.random.normal(ks[1], (P, L, r, d), jnp.float32)
        b = jax.random.normal(ks[2], (P, L, d, r), jnp.float32)
        idx = jax.random.randint(ks[3], (B,), 0, L, jnp.int32)
        got = bl.lora_delta_multi(x, a, b, idx)
        assert got.shape == (B, P, d)
        for p in range(P):
            want = ref.bgmv_expand_ref(ref.bgmv_shrink_ref(x, a[p], idx), b[p], idx)
            np.testing.assert_allclose(got[:, p], want, rtol=2e-4, atol=2e-4)

    def test_single_projection_equals_lora_delta(self):
        B, d, r, L = 4, 16, 4, 3
        ks = jax.random.split(jax.random.PRNGKey(22), 4)
        x = jax.random.normal(ks[0], (B, d), jnp.float32)
        a = jax.random.normal(ks[1], (1, L, r, d), jnp.float32)
        b = jax.random.normal(ks[2], (1, L, d, r), jnp.float32)
        idx = jax.random.randint(ks[3], (B,), 0, L, jnp.int32)
        got = bl.lora_delta_multi(x, a, b, idx)[:, 0]
        want = bl.lora_delta(x, a[0], b[0], idx)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestModelQkvFusionEquivalence:
    """_proj_qkv (the reverted §Perf fusion) must stay semantically equal to
    three separate _proj calls, so it remains safe to re-enable on TPU."""

    def test_fused_equals_separate(self):
        from compile import model as m
        cfg = m.ModelConfig(vocab=64, d_model=32, n_layers=1, n_heads=4,
                            d_ff=48, max_seq=16, n_slots=3, lora_rank=4,
                            n_router_outputs=4, decode_batch=2)
        weights = m.init_weights(cfg, seed=5)
        banks = m.init_banks(cfg, seed=6)
        x = jax.random.normal(jax.random.PRNGKey(7), (5, cfg.d_model))
        idx = jnp.array([0, 1, 2, 1, 0], jnp.int32)
        q, k, v = m._proj_qkv(x, weights, banks, 0, idx, cfg)
        q2 = m._proj(x, weights["wq"][0], banks, 0, 0, idx, cfg)
        k2 = m._proj(x, weights["wk"][0], banks, 0, 1, idx, cfg)
        v2 = m._proj(x, weights["wv"][0], banks, 0, 2, idx, cfg)
        np.testing.assert_allclose(q, q2, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(k, k2, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(v, v2, rtol=2e-5, atol=2e-5)
