"""AOT pipeline checks: manifest ↔ weights ↔ HLO artifacts stay consistent.

These run against a freshly-lowered micro config in a tmpdir (fast) and, when
``artifacts/`` exists, validate the shipped manifest too — so a stale or
hand-edited artifacts directory fails loudly before the Rust runtime trips
over it.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PYTHON_DIR = os.path.join(REPO, "python")
ARTIFACTS = os.path.join(REPO, "artifacts")


@pytest.fixture(scope="module")
def micro_artifacts(tmp_path_factory):
    """Lower a micro model into a tmpdir (exercises the full aot.py path)."""
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ)
    res = subprocess.run(
        [
            sys.executable, "-m", "compile.aot",
            "--out", str(out),
            "--d-model", "64",
            "--n-layers", "1",
            "--prefill-buckets", "8",
        ],
        cwd=PYTHON_DIR,
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert res.returncode == 0, res.stderr
    return str(out)


def _load_manifest(d):
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f)


class TestMicroLowering:
    def test_all_files_exist(self, micro_artifacts):
        man = _load_manifest(micro_artifacts)
        for art in man["artifacts"]:
            path = os.path.join(micro_artifacts, art["file"])
            assert os.path.exists(path), art["file"]
            assert os.path.getsize(path) > 0

    def test_hlo_is_text_with_entry(self, micro_artifacts):
        man = _load_manifest(micro_artifacts)
        for art in man["artifacts"]:
            with open(os.path.join(micro_artifacts, art["file"])) as f:
                head = f.read(4096)
            assert "HloModule" in head, art["name"]
            assert "ENTRY" in open(
                os.path.join(micro_artifacts, art["file"])
            ).read()

    def test_weights_bin_matches_manifest(self, micro_artifacts):
        man = _load_manifest(micro_artifacts)
        wpath = os.path.join(micro_artifacts, man["weights_file"])
        total = sum(w["nbytes"] for w in man["weights"])
        assert os.path.getsize(wpath) == total
        # offsets are contiguous and ordered
        off = 0
        for w in man["weights"]:
            assert w["offset"] == off
            assert w["nbytes"] == 4 * int(np.prod(w["shape"]))
            off += w["nbytes"]

    def test_param_counts_match_hlo(self, micro_artifacts):
        """HLO parameter count must equal the manifest signature length."""
        man = _load_manifest(micro_artifacts)
        for art in man["artifacts"]:
            text = open(os.path.join(micro_artifacts, art["file"])).read()
            entry = text[text.index("ENTRY"):]
            body = entry[: entry.index("ROOT")]
            n_params = body.count("parameter(")
            assert n_params == len(art["params"]), art["name"]

    def test_decode_artifact_signature(self, micro_artifacts):
        man = _load_manifest(micro_artifacts)
        dec = [a for a in man["artifacts"] if a["name"].startswith("decode")]
        assert len(dec) == 1
        names = [p["name"] for p in dec[0]["params"]]
        for expected in ("tokens", "positions", "adapter_slots", "k_cache",
                        "v_cache", "a_bank", "b_bank"):
            assert expected in names
        outs = [o["name"] for o in dec[0]["outputs"]]
        assert outs == ["logits", "k_cache", "v_cache"]

    def test_weights_are_finite(self, micro_artifacts):
        man = _load_manifest(micro_artifacts)
        raw = np.fromfile(
            os.path.join(micro_artifacts, man["weights_file"]), dtype="<f4"
        )
        assert np.isfinite(raw).all()
        assert np.abs(raw).max() < 100.0


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts/ not built",
)
class TestShippedArtifacts:
    def test_manifest_complete(self):
        man = _load_manifest(ARTIFACTS)
        names = {a["name"] for a in man["artifacts"]}
        for t in man["prefill_buckets"]:
            assert f"prefill_t{t}" in names
        assert any(n.startswith("decode_b") for n in names)
        assert "inject_row" in names
        assert "router_head" in names

    def test_files_present_and_sized(self):
        man = _load_manifest(ARTIFACTS)
        for art in man["artifacts"]:
            path = os.path.join(ARTIFACTS, art["file"])
            assert os.path.exists(path), art["file"]
        wsize = os.path.getsize(os.path.join(ARTIFACTS, man["weights_file"]))
        assert wsize == sum(w["nbytes"] for w in man["weights"])

    def test_config_consistency(self):
        man = _load_manifest(ARTIFACTS)
        cfg = man["config"]
        cache_elems = (
            cfg["n_layers"] * cfg["decode_batch"] * cfg["max_seq"]
            * cfg["n_heads"] * (cfg["d_model"] // cfg["n_heads"])
        )
        dec = [a for a in man["artifacts"] if a["name"].startswith("decode")][0]
        kc = [p for p in dec["params"] if p["name"] == "k_cache"][0]
        assert int(np.prod(kc["shape"])) == cache_elems
