"""L2 correctness: prefill/decode consistency, cache injection, router head.

The decisive invariant: running ``prefill`` on a prompt and then ``decode_step``
token-by-token must produce exactly the logits that ``prefill`` on the longer
sequence produces — i.e. the KV cache plumbing (batched layout, per-row
positions, device-side row injection) is semantics-preserving.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m

CFG = m.ModelConfig(
    vocab=128,
    d_model=64,
    n_layers=2,
    n_heads=4,
    d_ff=96,
    max_seq=32,
    n_slots=4,
    lora_rank=8,
    n_router_outputs=8,
    decode_batch=4,
)


@pytest.fixture(scope="module")
def weights():
    return m.init_weights(CFG, seed=0)


@pytest.fixture(scope="module")
def banks():
    return m.init_banks(CFG, seed=1)


def _prompt(t, seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (1, t), 0, CFG.vocab, jnp.int32
    )


class TestPrefill:
    def test_shapes(self, weights, banks):
        tokens = _prompt(8)
        logits, hidden, k, v = m.prefill(
            CFG, weights, banks, tokens, jnp.array([1], jnp.int32)
        )
        assert logits.shape == (8, CFG.vocab)
        assert hidden.shape == (8, CFG.d_model)
        assert k.shape == CFG.cache_shape(1)
        assert v.shape == CFG.cache_shape(1)

    def test_finite(self, weights, banks):
        logits, hidden, k, v = m.prefill(
            CFG, weights, banks, _prompt(16), jnp.array([0], jnp.int32)
        )
        for arr in (logits, hidden, k, v):
            assert np.isfinite(np.asarray(arr)).all()

    def test_adapter_slot_changes_output(self, weights, banks):
        """Different LoRA slots must yield different logits (banks differ)."""
        tokens = _prompt(8)
        l0, *_ = m.prefill(CFG, weights, banks, tokens, jnp.array([0], jnp.int32))
        l1, *_ = m.prefill(CFG, weights, banks, tokens, jnp.array([1], jnp.int32))
        assert not np.allclose(np.asarray(l0), np.asarray(l1))

    def test_causality(self, weights, banks):
        """Last-token logits depend only on the prefix: changing trailing
        padding beyond position t-1 must not change cache rows < t."""
        t = 8
        tokens = _prompt(t)
        slot = jnp.array([0], jnp.int32)
        _, _, k1, _ = m.prefill(CFG, weights, banks, tokens, slot)
        tokens2 = tokens.at[0, t - 1].set((tokens[0, t - 1] + 1) % CFG.vocab)
        _, _, k2, _ = m.prefill(CFG, weights, banks, tokens2, slot)
        np.testing.assert_allclose(
            np.asarray(k1)[:, :, : t - 1], np.asarray(k2)[:, :, : t - 1],
            rtol=1e-6, atol=1e-6,
        )


class TestDecodeConsistency:
    def test_decode_matches_prefill(self, weights, banks):
        """prefill(t) ++ decode(token t) == prefill(t+1) logits."""
        t = 8
        full = _prompt(t + 1, seed=3)
        slot = jnp.array([2], jnp.int32)

        want_logits, *_ = m.prefill(CFG, weights, banks, full, slot)

        _, _, k_rows, v_rows = m.prefill(
            CFG, weights, banks, full[:, :t], slot
        )
        b = CFG.decode_batch
        k_cache = jnp.zeros(CFG.cache_shape(b), jnp.float32)
        v_cache = jnp.zeros(CFG.cache_shape(b), jnp.float32)
        row = jnp.int32(1)
        k_cache, v_cache = m.inject_row(k_cache, v_cache, k_rows, v_rows, row)

        tokens = jnp.zeros((b,), jnp.int32).at[1].set(full[0, t])
        positions = jnp.zeros((b,), jnp.int32).at[1].set(t)
        slots = jnp.zeros((b,), jnp.int32).at[1].set(2)
        logits, _, _ = m.decode_step(
            CFG, weights, banks, tokens, positions, slots, k_cache, v_cache
        )
        np.testing.assert_allclose(
            np.asarray(logits[1]), np.asarray(want_logits[t]),
            rtol=2e-4, atol=2e-4,
        )

    def test_multi_step_decode_matches_prefill(self, weights, banks):
        """Three consecutive decode steps track prefill exactly."""
        t0, steps = 4, 3
        full = _prompt(t0 + steps, seed=5)
        slot = jnp.array([1], jnp.int32)
        b = CFG.decode_batch

        _, _, k_rows, v_rows = m.prefill(CFG, weights, banks, full[:, :t0], slot)
        k_cache = jnp.zeros(CFG.cache_shape(b), jnp.float32)
        v_cache = jnp.zeros(CFG.cache_shape(b), jnp.float32)
        k_cache, v_cache = m.inject_row(
            k_cache, v_cache, k_rows, v_rows, jnp.int32(0)
        )
        for s in range(steps):
            tokens = jnp.zeros((b,), jnp.int32).at[0].set(full[0, t0 + s])
            positions = jnp.zeros((b,), jnp.int32).at[0].set(t0 + s)
            slots = jnp.full((b,), 1, jnp.int32)
            logits, k_cache, v_cache = m.decode_step(
                CFG, weights, banks, tokens, positions, slots, k_cache, v_cache
            )
            want, *_ = m.prefill(
                CFG, weights, banks, full[:, : t0 + s + 1], slot
            )
            np.testing.assert_allclose(
                np.asarray(logits[0]), np.asarray(want[t0 + s]),
                rtol=5e-4, atol=5e-4,
            )

    def test_rows_are_independent(self, weights, banks):
        """A request in row 0 must be unaffected by traffic in row 1."""
        t = 6
        p0 = _prompt(t, seed=7)
        p1 = _prompt(t, seed=8)
        b = CFG.decode_batch
        slot = jnp.array([0], jnp.int32)

        def run(populate_other):
            _, _, k_r, v_r = m.prefill(CFG, weights, banks, p0, slot)
            k_c = jnp.zeros(CFG.cache_shape(b), jnp.float32)
            v_c = jnp.zeros(CFG.cache_shape(b), jnp.float32)
            k_c, v_c = m.inject_row(k_c, v_c, k_r, v_r, jnp.int32(0))
            tokens = jnp.zeros((b,), jnp.int32).at[0].set(5)
            positions = jnp.zeros((b,), jnp.int32).at[0].set(t)
            slots = jnp.zeros((b,), jnp.int32)
            if populate_other:
                _, _, k_o, v_o = m.prefill(
                    CFG, weights, banks, p1, jnp.array([3], jnp.int32)
                )
                k_c, v_c = m.inject_row(k_c, v_c, k_o, v_o, jnp.int32(1))
                tokens = tokens.at[1].set(9)
                positions = positions.at[1].set(t)
                slots = slots.at[1].set(3)
            logits, _, _ = m.decode_step(
                CFG, weights, banks, tokens, positions, slots, k_c, v_c
            )
            return np.asarray(logits[0])

        np.testing.assert_allclose(
            run(False), run(True), rtol=1e-5, atol=1e-5
        )


class TestInjectRow:
    def test_writes_only_target_row(self):
        b = CFG.decode_batch
        k_c = jnp.ones(CFG.cache_shape(b), jnp.float32)
        v_c = jnp.ones(CFG.cache_shape(b), jnp.float32) * 2
        k_r = jnp.full(CFG.cache_shape(1), 7.0, jnp.float32)
        v_r = jnp.full(CFG.cache_shape(1), 8.0, jnp.float32)
        k2, v2 = m.inject_row(k_c, v_c, k_r, v_r, jnp.int32(2))
        k2, v2 = np.asarray(k2), np.asarray(v2)
        assert (k2[:, 2] == 7.0).all() and (v2[:, 2] == 8.0).all()
        mask = np.arange(b) != 2
        assert (k2[:, mask] == 1.0).all() and (v2[:, mask] == 2.0).all()


class TestRouterHead:
    def test_scores_in_unit_interval(self, weights):
        hidden = jax.random.normal(
            jax.random.PRNGKey(0), (1, CFG.d_model), jnp.float32
        )
        scores = m.router_head(weights, hidden)
        s = np.asarray(scores)
        assert s.shape == (1, CFG.n_router_outputs)
        assert ((s > 0) & (s < 1)).all()

    def test_distinct_prompts_distinct_scores(self, weights, banks):
        _, h1, _, _ = m.prefill(
            CFG, weights, banks, _prompt(8, 1), jnp.array([0], jnp.int32)
        )
        _, h2, _, _ = m.prefill(
            CFG, weights, banks, _prompt(8, 2), jnp.array([0], jnp.int32)
        )
        s1 = np.asarray(m.router_head(weights, h1[-1:]))
        s2 = np.asarray(m.router_head(weights, h2[-1:]))
        assert not np.allclose(s1, s2)


class TestBuildingBlocks:
    def test_rms_norm_unit_scale(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64), jnp.float32)
        y = np.asarray(m.rms_norm(x, jnp.ones((64,), jnp.float32)))
        rms = np.sqrt((y**2).mean(-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_rope_preserves_norm(self):
        cfg = CFG
        x = jax.random.normal(
            jax.random.PRNGKey(1), (4, cfg.n_heads, cfg.head_dim), jnp.float32
        )
        cos, sin = m.rope_angles(cfg, jnp.arange(4, dtype=jnp.int32))
        y = m.apply_rope(x, cos, sin)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(y), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1),
            rtol=1e-5,
        )

    def test_rope_relative_property(self):
        """q·k after RoPE depends only on relative distance."""
        cfg = CFG
        q = jax.random.normal(jax.random.PRNGKey(2), (cfg.head_dim,))
        k = jax.random.normal(jax.random.PRNGKey(3), (cfg.head_dim,))

        def dot_at(pq, pk):
            cos_q, sin_q = m.rope_angles(cfg, jnp.array([pq], jnp.int32))
            cos_k, sin_k = m.rope_angles(cfg, jnp.array([pk], jnp.int32))
            qr = m.apply_rope(q[None, None, :], cos_q, sin_q)
            kr = m.apply_rope(k[None, None, :], cos_k, sin_k)
            return float(jnp.sum(qr * kr))

        np.testing.assert_allclose(dot_at(3, 1), dot_at(9, 7), rtol=1e-4)
