"""Pytest bootstrap: make ``compile`` importable regardless of invocation cwd.

The tests do ``from compile import ...``; without this, running
``pytest python/tests`` from the repo root fails at collection because only
``python/tests`` (not ``python/``) lands on sys.path.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
