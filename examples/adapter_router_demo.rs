//! Adapter-router walkthrough (§3.2/§5.2): profile adapters on the task
//! suites, train the router, and show (a) the Table 12 accuracy comparison
//! and (b) Algorithm 1's cache-aware selection deciding real requests.
//!
//! ```bash
//! cargo run --release --example adapter_router_demo
//! ```

use anyhow::Result;

use edgelora::coordinator::selection::{select_adapter, ResidencyView};
use edgelora::router::confidence::{TaskWorld, TABLE12_ADAPTERS, TABLE12_TASKS};
use edgelora::router::trainer::{table12_experiment, train_router};
use edgelora::router::AdapterRouter;
use edgelora::util::rng::Pcg64;

struct FakeCache(Vec<u64>);
impl ResidencyView for FakeCache {
    fn is_resident(&self, id: u64) -> bool {
        self.0.contains(&id)
    }
}

fn main() -> Result<()> {
    // --- Table 12 reproduction ---
    let world = TaskWorld::table12();
    println!("profiling 7 adapters × 5 suites, training the router …\n");
    let rows = table12_experiment(&world, &TABLE12_ADAPTERS, 4000, 0.98, 0xde30);
    print!("{:<36}", "Model");
    for t in TABLE12_TASKS {
        print!("{t:>9}");
    }
    println!("{:>9}", "Average");
    for r in &rows {
        print!("{:<36}", r.name);
        for v in &r.per_task {
            print!("{v:>9.2}");
        }
        println!("{:>9.2}", r.average);
    }
    let router_avg = rows.last().unwrap().average;
    let best_single = rows[..rows.len() - 1]
        .iter()
        .map(|r| r.average)
        .fold(0.0f64, f64::max);
    println!(
        "\nrouter {router_avg:.2} vs best single adapter {best_single:.2} \
         (oracle ceiling {:.2})",
        world.oracle_accuracy() * 100.0
    );

    // --- Algorithm 1 in action ---
    println!("\n--- cache-aware selection (Algorithm 1, top-k = 3) ---");
    let router = train_router(&world, 1000, 0.95, 7);
    let mut rng = Pcg64::new(9);
    let cache = FakeCache(vec![2, 6]); // Defne + Sauerkraut resident
    for task in 0..5 {
        let prompt = world.sample_prompt(task, 32, &mut rng);
        let top = router.top_k(&prompt, 3);
        let sel = select_adapter(&prompt, None, &router, &cache, 3);
        println!(
            "task {:<9} top-3 = {:?} → chose {} ({}, {})",
            TABLE12_TASKS[task],
            top,
            TABLE12_ADAPTERS[sel.adapter as usize],
            if sel.cached { "cache hit" } else { "load from disk" },
            if sel.auto { "auto" } else { "explicit" },
        );
    }
    Ok(())
}
