//! Edge-device sweep on the calibrated simulator: EdgeLoRA vs the llama.cpp
//! baseline across Jetson AGX Orin, Jetson Orin Nano and Raspberry Pi 5,
//! scaling the adapter count until the baseline OOMs — the Table 4 story as
//! a runnable example.
//!
//! ```bash
//! cargo run --release --example edge_device_sweep
//! ```

use anyhow::Result;

use edgelora::config::{preset, EngineKind};
use edgelora::experiments::harness::{run_edgelora, run_llamacpp, ExperimentSpec};

fn main() -> Result<()> {
    edgelora::util::logging::init();
    // short traces for an example run; EDGELORA_FULL_TRACES=1 for paper-length
    if std::env::var("EDGELORA_FULL_TRACES").is_err() {
        std::env::set_var("EDGELORA_FULL_TRACES", "0");
    }

    println!("device sweep: throughput (req/s) / avg latency (s) per engine\n");
    for preset_name in ["S1@AGX", "S2@Nano", "S3@Rasp"] {
        let p = preset(preset_name)?;
        println!(
            "--- {preset_name}: {} on {} ({} slots, {} req/s offered) ---",
            p.model.base_model, p.device, p.server.slots, p.workload.rate
        );
        for n in [20usize, 100, 1000] {
            let mut spec = ExperimentSpec::from_preset(&p, EngineKind::EdgeLora);
            spec.workload.n_adapters = n;
            spec.workload.duration_s = 60.0;
            let llama = run_llamacpp(&spec, &format!("sweep_l_{preset_name}_{n}"))?;
            let edge = run_edgelora(&spec, &format!("sweep_e_{preset_name}_{n}"))?;
            let lc = if llama.oom {
                "OOM".to_string()
            } else {
                format!(
                    "{} req/s / {} s",
                    llama.fmt_throughput(),
                    llama.fmt_latency()
                )
            };
            println!(
                "  n={n:<5} llama.cpp: {lc:<24} EdgeLoRA: {} req/s / {} s (hit {:.2}, batch {:.1})",
                edge.fmt_throughput(),
                edge.fmt_latency(),
                edge.summary.cache_hit_rate,
                edge.mean_batch,
            );
        }
        println!();
    }
    println!("note: llama.cpp preloads every adapter and OOMs at scale;");
    println!("EdgeLoRA swaps adapters through the heterogeneous memory manager.");
    Ok(())
}
