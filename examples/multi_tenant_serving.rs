//! End-to-end multi-tenant serving on real compute — the repository's E2E
//! validation run (DESIGN.md §6, recorded in EXPERIMENTS.md §E2E).
//!
//! Loads the AOT tiny-Llama artifacts, creates a disk store of LoRA
//! adapters (more than fit in memory, so the heterogeneous memory manager
//! must swap), replays a Gamma/power-law workload trace through the full
//! EdgeLoRA coordinator, and reports the paper's four metrics. A second
//! pass runs the same trace with adaptive adapter selection disabled for
//! the EdgeLoRA vs EdgeLoRA(w/o AAS) comparison of Figure 8.
//!
//! ```bash
//! make artifacts && cargo run --release --example multi_tenant_serving
//! ```

use std::sync::Arc;

use anyhow::{Context, Result};

use edgelora::adapters::{AdapterStore, LoraShape};
use edgelora::backend::pjrt::PjrtBackend;
use edgelora::backend::ModelBackend;
use edgelora::config::{EngineKind, ServerConfig, WorkloadConfig};
use edgelora::coordinator::EdgeLoraEngine;
use edgelora::memory::{AdapterMemoryManager, CachePolicy};
use edgelora::metrics::Summary;
use edgelora::quant::QuantType;
use edgelora::router::confidence::{TaskModelRouter, TaskWorld};
use edgelora::util::time::WallClock;
use edgelora::workload::{generate, Trace};

fn build_engine(
    artifacts: &str,
    n_adapters: usize,
    kind: EngineKind,
    tag: &str,
) -> Result<EdgeLoraEngine> {
    let backend = PjrtBackend::new(artifacts).context("run `make artifacts` first")?;
    let cfg = backend.runtime().manifest.config.clone();
    let store_dir = std::env::temp_dir().join(format!("edgelora_mts_{tag}"));
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = AdapterStore::create(
        &store_dir,
        LoraShape {
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            rank: cfg.lora_rank,
        },
        QuantType::Q8_0,
    )?;
    store.populate_synthetic(n_adapters)?;
    let pool_slots = backend.pool_slots();
    let memory = AdapterMemoryManager::new(Arc::new(store), pool_slots, CachePolicy::Lru);
    let world = TaskWorld::synthetic(n_adapters, 5, 3);
    let router = TaskModelRouter::new(world.acc.clone(), 0.95, 5);
    let slots = backend.decode_batch_width();
    Ok(EdgeLoraEngine::new(
        Box::new(backend),
        memory,
        Box::new(router),
        Arc::new(WallClock::new()),
        ServerConfig {
            slots,
            top_k: 3,
            cache_capacity: Some(pool_slots),
            engine: kind,
            ..ServerConfig::default()
        },
    ))
}

fn report(name: &str, s: &Summary, engine: &EdgeLoraEngine, wall_s: f64) {
    println!("\n== {name} ==");
    println!("requests           : {}", s.requests);
    println!("wall time          : {wall_s:.1} s");
    println!("throughput         : {:.2} req/s", s.throughput_rps);
    println!("token throughput   : {:.1} tok/s", s.token_throughput);
    println!("avg latency        : {:.3} s", s.avg_latency_s);
    println!("p50 / p99 latency  : {:.3} / {:.3} s", s.p50_latency_s, s.p99_latency_s);
    println!("first-token (avg)  : {:.3} s", s.avg_first_token_s);
    println!("SLO attainment     : {:.1} %", 100.0 * s.slo_attainment);
    println!("cache hit rate     : {:.2}", s.cache_hit_rate);
    println!("mean decode batch  : {:.2}", engine.stats.mean_batch());
    println!("adapter loads      : {}", engine.stats.adapter_loads);
    println!("router passes      : {}", engine.stats.router_passes);
}

fn main() -> Result<()> {
    edgelora::util::logging::init();
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // 16 adapters, pool of 7 resident slots (decode_batch 8 − 1 reserved):
    // the memory manager MUST swap — this exercises cache, pool and loads.
    let n_adapters = 16;
    let trace: Trace = generate(&WorkloadConfig {
        n_adapters,
        alpha: 1.0,
        rate: 3.0,
        cv: 1.0,
        duration_s: 20.0,
        input_range: (4, 48),
        output_range: (2, 10),
        auto_select_fraction: 1.0,
        seed: 0xe2e,
        ..WorkloadConfig::default()
    });
    println!(
        "trace: {} requests over {:.0}s across {} adapters ({} distinct requested)",
        trace.len(),
        trace.duration_s,
        n_adapters,
        trace.distinct_adapters()
    );

    // --- full EdgeLoRA ---
    let mut engine = build_engine(&artifacts, n_adapters, EngineKind::EdgeLora, "full")?;
    let t0 = std::time::Instant::now();
    let summary = engine.run_trace(&trace)?;
    report("EdgeLoRA (AAS on, real PJRT)", &summary, &engine, t0.elapsed().as_secs_f64());
    assert_eq!(summary.requests as usize, trace.len());

    // --- w/o AAS (explicit adapters, no router pass) ---
    let mut engine2 =
        build_engine(&artifacts, n_adapters, EngineKind::EdgeLoraNoAas, "noaas")?;
    let t1 = std::time::Instant::now();
    let summary2 = engine2.run_trace(&trace)?;
    report(
        "EdgeLoRA w/o AAS (explicit adapters)",
        &summary2,
        &engine2,
        t1.elapsed().as_secs_f64(),
    );
    assert_eq!(summary2.requests as usize, trace.len());
    assert_eq!(engine2.stats.router_passes, 0);

    println!(
        "\nAAS overhead on first-token latency: {:.3}s vs {:.3}s (paper: ≈ one prompt decode)",
        summary.avg_first_token_s, summary2.avg_first_token_s
    );
    println!("OK");
    Ok(())
}
