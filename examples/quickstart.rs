//! Quickstart: load the AOT artifacts, build an EdgeLoRA engine on the real
//! PJRT backend, serve a handful of requests, and print the metrics.
//!
//! ```bash
//! make artifacts                       # once: lower the model to HLO text
//! cargo run --release --example quickstart
//! ```
//!
//! Everything on the request path is Rust: the binary loads the HLO-text
//! artifacts, uploads weights to the PJRT CPU device, and runs adaptive
//! adapter selection + batched LoRA decode for each request.

use std::sync::Arc;

use anyhow::{Context, Result};

use edgelora::adapters::{AdapterStore, LoraShape};
use edgelora::backend::pjrt::PjrtBackend;
use edgelora::backend::ModelBackend;
use edgelora::config::{EngineKind, ServerConfig, WorkloadConfig};
use edgelora::coordinator::EdgeLoraEngine;
use edgelora::memory::{AdapterMemoryManager, CachePolicy};
use edgelora::quant::QuantType;
use edgelora::router::confidence::{TaskModelRouter, TaskWorld};
use edgelora::util::time::WallClock;
use edgelora::workload::generate;

fn main() -> Result<()> {
    edgelora::util::logging::init();
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // 1. Real compute backend: AOT HLO artifacts on the PJRT CPU client.
    println!("loading artifacts from {artifacts}/ …");
    let backend = PjrtBackend::new(&artifacts)
        .context("did you run `make artifacts` first?")?;
    let model_cfg = backend.runtime().manifest.config.clone();
    println!(
        "model: d_model={} n_layers={} vocab={} decode_batch={}",
        model_cfg.d_model, model_cfg.n_layers, model_cfg.vocab, model_cfg.decode_batch
    );

    // 2. Adapter store on disk (8 synthetic LoRA adapters, Q8_0-quantized).
    let store_dir = std::env::temp_dir().join("edgelora_quickstart");
    let _ = std::fs::remove_dir_all(&store_dir);
    let shape = LoraShape {
        n_layers: model_cfg.n_layers,
        d_model: model_cfg.d_model,
        rank: model_cfg.lora_rank,
    };
    let n_adapters = 8;
    let store = AdapterStore::create(&store_dir, shape, QuantType::Q8_0)?;
    store.populate_synthetic(n_adapters)?;
    println!(
        "adapter store: {} adapters × {} KB on disk",
        store.count(),
        store.file_bytes() / 1024
    );

    // 3. Heterogeneous memory manager: LRU cache over the pre-allocated pool
    //    (one bank slot is reserved for the router's base-model pass).
    let pool_slots = backend.pool_slots();
    let memory = AdapterMemoryManager::new(Arc::new(store), pool_slots, CachePolicy::Lru);

    // 4. Adaptive adapter selection: the PJRT router head scores prompts on
    //    the real path; the task-model router is the fallback.
    let world = TaskWorld::synthetic(n_adapters, 4, 1);
    let router = TaskModelRouter::new(world.acc.clone(), 0.95, 2);

    let slots = backend.decode_batch_width();
    let mut engine = EdgeLoraEngine::new(
        Box::new(backend),
        memory,
        Box::new(router),
        Arc::new(WallClock::new()),
        ServerConfig {
            slots,
            top_k: 3,
            cache_capacity: Some(pool_slots),
            engine: EngineKind::EdgeLora,
            ..ServerConfig::default()
        },
    );

    // 5. A short burst of requests across all adapters (none name their
    //    adapter — every one exercises Algorithm 1).
    let trace = generate(&WorkloadConfig {
        n_adapters,
        rate: 6.0,
        duration_s: 2.0,
        input_range: (4, 24),
        output_range: (2, 6),
        auto_select_fraction: 1.0,
        seed: 42,
        ..WorkloadConfig::default()
    });
    println!("serving {} requests …", trace.len());
    let summary = engine.run_trace(&trace)?;

    println!("\n== quickstart results (real PJRT compute) ==");
    println!("requests          : {}", summary.requests);
    println!("throughput        : {:.2} req/s", summary.throughput_rps);
    println!("avg latency       : {:.3} s", summary.avg_latency_s);
    println!("first-token (avg) : {:.3} s", summary.avg_first_token_s);
    println!("SLO attainment    : {:.1} %", 100.0 * summary.slo_attainment);
    println!("cache hit rate    : {:.2}", summary.cache_hit_rate);
    println!("mean decode batch : {:.2}", engine.stats.mean_batch());
    println!("router passes     : {}", engine.stats.router_passes);
    println!("adapter loads     : {}", engine.stats.adapter_loads);
    assert_eq!(summary.requests as usize, trace.len(), "no request lost");
    println!("\nOK");
    Ok(())
}
