//! Streaming lifecycle API demo (DESIGN.md §Serving API): start an
//! in-process 2-replica sim cluster service, then drive it over real TCP —
//! a streamed completion (SSE frames printed as they arrive), a runtime
//! adapter registration, and the registry listing.
//!
//!     cargo run --example streaming_client
//!
//! Point it at an already-running `edgelora serve-sim` instead with
//!     cargo run --example streaming_client -- 127.0.0.1:8091

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use edgelora::backend::devices::DeviceProfile;
use edgelora::cluster::ClusterConfig;
use edgelora::config::{EngineKind, ModelSetting, ServerConfig, WorkloadConfig};
use edgelora::experiments::harness::{build_cluster, ClusterSpec, ExperimentSpec};
use edgelora::memory::CachePolicy;
use edgelora::server::http::HttpServer;
use edgelora::server::ClusterService;

fn post(addr: &str, path: &str, body: &str) -> std::io::Result<TcpStream> {
    let mut s = TcpStream::connect(addr)?;
    write!(
        s,
        "POST {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    Ok(s)
}

fn get_body(addr: &str, path: &str) -> std::io::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    write!(s, "GET {path} HTTP/1.1\r\n\r\n")?;
    let mut out = String::new();
    s.read_to_string(&mut out)?;
    Ok(out.split("\r\n\r\n").nth(1).unwrap_or("").to_string())
}

fn main() -> anyhow::Result<()> {
    let external = std::env::args().nth(1);
    // keep the server alive for the whole demo when we self-host
    let mut _held: Option<(Arc<HttpServer>, Arc<std::sync::atomic::AtomicBool>)> = None;
    let addr = match external {
        Some(a) => a,
        None => {
            let n_adapters = 8;
            let spec = ClusterSpec {
                base: ExperimentSpec {
                    model: ModelSetting::s3(),
                    device: DeviceProfile::agx_orin(),
                    engine: EngineKind::EdgeLora,
                    server: ServerConfig {
                        slots: 2,
                        cache_capacity: Some(4),
                        ..ServerConfig::default()
                    },
                    workload: WorkloadConfig {
                        n_adapters,
                        ..WorkloadConfig::default()
                    },
                    tdp_watts: None,
                    cache_policy: CachePolicy::Lru,
                    router_acc: 0.95,
                },
                devices: vec![DeviceProfile::agx_orin(); 2],
                cluster: ClusterConfig::default(),
            };
            let service = ClusterService::new(build_cluster(&spec, "streaming_demo")?, n_adapters);
            let server = Arc::new(HttpServer::bind("127.0.0.1:0", 2, service.handler())?);
            let addr = server.local_addr()?.to_string();
            let flag = server.shutdown_flag();
            let srv = Arc::clone(&server);
            std::thread::spawn(move || srv.serve());
            _held = Some((server, flag));
            println!("self-hosted sim cluster on {addr}\n");
            addr
        }
    };

    // 1. register a tenant's adapter at runtime
    let mut s = post(&addr, "/v1/adapters", r#"{"id":42}"#)?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    println!("register adapter 42 → {}", resp.lines().next().unwrap_or(""));

    // 2. streamed completion against it: print SSE frames as they arrive
    println!("\nstreaming completion (adapter 42, 8 tokens):");
    let s = post(
        &addr,
        "/v1/completions",
        r#"{"prompt_tokens":[1,2,3],"max_tokens":8,"adapter":42,"stream":true}"#,
    )?;
    for line in BufReader::new(s).lines() {
        let line = line?;
        let line = line.trim_end_matches('\r');
        if line.starts_with("event: ") || line.starts_with("data: ") {
            println!("  {line}");
        }
    }

    // 3. the registry knows where the adapter now lives
    println!("\nGET /v1/adapters → {}", get_body(&addr, "/v1/adapters")?);
    println!("GET /cluster     → {}", get_body(&addr, "/cluster")?);

    if let Some((_, flag)) = _held {
        flag.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    Ok(())
}
