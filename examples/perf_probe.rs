//! Perf probe for the real PJRT hot path: measures prefill latency and
//! decode-step latency (per batch occupancy) in isolation, so §Perf changes
//! can be quantified without workload-pacing noise.
//!
//! ```bash
//! cargo run --release --example perf_probe [artifacts]
//! ```

use anyhow::{Context, Result};

use edgelora::adapters::{LoraShape, LoraWeights};
use edgelora::backend::pjrt::PjrtBackend;
use edgelora::backend::{DecodeRow, ModelBackend};
use edgelora::quant::QuantType;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let mut b = PjrtBackend::new(&artifacts).context("run `make artifacts` first")?;
    let cfg = b.runtime().manifest.config.clone();
    let shape = LoraShape {
        n_layers: cfg.n_layers,
        d_model: cfg.d_model,
        rank: cfg.lora_rank,
    };
    let width = b.decode_batch_width();
    for slot in 0..b.pool_slots().min(width) {
        let q = LoraWeights::synthetic(shape, slot as u64).to_quant(QuantType::Q8_0);
        b.load_adapter(slot, &q.view())?;
    }

    // prefill per bucket
    for &t in &b.runtime().manifest.prefill_buckets.clone() {
        let prompt: Vec<u32> = (0..t as u32).map(|i| 1 + i % 500).collect();
        let n = 5;
        let t0 = std::time::Instant::now();
        for row in 0..n {
            b.prefill(row % width, &prompt, 0)?;
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / n as f64;
        println!("prefill t={t:<4}  {ms:8.2} ms");
    }

    // router pass
    let prompt: Vec<u32> = (0..32).collect();
    let t0 = std::time::Instant::now();
    for _ in 0..5 {
        b.router_pass(&prompt)?;
    }
    println!(
        "router pass    {:8.2} ms",
        t0.elapsed().as_secs_f64() * 1e3 / 5.0
    );

    // decode steps per occupancy
    for occ in [1usize, 2, 4, width] {
        let rows: Vec<DecodeRow> = (0..occ)
            .map(|i| DecodeRow {
                row: i,
                token: 7,
                pos: 40 + i as u32,
                bank_slot: i % b.pool_slots().max(1),
            })
            .collect();
        let n = 20;
        let mut toks = Vec::new();
        let t0 = std::time::Instant::now();
        for k in 0..n {
            let mut rs = rows.clone();
            for r in rs.iter_mut() {
                r.pos += k as u32;
            }
            b.decode_step_into(&rs, &mut toks)?;
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / n as f64;
        println!(
            "decode b={occ:<3}   {ms:8.2} ms/step  ({:.1} tok/s)",
            occ as f64 * 1e3 / ms
        );
    }

    // adapter load: single dequantize of the pool payload + bank rewrite +
    // flush — the whole device half of a zero-copy swap
    let q = LoraWeights::synthetic(shape, 99).to_quant(QuantType::Q8_0);
    let t0 = std::time::Instant::now();
    for i in 0..5 {
        b.load_adapter(i % b.pool_slots().max(1), &q.view())?;
    }
    println!(
        "adapter load   {:8.2} ms",
        t0.elapsed().as_secs_f64() * 1e3 / 5.0
    );
    Ok(())
}
