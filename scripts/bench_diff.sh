#!/usr/bin/env bash
# Diff a fresh BENCH_hotpath.json against the committed baseline and fail on
# perf regression (ROADMAP follow-up: BENCH_* trajectory gating in CI).
#
#   ./scripts/bench_diff.sh BASELINE FRESH [MAX_RATIO]
#
# A metric regresses when fresh > baseline * MAX_RATIO (default 1.2, i.e.
# >20% slower; override with $3 or EDGELORA_BENCH_DIFF_RATIO). Like the
# bench's absolute hard asserts, the ratio is additionally multiplied by
# EDGELORA_BENCH_SLACK (default 1) so noisy shared CI runners — which are
# legitimately slower than the calibrated budgets — don't fail the diff for
# machine-speed reasons the slack already absorbs. Metrics only present in
# one file are reported but never fail the diff — a new bench lands with its
# first measurement, a retired one just drops out.
#
# The committed baseline holds *measured* numbers from an accepted run
# (it was budget-seeded before the hot-path PR), so the gate means "never
# regress 20% (×slack) vs the last accepted run". After a deliberate perf
# change, re-run the bench and commit the rewritten BENCH_hotpath.json to
# move the baseline.
set -euo pipefail

if [[ $# -lt 2 ]]; then
    echo "usage: $0 BASELINE FRESH [MAX_RATIO]" >&2
    exit 2
fi
baseline="$1"
fresh="$2"
ratio="${3:-${EDGELORA_BENCH_DIFF_RATIO:-1.2}}"
slack="${EDGELORA_BENCH_SLACK:-1}"
ratio="$(awk -v r="$ratio" -v s="$slack" 'BEGIN { if (s < 1) s = 1; printf "%.4f", r * s }')"

awk -v ratio="$ratio" -v basefile="$baseline" -v freshfile="$fresh" '
function parse(file, arr,   line, k, v) {
    while ((getline line < file) > 0) {
        # lines look like:   "section/name": 123.4,
        if (line ~ /"[^"]+"[[:space:]]*:[[:space:]]*-?[0-9]/) {
            k = line
            sub(/^[^"]*"/, "", k)
            sub(/".*$/, "", k)
            v = line
            sub(/^[^:]*:[[:space:]]*/, "", v)
            sub(/[,}[:space:]]*$/, "", v)
            arr[k] = v + 0
        }
    }
    close(file)
}
BEGIN {
    parse(basefile, base)
    parse(freshfile, fresh)
    bad = 0
    shared = 0
    for (k in fresh) {
        if (!(k in base)) {
            printf "  new        %-44s %14.1f ns/op\n", k, fresh[k]
            continue
        }
        shared++
        r = (base[k] > 0) ? fresh[k] / base[k] : 0
        flag = (r > ratio) ? "REGRESSED" : "ok"
        printf "  %-10s %-44s %14.1f -> %12.1f  (%.2fx)\n", flag, k, base[k], fresh[k], r
        if (r > ratio) bad++
    }
    for (k in base) {
        if (!(k in fresh)) {
            printf "  retired    %-44s %14.1f ns/op (baseline only)\n", k, base[k]
        }
    }
    if (shared == 0) {
        print "bench-diff: no shared metrics between baseline and fresh run"
        exit 0
    }
    if (bad > 0) {
        printf "bench-diff: FAIL — %d metric(s) regressed beyond %.2fx baseline\n", bad, ratio
        exit 1
    }
    printf "bench-diff: OK — %d metric(s) within %.2fx of baseline\n", shared, ratio
}'
