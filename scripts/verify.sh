#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): build + test + hot-path perf asserts.
#
#   ./scripts/verify.sh          # build, unit+integration tests, perf gates
#   ./scripts/verify.sh --quick  # skip the bench perf gates
#
# The bench step runs only the `batcher`, `memory` and `engine` filters of
# the hotpath bench; those benches carry their own hard asserts (u-batch
# plan < 5µs, cache op < 1µs, pool op allocation-free, decode tick
# allocation-free) and emit BENCH_hotpath.json at the repo root for the
# perf trajectory.
set -euo pipefail

cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: cargo not found on PATH — install a Rust toolchain" >&2
    exit 1
fi

echo "== tier-1: cargo build --release =="
cargo build --release --manifest-path rust/Cargo.toml

echo "== tier-1: cargo test -q =="
cargo test -q --manifest-path rust/Cargo.toml

if [[ "${1:-}" != "--quick" ]]; then
    echo "== perf gates: hotpath bench (all sections, hard asserts inside) =="
    cargo bench --manifest-path rust/Cargo.toml --bench hotpath
    if [[ -f BENCH_hotpath.json ]]; then
        echo "== BENCH_hotpath.json =="
        cat BENCH_hotpath.json
    fi
fi

echo "verify: OK"
