#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): build + test + hot-path perf asserts +
# the cluster tier.
#
#   ./scripts/verify.sh          # build, tests, perf gates, cluster tier
#   ./scripts/verify.sh --quick  # build + tests only
#
# The bench step runs the full hotpath bench; its sections carry their own
# hard asserts (u-batch plan < 5µs, cache op < 1µs, pool op allocation-free,
# decode tick allocation-free, cluster dispatch < 1µs, cluster stepping
# allocation-free) and rewrite BENCH_hotpath.json at the repo root. The
# fresh numbers are then diffed against the *committed* baseline
# (scripts/bench_diff.sh): any hot-path metric more than 20% over baseline
# fails verification.
#
# The cluster tier replays the scaling ablation at tiny scale (N ∈ {1,2},
# short trace) so the sharded-serving path stays green offline. The capacity
# tier replays the paged-vs-static capacity table at tiny scale so the
# unified paging path (admission, eviction-under-pressure, preemption) stays
# green offline too — and, with EDGELORA_PREFIX_TINY=1, the prefix-sharing
# ablation (prompt pages charged + TTFT, sharing on vs off — DESIGN.md
# §Prefix sharing). The chaos tier replays the elasticity table at tiny
# scale (EDGELORA_CHAOS_TINY=1): autoscale vs fixed floor under a load
# spike plus a seeded kill+heal chaos cell with request-conservation
# accounting (DESIGN.md §Failure model). The slo tier replays the QoS table
# at tiny scale (EDGELORA_SLO_TINY=1): offered load vs per-class p99 TTFT +
# SLO attainment with admission on/off under a flash-crowd spike
# (DESIGN.md §QoS & overload). The prefill tier replays the chunked-vs-
# monolithic prefill interference table at tiny scale
# (EDGELORA_PREFILL_TINY=1): a long-prompt admission against resident
# decodes, reporting resident worst-gap ITL and long-prompt TTFT with
# chunking on vs off (DESIGN.md §Chunked prefill & the decode hot path).
# The serve tier drives the
# streaming lifecycle API +
# adapter registry end-to-end: it spawns `serve-sim` on an ephemeral port
# and talks to it over raw TcpStreams (streamed completion, mid-stream
# hangup → cancellation, register/serve/delete) — DESIGN.md §Serving API.
# The lint tier runs the repo-native invariant linter over rust/src
# (DESIGN.md §Static analysis): determinism, panic-free net/+server/,
# allocation-free hot-path manifest, lock-order acyclicity, and wire-tag
# exhaustiveness — `edgelora lint --deny` exits nonzero on any violation.
# The net tier replays the distributed table at tiny scale
# (EDGELORA_NET_TINY=1): in-process vs socket fleet + the prefix-affinity
# scale-out ablation, then runs the net_* e2e tests (router + real worker
# processes: bit-identity, kill -9 rehome, SIGTERM drain, dead-fleet 503)
# — DESIGN.md §Distributed serving.
set -euo pipefail

cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    # Soft-skip: containers without a Rust toolchain can't run any tier, but
    # that is an environment gap, not a code failure. CI always has cargo, so
    # the perf gates (bench + bench_diff) stay hard wherever they can run.
    echo "verify: WARNING — cargo not found on PATH; skipping all tiers" >&2
    echo "verify: SKIPPED (no Rust toolchain)" >&2
    exit 0
fi

echo "== tier-1: cargo build --release =="
cargo build --release --manifest-path rust/Cargo.toml

echo "== tier-1: cargo test -q =="
cargo test -q --manifest-path rust/Cargo.toml

if [[ "${1:-}" != "--quick" ]]; then
    echo "== lint tier: repo-native invariant linter (DESIGN.md §Static analysis) =="
    cargo run --release --manifest-path rust/Cargo.toml -- lint --deny

    baseline=""
    if [[ -f BENCH_hotpath.json ]]; then
        # the bench rewrites BENCH_hotpath.json in place — snapshot the
        # committed baseline before it runs
        baseline="$(mktemp)"
        cp BENCH_hotpath.json "$baseline"
    fi

    echo "== perf gates: hotpath bench (all sections, hard asserts inside) =="
    cargo bench --manifest-path rust/Cargo.toml --bench hotpath

    if [[ -n "$baseline" && -f BENCH_hotpath.json ]]; then
        echo "== perf trajectory: fresh vs committed baseline (>20% fails) =="
        ./scripts/bench_diff.sh "$baseline" BENCH_hotpath.json
        rm -f "$baseline"
    elif [[ -f BENCH_hotpath.json ]]; then
        echo "== BENCH_hotpath.json (no baseline committed — first run) =="
        cat BENCH_hotpath.json
    fi

    echo "== cluster tier: tiny scaling table (N<=2, short trace) =="
    EDGELORA_SCALING_TINY=1 cargo run --release --manifest-path rust/Cargo.toml -- \
        bench-table --table scaling

    echo "== capacity tier: tiny paged-vs-static capacity + prefix-sharing ablation =="
    EDGELORA_CAPACITY_TINY=1 EDGELORA_PREFIX_TINY=1 \
        cargo run --release --manifest-path rust/Cargo.toml -- \
        bench-table --table capacity

    echo "== chaos tier: tiny elasticity table (autoscale + kill/heal, seeded) =="
    EDGELORA_CHAOS_TINY=1 cargo run --release --manifest-path rust/Cargo.toml -- \
        bench-table --table elasticity

    echo "== slo tier: tiny QoS table (per-class p99 + SLO, admission on/off) =="
    EDGELORA_SLO_TINY=1 cargo run --release --manifest-path rust/Cargo.toml -- \
        bench-table --table slo

    echo "== prefill tier: tiny chunked-vs-monolithic prefill interference table =="
    EDGELORA_PREFILL_TINY=1 cargo run --release --manifest-path rust/Cargo.toml -- \
        bench-table --table prefill

    echo "== serve tier: streaming + registry e2e over TcpStream (serve_*) =="
    cargo test -q --manifest-path rust/Cargo.toml --test integration serve_

    echo "== net tier: tiny distributed table (sockets vs in-process, affinity ablation) =="
    EDGELORA_NET_TINY=1 cargo run --release --manifest-path rust/Cargo.toml -- \
        bench-table --table distributed

    echo "== net tier: router + worker-process e2e (net_*) =="
    cargo test -q --manifest-path rust/Cargo.toml --test integration net_
fi

echo "verify: OK"
