//! Integration tests across module boundaries. Single binary (link time on
//! the xla stack is the bottleneck in this environment).
//!
//! PJRT-backed tests (`pjrt_*`) need `artifacts/` built (`make artifacts`);
//! they self-skip when it is absent so `cargo test` works pre-AOT.

use std::sync::Arc;

use edgelora::adapters::{AdapterStore, LoraShape, LoraWeights};
use edgelora::backend::devices::DeviceProfile;
#[cfg(feature = "pjrt")]
use edgelora::backend::pjrt::PjrtBackend;
use edgelora::backend::sim::SimBackend;
#[cfg(feature = "pjrt")]
use edgelora::backend::{DecodeRow, ModelBackend};
use edgelora::baseline::LlamaCppEngine;
use edgelora::config::{EngineKind, ModelSetting, ServerConfig, WorkloadConfig};
use edgelora::coordinator::EdgeLoraEngine;
use edgelora::memory::{AdapterMemoryManager, CachePolicy};
use edgelora::quant::QuantType;
use edgelora::router::confidence::{TaskModelRouter, TaskWorld};
use edgelora::util::prop::prop_check;
use edgelora::util::rng::Pcg64;
use edgelora::util::time::{Clock, VirtualClock};
#[cfg(feature = "pjrt")]
use edgelora::util::time::WallClock;
use edgelora::workload::{generate, Trace};

#[cfg(feature = "pjrt")]
fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn tmp_store(tag: &str, shape: LoraShape, n: usize) -> Arc<AdapterStore> {
    let dir = std::env::temp_dir().join(format!("elra_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = AdapterStore::create(&dir, shape, QuantType::Q8_0).unwrap();
    store.populate_synthetic(n).unwrap();
    Arc::new(store)
}

// ---------------------------------------------------------------------------
// PJRT: artifacts round-trip with real numerics
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_backend_generates_tokens_end_to_end() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let mut b = PjrtBackend::new(&dir).unwrap();
    let width = b.decode_batch_width();
    assert!(width >= 2);

    // prefill two rows with different prompts + adapters
    let shape = {
        let c = &b.runtime().manifest.config;
        LoraShape { n_layers: c.n_layers, d_model: c.d_model, rank: c.lora_rank }
    };
    let q1 = LoraWeights::synthetic(shape, 1).to_quant(QuantType::F32);
    let q2 = LoraWeights::synthetic(shape, 2).to_quant(QuantType::F32);
    b.load_adapter(0, &q1.view()).unwrap();
    b.load_adapter(1, &q2.view()).unwrap();
    let p0: Vec<u32> = (1..9).collect();
    let p1: Vec<u32> = (10..16).collect();
    let t0 = b.prefill(0, &p0, 0).unwrap();
    let t1 = b.prefill(1, &p1, 1).unwrap();
    let vocab = b.runtime().manifest.config.vocab as u32;
    assert!(t0 < vocab && t1 < vocab);

    // three decode steps; rows must evolve independently and deterministically
    let mut toks = vec![t0, t1];
    let mut pos = vec![p0.len() as u32, p1.len() as u32];
    for _ in 0..3 {
        let rows = vec![
            DecodeRow { row: 0, token: toks[0], pos: pos[0], bank_slot: 0, kv_probe: 0 },
            DecodeRow { row: 1, token: toks[1], pos: pos[1], bank_slot: 1, kv_probe: 0 },
        ];
        let mut out = Vec::new();
        b.decode_step_into(&rows, &mut out).unwrap();
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|&t| t < vocab));
        toks = out;
        pos[0] += 1;
        pos[1] += 1;
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_decode_deterministic_and_adapter_sensitive() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let run = |adapter_seed: u64| -> Vec<u32> {
        let mut b = PjrtBackend::new(&dir).unwrap();
        let c = b.runtime().manifest.config.clone();
        let shape = LoraShape { n_layers: c.n_layers, d_model: c.d_model, rank: c.lora_rank };
        // strong B scale so the two adapters visibly steer the argmax
        let q = LoraWeights::synthetic_scaled(shape, adapter_seed, 0.5).to_quant(QuantType::F32);
        b.load_adapter(0, &q.view()).unwrap();
        let prompt: Vec<u32> = (3..20).collect();
        let first = b.prefill(0, &prompt, 0).unwrap();
        let mut toks = vec![first];
        let mut pos = prompt.len() as u32;
        let mut out = Vec::new();
        for _ in 0..4 {
            let rows = vec![DecodeRow { row: 0, token: toks[toks.len() - 1], pos, bank_slot: 0, kv_probe: 0 }];
            b.decode_step_into(&rows, &mut out).unwrap();
            toks.push(out[0]);
            pos += 1;
        }
        toks
    };
    let a = run(7);
    let b_ = run(7);
    assert_eq!(a, b_, "same adapter → identical generation");
    let c = run(8);
    assert_ne!(a, c, "different LoRA adapters must change the output");
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_router_scores_prompt_dependent() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let mut b = PjrtBackend::new(&dir).unwrap();
    let s1 = b.router_pass(&[1, 2, 3, 4]).unwrap().unwrap();
    let s2 = b.router_pass(&[900, 901, 902, 903]).unwrap().unwrap();
    assert_eq!(s1.len(), b.runtime().manifest.config.n_router_outputs);
    assert!(s1.iter().all(|&s| (0.0..=1.0).contains(&s)));
    assert_ne!(s1, s2, "router scores must depend on the prompt");
    // deterministic
    let s1b = b.router_pass(&[1, 2, 3, 4]).unwrap().unwrap();
    assert_eq!(s1, s1b);
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_full_engine_serves_trace() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let backend = PjrtBackend::new(&dir).unwrap();
    let c = backend.runtime().manifest.config.clone();
    let shape = LoraShape { n_layers: c.n_layers, d_model: c.d_model, rank: c.lora_rank };
    let pool = backend.pool_slots();
    let slots = backend.decode_batch_width();
    let store = tmp_store("pjrt_engine", shape, 12);
    let memory = AdapterMemoryManager::new(store, pool, CachePolicy::Lru);
    let world = TaskWorld::synthetic(12, 4, 3);
    let router = TaskModelRouter::new(world.acc.clone(), 0.95, 5);
    let mut engine = EdgeLoraEngine::new(
        Box::new(backend),
        memory,
        Box::new(router),
        Arc::new(WallClock::new()),
        ServerConfig {
            slots,
            top_k: 3,
            cache_capacity: Some(pool),
            engine: EngineKind::EdgeLora,
            ..ServerConfig::default()
        },
    );
    let trace = generate(&WorkloadConfig {
        n_adapters: 12,
        rate: 8.0,
        duration_s: 1.5,
        input_range: (4, 16),
        output_range: (2, 5),
        ..WorkloadConfig::default()
    });
    let n = trace.len() as u64;
    assert!(n > 0);
    let summary = engine.run_trace(&trace).unwrap();
    assert_eq!(summary.requests, n, "every request must complete on PJRT");
    assert!(engine.stats.adapter_loads > 0, "12 adapters > pool ⇒ loads");
}

// ---------------------------------------------------------------------------
// Sim: EdgeLoRA vs baseline, paper-shape checks
// ---------------------------------------------------------------------------

fn sim_edgelora(
    n_adapters: usize,
    slots: usize,
    cache_cap: usize,
    kind: EngineKind,
    wl: &WorkloadConfig,
    tag: &str,
) -> (EdgeLoraEngine, Arc<VirtualClock>) {
    let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
    let backend = SimBackend::new(
        DeviceProfile::agx_orin(),
        ModelSetting::s1(),
        clock.clone(),
        slots,
        cache_cap,
        None,
    )
    .unwrap();
    let shape = LoraShape { n_layers: 2, d_model: 32, rank: 4 };
    let store = tmp_store(tag, shape, n_adapters);
    let memory = AdapterMemoryManager::new(store, cache_cap, CachePolicy::Lru);
    let world = TaskWorld::synthetic(n_adapters, 5, wl.seed);
    let router = TaskModelRouter::new(world.acc.clone(), 0.95, 7);
    let engine = EdgeLoraEngine::new(
        Box::new(backend),
        memory,
        Box::new(router),
        clock.clone(),
        ServerConfig {
            slots,
            top_k: 3,
            cache_capacity: Some(cache_cap),
            engine: kind,
            ..ServerConfig::default()
        },
    );
    (engine, clock)
}

#[test]
fn edgelora_beats_llamacpp_on_multi_adapter_workload() {
    // The Table 4 headline: 2–4× throughput at n where both still run.
    let wl = WorkloadConfig {
        n_adapters: 20,
        rate: 0.5,
        duration_s: 120.0,
        input_range: (8, 256),
        output_range: (8, 128),
        auto_select_fraction: 0.0,
        ..WorkloadConfig::default()
    };
    let trace = generate(&wl);

    let (mut edge, _) = sim_edgelora(20, 20, 16, EngineKind::EdgeLoraNoAas, &wl, "t4edge");
    let edge_summary = edge.run_trace(&trace).unwrap();

    let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
    let backend = SimBackend::new(
        DeviceProfile::agx_orin(),
        ModelSetting::s1(),
        clock.clone(),
        20,
        1,
        None,
    )
    .unwrap();
    let mut llama = LlamaCppEngine::new(Box::new(backend), clock, 20, 20).unwrap();
    let llama_summary = llama.run_trace(&trace).unwrap();

    assert_eq!(edge_summary.requests, trace.len() as u64);
    assert_eq!(llama_summary.requests, trace.len() as u64);
    let speedup = edge_summary.avg_latency_s / llama_summary.avg_latency_s;
    assert!(
        llama_summary.avg_latency_s > 1.5 * edge_summary.avg_latency_s,
        "EdgeLoRA should cut latency well below llama.cpp (ratio {speedup:.2})"
    );
}

#[test]
fn llamacpp_ooms_where_edgelora_scales() {
    // Table 4's OOM rows: same device+model, 1000 adapters.
    let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
    let backend = SimBackend::new(
        DeviceProfile::agx_orin(),
        ModelSetting::s1(),
        clock.clone(),
        20,
        1,
        None,
    )
    .unwrap();
    assert!(LlamaCppEngine::new(Box::new(backend), clock, 20, 1000).is_err());

    let wl = WorkloadConfig {
        n_adapters: 1000,
        rate: 0.5,
        duration_s: 60.0,
        input_range: (8, 64),
        output_range: (8, 32),
        ..WorkloadConfig::default()
    };
    let trace = generate(&wl);
    let (mut edge, _) = sim_edgelora(1000, 20, 30, EngineKind::EdgeLora, &wl, "oomscale");
    let s = edge.run_trace(&trace).unwrap();
    assert_eq!(s.requests, trace.len() as u64, "EdgeLoRA serves 1000 adapters");
}

#[test]
fn aas_improves_cache_hits_over_forced_misses() {
    // AAS prefers cached candidates (Algorithm 1) → hit rate ≥ explicit.
    let wl = WorkloadConfig {
        n_adapters: 40,
        rate: 2.0,
        duration_s: 120.0,
        input_range: (8, 32),
        output_range: (4, 12),
        alpha: 0.3,
        ..WorkloadConfig::default()
    };
    let trace = generate(&wl);
    let (mut with_aas, _) = sim_edgelora(40, 10, 8, EngineKind::EdgeLora, &wl, "aason");
    with_aas.warm_cache(0..8).unwrap();
    let s1 = with_aas.run_trace(&trace).unwrap();

    let (mut without, _) = sim_edgelora(40, 10, 8, EngineKind::EdgeLoraNoAas, &wl, "aasoff");
    without.warm_cache(0..8).unwrap();
    let s2 = without.run_trace(&trace).unwrap();

    assert!(
        s1.cache_hit_rate >= s2.cache_hit_rate,
        "AAS hit rate {} should be ≥ explicit {}",
        s1.cache_hit_rate,
        s2.cache_hit_rate
    );
}

#[test]
fn burstiness_degrades_both_engines() {
    // Tables 9/10 shape: cv=2 much worse than cv=1 for EdgeLoRA too.
    let run_cv = |cv: f64| {
        let wl = WorkloadConfig {
            n_adapters: 50,
            rate: 0.5,
            cv,
            duration_s: 150.0,
            input_range: (8, 256),
            output_range: (8, 128),
            ..WorkloadConfig::default()
        };
        let trace = generate(&wl);
        let (mut e, _) = sim_edgelora(50, 20, 16, EngineKind::EdgeLoraNoAas, &wl, &format!("cv{cv}"));
        e.run_trace(&trace).unwrap().avg_latency_s
    };
    let lat1 = run_cv(1.0);
    let lat2 = run_cv(2.0);
    assert!(lat2 > lat1, "cv=2 latency {lat2} should exceed cv=1 {lat1}");
}

// ---------------------------------------------------------------------------
// Cluster scaling (ISSUE 2 acceptance: bench-table --table scaling)
// ---------------------------------------------------------------------------

#[test]
fn cluster_scales_3x_at_4_replicas_and_affinity_beats_random() {
    use edgelora::cluster::{ClusterConfig, DispatchPolicy};
    use edgelora::experiments::harness::{run_cluster, ClusterSpec};
    use edgelora::experiments::tables::scaling_spec;

    let spec = scaling_spec(true); // tiny trace: 5 s at 160 req/s ≈ 800 reqs
    let run = |n: usize, policy: DispatchPolicy, tag: &str| {
        let cspec = ClusterSpec::homogeneous(
            spec.clone(),
            n,
            ClusterConfig {
                policy,
                ..ClusterConfig::default()
            },
        );
        run_cluster(&cspec, tag).unwrap()
    };
    let r1 = run(1, DispatchPolicy::AdapterAffinity, "acc1");
    let r4 = run(4, DispatchPolicy::AdapterAffinity, "acc4");
    let rr = run(4, DispatchPolicy::Random, "accr");
    // conservation everywhere
    assert!(r1.summary.requests > 0);
    assert_eq!(r1.summary.requests, r4.summary.requests);
    assert_eq!(r4.summary.requests, rr.summary.requests);
    // ≥3× cluster throughput at N=4 vs N=1 at fixed offered load
    let speedup = r4.summary.throughput_rps / r1.summary.throughput_rps;
    assert!(
        speedup >= 3.0,
        "N=4 speedup {speedup:.2} below 3x (N=1 {:.2} req/s, N=4 {:.2} req/s)",
        r1.summary.throughput_rps,
        r4.summary.throughput_rps
    );
    // affinity routing beats random dispatch on cache hit rate
    assert!(
        r4.summary.cache_hit_rate > rr.summary.cache_hit_rate,
        "affinity hit {} vs random {}",
        r4.summary.cache_hit_rate,
        rr.summary.cache_hit_rate
    );
    // the skewed tenant mix engages stealing, and replicas shorten the tail
    assert!(r4.steals > 0, "hot tenants should trigger work stealing");
    assert!(
        r4.summary.p99_latency_s < r1.summary.p99_latency_s,
        "p99 {} should drop below single-replica {}",
        r4.summary.p99_latency_s,
        r1.summary.p99_latency_s
    );
}

// ---------------------------------------------------------------------------
// Unified paged memory (ISSUE 3 acceptance): paged vs static headroom
// ---------------------------------------------------------------------------

/// An edge device whose budget leaves ~1.26 GiB beside the S3 base model —
/// tight enough that the static worst-case KV reservation for 8 slots
/// (~0.88 GiB) eats most of the adapter pool. AGX timing constants; only
/// the memory budget differs.
fn tight_budget_device() -> DeviceProfile {
    DeviceProfile {
        name: "tight-edge",
        memory_bytes: ModelSetting::s3().base_model_bytes() + (1288 << 20),
        ..DeviceProfile::agx_orin()
    }
}

fn paged_vs_static_spec(paged: bool, cache_blocks: usize) -> edgelora::experiments::harness::ExperimentSpec {
    use edgelora::experiments::harness::ExperimentSpec;
    ExperimentSpec {
        model: ModelSetting::s3(),
        device: tight_budget_device(),
        engine: EngineKind::EdgeLoraNoAas,
        server: ServerConfig {
            slots: 8,
            top_k: 3,
            cache_capacity: Some(cache_blocks),
            engine: EngineKind::EdgeLoraNoAas,
            paged,
            ..ServerConfig::default()
        },
        workload: WorkloadConfig {
            n_adapters: 64,
            alpha: 0.3,
            rate: 24.0,
            duration_s: 10.0,
            input_range: (8, 24),
            output_range: (4, 12),
            auto_select_fraction: 0.0,
            seed: 0x9a6ed,
            ..WorkloadConfig::default()
        },
        tdp_watts: None,
        cache_policy: edgelora::memory::CachePolicy::Lru,
        router_acc: 0.95,
    }
}

#[test]
fn paged_memory_sustains_1_5x_resident_adapters_vs_static_headroom() {
    use edgelora::experiments::harness::{
        paged_plan, run_edgelora, static_max_blocks,
    };
    let device = tight_budget_device();
    let model = ModelSetting::s3();
    let slots = 8usize;
    // analytic capacity at the same budget: reclaiming the worst-case KV
    // headroom must fund at least 1.5x the adapter blocks
    let static_blocks = static_max_blocks(&device, &model, slots);
    let plan = paged_plan(&device, &model, 16);
    let expected_tokens = (8 + 24) / 2 + (4 + 12) / 2; // workload means
    let paged_blocks = plan.max_blocks_at(slots, expected_tokens);
    assert!(static_blocks >= 2, "static config must still function");
    assert!(
        paged_blocks as f64 >= 1.5 * static_blocks as f64,
        "paged capacity {paged_blocks} must be >= 1.5x static {static_blocks}"
    );
    // measured on a skewed trace at the same DeviceProfile budget
    let stat = run_edgelora(&paged_vs_static_spec(false, static_blocks), "pvs_static").unwrap();
    let pag = run_edgelora(&paged_vs_static_spec(true, paged_blocks), "pvs_paged").unwrap();
    assert!(!stat.oom && !pag.oom);
    let n = {
        let mut wl = paged_vs_static_spec(false, static_blocks).workload;
        wl.auto_select_fraction = 0.0;
        generate(&wl).len() as u64
    };
    assert_eq!(stat.summary.requests, n, "static engine must serve the trace");
    assert_eq!(pag.summary.requests, n, "paged engine must serve the trace");
    assert!(
        pag.resident_adapters as f64 >= 1.5 * stat.resident_adapters as f64,
        "paged resident {} must sustain >= 1.5x static {}",
        pag.resident_adapters,
        stat.resident_adapters
    );
    assert!(
        pag.summary.cache_hit_rate > stat.summary.cache_hit_rate,
        "more resident adapters must lift the hit rate: paged {} vs static {}",
        pag.summary.cache_hit_rate,
        stat.summary.cache_hit_rate
    );
    assert!(pag.kv_page_faults > 0, "decode must grow KV page by page");
    assert!(pag.total_pages > 0 && stat.total_pages == 0);
}

/// Deterministic preempt-and-recompute: the same trace + seed through a
/// page-starved engine yields bit-identical tokens (order-sensitive
/// checksum) and an identical Recorder completion order, run after run.
#[test]
fn paged_preemption_recompute_is_deterministic() {
    use edgelora::memory::SharedPages;
    use edgelora::workload::TraceRequest;

    let shape = LoraShape { n_layers: 2, d_model: 16, rank: 4 };
    let kv_tok = ModelSetting::s3().kv_bytes_per_token();
    let trace = Trace {
        requests: (0..6)
            .map(|i| TraceRequest {
                id: i,
                arrival_s: 0.0,
                true_adapter: i % 4,
                explicit_adapter: Some(i % 4),
                input_tokens: 8,
                output_tokens: 24,
                qos: edgelora::workload::QosClass::Interactive,
                deadline_s: None,
            })
            .collect(),
        duration_s: 1.0,
        n_adapters: 4,
    };
    let run = |tag: &str| {
        let store = tmp_store(tag, shape, 4);
        let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
        let backend = SimBackend::new(
            DeviceProfile::agx_orin(),
            ModelSetting::s3(),
            clock.clone(),
            3,
            2,
            None,
        )
        .unwrap();
        // 12 pages of 4 KV positions each; adapter blocks cost 2 pages: a
        // full request (8 KV pages + its block) saturates the pool, so
        // concurrent slots must shed adapters and then preempt
        let shared = SharedPages::new(12, kv_tok * 4);
        let memory = AdapterMemoryManager::new_paged(
            store,
            2,
            CachePolicy::Lru,
            shared,
            2,
        );
        let world = TaskWorld::synthetic(4, 4, 1);
        let router = TaskModelRouter::new(world.acc.clone(), 0.95, 2);
        let mut e = EdgeLoraEngine::new(
            Box::new(backend),
            memory,
            Box::new(router),
            clock.clone(),
            ServerConfig {
                slots: 3,
                top_k: 3,
                cache_capacity: Some(2),
                engine: EngineKind::EdgeLoraNoAas,
                prefetch: true,
                ..ServerConfig::default()
            },
        );
        e.recorder.enable_log();
        let s = e.run_trace(&trace).unwrap();
        (
            s.requests,
            e.stats.preemptions,
            e.stats.kv_page_faults,
            e.stats.token_checksum,
            e.recorder.completion_log(),
            clock.now(),
        )
    };
    let (n1, pre1, faults1, sum1, log1, end1) = run("det_pg_a");
    let (n2, pre2, faults2, sum2, log2, end2) = run("det_pg_b");
    assert_eq!(n1, 6, "every preempted request must be re-served");
    assert!(pre1 > 0, "12-page pool with 3 growing slots must preempt");
    assert!(faults1 > 0);
    assert_eq!(pre1, pre2, "preemption schedule must reproduce");
    assert_eq!(faults1, faults2);
    assert_eq!(sum1, sum2, "token stream must be bit-identical across runs");
    assert_eq!(log1, log2, "Recorder completion order must reproduce");
    assert_eq!(n1, n2);
    assert_eq!(end1, end2, "virtual end time must reproduce");
    assert_eq!(log1.len(), 6);
}

#[test]
fn paged_engine_truncates_overlong_requests_instead_of_erroring() {
    use edgelora::memory::SharedPages;
    use edgelora::workload::TraceRequest;

    let shape = LoraShape { n_layers: 2, d_model: 16, rank: 4 };
    let store = tmp_store("overlong_pg", shape, 2);
    let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
    let backend = SimBackend::new(
        DeviceProfile::agx_orin(),
        ModelSetting::s3(),
        clock.clone(),
        2,
        2,
        None,
    )
    .unwrap();
    let kv_tok = ModelSetting::s3().kv_bytes_per_token();
    let memory = AdapterMemoryManager::new_paged(
        store,
        2,
        CachePolicy::Lru,
        SharedPages::new(64, kv_tok * 16),
        2,
    );
    let world = TaskWorld::synthetic(2, 4, 1);
    let router = TaskModelRouter::new(world.acc.clone(), 0.95, 2);
    let mut e = EdgeLoraEngine::new(
        Box::new(backend),
        memory,
        Box::new(router),
        clock,
        ServerConfig {
            slots: 2,
            top_k: 3,
            cache_capacity: Some(2),
            engine: EngineKind::EdgeLoraNoAas,
            ..ServerConfig::default()
        },
    );
    // prompt 8 + 600 requested outputs blows past max_positions (512): the
    // engine must truncate to KV capacity (n_ctx-style), not die mid-decode
    let trace = Trace {
        requests: vec![TraceRequest {
            id: 1,
            arrival_s: 0.0,
            true_adapter: 0,
            explicit_adapter: Some(0),
            input_tokens: 8,
            output_tokens: 600,
            qos: edgelora::workload::QosClass::Interactive,
            deadline_s: None,
        }],
        duration_s: 1.0,
        n_adapters: 2,
    };
    let s = e.run_trace(&trace).unwrap();
    assert_eq!(s.requests, 1);
    assert_eq!(s.total_output_tokens, 512 - 8, "truncated to max_positions");
}

// ---------------------------------------------------------------------------
// Property tests over the engine (coordinator invariants)
// ---------------------------------------------------------------------------

#[test]
fn prop_engine_never_loses_requests() {
    prop_check(
        12,
        0xe2e1,
        |rng: &mut Pcg64| {
            vec![
                rng.gen_range_usize(1, 30),  // n_adapters
                rng.gen_range_usize(1, 12),  // slots
                rng.gen_range_usize(2, 10),  // cache capacity
                rng.gen_range_usize(1, 10),  // rate (req/s)
                rng.gen_range_usize(0, 2),   // engine kind
                rng.gen_range_usize(0, 1000),// seed
            ]
        },
        |case| {
            let [n_adapters, slots, cache, rate, kind, seed] = case[..] else {
                return true;
            };
            let kind = if kind == 0 { EngineKind::EdgeLora } else { EngineKind::EdgeLoraNoAas };
            let wl = WorkloadConfig {
                n_adapters,
                rate: rate as f64,
                duration_s: 20.0,
                input_range: (4, 32),
                output_range: (2, 10),
                seed: seed as u64,
                ..WorkloadConfig::default()
            };
            let trace = generate(&wl);
            let cache = cache.min(n_adapters.max(2));
            let (mut e, _) = sim_edgelora(
                n_adapters, slots, cache, kind, &wl,
                &format!("prop{n_adapters}_{slots}_{cache}_{rate}_{seed}"),
            );
            match e.run_trace(&trace) {
                Ok(s) => s.requests == trace.len() as u64,
                Err(_) => false,
            }
        },
    );
}

#[test]
fn prop_latency_accounting_consistent() {
    // first_token ≤ latency, queueing ≥ 0, throughput = n/duration.
    prop_check(
        8,
        0xe2e2,
        |rng: &mut Pcg64| {
            vec![
                rng.gen_range_usize(2, 20),
                rng.gen_range_usize(1, 8),
                rng.gen_range_usize(0, 500),
            ]
        },
        |case| {
            let [n_adapters, slots, seed] = case[..] else { return true };
            let wl = WorkloadConfig {
                n_adapters: n_adapters.max(1),
                rate: 2.0,
                duration_s: 15.0,
                input_range: (4, 16),
                output_range: (2, 6),
                seed: seed as u64,
                ..WorkloadConfig::default()
            };
            let trace = generate(&wl);
            if trace.is_empty() {
                return true;
            }
            let (mut e, _) = sim_edgelora(
                n_adapters.max(1), slots.max(1), 4,
                EngineKind::EdgeLoraNoAas, &wl,
                &format!("lat{n_adapters}_{slots}_{seed}"),
            );
            let s = e.run_trace(&trace).unwrap();
            s.avg_first_token_s <= s.avg_latency_s + 1e-9
                && s.avg_queueing_s >= 0.0
                && (s.throughput_rps - s.requests as f64 / s.duration_s).abs() < 1e-6
        },
    );
}

// ---------------------------------------------------------------------------
// HTTP API integration
// ---------------------------------------------------------------------------

#[test]
fn http_server_serves_json_api() {
    use edgelora::server::http::{Handler, HttpServer, Request, Response};
    use std::io::{Read, Write};
    use std::sync::atomic::Ordering;

    let handler: Handler = Arc::new(|req: Request| {
        match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/v1/completions") => {
                match edgelora::server::api::parse_completion(&req.body) {
                    Ok(p) => Response::json(
                        200,
                        edgelora::server::api::completion_response(
                            1, p.adapter.unwrap_or(0), p.adapter.is_none(),
                            &[42, 43], 0.1, 0.2,
                        )
                        .into_bytes(),
                    )
                    .into(),
                    Err(e) => Response::error(400, &e.to_string()).into(),
                }
            }
            _ => Response::json(404, b"{}".to_vec()).into(),
        }
    });
    let server = Arc::new(HttpServer::bind("127.0.0.1:0", 2, handler).unwrap());
    let addr = server.local_addr().unwrap();
    let flag = server.shutdown_flag();
    let srv = Arc::clone(&server);
    let t = std::thread::spawn(move || srv.serve().unwrap());

    let body = r#"{"prompt_tokens":[1,2,3],"max_tokens":2}"#;
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "POST /v1/completions HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    assert!(resp.contains("200 OK"), "{resp}");
    assert!(resp.contains("\"auto_selected\":true"), "{resp}");
    assert!(resp.contains("\"tokens\":[42,43]"), "{resp}");

    // malformed request → 400
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    write!(stream, "POST /v1/completions HTTP/1.1\r\nContent-Length: 3\r\n\r\nxyz").unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    assert!(resp.contains("400"), "{resp}");

    flag.store(true, Ordering::SeqCst);
    t.join().unwrap();
}

// ---------------------------------------------------------------------------
// Serving API end-to-end: streaming lifecycle + adapter registry
// (DESIGN.md §Serving API; the serve tier of scripts/verify.sh runs these)
// ---------------------------------------------------------------------------

/// Tiny 2-ish-replica cluster service for HTTP tests (identical builds with
/// different tags produce bit-identical clusters over fresh stores).
fn mk_service(tag: &str, replicas: usize) -> Arc<edgelora::server::ClusterService> {
    use edgelora::cluster::ClusterConfig;
    use edgelora::experiments::harness::{build_cluster, ClusterSpec, ExperimentSpec};
    let n_adapters = 8;
    let spec = ClusterSpec {
        base: ExperimentSpec {
            model: ModelSetting::s3(),
            device: DeviceProfile::agx_orin(),
            engine: EngineKind::EdgeLora,
            server: ServerConfig {
                slots: 2,
                cache_capacity: Some(4),
                ..ServerConfig::default()
            },
            workload: WorkloadConfig {
                n_adapters,
                ..WorkloadConfig::default()
            },
            tdp_watts: None,
            cache_policy: CachePolicy::Lru,
            router_acc: 0.95,
        },
        devices: vec![DeviceProfile::agx_orin(); replicas],
        cluster: ClusterConfig::default(),
    };
    let cluster = build_cluster(&spec, tag).unwrap();
    edgelora::server::ClusterService::new(cluster, n_adapters)
}

fn serve_in_background(
    service: &Arc<edgelora::server::ClusterService>,
) -> (
    std::net::SocketAddr,
    Arc<std::sync::atomic::AtomicBool>,
    std::thread::JoinHandle<()>,
) {
    use edgelora::server::http::HttpServer;
    let server = Arc::new(HttpServer::bind("127.0.0.1:0", 4, service.handler()).unwrap());
    let addr = server.local_addr().unwrap();
    let flag = server.shutdown_flag();
    let srv = Arc::clone(&server);
    let t = std::thread::spawn(move || srv.serve().unwrap());
    (addr, flag, t)
}

fn http_req(addr: std::net::SocketAddr, raw: &str) -> String {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(raw.as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    http_req(addr, &format!("GET {path} HTTP/1.1\r\n\r\n"))
}

fn http_post(addr: std::net::SocketAddr, path: &str, body: &str) -> String {
    http_req(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn http_delete(addr: std::net::SocketAddr, path: &str) -> String {
    http_req(addr, &format!("DELETE {path} HTTP/1.1\r\n\r\n"))
}

/// Response body (after the blank line).
fn body_of(resp: &str) -> &str {
    resp.split("\r\n\r\n").nth(1).unwrap_or("")
}

/// (event name, data json) pairs out of a chunked SSE response. Every frame
/// is written as one chunk, so `event:`/`data:` lines arrive intact.
fn sse_events(resp: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let mut cur: Option<String> = None;
    for line in resp.lines() {
        if let Some(name) = line.strip_prefix("event: ") {
            cur = Some(name.trim().to_string());
        } else if let Some(data) = line.strip_prefix("data: ") {
            if let Some(name) = cur.take() {
                out.push((name, data.trim().to_string()));
            }
        }
    }
    out
}

fn sse_tokens(events: &[(String, String)]) -> Vec<u32> {
    use edgelora::util::json::Json;
    events
        .iter()
        .filter(|(n, _)| n == "token")
        .map(|(_, d)| {
            let j = Json::parse(d).unwrap();
            j.get("token").unwrap().as_usize().unwrap() as u32
        })
        .collect()
}

#[test]
fn serve_http_streamed_and_blocking_completions_bit_identical() {
    use edgelora::util::json::Json;
    // two identical clusters: stream on one, block on the other — request
    // id 1 on both, so token output must match bit-for-bit
    let svc_stream = mk_service("svc_stream", 2);
    let svc_block = mk_service("svc_block", 2);
    let (addr_a, flag_a, ta) = serve_in_background(&svc_stream);
    let (addr_b, flag_b, tb) = serve_in_background(&svc_block);

    let resp = http_post(
        addr_a,
        "/v1/completions",
        r#"{"prompt_tokens":[1,2,3,4],"max_tokens":6,"adapter":2,"stream":true}"#,
    );
    assert!(resp.contains("Transfer-Encoding: chunked"), "{resp}");
    assert!(resp.contains("text/event-stream"), "{resp}");
    assert!(resp.ends_with("0\r\n\r\n"), "chunked stream must terminate");
    let events = sse_events(&resp);
    let names: Vec<&str> = events.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(names[0], "queued", "{names:?}");
    assert_eq!(names[1], "admitted", "{names:?}");
    assert_eq!(*names.last().unwrap(), "done", "{names:?}");
    let streamed = sse_tokens(&events);
    assert_eq!(streamed.len(), 6, "{names:?}");

    let resp = http_post(
        addr_b,
        "/v1/completions",
        r#"{"prompt_tokens":[1,2,3,4],"max_tokens":6,"adapter":2}"#,
    );
    assert!(resp.contains("200 OK"), "{resp}");
    let j = Json::parse(body_of(&resp)).unwrap();
    assert_eq!(j.get("id").unwrap().as_usize(), Some(1));
    let blocked: Vec<u32> = j
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap() as u32)
        .collect();
    assert_eq!(
        streamed, blocked,
        "streamed and one-shot completions must be bit-identical"
    );
    // the one-shot response now carries real per-request latencies
    assert!(j.get("first_token_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(
        j.get("total_s").unwrap().as_f64().unwrap()
            >= j.get("first_token_s").unwrap().as_f64().unwrap()
    );

    for (flag, t) in [(flag_a, ta), (flag_b, tb)] {
        flag.store(true, std::sync::atomic::Ordering::SeqCst);
        t.join().unwrap();
    }
}

#[test]
fn serve_http_error_paths_404_405_413() {
    use std::io::{Read, Write};
    let svc = mk_service("svc_err", 1);
    let (addr, flag, t) = serve_in_background(&svc);

    // unknown route → 404
    assert!(http_get(addr, "/nope").starts_with("HTTP/1.1 404"), "unknown route");
    assert!(http_get(addr, "/v1/adapters/xyz").starts_with("HTTP/1.1 404"));
    // wrong method on known routes → 405
    assert!(http_req(addr, "PUT /v1/completions HTTP/1.1\r\nContent-Length: 0\r\n\r\n")
        .starts_with("HTTP/1.1 405"));
    assert!(http_delete(addr, "/health").starts_with("HTTP/1.1 405"));
    assert!(http_get(addr, "/v1/adapters/3").starts_with("HTTP/1.1 405"));
    assert!(http_get(addr, "/v1/requests/3/cancel").starts_with("HTTP/1.1 405"));
    // oversized body → 413, decided from the header before any body read
    {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        write!(
            s,
            "POST /v1/completions HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n"
        )
        .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 413"), "{out}");
    }
    // cancel of an unknown request → 404
    assert!(http_post(addr, "/v1/requests/777/cancel", "").starts_with("HTTP/1.1 404"));
    // negative adapter → 400 (the parse bugfix, end to end)
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[1],"adapter":-5}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
    assert!(resp.contains("non-negative"), "{resp}");
    // unregistered adapter id → 404, not an engine error
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[1],"adapter":777}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");

    flag.store(true, std::sync::atomic::Ordering::SeqCst);
    t.join().unwrap();
}

#[test]
fn serve_http_registry_register_pin_delete_lifecycle() {
    use edgelora::util::json::Json;
    let svc = mk_service("svc_reg", 2);
    let (addr, flag, t) = serve_in_background(&svc);

    // register a new adapter at runtime (synthetic weights)
    let resp = http_post(addr, "/v1/adapters", r#"{"id":99}"#);
    assert!(resp.starts_with("HTTP/1.1 201"), "{resp}");
    // duplicate registration → 409
    assert!(http_post(addr, "/v1/adapters", r#"{"id":99}"#).starts_with("HTTP/1.1 409"));
    // a completion against the fresh adapter serves fine
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[5,6],"max_tokens":3,"adapter":99}"#,
    );
    assert!(resp.contains("200 OK"), "{resp}");
    // fleet-wide pin: resident + pinned on both shards
    let resp = http_post(addr, "/v1/adapters/99/pin", "");
    assert!(resp.contains("200 OK"), "{resp}");
    let j = Json::parse(body_of(&resp)).unwrap();
    assert_eq!(j.get("pinned_shards").unwrap().as_usize(), Some(2));
    let listing = http_get(addr, "/v1/adapters");
    let j = Json::parse(body_of(&listing)).unwrap();
    let rows = j.get("rows").unwrap().as_arr().unwrap();
    let row99 = rows
        .iter()
        .find(|r| r.get("id").unwrap().as_usize() == Some(99))
        .expect("listing must include the registered adapter");
    assert_eq!(row99.get("pinned").unwrap().as_bool(), Some(true));
    assert_eq!(
        row99.get("resident_shards").unwrap().as_arr().unwrap().len(),
        2,
        "pin must make the adapter resident on every shard"
    );
    // delete: drains, evicts every shard, unregisters
    let resp = http_delete(addr, "/v1/adapters/99");
    assert!(resp.contains("200 OK"), "{resp}");
    let j = Json::parse(body_of(&resp)).unwrap();
    assert_eq!(j.get("purged_shards").unwrap().as_usize(), Some(2));
    // …so subsequent requests for the id are 404
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[5],"max_tokens":2,"adapter":99}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
    assert!(http_delete(addr, "/v1/adapters/99").starts_with("HTTP/1.1 404"));
    let listing = http_get(addr, "/v1/adapters");
    let j = Json::parse(body_of(&listing)).unwrap();
    assert!(
        !j.get("rows")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .any(|r| r.get("id").unwrap().as_usize() == Some(99)),
        "deleted adapter must vanish from the listing"
    );
    // re-registration after delete works
    assert!(http_post(addr, "/v1/adapters", r#"{"id":99}"#).starts_with("HTTP/1.1 201"));

    flag.store(true, std::sync::atomic::Ordering::SeqCst);
    t.join().unwrap();
}

/// The serve tier's process-level check: spawn the real `serve-sim` binary
/// on an ephemeral port and drive a streamed completion, a mid-stream
/// client hangup (→ cancellation, pages/slots released), and the registry,
/// all over raw `TcpStream`s.
#[test]
fn serve_sim_binary_streams_cancels_and_registers_over_tcp() {
    use std::io::{BufRead, BufReader, Read, Write};
    use std::process::{Command, Stdio};

    use edgelora::util::json::Json;

    struct ChildGuard(std::process::Child);
    impl Drop for ChildGuard {
        fn drop(&mut self) {
            let _ = self.0.kill();
            let _ = self.0.wait();
        }
    }

    let mut child = Command::new(env!("CARGO_BIN_EXE_edgelora"))
        .args([
            "serve-sim",
            "--addr",
            "127.0.0.1:0",
            "--replicas",
            "2",
            "--adapters",
            "8",
            "--slots",
            "2",
            "--cache",
            "4",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning serve-sim");
    let stdout = child.stdout.take().unwrap();
    let guard = ChildGuard(child);
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr: std::net::SocketAddr = line
        .trim()
        .strip_prefix("LISTENING ")
        .unwrap_or_else(|| panic!("bad bind line: {line}"))
        .parse()
        .unwrap();

    // 1. streamed completion: ordered lifecycle events over SSE
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[1,2,3],"max_tokens":5,"adapter":1,"stream":true}"#,
    );
    let events = sse_events(&resp);
    let names: Vec<&str> = events.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(names.first().copied(), Some("queued"), "{names:?}");
    assert_eq!(names.get(1).copied(), Some("admitted"), "{names:?}");
    assert_eq!(names.last().copied(), Some("done"), "{names:?}");
    let token_indices: Vec<usize> = events
        .iter()
        .filter(|(n, _)| n == "token")
        .map(|(_, d)| Json::parse(d).unwrap().get("index").unwrap().as_usize().unwrap())
        .collect();
    assert_eq!(token_indices, vec![0, 1, 2, 3, 4], "tokens stream in order");

    // 2. mid-stream client hangup → server cancels, slot/pages come back
    {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        let body = r#"{"prompt_tokens":[1,2],"max_tokens":4096,"adapter":2,"stream":true}"#;
        write!(
            s,
            "POST /v1/completions HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut buf = [0u8; 512];
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "stream head must arrive");
        // hang up mid-stream (4096 tokens are far from delivered)
        drop(s);
    }
    let mut released = false;
    for _ in 0..200 {
        let resp = http_get(addr, "/cluster");
        let j = Json::parse(body_of(&resp)).unwrap();
        let shards = j.get("shards").unwrap().as_arr().unwrap();
        let sum = |k: &str| -> usize {
            shards
                .iter()
                .map(|s| s.get(k).unwrap().as_usize().unwrap())
                .sum()
        };
        if sum("cancelled") >= 1
            && sum("active_slots") == 0
            && sum("kv_pages") == 0
            && sum("queue") == 0
        {
            released = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    assert!(released, "hangup must cancel the request and release slot/KV pages");

    // 3. registry over the wire: register → serve → delete → 404
    assert!(http_post(addr, "/v1/adapters", r#"{"id":42}"#).starts_with("HTTP/1.1 201"));
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[7],"max_tokens":2,"adapter":42}"#,
    );
    assert!(resp.contains("200 OK"), "{resp}");
    assert!(http_delete(addr, "/v1/adapters/42").contains("200 OK"));
    assert!(http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[7],"max_tokens":2,"adapter":42}"#
    )
    .starts_with("HTTP/1.1 404"));

    drop(guard);
}

// ---------------------------------------------------------------------------
// Virtual clock sanity across module seams
// ---------------------------------------------------------------------------

#[test]
fn virtual_time_is_fast() {
    // a 5-minute S1@AGX trace must replay in well under real time
    let wl = WorkloadConfig {
        n_adapters: 50,
        rate: 0.5,
        duration_s: 300.0,
        input_range: (8, 256),
        output_range: (8, 128),
        ..WorkloadConfig::default()
    };
    let trace = generate(&wl);
    let (mut e, clock) = sim_edgelora(50, 20, 16, EngineKind::EdgeLoraNoAas, &wl, "vtime");
    let t0 = std::time::Instant::now();
    let s = e.run_trace(&trace).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(s.requests, trace.len() as u64);
    assert!(clock.now() >= 299.0, "virtual clock advanced through the trace");
    assert!(wall < 30.0, "5-minute trace should replay fast (took {wall:.1}s)");
}

// ---------------------------------------------------------------------------
// Property tests: memory-manager invariants under random operation streams
// ---------------------------------------------------------------------------

#[test]
fn prop_memory_manager_invariants() {
    // Random access streams must preserve: (a) bank slots of resident
    // adapters are pairwise distinct, (b) resident count ≤ capacity,
    // (c) pool free+resident == capacity (block conservation),
    // (d) a hit never changes an adapter's slot.
    let shape = LoraShape { n_layers: 1, d_model: 16, rank: 2 };
    let store = tmp_store("prop_mm", shape, 24);
    prop_check(
        40,
        0x3e3e,
        |rng: &mut Pcg64| {
            let cap = rng.gen_range_usize(1, 6);
            let mut ops = vec![cap];
            for _ in 0..rng.gen_range_usize(1, 60) {
                ops.push(rng.gen_range_usize(0, 23));
            }
            ops
        },
        |case| {
            let (cap, accesses) = case.split_first().unwrap();
            let cap = (*cap).max(1);
            let mut m = AdapterMemoryManager::new(
                Arc::clone(&store),
                cap,
                CachePolicy::Lru,
            );
            let mut last_slot: std::collections::HashMap<u64, usize> =
                std::collections::HashMap::new();
            for &id in accesses {
                let id = id as u64;
                let was_resident = m.is_resident(id);
                let prev_slot = m.peek_slot(id);
                let res = match m.ensure_resident(id) {
                    Ok(r) => r,
                    Err(_) => return false,
                };
                if was_resident {
                    // (d) hit keeps the slot
                    if !res.is_hit() || Some(res.resident().bank_slot) != prev_slot {
                        return false;
                    }
                }
                last_slot.insert(id, res.resident().bank_slot);
                // (b)
                if m.resident_count() > cap {
                    return false;
                }
                // (c) block conservation
                if m.pool().free_blocks() + m.resident_count() != cap {
                    return false;
                }
                // (a) distinct slots across resident adapters
                let mut seen = std::collections::HashSet::new();
                for (&aid, _) in last_slot.iter() {
                    if m.is_resident(aid) {
                        let s = m.peek_slot(aid).unwrap();
                        if !seen.insert(s) {
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_zero_copy_swap_bit_identical_to_legacy_decode() {
    // The zero-copy path (read_raw_into → pool block → QuantView::dequantize)
    // must produce bank weights bit-identical to the legacy path
    // (store.get → LoraWeights → flatten) for random shapes, ids and all
    // three quantization types.
    prop_check(
        24,
        0x2e40c0,
        |rng: &mut Pcg64| {
            vec![
                rng.gen_range_usize(1, 4),   // n_layers
                rng.gen_range_usize(1, 6) * 8, // d_model
                rng.gen_range_usize(1, 5),   // rank
                rng.gen_range_usize(0, 3),   // quant selector
                rng.gen_range_usize(0, 50),  // adapter id
            ]
        },
        |case| {
            let [n_layers, d_model, rank, qsel, id] = case[..] else {
                return true;
            };
            let shape = LoraShape {
                n_layers: n_layers.max(1),
                d_model: d_model.max(8),
                rank: rank.max(1),
            };
            let quant = match qsel {
                0 => QuantType::F32,
                1 => QuantType::Q8_0,
                _ => QuantType::Q4_0,
            };
            let dir = std::env::temp_dir().join(format!(
                "elra_zc_{}_{}_{}_{}_{}_{}",
                n_layers, d_model, rank, qsel, id,
                std::process::id()
            ));
            let _ = std::fs::remove_dir_all(&dir);
            let store = Arc::new(AdapterStore::create(&dir, shape, quant).unwrap());
            store.put(id as u64, &LoraWeights::synthetic(shape, id as u64)).unwrap();
            let mut m = AdapterMemoryManager::new(Arc::clone(&store), 2, CachePolicy::Lru);
            if m.ensure_resident(id as u64).is_err() {
                return false;
            }
            let legacy = store.get(id as u64).unwrap().flatten();
            let zero_copy = match m.quant_view(id as u64) {
                Some(v) => v.dequantize(),
                None => return false,
            };
            let same = legacy == zero_copy;
            let _ = std::fs::remove_dir_all(&dir);
            same
        },
    );
}

#[test]
fn prop_histogram_matches_exact_oracle() {
    // Histogram percentiles must agree with exact sorted-order percentiles
    // within the bucket resolution (5%) for arbitrary sample sets.
    use edgelora::metrics::Histogram;
    prop_check(
        60,
        0x415706,
        |rng: &mut Pcg64| {
            let n = rng.gen_range_usize(1, 400);
            // samples in ms as integers to keep the case shrinkable
            (0..n)
                .map(|_| rng.gen_range_usize(1, 2_000_000))
                .collect::<Vec<usize>>()
        },
        |samples_ms| {
            if samples_ms.is_empty() {
                return true;
            }
            let mut h = Histogram::latency();
            let mut exact: Vec<f64> =
                samples_ms.iter().map(|&ms| ms.max(1) as f64 / 1000.0).collect();
            for &v in &exact {
                h.record(v);
            }
            exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for p in [10.0, 50.0, 90.0, 99.0] {
                let idx = (((p / 100.0) * exact.len() as f64).ceil() as usize)
                    .clamp(1, exact.len())
                    - 1;
                let want = exact[idx];
                let got = h.percentile(p);
                // bucket resolution is 5% growth + rounding at edges
                if got < want / 1.06 || got > want * 1.12 {
                    return false;
                }
            }
            // mean is exact
            let mean = exact.iter().sum::<f64>() / exact.len() as f64;
            (h.mean() - mean).abs() <= mean * 1e-9 + 1e-12
        },
    );
}

#[test]
fn engine_rejects_overlong_generation_gracefully() {
    // A request whose prompt+output exceeds max_positions must not corrupt
    // the engine: the sim backend errors, run_trace surfaces it.
    let wl = WorkloadConfig {
        n_adapters: 2,
        rate: 1.0,
        duration_s: 4.0,
        input_range: (4, 8),
        output_range: (2, 4),
        ..WorkloadConfig::default()
    };
    let trace = generate(&wl);
    let (mut e, _) = sim_edgelora(2, 2, 2, EngineKind::EdgeLoraNoAas, &wl, "overlong");
    // normal trace is fine
    assert!(e.run_trace(&trace).is_ok());
}

// ---------------------------------------------------------------------------
// Prefix/KV page sharing (DESIGN.md §Prefix sharing)
// ---------------------------------------------------------------------------

/// Paged S3 engine with a `page_tokens`-position page geometry and the
/// prefix-sharing flag under test.
fn paged_share_engine(
    share: bool,
    n_pages: usize,
    slots: usize,
    page_tokens: usize,
    tag: &str,
) -> (EdgeLoraEngine, Arc<VirtualClock>) {
    use edgelora::memory::SharedPages;
    let shape = LoraShape { n_layers: 2, d_model: 16, rank: 4 };
    let store = tmp_store(tag, shape, 4);
    let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
    let backend = SimBackend::new(
        DeviceProfile::agx_orin(),
        ModelSetting::s3(),
        clock.clone(),
        slots,
        2,
        None,
    )
    .unwrap();
    let kv_tok = ModelSetting::s3().kv_bytes_per_token();
    let memory = AdapterMemoryManager::new_paged(
        store,
        2,
        CachePolicy::Lru,
        SharedPages::new(n_pages, kv_tok * page_tokens),
        2,
    );
    let world = TaskWorld::synthetic(4, 4, 1);
    let router = TaskModelRouter::new(world.acc.clone(), 0.95, 2);
    let engine = EdgeLoraEngine::new(
        Box::new(backend),
        memory,
        Box::new(router),
        clock.clone(),
        ServerConfig {
            slots,
            top_k: 3,
            cache_capacity: Some(2),
            engine: EngineKind::EdgeLoraNoAas,
            prefix_share: share,
            ..ServerConfig::default()
        },
    );
    (engine, clock)
}

/// The acceptance trace: a hot same-adapter burst whose prompts share the
/// adapter's task preamble (first ~3/4 of `synth_prompt`), plus a band of
/// tiny *identical* prompts (len < 4 ⇒ all-preamble) that exercises the
/// full-coverage prefill skip and the shared-tail COW fork.
fn shared_prefix_trace() -> Trace {
    use edgelora::workload::TraceRequest;
    let mut requests: Vec<TraceRequest> = (0..24)
        .map(|i| TraceRequest {
            id: i,
            arrival_s: 0.0,
            true_adapter: 0,
            explicit_adapter: Some(0),
            input_tokens: 32,
            output_tokens: 8,
            qos: edgelora::workload::QosClass::Interactive,
            deadline_s: None,
        })
        .collect();
    requests.extend((100..112).map(|i| TraceRequest {
        id: i,
        arrival_s: 0.0,
        true_adapter: 1,
        explicit_adapter: Some(1),
        input_tokens: 3,
        output_tokens: 8,
        qos: edgelora::workload::QosClass::Interactive,
        deadline_s: None,
    }));
    Trace { requests, duration_s: 1.0, n_adapters: 4 }
}

/// Fold a tap's Token events into per-request token sequences, deduplicating
/// re-emitted indices the way the HTTP layer does (preemption recompute).
fn per_request_tokens(
    tap: &edgelora::coordinator::TapRx,
) -> std::collections::BTreeMap<u64, Vec<u32>> {
    use edgelora::coordinator::EngineEvent;
    let mut map: std::collections::BTreeMap<u64, Vec<u32>> = std::collections::BTreeMap::new();
    for (id, ev) in tap.try_iter() {
        if let EngineEvent::Token { index, token, .. } = ev {
            let v = map.entry(id).or_default();
            if index as usize == v.len() {
                v.push(token);
            }
        }
    }
    map
}

/// ISSUE 5 acceptance: at a fixed page budget, the skewed same-adapter
/// shared-prefix trace charges >= 30% fewer prompt pages with sharing on,
/// with bit-identical per-request token sequences — and the fully-covered
/// prompts drop TTFT to near-decode latency.
#[test]
fn prefix_sharing_saves_30pct_prompt_pages_with_bit_identical_tokens() {
    let trace = shared_prefix_trace();
    let n = trace.len() as u64;
    let run = |share: bool, tag: &str| {
        let (mut e, _clock) = paged_share_engine(share, 256, 4, 8, tag);
        let tap = e.events().tap();
        let summary = e.run_trace(&trace).unwrap();
        let tokens = per_request_tokens(&tap);
        (e, summary, tokens)
    };
    let (on, s_on, toks_on) = run(true, "pfx_on");
    let (off, s_off, toks_off) = run(false, "pfx_off");

    // correctness: nothing lost either way, and the token streams are
    // bit-identical per request — shared pages read exactly like private
    // ones through the page table
    assert_eq!(s_on.requests, n);
    assert_eq!(s_off.requests, n);
    assert_eq!(toks_on.len(), n as usize);
    assert_eq!(toks_on, toks_off, "sharing must not change any token");

    // sharing engaged: the 23 repeat admissions of adapter 0 map the
    // preamble pages, the 11 identical tiny prompts fully map + COW-fork
    assert_eq!(off.stats.prefix_lookups, 0);
    assert_eq!(off.stats.shared_prompt_pages, 0);
    assert!(on.stats.prefix_lookups > 0);
    assert!(on.stats.prefix_hits >= 20, "hits {}", on.stats.prefix_hits);
    assert!(on.stats.cow_forks > 0, "identical prompts must fork shared tails");
    assert!(
        s_on.prefix_hit_rate > 0.5,
        "summary hit rate {}",
        s_on.prefix_hit_rate
    );
    assert_eq!(s_on.shared_kv_pages, on.stats.shared_prompt_pages);

    // the headline: >= 30% fewer prompt pages charged at the same budget
    assert!(
        10 * on.stats.prompt_pages_charged <= 7 * off.stats.prompt_pages_charged,
        "prompt pages charged: on {} vs off {} (need >= 30% saved)",
        on.stats.prompt_pages_charged,
        off.stats.prompt_pages_charged
    );
    // prefill-skip: average TTFT strictly improves
    assert!(
        s_on.avg_first_token_s < s_off.avg_first_token_s,
        "TTFT on {} must beat off {}",
        s_on.avg_first_token_s,
        s_off.avg_first_token_s
    );

    // conservation at drain: free + adapter blocks + radix pages == total
    for e in [&on, &off] {
        assert_eq!(e.kv_pages_in_use(), 0);
        let held = (e.memory().resident_count() + e.memory().prefetch_outstanding()) * 2;
        assert_eq!(e.free_pages() + held + e.prefix_pages_held(), 256);
    }
    assert_eq!(off.prefix_pages_held(), 0, "sharing off keeps no radix pages");
}

/// Same trace, two sharing-on runs: everything (checksum, schedule, radix
/// stats) must reproduce — the determinism guarantee extends to the radix.
#[test]
fn prefix_sharing_is_deterministic_run_to_run() {
    let trace = shared_prefix_trace();
    let run = |tag: &str| {
        let (mut e, clock) = paged_share_engine(true, 96, 4, 8, tag);
        e.recorder.enable_log();
        e.run_trace(&trace).unwrap();
        (
            e.stats.token_checksum,
            e.stats.prefix_hits,
            e.stats.cow_forks,
            e.stats.prefix_reclaims,
            e.recorder.completion_log(),
            clock.now(),
        )
    };
    assert_eq!(run("pfx_det_a"), run("pfx_det_b"));
}

/// Pressure ladder: radix pages are evictable only at refcount 1, and they
/// go *before* resident adapters (a prefix page costs one prefill to
/// rebuild; an adapter costs a disk reload).
#[test]
fn prefix_pages_reclaim_under_pressure_before_preempting() {
    // 20 pages, 3 slots: the radix fills from completed requests, then
    // later admissions' KV growth must reclaim those pages
    let trace = shared_prefix_trace();
    let (mut e, _clock) = paged_share_engine(true, 20, 3, 8, "pfx_pressure");
    let s = e.run_trace(&trace).unwrap();
    assert_eq!(s.requests, trace.len() as u64, "pressure must not lose work");
    assert!(e.stats.prefix_reclaims > 0, "tight pool must reclaim radix pages");
    assert_eq!(e.kv_pages_in_use(), 0);
    let held = (e.memory().resident_count() + e.memory().prefetch_outstanding()) * 2;
    assert_eq!(e.free_pages() + held + e.prefix_pages_held(), 20);
}

// ---------------------------------------------------------------------------
// Bounded event channels (streaming backpressure)
// ---------------------------------------------------------------------------

/// ISSUE 5 acceptance: an undrained subscriber must not make engine memory
/// grow with the token count — the channel stays at its bound (plus the
/// handful of lifecycle events), coalesces old tokens, and still delivers
/// the terminal event.
#[test]
fn bounded_event_channel_caps_memory_with_undrained_subscriber() {
    use edgelora::coordinator::EngineEvent;
    use edgelora::workload::TraceRequest;
    let wl = WorkloadConfig {
        n_adapters: 2,
        ..WorkloadConfig::default()
    };
    let (mut e, _clock) = sim_edgelora(2, 2, 2, EngineKind::EdgeLoraNoAas, &wl, "bounded_ch");
    let bus = e.events();
    let cap = 64usize;
    let rx = bus.subscribe_with_capacity(1, cap);
    e.push_request(TraceRequest {
        id: 1,
        arrival_s: 0.0,
        true_adapter: 0,
        explicit_adapter: Some(0),
        input_tokens: 8,
        output_tokens: 400, // would buffer 400 Token events unbounded
        qos: edgelora::workload::QosClass::Interactive,
        deadline_s: None,
    });
    e.drain().unwrap();
    // never drained: the buffer is capped, not proportional to the output
    assert!(
        rx.len() <= cap + 8,
        "undrained channel grew to {} (cap {cap})",
        rx.len()
    );
    assert!(rx.coalesced() > 300, "coalesced {}", rx.coalesced());
    let evs: Vec<EngineEvent> = rx.try_iter().collect();
    assert!(matches!(evs[0], EngineEvent::Queued { .. }), "{:?}", &evs[..2]);
    assert!(
        matches!(evs.last(), Some(EngineEvent::Done { .. })),
        "terminal event must never be dropped: {:?}",
        evs.last()
    );
    // surviving tokens are ordered, gaps allowed, freshest kept
    let idx: Vec<u32> = evs
        .iter()
        .filter_map(|ev| match ev {
            EngineEvent::Token { index, .. } => Some(*index),
            _ => None,
        })
        .collect();
    assert!(idx.windows(2).all(|w| w[0] < w[1]), "reordered: {idx:?}");
    assert_eq!(*idx.last().unwrap(), 399, "freshest token survives");
}

// ---------------------------------------------------------------------------
// Connection: close + pipelining tolerance (serve tier)
// ---------------------------------------------------------------------------

#[test]
fn serve_http_advertises_connection_close_and_tolerates_pipelining() {
    use std::io::{Read, Write};
    let svc = mk_service("serve_cc", 1);
    let (addr, flag, t) = serve_in_background(&svc);

    // every response advertises one-request-per-connection
    let resp = http_get(addr, "/health");
    assert!(resp.contains("Connection: close"), "{resp}");

    // a pipelining client writes two requests back-to-back: it must still
    // receive the complete first response and a clean EOF (no RST killing
    // the response, no hang waiting for a second one)
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(b"GET /health HTTP/1.1\r\n\r\nGET /cluster HTTP/1.1\r\n\r\n")
        .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap(); // returning at all ⇒ no hang
    assert!(out.contains("\"status\":\"ok\""), "{out}");
    assert!(out.contains("Connection: close"), "{out}");
    assert_eq!(
        out.matches("HTTP/1.1 ").count(),
        1,
        "exactly one response per connection: {out}"
    );

    flag.store(true, std::sync::atomic::Ordering::SeqCst);
    t.join().unwrap();
}

// ---------------------------------------------------------------------------
// QoS admission end-to-end: rate-limit sheds over HTTP (serve tier)
// ---------------------------------------------------------------------------

/// Like `mk_service` but with edge QoS admission on and a near-zero tenant
/// rate: the first request spends the whole bucket (burst 1), so the second
/// is shed — deterministically, since refill runs on the virtual clock.
fn mk_qos_service(tag: &str) -> Arc<edgelora::server::ClusterService> {
    use edgelora::cluster::{ClusterConfig, QosConfig};
    use edgelora::experiments::harness::{build_cluster, ClusterSpec, ExperimentSpec};
    let n_adapters = 8;
    let spec = ClusterSpec {
        base: ExperimentSpec {
            model: ModelSetting::s3(),
            device: DeviceProfile::agx_orin(),
            engine: EngineKind::EdgeLora,
            server: ServerConfig {
                slots: 2,
                cache_capacity: Some(4),
                ..ServerConfig::default()
            },
            workload: WorkloadConfig {
                n_adapters,
                ..WorkloadConfig::default()
            },
            tdp_watts: None,
            cache_policy: CachePolicy::Lru,
            router_acc: 0.95,
        },
        devices: vec![DeviceProfile::agx_orin()],
        cluster: ClusterConfig {
            qos: QosConfig {
                enabled: true,
                tenant_rate: 0.001,
                tenant_burst: 1.0,
                deadline_slack: 1.0,
            },
            ..ClusterConfig::default()
        },
    };
    let cluster = build_cluster(&spec, tag).unwrap();
    edgelora::server::ClusterService::new(cluster, n_adapters)
}

/// ISSUE 7 acceptance (wire format): a shed is machine-retryable end to end —
/// 429 with a `Retry-After` header on the one-shot path, a terminal `shed`
/// SSE frame on the streaming path — and the shed counters surface in
/// `/health`. The `"qos"` field round-trips ("batch" accepted, junk 400).
#[test]
fn serve_http_qos_rate_limit_sheds_with_retry_after_and_shed_frame() {
    let svc = mk_qos_service("svc_qos");
    let (addr, flag, t) = serve_in_background(&svc);

    // bucket starts full (burst 1): the first request is served normally,
    // and the "qos" request field parses ("batch" is a valid class)
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[1,2],"max_tokens":4,"adapter":2,"qos":"batch"}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");

    // same tenant, bucket empty: shed with 429 + Retry-After, body names
    // the reason so clients can distinguish rate limiting from overload
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[1,2],"max_tokens":4,"adapter":2}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 429"), "{resp}");
    assert!(resp.contains("\r\nRetry-After: "), "{resp}");
    assert!(resp.contains("rate_limit"), "{resp}");

    // streaming path: the shed arrives as the terminal SSE frame
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[1,2],"max_tokens":4,"adapter":2,"stream":true}"#,
    );
    assert!(resp.contains("text/event-stream"), "{resp}");
    let events = sse_events(&resp);
    let (name, data) = events.last().expect("stream must carry a frame");
    assert_eq!(name, "shed", "{events:?}");
    assert!(data.contains("rate_limit"), "{data}");

    // both sheds are on the health surface
    let health = http_get(addr, "/health");
    assert!(health.contains("\"shed_rate_limit\":2"), "{health}");

    // an invalid class is rejected before admission (no token spent)
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[1],"qos":"vip"}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");

    flag.store(true, std::sync::atomic::Ordering::SeqCst);
    t.join().unwrap();
}

// ---------------------------------------------------------------------------
// Distributed serving e2e: router + real worker processes over localhost
// sockets (DESIGN.md §Distributed serving; the net tier of verify.sh runs
// these under EDGELORA_NET_TINY=1)
// ---------------------------------------------------------------------------

/// Kill-on-drop wrapper so a failing assert never leaks a worker process.
struct NodeProc(std::process::Child);
impl Drop for NodeProc {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Flags shared by every spawned process and mirrored by [`net_spec`]: all
/// spec inputs are explicit, so worker processes and the in-process
/// reference cluster build bit-identical engines and synthetic stores.
const NET_FLAGS: &[&str] = &["--model", "S1", "--adapters", "8", "--slots", "2"];

/// The in-process twin of what `serve-node`/`serve-router` build from
/// [`NET_FLAGS`] (the `sim_cluster_spec` path in `main.rs`).
fn net_spec(n: usize) -> edgelora::experiments::harness::ClusterSpec {
    use edgelora::cluster::ClusterConfig;
    use edgelora::experiments::harness::{ClusterSpec, ExperimentSpec};
    ClusterSpec {
        base: ExperimentSpec {
            model: ModelSetting::s1(),
            device: DeviceProfile::agx_orin(),
            engine: EngineKind::EdgeLora,
            server: ServerConfig {
                engine: EngineKind::EdgeLora,
                slots: 2,
                ..ServerConfig::default()
            },
            workload: WorkloadConfig {
                n_adapters: 8,
                ..WorkloadConfig::default()
            },
            tdp_watts: None,
            cache_policy: CachePolicy::Lru,
            router_acc: 0.95,
        },
        devices: vec![DeviceProfile::agx_orin(); n],
        cluster: ClusterConfig::default(),
    }
}

/// Spawn one `serve-node` worker process on an ephemeral port and parse its
/// `LISTENING addr` line. A background thread keeps draining stdout so the
/// child can never block on a full pipe.
fn spawn_node(shard: usize, replicas: usize) -> (NodeProc, String) {
    use std::io::{BufRead, BufReader};
    use std::process::{Command, Stdio};
    let mut child = Command::new(env!("CARGO_BIN_EXE_edgelora"))
        .args(["serve-node", "--listen", "127.0.0.1:0"])
        .args(["--shard", &shard.to_string(), "--replicas", &replicas.to_string()])
        .args(NET_FLAGS)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let stdout = child.stdout.take().unwrap();
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        let n = reader.read_line(&mut line).unwrap();
        assert!(n > 0, "worker {shard} exited before binding");
        if let Some(a) = line.trim().strip_prefix("LISTENING ") {
            break a.to_string();
        }
    };
    std::thread::spawn(move || {
        let mut sink = String::new();
        loop {
            sink.clear();
            match reader.read_line(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    });
    (NodeProc(child), addr)
}

/// A trace whose requests spread over the 8 adapters; arrivals 10 ms apart
/// so the paced remote replay lets gossip flow between dispatches.
fn net_trace(n_requests: u64, output_tokens: usize) -> Trace {
    use edgelora::workload::{QosClass, TraceRequest};
    let requests = (0..n_requests)
        .map(|i| TraceRequest {
            id: i,
            arrival_s: i as f64 * 0.01,
            true_adapter: i % 8,
            explicit_adapter: Some(i % 8),
            input_tokens: 12,
            output_tokens,
            qos: QosClass::Interactive,
            deadline_s: None,
        })
        .collect();
    Trace { requests, duration_s: 1.0, n_adapters: 8 }
}

/// ISSUE 9 acceptance: a router + 2 worker *processes* over localhost
/// sockets replay a seeded trace with zero request loss/duplication, and
/// per-request token streams bit-identical to the in-process
/// `ClusterEngine` at the same seed (sim tokens are pure functions of
/// request content, so placement and pacing cannot change them).
#[test]
fn net_router_over_worker_processes_bit_identical_to_in_process() {
    use edgelora::coordinator::EngineEvent;
    use edgelora::experiments::harness::{build_cluster, mk_store};
    use edgelora::net::RemoteCluster;
    use std::collections::BTreeMap;

    let trace = net_trace(20, 6);
    let n = trace.len() as u64;

    // in-process reference: same spec, same trace, virtual clocks
    let spec = net_spec(2);
    let mut local = build_cluster(&spec, "net_e2e_local").unwrap();
    let local_tap = local.events().tap();
    let local_report = local.run_trace(&trace).unwrap();
    let local_tokens = per_request_tokens(&local_tap);
    assert_eq!(local_report.summary.requests, n);
    assert_eq!(local_tokens.len(), n as usize);

    // socket fleet: two real worker processes, this test is the router
    let (_w0, a0) = spawn_node(0, 2);
    let (_w1, a1) = spawn_node(1, 2);
    let store = mk_store(&spec.base, "net_e2e_router").unwrap();
    let mut rc =
        RemoteCluster::connect(&[a0, a1], 0, spec.cluster.clone(), store, 8).unwrap();
    let tap = rc.events().tap();
    let report = rc.run_trace(&trace).unwrap();

    // fold the router-bus event stream the way consumers do: contiguous
    // token frontier per id, and count terminal events per id
    let mut remote_tokens: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
    let mut terminals: BTreeMap<u64, usize> = BTreeMap::new();
    for (id, ev) in tap.try_iter() {
        match ev {
            EngineEvent::Token { index, token, .. } => {
                let v = remote_tokens.entry(id).or_default();
                if index as usize == v.len() {
                    v.push(token);
                }
            }
            other if other.is_terminal() => *terminals.entry(id).or_default() += 1,
            _ => {}
        }
    }

    // zero loss, zero duplication: every id completes exactly once
    assert_eq!(report.summary.requests, n, "no request may be lost");
    assert_eq!(rc.recorder.completed(), n, "every request completes once");
    assert_eq!(report.shed_total, 0);
    assert_eq!(terminals.len(), n as usize, "one terminal per id: {terminals:?}");
    assert!(
        terminals.values().all(|&c| c == 1),
        "terminal events must be unique per id: {terminals:?}"
    );

    // the headline: per-request token streams bit-identical across the
    // process boundary
    assert_eq!(remote_tokens, local_tokens, "socket fleet must reproduce solo tokens");

    rc.close();
}

/// ISSUE 9 acceptance (failure half): `kill -9` of a worker process
/// mid-trace — the dead-TCP path, no Draining frame, no Bye — rehomes its
/// in-flight requests onto the surviving worker with conservation: every
/// request still completes exactly once.
#[test]
fn net_kill9_worker_mid_trace_rehomes_with_conservation() {
    use edgelora::cluster::Dispatched;
    use edgelora::experiments::harness::mk_store;
    use edgelora::net::RemoteCluster;

    let spec = net_spec(2);
    // long outputs: the backlog must outlive the kill below
    let trace = net_trace(32, 48);
    let n = trace.len() as u64;
    let (w0, a0) = spawn_node(0, 2);
    let (w1, a1) = spawn_node(1, 2);
    let store = mk_store(&spec.base, "net_e2e_kill").unwrap();
    let mut rc =
        RemoteCluster::connect(&[a0, a1], 0, spec.cluster.clone(), store, 8).unwrap();

    // blast the whole trace in unpaced: both shards build a deep backlog
    for req in &trace.requests {
        let d = rc.try_dispatch(req.clone()).unwrap();
        assert!(matches!(d, Dispatched::To(_)), "live fleet must admit {}", req.id);
    }
    // SIGKILL whichever shard owns work (consistent hashing spreads 8
    // adapters over 2 shards, but stay robust to a pathological ring)
    let victim = if rc.dispatched[1] > 0 { 1 } else { 0 };
    let mut procs = [w0, w1];
    procs[victim].0.kill().unwrap();

    rc.quiesce().unwrap();
    let report = rc.report();
    assert_eq!(
        report.summary.requests + report.shed_total,
        n,
        "conservation: completed + shed must cover the offered trace"
    );
    assert_eq!(report.shed_total, 0, "a live survivor means nothing sheds");
    assert!(
        report.rehomed_total > 0,
        "the dead shard's in-flight work must rehome (victim {victim})"
    );
    assert_eq!(rc.link_state_name(victim), "dead");
    rc.close();
}

/// Graceful shutdown e2e: SIGTERM to a worker process drains it — active
/// work is evacuated and handed back in a terminal `Draining` frame, the
/// router rehomes it without waiting out the Dead ladder, and the process
/// exits cleanly (status 0).
#[cfg(unix)]
#[test]
fn net_sigterm_worker_drains_and_router_rehomes() {
    use edgelora::cluster::Dispatched;
    use edgelora::experiments::harness::mk_store;
    use edgelora::net::RemoteCluster;

    fn send_sigterm(pid: u32) {
        extern "C" {
            fn kill(pid: i32, sig: i32) -> i32;
        }
        unsafe {
            kill(pid as i32, 15);
        }
    }

    let spec = net_spec(2);
    let trace = net_trace(32, 48);
    let n = trace.len() as u64;
    let (w0, a0) = spawn_node(0, 2);
    let (w1, a1) = spawn_node(1, 2);
    let store = mk_store(&spec.base, "net_e2e_term").unwrap();
    let mut rc =
        RemoteCluster::connect(&[a0, a1], 0, spec.cluster.clone(), store, 8).unwrap();
    for req in &trace.requests {
        let d = rc.try_dispatch(req.clone()).unwrap();
        assert!(matches!(d, Dispatched::To(_)), "live fleet must admit {}", req.id);
    }
    let victim = if rc.dispatched[1] > 0 { 1 } else { 0 };
    let mut procs = [w0, w1];
    send_sigterm(procs[victim].0.id());

    rc.quiesce().unwrap();
    let report = rc.report();
    assert_eq!(report.summary.requests, n, "drain handover must lose nothing");
    assert_eq!(report.shed_total, 0);
    assert!(
        report.rehomed_total > 0,
        "the Draining frame must hand the backlog back (victim {victim})"
    );
    assert_eq!(
        rc.link_state_name(victim),
        "draining",
        "a drained worker is retired, not declared dead"
    );
    let status = procs[victim].0.wait().unwrap();
    assert!(status.success(), "drained worker must exit cleanly: {status:?}");
    rc.close();
}

/// The full binary pipeline: `serve-router` process + 2 `serve-node`
/// processes. A blocking completion round-trips through real sockets; then
/// `kill -9` of the whole fleet turns the next dispatch into a 503 with a
/// `Retry-After` hint and a body naming every shard and its state
/// (satellite: router-side sheds are machine-retryable and diagnosable).
#[test]
fn net_router_process_serves_http_then_503_names_dead_shards() {
    use std::io::{BufRead, BufReader};
    use std::process::{Command, Stdio};

    let (mut w0, a0) = spawn_node(0, 2);
    let (mut w1, a1) = spawn_node(1, 2);
    let mut router = Command::new(env!("CARGO_BIN_EXE_edgelora"))
        .args(["serve-router", "--addr", "127.0.0.1:0"])
        .args(["--workers", &format!("{a0},{a1}")])
        .args(NET_FLAGS)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let stdout = router.stdout.take().unwrap();
    let router = NodeProc(router);
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    let addr: std::net::SocketAddr = loop {
        line.clear();
        let n = reader.read_line(&mut line).unwrap();
        assert!(n > 0, "router exited before binding");
        if let Some(a) = line.trim().strip_prefix("LISTENING ") {
            break a.parse().unwrap();
        }
    };

    // live fleet: one-shot completion over HTTP → TCP → worker and back
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[1,2,3],"max_tokens":4,"adapter":3}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("\"tokens\":["), "{resp}");

    // the fleet surface reads identically against sockets
    let resp = http_get(addr, "/cluster");
    assert_eq!(
        resp.matches("\"state\":\"alive\"").count(),
        2,
        "both shards alive: {resp}"
    );

    // kill -9 both workers: the next dispatch finds the fleet dead
    w0.0.kill().unwrap();
    w1.0.kill().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(100));
    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[1,2,3],"max_tokens":4,"adapter":3}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 503"), "{resp}");
    assert!(resp.contains("\r\nRetry-After: "), "{resp}");
    assert!(resp.contains("unreachable"), "{resp}");
    assert!(
        resp.contains("shard 0") && resp.contains("shard 1") && resp.contains("dead"),
        "the 503 body must name every shard and its state: {resp}"
    );
    drop(router);
}

/// `serve-sim --distributed 2` spawns its own worker processes, serves the
/// identical HTTP surface through the socket router, and — on SIGTERM —
/// exits cleanly, reaping the children instead of orphaning them.
#[cfg(unix)]
#[test]
fn serve_sim_distributed_serves_and_reaps_children_on_sigterm() {
    use std::io::{BufRead, BufReader};
    use std::process::{Command, Stdio};

    fn send_sigterm(pid: u32) {
        extern "C" {
            fn kill(pid: i32, sig: i32) -> i32;
        }
        unsafe {
            kill(pid as i32, 15);
        }
    }

    let mut child = Command::new(env!("CARGO_BIN_EXE_edgelora"))
        .args(["serve-sim", "--distributed", "2", "--addr", "127.0.0.1:0"])
        .args(NET_FLAGS)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let stdout = child.stdout.take().unwrap();
    let mut guard = NodeProc(child);
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    let addr: std::net::SocketAddr = loop {
        line.clear();
        let n = reader.read_line(&mut line).unwrap();
        assert!(n > 0, "distributed serve-sim exited before binding");
        if let Some(a) = line.trim().strip_prefix("LISTENING ") {
            break a.parse().unwrap();
        }
    };

    let resp = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt_tokens":[5,6,7],"max_tokens":4,"adapter":1}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("\"tokens\":["), "{resp}");
    let resp = http_get(addr, "/cluster");
    assert_eq!(
        resp.matches("\"state\":\"alive\"").count(),
        2,
        "two worker shards behind the router: {resp}"
    );

    // SIGTERM → shutdown flag → serve loop exits → ChildGuard reaps the
    // worker children → clean exit status
    send_sigterm(guard.0.id());
    let status = guard.0.wait().unwrap();
    assert!(status.success(), "router must exit cleanly on SIGTERM: {status:?}");
}

/// Satellite: HTTP keep-alive end to end — a client that opts in with
/// `Connection: keep-alive` pipelines two completions back-to-back on one
/// connection and gets both answers; the close opt-out on the second
/// request ends the connection cleanly.
#[test]
fn serve_http_keepalive_pipelines_two_completions_on_one_connection() {
    use std::io::{Read, Write};
    let svc = mk_service("serve_ka_e2e", 1);
    let (addr, flag, t) = serve_in_background(&svc);

    let body = r#"{"prompt_tokens":[1,2,3],"max_tokens":4,"adapter":1}"#;
    let first = format!(
        "POST /v1/completions HTTP/1.1\r\nConnection: keep-alive\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let second = format!(
        "POST /v1/completions HTTP/1.1\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(format!("{first}{second}").as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();

    assert_eq!(out.matches("HTTP/1.1 200").count(), 2, "{out}");
    assert_eq!(out.matches("\"tokens\":[").count(), 2, "both completions answered: {out}");
    assert!(out.contains("Connection: keep-alive"), "{out}");
    assert!(out.contains("Connection: close"), "{out}");

    flag.store(true, std::sync::atomic::Ordering::SeqCst);
    t.join().unwrap();
}
