//! Experiment harness: builds engines for the paper's settings and runs the
//! synthetic workloads. Shared by `cargo bench --bench paper_tables`, the
//! CLI's `bench-table` subcommand, and the integration tests, so every table
//! is regenerated through exactly one code path.

use std::sync::Arc;

use anyhow::Result;

use crate::adapters::{AdapterStore, LoraShape};
use crate::backend::devices::DeviceProfile;
use crate::backend::sim::{SimBackend, SIM_MAX_SEQ};
use crate::baseline::LlamaCppEngine;
use crate::cluster::{ClusterConfig, ClusterEngine, ClusterReport, Replica};
use crate::config::{EngineKind, ModelSetting, Preset, ServerConfig, WorkloadConfig};
use crate::coordinator::EdgeLoraEngine;
use crate::memory::{AdapterMemoryManager, CachePolicy, SharedPages};
use crate::metrics::Summary;
use crate::router::confidence::{TaskModelRouter, TaskWorld};
use crate::router::trainer::train_router;
use crate::util::time::{Clock, VirtualClock};
use crate::workload::{generate, Trace};

/// Everything needed to run one experiment cell.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    pub model: ModelSetting,
    pub device: DeviceProfile,
    pub engine: EngineKind,
    pub server: ServerConfig,
    pub workload: WorkloadConfig,
    pub tdp_watts: Option<f64>,
    pub cache_policy: CachePolicy,
    /// classifier accuracy of the synthetic router
    pub router_acc: f64,
}

impl ExperimentSpec {
    pub fn from_preset(p: &Preset, engine: EngineKind) -> Self {
        Self {
            model: p.model.clone(),
            device: DeviceProfile::by_name(p.device).expect("preset device"),
            engine,
            server: ServerConfig {
                engine,
                ..p.server.clone()
            },
            workload: p.workload.clone(),
            tdp_watts: None,
            cache_policy: CachePolicy::Lru,
            router_acc: 0.95,
        }
    }

    /// Pool blocks for the EdgeLoRA cache: enough for the slot count plus
    /// headroom, capped by what fits beside the model in device memory.
    pub fn cache_capacity(&self) -> usize {
        if let Some(c) = self.server.cache_capacity {
            return c;
        }
        let free = self
            .device
            .memory_bytes
            .saturating_sub(self.model.base_model_bytes());
        // keep half the free memory for KV/activations
        let budget = free / 2;
        let per = self.model.adapter_resident_bytes().max(1);
        (budget / per)
            .clamp(2, (2 * self.server.slots).max(4))
            .min(self.workload.n_adapters.max(2))
    }
}

/// Page geometry + budget for one device shard (DESIGN.md §Unified paging):
/// every byte of the device's free memory (after the base model) becomes one
/// pool of `page_bytes` pages serving both adapter blocks and KV.
#[derive(Debug, Clone)]
pub struct PagedPlan {
    /// page size: `kv_page_tokens` KV positions' worth of bytes, so one KV
    /// page maps to exactly one allocator page
    pub page_bytes: usize,
    /// total pages in the shard's unified pool
    pub n_pages: usize,
    /// modeled pages one resident adapter block charges
    pub pages_per_block: usize,
    /// KV positions per page (the geometry the plan was built with)
    pub kv_page_tokens: usize,
}

impl PagedPlan {
    pub fn total_bytes(&self) -> usize {
        self.n_pages * self.page_bytes
    }

    /// Cap a requested adapter-block count so `slots` admissions (prompt
    /// pages + one decode page ≈ 2 pages each) always stay possible beside
    /// a fully-resident cache. None = not even one block fits (OOM).
    pub fn clamp_blocks(&self, requested: usize, slots: usize) -> Option<usize> {
        let reserve = 2 * slots;
        let max_blocks = self.n_pages.saturating_sub(reserve) / self.pages_per_block;
        if max_blocks == 0 {
            return None;
        }
        Some(requested.clamp(1, max_blocks))
    }

    /// Largest adapter cache this plan supports beside `slots` sequences of
    /// `expected_tokens` KV each — the paged capacity number the capacity
    /// table quotes against `static_max_blocks`.
    pub fn max_blocks_at(&self, slots: usize, expected_tokens: usize) -> usize {
        let kv_pages = slots * (expected_tokens.div_ceil(self.kv_page_tokens) + 1);
        self.n_pages.saturating_sub(kv_pages) / self.pages_per_block
    }
}

/// Build the unified-paging plan for one device + model: page size from the
/// model's per-token KV bytes, budget = device memory − base model.
pub fn paged_plan(device: &DeviceProfile, model: &ModelSetting, kv_page_tokens: usize) -> PagedPlan {
    let page_bytes = (model.kv_bytes_per_token() * kv_page_tokens.max(1)).max(1);
    let free = device
        .memory_bytes
        .saturating_sub(model.base_model_bytes());
    PagedPlan {
        page_bytes,
        n_pages: free / page_bytes,
        pages_per_block: model.adapter_resident_bytes().div_ceil(page_bytes).max(1),
        kv_page_tokens: kv_page_tokens.max(1),
    }
}

/// Largest adapter pool the *static-headroom* configuration affords: free
/// memory minus the worst-case `kv_bytes_for(slots)` reservation, divided by
/// the resident adapter footprint (mirrors `SimBackend::reserve_pool`).
pub fn static_max_blocks(device: &DeviceProfile, model: &ModelSetting, slots: usize) -> usize {
    let kv_worst = model.kv_bytes_per_token() * SIM_MAX_SEQ * slots;
    device
        .memory_bytes
        .saturating_sub(model.base_model_bytes())
        .saturating_sub(kv_worst)
        / model.adapter_resident_bytes().max(1)
}

/// Largest adapter count llama.cpp's preload-all policy fits (mirrors
/// `SimBackend::preload_adapters`: 1.5× f32 footprint + worst-case KV).
pub fn llamacpp_max_preload(device: &DeviceProfile, model: &ModelSetting, slots: usize) -> usize {
    let kv_worst = model.kv_bytes_per_token() * SIM_MAX_SEQ * slots;
    let free = device
        .memory_bytes
        .saturating_sub(model.base_model_bytes())
        .saturating_sub(kv_worst);
    free * 2 / (model.adapter_resident_bytes().max(1) * 3)
}

/// Max concurrent sequences beside a `pool_blocks`-adapter cache: static
/// mode must budget `SIM_MAX_SEQ` positions per row; paged mode only the
/// expected sequence length (+1 page of slack).
pub fn max_sequences(
    device: &DeviceProfile,
    model: &ModelSetting,
    pool_blocks: usize,
    tokens_per_seq: usize,
) -> usize {
    let kv_row = model.kv_bytes_per_token() * tokens_per_seq.max(1);
    device
        .memory_bytes
        .saturating_sub(model.base_model_bytes())
        .saturating_sub(pool_blocks * model.adapter_resident_bytes())
        / kv_row.max(1)
}

/// Outcome of one cell: summary + energy/aux stats.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub summary: Summary,
    pub avg_power_w: f64,
    pub mean_batch: f64,
    pub adapter_loads: u64,
    /// background adapter reads issued / used (async prefetch pipeline)
    pub prefetch_issued: u64,
    pub prefetch_hits: u64,
    /// adapters resident at drain time (the capacity the memory budget
    /// actually sustained)
    pub resident_adapters: usize,
    /// unified-paging accounting (zeros when the cell ran static headroom)
    pub kv_page_faults: u64,
    pub preemptions: u64,
    pub total_pages: usize,
    /// prefix-sharing accounting (DESIGN.md §Prefix sharing; zeros when
    /// unpaged or sharing off)
    pub prefix_hits: u64,
    pub prefix_lookups: u64,
    pub shared_prompt_pages: u64,
    pub prompt_pages_charged: u64,
    pub oom: bool,
}

impl CellResult {
    pub fn oom() -> Self {
        Self {
            summary: Summary::empty(),
            avg_power_w: 0.0,
            mean_batch: 0.0,
            adapter_loads: 0,
            prefetch_issued: 0,
            prefetch_hits: 0,
            resident_adapters: 0,
            kv_page_faults: 0,
            preemptions: 0,
            total_pages: 0,
            prefix_hits: 0,
            prefix_lookups: 0,
            shared_prompt_pages: 0,
            prompt_pages_charged: 0,
            oom: true,
        }
    }

    /// Table formatting: "0.44" or "OOM".
    pub fn fmt_throughput(&self) -> String {
        if self.oom {
            "OOM".into()
        } else {
            format!("{:.2}", self.summary.throughput_rps)
        }
    }

    pub fn fmt_latency(&self) -> String {
        if self.oom {
            "OOM".into()
        } else {
            format!("{:.2}", self.summary.avg_latency_s)
        }
    }

    pub fn fmt_first_token(&self) -> String {
        if self.oom {
            "OOM".into()
        } else {
            format!("{:.2}", self.summary.avg_first_token_s)
        }
    }

    pub fn fmt_slo(&self) -> String {
        if self.oom {
            "OOM".into()
        } else {
            format!("{:.2}%", 100.0 * self.summary.slo_attainment)
        }
    }
}

fn adapter_shape(model: &ModelSetting) -> LoraShape {
    // scaled-down proxy of the paper-size adapter: the *scheduling* costs in
    // the sim come from ModelSetting's byte/time math, so the store only
    // needs small real payloads for the pool/bank plumbing to be exercised.
    LoraShape {
        n_layers: 2,
        d_model: 64,
        rank: model.lora_rank.min(8),
    }
}

/// Create a throwaway on-disk adapter store populated with the spec's
/// synthetic adapters. Public so worker processes (`serve-node`) can build
/// their own store — `populate_synthetic` is deterministic per adapter id,
/// so every process sees byte-identical weights.
pub fn mk_store(spec: &ExperimentSpec, tag: &str) -> Result<Arc<AdapterStore>> {
    let dir = std::env::temp_dir().join(format!(
        "elra_exp_{tag}_{}_{}",
        spec.model.name,
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = AdapterStore::create(&dir, adapter_shape(&spec.model), spec.model.quant)?;
    store.populate_synthetic(spec.workload.n_adapters)?;
    Ok(Arc::new(store))
}

/// Build the memory side of one engine: the cache capacity actually used
/// and the (possibly page-backed) manager + its backend reservation. In
/// paged mode (`spec.server.paged`) the device's whole free budget becomes
/// one unified page pool shared by adapter blocks and KV; otherwise the
/// legacy static-headroom reservation applies. None = OOM.
fn plan_memory(spec: &ExperimentSpec) -> Option<(usize, Option<PagedPlan>)> {
    let requested = spec.cache_capacity();
    if !spec.server.paged {
        return Some((requested, None));
    }
    let plan = paged_plan(&spec.device, &spec.model, spec.server.kv_page_tokens);
    let cap = plan.clamp_blocks(requested, spec.server.slots)?;
    Some((cap, Some(plan)))
}

fn mk_memory(
    store: Arc<AdapterStore>,
    cache_cap: usize,
    policy: CachePolicy,
    plan: &Option<PagedPlan>,
) -> AdapterMemoryManager {
    match plan {
        Some(p) => AdapterMemoryManager::new_paged(
            store,
            cache_cap,
            policy,
            SharedPages::new(p.n_pages, p.page_bytes),
            p.pages_per_block,
        ),
        None => AdapterMemoryManager::new(store, cache_cap, policy),
    }
}

fn reserve_backend(backend: &mut SimBackend, cache_cap: usize, plan: &Option<PagedPlan>) -> Result<()> {
    match plan {
        Some(p) => backend.reserve_unified(p.total_bytes()),
        None => backend.reserve_pool(cache_cap),
    }
}

/// Run an EdgeLoRA (or w/o-AAS) cell.
pub fn run_edgelora(spec: &ExperimentSpec, tag: &str) -> Result<CellResult> {
    let clock = Arc::new(VirtualClock::new());
    let Some((cache_cap, plan)) = plan_memory(spec) else {
        return Ok(CellResult::oom());
    };
    let mut backend = SimBackend::new(
        spec.device.clone(),
        spec.model.clone(),
        clock.clone(),
        spec.server.slots,
        cache_cap,
        spec.tdp_watts,
    )?;
    if reserve_backend(&mut backend, cache_cap, &plan).is_err() {
        return Ok(CellResult::oom());
    }
    let store = mk_store(spec, tag)?;
    let memory = mk_memory(store, cache_cap, spec.cache_policy, &plan);
    let router: TaskModelRouter = {
        let world = TaskWorld::synthetic(
            spec.workload.n_adapters,
            5,
            spec.workload.seed ^ 0x77_00,
        );
        let r = train_router(&world, 200, spec.router_acc, spec.workload.seed);
        // router must cover every adapter id
        assert_eq!(r.est.len(), spec.workload.n_adapters);
        r
    };
    let mut engine = EdgeLoraEngine::new(
        Box::new(backend),
        memory,
        Box::new(router),
        clock.clone(),
        spec.server.clone(),
    );
    engine.warm_cache(0..cache_cap as u64)?;
    let trace = mk_trace(spec);
    let summary = engine.run_trace(&trace)?;
    let span = clock.now();
    let avg_power_w = engine_avg_power(&engine, span);
    Ok(CellResult {
        avg_power_w,
        mean_batch: engine.stats.mean_batch(),
        adapter_loads: engine.stats.adapter_loads,
        prefetch_issued: engine.stats.prefetch_issued,
        prefetch_hits: engine.stats.prefetch_hits,
        resident_adapters: engine.memory().resident_count(),
        kv_page_faults: engine.stats.kv_page_faults,
        preemptions: engine.stats.preemptions,
        total_pages: engine.total_pages(),
        prefix_hits: engine.stats.prefix_hits,
        prefix_lookups: engine.stats.prefix_lookups,
        shared_prompt_pages: engine.stats.shared_prompt_pages,
        prompt_pages_charged: engine.stats.prompt_pages_charged,
        oom: false,
        summary,
    })
}

fn engine_avg_power(engine: &EdgeLoraEngine, span: f64) -> f64 {
    // downcast the backend to the sim to read its energy account
    // (the PJRT backend has no power model)
    engine
        .backend()
        .as_any()
        .and_then(|a| a.downcast_ref::<SimBackend>())
        .map(|b| b.average_power(span))
        .unwrap_or(0.0)
}

/// Run a llama.cpp baseline cell (may OOM → CellResult::oom()).
pub fn run_llamacpp(spec: &ExperimentSpec, tag: &str) -> Result<CellResult> {
    let _ = tag;
    let clock = Arc::new(VirtualClock::new());
    let backend = SimBackend::new(
        spec.device.clone(),
        spec.model.clone(),
        clock.clone(),
        spec.server.slots,
        1,
        spec.tdp_watts,
    )?;
    let mut engine = match LlamaCppEngine::new(
        Box::new(backend),
        clock.clone(),
        spec.server.slots,
        spec.workload.n_adapters,
    ) {
        Ok(e) => e,
        Err(_) => return Ok(CellResult::oom()),
    };
    let mut wl = spec.workload.clone();
    wl.auto_select_fraction = 0.0; // baseline requires explicit adapters
    let trace = generate(&wl);
    let summary = engine.run_trace(&trace)?;
    let span = clock.now();
    let avg_power_w = engine
        .backend()
        .as_any()
        .and_then(|a| a.downcast_ref::<SimBackend>())
        .map(|b| b.average_power(span))
        .unwrap_or(0.0);
    Ok(CellResult {
        avg_power_w,
        mean_batch: 0.0,
        adapter_loads: engine.switches,
        prefetch_issued: 0,
        prefetch_hits: 0,
        resident_adapters: spec.workload.n_adapters,
        kv_page_faults: 0,
        preemptions: 0,
        total_pages: 0,
        prefix_hits: 0,
        prefix_lookups: 0,
        shared_prompt_pages: 0,
        prompt_pages_charged: 0,
        oom: false,
        summary,
    })
}

fn mk_trace(spec: &ExperimentSpec) -> Trace {
    let mut wl = spec.workload.clone();
    if spec.engine == EngineKind::EdgeLoraNoAas {
        wl.auto_select_fraction = 0.0;
    }
    generate(&wl)
}

/// One cluster experiment cell: the per-replica settings plus the replica
/// device mix and the dispatch/stealing policy.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub base: ExperimentSpec,
    /// one device per replica (heterogeneous mixes allowed)
    pub devices: Vec<DeviceProfile>,
    pub cluster: ClusterConfig,
}

impl ClusterSpec {
    /// Homogeneous cluster: `n` replicas of the base spec's device.
    pub fn homogeneous(base: ExperimentSpec, n: usize, cluster: ClusterConfig) -> Self {
        let devices = vec![base.device.clone(); n];
        Self {
            base,
            devices,
            cluster,
        }
    }
}

/// Build (but do not run) a cluster: one engine replica per device entry,
/// each with its own virtual clock, sim backend, memory shard and
/// prefetcher, all reading one shared adapter store. Shared by the scaling
/// experiments and the `serve-sim` HTTP front-end.
pub fn build_cluster(spec: &ClusterSpec, tag: &str) -> Result<ClusterEngine> {
    let store = mk_store(&spec.base, tag)?;
    let mut replicas = Vec::with_capacity(spec.devices.len());
    for shard in 0..spec.devices.len() {
        replicas.push(mk_cluster_replica(spec, &store, shard)?);
    }
    let mut cluster = ClusterEngine::new(replicas, spec.cluster.clone());
    // autoscaler spawn path: new shards are built exactly like the initial
    // fleet (cycling the device mix), reading the same shared store; the
    // cluster wires the shared recorder/bus onto the replica itself
    let fspec = spec.clone();
    let fstore = Arc::clone(&store);
    cluster.set_replica_factory(Box::new(move |shard| {
        mk_cluster_replica(&fspec, &fstore, shard)
    }));
    Ok(cluster)
}

/// Build one cluster shard: its own virtual clock, sim backend, memory
/// shard and router, reading the shared adapter store. Shard indices past
/// the device mix cycle through it (autoscaler spawns). Public because a
/// `serve-node` worker process builds exactly one shard from the same spec
/// (DESIGN.md §Distributed serving).
pub fn mk_cluster_replica(
    spec: &ClusterSpec,
    store: &Arc<AdapterStore>,
    shard: usize,
) -> Result<Replica> {
    let device = &spec.devices[shard % spec.devices.len()];
    let clock = Arc::new(VirtualClock::new());
    // per-replica cache sizing follows the replica's own device budget
    // (and its own unified page pool when paging is on)
    let mut rspec = spec.base.clone();
    rspec.device = device.clone();
    let (cache_cap, plan) = plan_memory(&rspec)
        .ok_or_else(|| anyhow::anyhow!("replica {shard} ({}) OOM", device.name))?;
    let mut backend = SimBackend::new(
        device.clone(),
        spec.base.model.clone(),
        clock.clone(),
        spec.base.server.slots,
        cache_cap,
        spec.base.tdp_watts,
    )?;
    reserve_backend(&mut backend, cache_cap, &plan)?;
    let memory = mk_memory(Arc::clone(store), cache_cap, spec.base.cache_policy, &plan)
        .with_shard(shard);
    // identical router per replica (same profiling data), deterministic
    let world = TaskWorld::synthetic(
        spec.base.workload.n_adapters,
        5,
        spec.base.workload.seed ^ 0x77_00,
    );
    let router = train_router(&world, 200, spec.base.router_acc, spec.base.workload.seed);
    let engine = EdgeLoraEngine::new(
        Box::new(backend),
        memory,
        Box::new(router),
        clock.clone(),
        spec.base.server.clone(),
    );
    Ok(Replica { engine, clock })
}

/// Run one cluster cell over the spec's workload.
pub fn run_cluster(spec: &ClusterSpec, tag: &str) -> Result<ClusterReport> {
    let mut cluster = build_cluster(spec, tag)?;
    let trace = mk_trace(&spec.base);
    cluster.run_trace(&trace)
}

/// Render an aligned text table (benches print these).
pub fn format_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = format!("\n=== {title} ===\n");
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}
