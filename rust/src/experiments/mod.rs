//! Experiment harness + one runner per paper table/figure. The benches, the
//! CLI's `bench-table` subcommand and the integration tests all regenerate
//! results through this single code path.

pub mod harness;
pub mod tables;

pub use harness::{
    build_cluster, format_table, llamacpp_max_preload, max_sequences, mk_cluster_replica,
    mk_store, paged_plan, run_cluster, run_edgelora, run_llamacpp, static_max_blocks,
    CellResult, ClusterSpec, ExperimentSpec, PagedPlan,
};
