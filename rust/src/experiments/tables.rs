//! One runner per table/figure in the paper's evaluation (§5). Each returns
//! formatted rows (and is exercised by `cargo bench --bench paper_tables`).
//! Paper-side expectations are documented inline; EXPERIMENTS.md records the
//! measured-vs-paper comparison.

use anyhow::Result;

use crate::backend::devices::{DeviceProfile, TimingModel};
use crate::cluster::{
    AutoscaleConfig, ClusterConfig, ClusterReport, DispatchPolicy, FaultEvent, FaultKind,
    HealthConfig, QosConfig,
};
use crate::config::{preset, EngineKind, ModelSetting, ServerConfig, WorkloadConfig};
use crate::experiments::harness::{
    build_cluster, format_table, llamacpp_max_preload, max_sequences, paged_plan,
    run_cluster, run_edgelora, run_llamacpp, static_max_blocks, CellResult, ClusterSpec,
    ExperimentSpec,
};
use crate::workload::{generate, Trace};
use crate::memory::CachePolicy;
use crate::router::confidence::{TaskWorld, TABLE12_ADAPTERS, TABLE12_TASKS};
use crate::router::trainer::table12_experiment;

/// Short-mode scaling: benches divide trace duration by this to stay quick.
/// 1 = full 5-minute paper traces.
pub fn duration_scale() -> f64 {
    match std::env::var("EDGELORA_FULL_TRACES").as_deref() {
        Ok("1") => 1.0,
        _ => 0.4, // 2-minute traces by default — same steady-state shape
    }
}

fn scaled(mut wl: WorkloadConfig) -> WorkloadConfig {
    wl.duration_s *= duration_scale();
    wl
}

/// Table 4: throughput vs n adapters, three device settings, three engines.
pub fn table4() -> Result<String> {
    let cells: Vec<(&str, Vec<usize>)> = vec![
        ("S1@AGX", vec![20, 50, 100, 1000]),
        ("S2@Nano", vec![20, 100, 500]),
        ("S3@Rasp", vec![20, 100, 200]),
    ];
    let mut rows = Vec::new();
    for (preset_name, ns) in cells {
        let p = preset(preset_name)?;
        for n in ns {
            let mut spec = ExperimentSpec::from_preset(&p, EngineKind::EdgeLora);
            spec.workload.n_adapters = n;
            spec.workload = scaled(spec.workload);
            let llama = run_llamacpp(&spec, &format!("t4l_{preset_name}_{n}"))?;
            let edge = run_edgelora(&spec, &format!("t4e_{preset_name}_{n}"))?;
            let mut spec_noaas = spec.clone();
            spec_noaas.engine = EngineKind::EdgeLoraNoAas;
            spec_noaas.server.engine = EngineKind::EdgeLoraNoAas;
            let noaas = run_edgelora(&spec_noaas, &format!("t4n_{preset_name}_{n}"))?;
            rows.push(vec![
                preset_name.to_string(),
                n.to_string(),
                llama.fmt_throughput(),
                edge.fmt_throughput(),
                noaas.fmt_throughput(),
            ]);
        }
    }
    Ok(format_table(
        "Table 4: Throughput (req/s) across devices",
        &["Setting", "n", "llama.cpp", "EdgeLoRA", "EdgeLoRA(w/o AAS)"],
        &rows,
    ))
}

/// Tables 5 & 6: SLO attainment and first-token latency vs n, S3@Nano.
pub fn table5_6() -> Result<(String, String)> {
    let p = preset("S3@Nano")?;
    let mut slo_rows = Vec::new();
    let mut ftl_rows = Vec::new();
    for n in [20, 100, 200, 500, 1000] {
        let mut spec = ExperimentSpec::from_preset(&p, EngineKind::EdgeLora);
        spec.workload.n_adapters = n;
        spec.workload = scaled(spec.workload);
        let llama = run_llamacpp(&spec, &format!("t56l_{n}"))?;
        let edge = run_edgelora(&spec, &format!("t56e_{n}"))?;
        let mut spec_noaas = spec.clone();
        spec_noaas.engine = EngineKind::EdgeLoraNoAas;
        spec_noaas.server.engine = EngineKind::EdgeLoraNoAas;
        let noaas = run_edgelora(&spec_noaas, &format!("t56n_{n}"))?;
        slo_rows.push(vec![
            n.to_string(),
            llama.fmt_slo(),
            edge.fmt_slo(),
            noaas.fmt_slo(),
        ]);
        ftl_rows.push(vec![
            n.to_string(),
            llama.fmt_first_token(),
            edge.fmt_first_token(),
            noaas.fmt_first_token(),
        ]);
    }
    Ok((
        format_table(
            "Table 5: SLO attainment, S3@Nano",
            &["n", "llama.cpp", "EdgeLoRA", "EdgeLoRA(w/o AAS)"],
            &slo_rows,
        ),
        format_table(
            "Table 6: First-token latency (s), S3@Nano",
            &["n", "llama.cpp", "EdgeLoRA", "EdgeLoRA(w/o AAS)"],
            &ftl_rows,
        ),
    ))
}

/// Tables 7 & 8: adapter-locality sweep (α), S1@AGX n=50.
pub fn table7_8() -> Result<(String, String)> {
    let p = preset("S1@AGX")?;
    let mut t7 = Vec::new();
    let mut t8 = Vec::new();
    for alpha in [0.5, 0.75, 1.0] {
        let mut spec = ExperimentSpec::from_preset(&p, EngineKind::EdgeLora);
        spec.workload.n_adapters = 50;
        spec.workload.alpha = alpha;
        spec.workload = scaled(spec.workload);
        let llama = run_llamacpp(&spec, &format!("t78l_{alpha}"))?;
        let edge = run_edgelora(&spec, &format!("t78e_{alpha}"))?;
        t7.push(vec![
            format!("{alpha}"),
            llama.fmt_throughput(),
            edge.fmt_throughput(),
        ]);
        t8.push(vec![
            format!("{alpha}"),
            llama.fmt_latency(),
            edge.fmt_latency(),
        ]);
    }
    Ok((
        format_table(
            "Table 7: Throughput (req/s) vs adapter locality, S1@AGX n=50",
            &["alpha", "llama.cpp", "EdgeLoRA"],
            &t7,
        ),
        format_table(
            "Table 8: Avg request latency (s) vs adapter locality, S1@AGX n=50",
            &["alpha", "llama.cpp", "EdgeLoRA"],
            &t8,
        ),
    ))
}

/// Tables 9 & 10: workload-skewness sweep (cv), S1@AGX n=50.
pub fn table9_10() -> Result<(String, String)> {
    let p = preset("S1@AGX")?;
    let mut t9 = Vec::new();
    let mut t10 = Vec::new();
    for cv in [1.0, 1.25, 1.5, 2.0] {
        let mut spec = ExperimentSpec::from_preset(&p, EngineKind::EdgeLora);
        spec.workload.n_adapters = 50;
        spec.workload.cv = cv;
        spec.workload = scaled(spec.workload);
        let llama = run_llamacpp(&spec, &format!("t910l_{cv}"))?;
        let edge = run_edgelora(&spec, &format!("t910e_{cv}"))?;
        t9.push(vec![
            format!("{cv}"),
            llama.fmt_throughput(),
            edge.fmt_throughput(),
        ]);
        t10.push(vec![
            format!("{cv}"),
            llama.fmt_latency(),
            edge.fmt_latency(),
        ]);
    }
    Ok((
        format_table(
            "Table 9: Throughput (req/s) vs workload skewness, S1@AGX n=50",
            &["cv", "llama.cpp", "EdgeLoRA"],
            &t9,
        ),
        format_table(
            "Table 10: Avg request latency (s) vs workload skewness, S1@AGX n=50",
            &["cv", "llama.cpp", "EdgeLoRA"],
            &t10,
        ),
    ))
}

/// Table 11: average power (W) across settings.
pub fn table11() -> Result<String> {
    let cells = [("S1@AGX", 20), ("S2@AGX", 50), ("S2@Nano", 20)];
    let mut rows = Vec::new();
    for (preset_name, n) in cells {
        let p = preset(preset_name)?;
        let mut spec = ExperimentSpec::from_preset(&p, EngineKind::EdgeLora);
        spec.workload.n_adapters = n;
        spec.workload = scaled(spec.workload);
        let llama = run_llamacpp(&spec, &format!("t11l_{preset_name}"))?;
        let edge = run_edgelora(&spec, &format!("t11e_{preset_name}"))?;
        let fmt = |c: &CellResult| {
            if c.oom {
                "OOM".to_string()
            } else {
                format!("{:.2}", c.avg_power_w)
            }
        };
        rows.push(vec![
            format!("{preset_name} (n={n})"),
            fmt(&llama),
            fmt(&edge),
        ]);
    }
    Ok(format_table(
        "Table 11: Power consumption (Watt)",
        &["Setting", "llama.cpp", "EdgeLoRA"],
        &rows,
    ))
}

/// Table 12: adapter-router accuracy (synthetic task world seeded from the
/// paper's measured matrix).
pub fn table12() -> Result<String> {
    let world = TaskWorld::table12();
    let rows = table12_experiment(&world, &TABLE12_ADAPTERS, 6000, 0.98, 0x712);
    let mut out_rows = Vec::new();
    for r in &rows {
        let mut cells = vec![r.name.clone()];
        cells.extend(r.per_task.iter().map(|v| format!("{v:.2}")));
        cells.push(format!("{:.2}", r.average));
        out_rows.push(cells);
    }
    let mut headers = vec!["Model"];
    headers.extend(TABLE12_TASKS);
    headers.push("Average");
    Ok(format_table(
        "Table 12: Adapter router accuracy",
        &headers,
        &out_rows,
    ))
}

/// Table 13: throughput under TDP modes, AGX.
pub fn table13() -> Result<String> {
    let mut rows = Vec::new();
    for tdp in [50.0, 30.0, 15.0] {
        let mut cells = vec![format!("{tdp:.0}W")];
        for preset_name in ["S1@AGX", "S2@AGX", "S3@AGX"] {
            let p = preset(preset_name)?;
            let mut spec = ExperimentSpec::from_preset(&p, EngineKind::EdgeLora);
            spec.tdp_watts = Some(tdp);
            spec.workload = scaled(spec.workload);
            let edge = run_edgelora(&spec, &format!("t13_{preset_name}_{tdp}"))?;
            cells.push(edge.fmt_throughput());
        }
        rows.push(cells);
    }
    Ok(format_table(
        "Table 13: Throughput (req/s) on Jetson AGX under different TDPs",
        &["TDP", "S1@AGX", "S2@AGX", "S3@AGX"],
        &rows,
    ))
}

/// Table 14: throughput vs slot count, Nano.
pub fn table14() -> Result<String> {
    let mut rows = Vec::new();
    for slots in [1usize, 5, 10, 20] {
        let mut cells = vec![slots.to_string()];
        for preset_name in ["S2@Nano", "S3@Nano"] {
            let p = preset(preset_name)?;
            let mut spec = ExperimentSpec::from_preset(&p, EngineKind::EdgeLora);
            spec.server.slots = slots;
            spec.workload = scaled(spec.workload);
            let edge = run_edgelora(&spec, &format!("t14_{preset_name}_{slots}"))?;
            cells.push(edge.fmt_throughput());
        }
        rows.push(cells);
    }
    Ok(format_table(
        "Table 14: Throughput (req/s) on Jetson Orin Nano vs number of slots",
        &["slots", "S2@Nano", "S3@Nano"],
        &rows,
    ))
}

/// Figure 8: throughput + latency vs n adapters for EdgeLoRA and w/o-AAS on
/// AGX and Nano (four panels as four column groups).
pub fn fig8() -> Result<String> {
    let mut rows = Vec::new();
    for n in [10usize, 50, 100, 500, 1000, 2000] {
        let mut cells = vec![n.to_string()];
        for preset_name in ["S1@AGX", "S3@Nano"] {
            let p = preset(preset_name)?;
            for kind in [EngineKind::EdgeLora, EngineKind::EdgeLoraNoAas] {
                let mut spec = ExperimentSpec::from_preset(&p, kind);
                spec.server.engine = kind;
                spec.workload.n_adapters = n;
                spec.workload = scaled(spec.workload);
                let cell = run_edgelora(&spec, &format!("f8_{preset_name}_{n}_{kind:?}"))?;
                cells.push(cell.fmt_throughput());
                cells.push(cell.fmt_latency());
            }
        }
        rows.push(cells);
    }
    Ok(format_table(
        "Figure 8: scalability vs number of adapters (thpt req/s | lat s)",
        &[
            "n",
            "AGX thpt",
            "AGX lat",
            "AGX thpt (w/o AAS)",
            "AGX lat (w/o AAS)",
            "Nano thpt",
            "Nano lat",
            "Nano thpt (w/o AAS)",
            "Nano lat (w/o AAS)",
        ],
        &rows,
    ))
}

/// The skewed multi-tenant workload the cluster-scaling experiment offers:
/// heavy fixed load (well past one replica's capacity), 64 tenants, 30% of
/// the traffic pinned on the two hottest (stresses stealing), explicit
/// adapters (exercises affinity + per-replica caches).
pub fn scaling_spec(tiny: bool) -> ExperimentSpec {
    ExperimentSpec {
        model: ModelSetting::s3(),
        device: DeviceProfile::agx_orin(),
        engine: EngineKind::EdgeLoraNoAas,
        server: ServerConfig {
            slots: 8,
            top_k: 3,
            cache_capacity: Some(8),
            engine: EngineKind::EdgeLoraNoAas,
            ..ServerConfig::default()
        },
        workload: WorkloadConfig {
            n_adapters: 64,
            alpha: 1.0,
            // ~5× one replica's capacity (decode+prefill floor ≈ 34 ms/req
            // at batch 8 ⇒ ≈ 29 req/s/replica): N=1 and N=4 are both
            // makespan-bound, so throughput scales ≈ linearly with replicas
            rate: 160.0,
            cv: 1.0,
            input_range: (8, 24),
            output_range: (8, 24),
            duration_s: if tiny { 5.0 } else { 20.0 },
            auto_select_fraction: 0.0,
            hot_fraction: 0.3,
            hot_adapters: 2,
            seed: 0xc1a5,
        },
        tdp_watts: None,
        cache_policy: CachePolicy::Lru,
        router_acc: 0.95,
    }
}

/// Cluster scaling: throughput and p50/p99 latency vs replica count at fixed
/// offered load, plus dispatch-policy (affinity vs random) and stealing
/// on/off ablations at the largest N. `EDGELORA_SCALING_TINY=1` shrinks the
/// sweep to N ∈ {1, 2} on a short trace — the offline CI cluster tier.
pub fn table_scaling() -> Result<String> {
    let tiny = std::env::var("EDGELORA_SCALING_TINY").as_deref() == Ok("1");
    let spec = scaling_spec(tiny);
    let ns: &[usize] = if tiny { &[1, 2] } else { &[1, 2, 4, 8] };
    let n_ablate = if tiny { 2 } else { 4 };
    let mut rows = Vec::new();
    let mut cell = |label: String, n: usize, policy: DispatchPolicy, stealing: bool, tag: &str| -> Result<()> {
        let cspec = ClusterSpec::homogeneous(
            spec.clone(),
            n,
            ClusterConfig {
                policy,
                stealing,
                ..ClusterConfig::default()
            },
        );
        let r = run_cluster(&cspec, tag)?;
        rows.push(vec![
            label,
            format!("{:.2}", r.summary.throughput_rps),
            format!("{:.2}", r.summary.p50_latency_s),
            format!("{:.2}", r.summary.p99_latency_s),
            format!("{:.3}", r.summary.cache_hit_rate),
            format!("{:.1}", r.makespan_s),
            r.steals.to_string(),
        ]);
        Ok(())
    };
    for &n in ns {
        cell(
            n.to_string(),
            n,
            DispatchPolicy::AdapterAffinity,
            true,
            &format!("scal_{n}"),
        )?;
    }
    cell(
        format!("{n_ablate} (random)"),
        n_ablate,
        DispatchPolicy::Random,
        true,
        "scal_rand",
    )?;
    cell(
        format!("{n_ablate} (no steal)"),
        n_ablate,
        DispatchPolicy::AdapterAffinity,
        false,
        "scal_nosteal",
    )?;
    Ok(format_table(
        "Scaling: replicas vs throughput/latency (S3@AGX, skewed tenants, fixed load)",
        &[
            "replicas",
            "thpt (req/s)",
            "p50 (s)",
            "p99 (s)",
            "cache hit",
            "makespan (s)",
            "steals",
        ],
        &rows,
    ))
}

/// Capacity (paper Table 4 analogue, DESIGN.md §Unified paging): max
/// simultaneously served adapters and max concurrent sequences per
/// `DeviceProfile`, llama.cpp preload-all vs EdgeLoRA with the static
/// worst-case KV headroom vs the unified paged pool — plus a measured short
/// skewed trace at the same memory budget (resident adapters + mean batch,
/// paged vs static ablation). `EDGELORA_CAPACITY_TINY=1` shrinks it to one
/// setting on a short trace — the offline CI capacity tier.
pub fn table_capacity() -> Result<String> {
    let tiny = std::env::var("EDGELORA_CAPACITY_TINY").as_deref() == Ok("1");
    let settings: &[&str] = if tiny {
        &["S2@Nano"]
    } else {
        &["S1@AGX", "S2@Nano", "S3@Rasp"]
    };
    let mut rows = Vec::new();
    for preset_name in settings {
        let p = preset(preset_name)?;
        let device = DeviceProfile::by_name(p.device).expect("preset device");
        let model = p.model.clone();
        let slots = p.server.slots;
        // expected sequence length for the measured workload below (the
        // quantity paged admission charges instead of SIM_MAX_SEQ)
        let (in_lo, in_hi) = (8usize, 24usize);
        let (out_lo, out_hi) = (4usize, 12usize);
        let expected_tokens = (in_lo + in_hi) / 2 + (out_lo + out_hi) / 2;

        // analytic capacity at the device budget
        let llama_max = llamacpp_max_preload(&device, &model, slots);
        let static_blocks = static_max_blocks(&device, &model, slots);
        let plan = paged_plan(&device, &model, p.server.kv_page_tokens);
        let paged_blocks = plan.max_blocks_at(slots, expected_tokens);
        let static_seqs = max_sequences(&device, &model, 4, crate::backend::sim::SIM_MAX_SEQ);
        let paged_seqs = max_sequences(&device, &model, 4, expected_tokens);

        // measured: same budget, short skewed trace, paged vs static
        let n_adapters = if tiny { 48 } else { 96 };
        let mk_spec = |paged: bool, cap: usize| ExperimentSpec {
            model: model.clone(),
            device: device.clone(),
            engine: EngineKind::EdgeLoraNoAas,
            server: ServerConfig {
                slots,
                top_k: 3,
                cache_capacity: Some(cap.clamp(2, n_adapters)),
                engine: EngineKind::EdgeLoraNoAas,
                paged,
                ..ServerConfig::default()
            },
            workload: WorkloadConfig {
                n_adapters,
                alpha: 0.3,
                rate: (2 * slots) as f64,
                duration_s: if tiny { 4.0 } else { 12.0 },
                input_range: (in_lo, in_hi),
                output_range: (out_lo, out_hi),
                auto_select_fraction: 0.0,
                seed: 0xca9,
                ..WorkloadConfig::default()
            },
            tdp_watts: None,
            cache_policy: CachePolicy::Lru,
            router_acc: 0.95,
        };
        let stat = run_edgelora(&mk_spec(false, static_blocks), &format!("cap_s_{preset_name}"))?;
        let pag = run_edgelora(&mk_spec(true, paged_blocks), &format!("cap_p_{preset_name}"))?;
        let fmt_meas = |c: &CellResult| {
            if c.oom {
                "OOM".to_string()
            } else {
                format!("{}@{:.1}", c.resident_adapters, c.mean_batch)
            }
        };
        rows.push(vec![
            preset_name.to_string(),
            llama_max.to_string(),
            static_blocks.to_string(),
            paged_blocks.to_string(),
            format!(
                "{:.2}x",
                paged_blocks as f64 / static_blocks.max(1) as f64
            ),
            static_seqs.to_string(),
            paged_seqs.to_string(),
            fmt_meas(&stat),
            fmt_meas(&pag),
        ]);
    }
    let capacity = format_table(
        "Capacity: max adapters / sequences per device (paged vs static KV headroom)",
        &[
            "Setting",
            "llama.cpp",
            "static blk",
            "paged blk",
            "gain",
            "static seq",
            "paged seq",
            "meas static",
            "meas paged",
        ],
        &rows,
    );
    Ok(format!("{capacity}\n{}", table_prefix_sharing()?))
}

/// Prefix-sharing ablation (DESIGN.md §Prefix sharing): hot same-adapter
/// traffic with fixed-length prompts (so the shared task preambles
/// page-align) at the same paged budget, sharing on vs off — the reclaimed
/// prompt pages and the prefill-skip TTFT win are the headline columns.
/// `EDGELORA_PREFIX_TINY=1` (or `EDGELORA_CAPACITY_TINY=1`) shrinks the
/// trace — the offline CI prefix tier.
pub fn table_prefix_sharing() -> Result<String> {
    let tiny = std::env::var("EDGELORA_PREFIX_TINY").as_deref() == Ok("1")
        || std::env::var("EDGELORA_CAPACITY_TINY").as_deref() == Ok("1");
    let p = preset("S2@Nano")?;
    let device = DeviceProfile::by_name(p.device).expect("preset device");
    let slots = p.server.slots;
    let mk = |share: bool| ExperimentSpec {
        model: p.model.clone(),
        device: device.clone(),
        engine: EngineKind::EdgeLoraNoAas,
        server: ServerConfig {
            slots,
            top_k: 3,
            cache_capacity: Some(8),
            engine: EngineKind::EdgeLoraNoAas,
            paged: true,
            prefix_share: share,
            ..ServerConfig::default()
        },
        workload: WorkloadConfig {
            n_adapters: 16,
            alpha: 0.3,
            // hot head of tenants repeating the same task preambles —
            // fixed input length keeps the shared prefixes page-aligned
            hot_fraction: 0.8,
            hot_adapters: 2,
            rate: (2 * slots) as f64,
            duration_s: if tiny { 3.0 } else { 10.0 },
            input_range: (32, 32),
            output_range: (4, 12),
            auto_select_fraction: 0.0,
            seed: 0x9f1e,
            ..WorkloadConfig::default()
        },
        tdp_watts: None,
        cache_policy: CachePolicy::Lru,
        router_acc: 0.95,
    };
    let off = run_edgelora(&mk(false), "pfx_off")?;
    let on = run_edgelora(&mk(true), "pfx_on")?;
    let saved = if off.prompt_pages_charged > 0 {
        100.0 * (1.0 - on.prompt_pages_charged as f64 / off.prompt_pages_charged as f64)
    } else {
        0.0
    };
    let rows = vec![vec![
        "S2@Nano".to_string(),
        off.prompt_pages_charged.to_string(),
        on.prompt_pages_charged.to_string(),
        format!("{saved:.0}%"),
        format!("{:.2}", on.summary.prefix_hit_rate),
        on.shared_prompt_pages.to_string(),
        off.fmt_first_token(),
        on.fmt_first_token(),
    ]];
    Ok(format_table(
        "Prefix sharing: prompt pages charged + TTFT, sharing off vs on (hot tenants)",
        &[
            "Setting",
            "pg chg off",
            "pg chg on",
            "saved",
            "hit rate",
            "shared pg",
            "ft off (s)",
            "ft on (s)",
        ],
        &rows,
    ))
}

/// Ablation: cache policy LRU vs LFU under skewed locality (§4.2 remark).
pub fn ablation_cache_policy() -> Result<String> {
    let p = preset("S1@AGX")?;
    let mut rows = Vec::new();
    for alpha in [0.5, 1.0, 2.0] {
        let mut cells = vec![format!("{alpha}")];
        for policy in [CachePolicy::Lru, CachePolicy::Lfu] {
            // explicit adapters + a small cache so the replacement policy is
            // actually exercised (with AAS steering to cached candidates the
            // hit rate saturates and the policies are indistinguishable)
            let mut spec = ExperimentSpec::from_preset(&p, EngineKind::EdgeLoraNoAas);
            spec.server.engine = EngineKind::EdgeLoraNoAas;
            spec.server.cache_capacity = Some(8);
            spec.workload.n_adapters = 100;
            spec.workload.alpha = alpha;
            spec.cache_policy = policy;
            spec.workload = scaled(spec.workload);
            let cell = run_edgelora(&spec, &format!("abl_{alpha}_{policy:?}"))?;
            cells.push(cell.fmt_throughput());
            cells.push(format!("{:.3}", cell.summary.cache_hit_rate));
        }
        rows.push(cells);
    }
    Ok(format_table(
        "Ablation: LRU vs LFU cache policy (S1@AGX, n=100, cache=8, explicit)",
        &["alpha", "LRU thpt", "LRU hit", "LFU thpt", "LFU hit"],
        &rows,
    ))
}

/// Ablation: router classifier accuracy sweep (selection quality knob).
pub fn ablation_router_acc() -> Result<String> {
    let p = preset("S3@Nano")?;
    let mut rows = Vec::new();
    for acc in [0.5, 0.8, 0.95] {
        let mut spec = ExperimentSpec::from_preset(&p, EngineKind::EdgeLora);
        spec.workload.n_adapters = 100;
        spec.router_acc = acc;
        spec.workload = scaled(spec.workload);
        let cell = run_edgelora(&spec, &format!("ablr_{acc}"))?;
        rows.push(vec![
            format!("{acc}"),
            cell.fmt_throughput(),
            cell.fmt_first_token(),
            format!("{:.3}", cell.summary.cache_hit_rate),
        ]);
    }
    Ok(format_table(
        "Ablation: router classifier accuracy (S3@Nano, n=100)",
        &["router acc", "thpt", "first-token (s)", "cache hit"],
        &rows,
    ))
}

/// Ablation: async adapter prefetch on/off under low locality (the swap-path
/// regime the zero-copy + prefetch pipeline targets: adapters ≫ cache).
pub fn ablation_prefetch() -> Result<String> {
    let p = preset("S1@AGX")?;
    let mut rows = Vec::new();
    for alpha in [0.1, 0.5, 1.0] {
        let mut cells = vec![format!("{alpha}")];
        for prefetch in [false, true] {
            let mut spec = ExperimentSpec::from_preset(&p, EngineKind::EdgeLoraNoAas);
            spec.server.engine = EngineKind::EdgeLoraNoAas;
            spec.server.cache_capacity = Some(8);
            spec.server.prefetch = prefetch;
            spec.workload.n_adapters = 100;
            spec.workload.alpha = alpha;
            spec.workload.rate = 1.0;
            spec.workload = scaled(spec.workload);
            let cell = run_edgelora(&spec, &format!("ablpf_{alpha}_{prefetch}"))?;
            cells.push(cell.fmt_first_token());
            cells.push(format!("{:.3}", cell.summary.cache_hit_rate));
            if prefetch {
                cells.push(format!("{}/{}", cell.prefetch_hits, cell.prefetch_issued));
            }
        }
        rows.push(cells);
    }
    Ok(format_table(
        "Ablation: async adapter prefetch (S1@AGX, n=100, cache=8, explicit)",
        &[
            "alpha",
            "off ft (s)",
            "off hit",
            "on ft (s)",
            "on hit",
            "pf hit/issued",
        ],
        &rows,
    ))
}

/// The elasticity workload: quiet baseline traffic with a hard load spike in
/// the middle (several× one replica's capacity) and a light tail long enough
/// for the autoscaler to drain back to the floor. Built by merging two
/// generated traces, so arrival statistics stay the workload module's.
fn elasticity_trace(tiny: bool, n_adapters: usize, seed: u64) -> Trace {
    let (duration_s, spike_start, spike_len) =
        if tiny { (10.0, 1.0, 2.0) } else { (24.0, 4.0, 6.0) };
    let mk_wl = |rate: f64, dur: f64, seed: u64| WorkloadConfig {
        n_adapters,
        alpha: 1.0,
        rate,
        cv: 1.0,
        input_range: (8, 24),
        output_range: (8, 24),
        duration_s: dur,
        auto_select_fraction: 0.0,
        hot_fraction: 0.3,
        hot_adapters: 2,
        seed,
        ..WorkloadConfig::default()
    };
    let base = generate(&mk_wl(4.0, duration_s, seed));
    let spike = generate(&mk_wl(60.0, spike_len, seed ^ 0x59_1c_e0));
    let mut requests = base.requests;
    requests.extend(spike.requests.into_iter().map(|mut r| {
        r.arrival_s += spike_start;
        r
    }));
    requests.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    for (i, r) in requests.iter_mut().enumerate() {
        r.id = i as u64;
    }
    let trace = Trace {
        requests,
        duration_s,
        n_adapters,
    };
    trace.validate().expect("merged spike trace is well-formed");
    trace
}

/// Everything the elasticity table (and its test) needs from the three runs.
pub struct ElasticityRuns {
    pub offered: usize,
    pub floor: usize,
    /// fixed fleet pinned at the floor size — the spike has nowhere to go
    pub fixed: ClusterReport,
    /// autoscale on: floor replicas, spawn-to-ceiling under the spike
    pub autoscaled: ClusterReport,
    /// fixed 2-replica fleet with a seeded kill+heal through the spike
    pub chaos: ClusterReport,
}

/// Run the elasticity cells (shared by `bench-table --table elasticity` and
/// the chaos CI tier test).
pub fn run_elasticity_cells(tiny: bool) -> Result<ElasticityRuns> {
    let floor = 1usize;
    let ceiling = 3usize;
    let base = ExperimentSpec {
        model: ModelSetting::s3(),
        device: DeviceProfile::agx_orin(),
        engine: EngineKind::EdgeLoraNoAas,
        server: ServerConfig {
            slots: 8,
            top_k: 3,
            cache_capacity: Some(8),
            engine: EngineKind::EdgeLoraNoAas,
            ..ServerConfig::default()
        },
        workload: WorkloadConfig {
            n_adapters: 32,
            auto_select_fraction: 0.0,
            ..WorkloadConfig::default()
        },
        tdp_watts: None,
        cache_policy: CachePolicy::Lru,
        router_acc: 0.95,
    };
    let trace = elasticity_trace(tiny, base.workload.n_adapters, 0xe1a5);
    let autoscale = AutoscaleConfig {
        enabled: true,
        floor,
        ceiling,
        queue_high: 4.0,
        queue_low: 1.0,
        cooldown_s: 0.3,
        eval_interval_s: 0.05,
        ..AutoscaleConfig::default()
    };

    let run = |n: usize, cluster: ClusterConfig, tag: &str| -> Result<ClusterReport> {
        let spec = ClusterSpec::homogeneous(base.clone(), n, cluster);
        let mut c = build_cluster(&spec, tag)?;
        c.run_trace(&trace)
    };
    let fixed = run(floor, ClusterConfig::default(), "elas_fixed")?;
    let autoscaled = run(
        floor,
        ClusterConfig {
            autoscale,
            ..ClusterConfig::default()
        },
        "elas_auto",
    )?;
    // chaos cell: kill one of two shards as the spike lands, heal it after —
    // the fast detector ladder keeps kill→Dead well inside the trace
    let (kill_at, heal_at) = if tiny { (1.5, 3.5) } else { (5.0, 10.0) };
    let chaos = run(
        2,
        ClusterConfig {
            faults: vec![
                FaultEvent {
                    at_s: kill_at,
                    replica: 0,
                    kind: FaultKind::Kill,
                },
                FaultEvent {
                    at_s: heal_at,
                    replica: 0,
                    kind: FaultKind::Heal,
                },
            ],
            health: HealthConfig {
                suspect_after_s: 0.2,
                dead_after_s: 0.5,
                ..HealthConfig::default()
            },
            ..ClusterConfig::default()
        },
        "elas_chaos",
    )?;
    Ok(ElasticityRuns {
        offered: trace.len(),
        floor,
        fixed,
        autoscaled,
        chaos,
    })
}

/// Elasticity: a fixed floor fleet vs the queue/page-pressure autoscaler
/// under a load spike, plus a seeded kill+heal chaos cell with request
/// conservation (every offered request completes exactly once — the shared
/// recorder balances). `EDGELORA_CHAOS_TINY=1` shrinks the traces — the
/// offline CI chaos tier.
pub fn table_elasticity() -> Result<String> {
    let tiny = std::env::var("EDGELORA_CHAOS_TINY").as_deref() == Ok("1");
    let r = run_elasticity_cells(tiny)?;
    let row = |label: &str, rep: &ClusterReport| {
        vec![
            label.to_string(),
            format!("{}/{}", rep.peak_serving, rep.final_serving),
            format!("{}/{}", rep.summary.requests, r.offered),
            format!("{:.2}", rep.summary.throughput_rps),
            format!("{:.2}%", 100.0 * rep.summary.slo_attainment),
            format!("{:.2}", rep.summary.p99_latency_s),
            rep.spawns.to_string(),
            rep.rehomed_total.to_string(),
            rep.restarts.iter().sum::<u64>().to_string(),
        ]
    };
    let rows = vec![
        row("fixed x1", &r.fixed),
        row("autoscale 1..3", &r.autoscaled),
        row("chaos x2 kill+heal", &r.chaos),
    ];
    Ok(format_table(
        "Elasticity: autoscale vs fixed floor under a load spike + chaos kill/heal (S3@AGX)",
        &[
            "fleet",
            "peak/final",
            "done/offered",
            "thpt (req/s)",
            "SLO",
            "p99 (s)",
            "spawns",
            "rehomed",
            "restarts",
        ],
        &rows,
    ))
}

/// The QoS workload (DESIGN.md §QoS & overload): mixed-class multi-tenant
/// traffic — ¾ Batch, ¼ Interactive carrying a first-token deadline — with
/// a mid-trace flash-crowd spike (rate doubles and half the spike traffic
/// piles onto the hottest tenant). `rate` is the baseline offered load.
fn slo_trace(tiny: bool, rate: f64, seed: u64) -> Trace {
    let duration_s = if tiny { 4.0 } else { 16.0 };
    generate(&WorkloadConfig {
        n_adapters: 16,
        alpha: 1.0,
        rate,
        cv: 1.5,
        input_range: (8, 24),
        output_range: (8, 24),
        duration_s,
        auto_select_fraction: 0.0,
        hot_fraction: 0.2,
        hot_adapters: 2,
        batch_fraction: 0.75,
        deadline_s: 6.0,
        spike_start_s: duration_s * 0.4,
        spike_len_s: duration_s * 0.2,
        spike_mult: 2.0,
        flash_fraction: 0.5,
        churn_period_s: 0.0,
        seed,
    })
}

/// Everything the SLO table (and its test) needs from the three runs.
pub struct SloRuns {
    pub offered_unloaded: usize,
    pub offered_overload: usize,
    /// baseline load well under one replica's capacity, QoS on
    pub unloaded: ClusterReport,
    /// ~3× saturation, priority classes + deadline-aware admission on
    pub qos_on: ClusterReport,
    /// same overload, class-blind FIFO ablation (no QoS anywhere)
    pub qos_off: ClusterReport,
}

/// Run the SLO cells (shared by `bench-table --table slo` and the QoS CI
/// tier test). Single S3@AGX replica, 8 slots (capacity ≈ 29 req/s):
/// unloaded at 8 req/s, overloaded at a baseline 80 req/s plus the spike.
pub fn run_slo_cells(tiny: bool) -> Result<SloRuns> {
    let mk_spec = |qos: bool| ExperimentSpec {
        model: ModelSetting::s3(),
        device: DeviceProfile::agx_orin(),
        engine: EngineKind::EdgeLoraNoAas,
        server: ServerConfig {
            slots: 8,
            top_k: 3,
            cache_capacity: Some(8),
            engine: EngineKind::EdgeLoraNoAas,
            qos,
            ..ServerConfig::default()
        },
        workload: WorkloadConfig {
            n_adapters: 16,
            auto_select_fraction: 0.0,
            ..WorkloadConfig::default()
        },
        tdp_watts: None,
        cache_policy: CachePolicy::Lru,
        router_acc: 0.95,
    };
    // deadline-aware admission on; per-tenant rate limiting off here so the
    // table isolates priority + deadline shedding (the rate limiter has its
    // own property/conservation tests)
    let qos_cluster = || ClusterConfig {
        qos: QosConfig {
            enabled: true,
            tenant_rate: 0.0,
            tenant_burst: 4.0,
            deadline_slack: 1.0,
        },
        ..ClusterConfig::default()
    };
    let quiet = slo_trace(tiny, 8.0, 0x510);
    let heavy = slo_trace(tiny, 80.0, 0x510);
    let run = |spec: ExperimentSpec,
               cluster: ClusterConfig,
               trace: &Trace,
               tag: &str|
     -> Result<ClusterReport> {
        let cspec = ClusterSpec::homogeneous(spec, 1, cluster);
        let mut c = build_cluster(&cspec, tag)?;
        c.run_trace(trace)
    };
    let unloaded = run(mk_spec(true), qos_cluster(), &quiet, "slo_quiet")?;
    let qos_on = run(mk_spec(true), qos_cluster(), &heavy, "slo_on")?;
    let qos_off = run(mk_spec(false), ClusterConfig::default(), &heavy, "slo_off")?;
    Ok(SloRuns {
        offered_unloaded: quiet.len(),
        offered_overload: heavy.len(),
        unloaded,
        qos_on,
        qos_off,
    })
}

/// SLO under flash-crowd overload: per-class p99 TTFT and SLO attainment at
/// an unloaded baseline vs ~3× saturation with QoS on, plus the class-blind
/// no-QoS ablation at the same overload. Interactive holds its tail while
/// Batch absorbs the loss; the shed column shows deadline-aware admission
/// working. `EDGELORA_SLO_TINY=1` shrinks the traces — the offline CI QoS
/// tier.
pub fn table_slo() -> Result<String> {
    let tiny = std::env::var("EDGELORA_SLO_TINY").as_deref() == Ok("1");
    let r = run_slo_cells(tiny)?;
    let row = |label: &str, offered: usize, rep: &ClusterReport| {
        let s = &rep.summary;
        vec![
            label.to_string(),
            format!("{}/{}", s.requests, offered),
            format!("{}+{}", s.shed_rate_limit, s.shed_deadline),
            format!("{:.2}", s.interactive.p99_ttft_s),
            format!("{:.1}%", 100.0 * s.interactive.slo_attainment),
            format!("{:.2}", s.batch.p99_ttft_s),
            format!("{:.1}%", 100.0 * s.batch.slo_attainment),
        ]
    };
    let rows = vec![
        row("unloaded (qos)", r.offered_unloaded, &r.unloaded),
        row("overload (qos)", r.offered_overload, &r.qos_on),
        row("overload (no qos)", r.offered_overload, &r.qos_off),
    ];
    Ok(format_table(
        "SLO: per-class tail latency under flash-crowd overload (S3@AGX x1, ¾ batch)",
        &[
            "cell",
            "done/offered",
            "shed rl+dl",
            "int p99 ttft",
            "int SLO",
            "bat p99 ttft",
            "bat SLO",
        ],
        &rows,
    ))
}

/// Everything the prefill table (and its CI-tier test) needs from the two
/// interference runs.
pub struct PrefillRuns {
    /// chunk budget used by the chunked cell (tokens per tick)
    pub chunk_tokens: usize,
    /// long-prompt length admitted against the residents
    pub long_input: usize,
    /// model-side steady 3-row decode step (the flat-ITL reference)
    pub baseline_itl_s: f64,
    /// worst resident inter-token gap during the chunked admission
    pub chunked_gap_s: f64,
    /// long-prompt TTFT with chunking on
    pub chunked_ttft_s: f64,
    /// worst resident gap during the monolithic admission (the stall)
    pub mono_gap_s: f64,
    /// long-prompt TTFT with chunking off
    pub mono_ttft_s: f64,
}

/// One chunked-vs-monolithic prefill interference cell (DESIGN.md §Chunked
/// prefill & the decode hot path): three residents decode steadily on a
/// single S3@AGX engine, then a long prompt is admitted. Returns the worst
/// resident inter-token gap whose later token lands inside the admission
/// window `(submit, done + pad]`, and the long request's TTFT.
fn prefill_cell(
    chunk_tokens: usize,
    long_input: usize,
    resident_out: usize,
    window_pad_s: f64,
    tag: &str,
) -> Result<(f64, f64)> {
    use crate::adapters::{AdapterStore, LoraShape};
    use crate::backend::sim::SimBackend;
    use crate::coordinator::{EdgeLoraEngine, EngineEvent};
    use crate::memory::AdapterMemoryManager;
    use crate::quant::QuantType;
    use crate::router::confidence::TaskModelRouter;
    use crate::util::time::VirtualClock;
    use crate::workload::{QosClass, TraceRequest};
    use std::sync::Arc;

    let dir = std::env::temp_dir().join(format!(
        "elra_prefill_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let shape = LoraShape { n_layers: 2, d_model: 16, rank: 4 };
    let store = AdapterStore::create(&dir, shape, QuantType::Q8_0)?;
    store.populate_synthetic(4)?;
    let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
    let backend = SimBackend::new(
        DeviceProfile::agx_orin(),
        ModelSetting::s3(),
        clock.clone(),
        4,
        4,
        None,
    )?
    .with_max_seq(2 * long_input);
    let memory = AdapterMemoryManager::new(Arc::new(store), 4, CachePolicy::Lru);
    let world = crate::router::confidence::TaskWorld::synthetic(4, 4, 1);
    let router = TaskModelRouter::new(world.acc.clone(), 0.95, 2);
    let mut e = EdgeLoraEngine::new(
        Box::new(backend),
        memory,
        Box::new(router),
        clock,
        ServerConfig {
            slots: 4,
            top_k: 3,
            cache_capacity: Some(4),
            engine: EngineKind::EdgeLoraNoAas,
            prefetch: false,
            prefill_chunk_tokens: chunk_tokens,
            ..ServerConfig::default()
        },
    );
    let req = |id: u64, input: usize, output: usize| TraceRequest {
        id,
        arrival_s: 0.0,
        true_adapter: 0,
        explicit_adapter: Some(0),
        input_tokens: input,
        output_tokens: output,
        qos: QosClass::Interactive,
        deadline_s: None,
    };
    let bus = e.events();
    let tap = bus.tap();
    let mut streams: std::collections::BTreeMap<u64, Vec<f64>> =
        std::collections::BTreeMap::new();
    e.begin();
    for a in 0..3u64 {
        e.submit(req(a + 1, 16, resident_out));
    }
    // warm until all three residents decode steadily (bounded)
    for _ in 0..80 {
        e.step()?;
        for (id, ev) in tap.try_iter() {
            if let EngineEvent::Token { t, .. } = ev {
                streams.entry(id).or_default().push(t);
            }
        }
        if (1..=3).all(|id| streams.get(&id).is_some_and(|s| s.len() >= 10)) {
            break;
        }
    }
    anyhow::ensure!(
        (1..=3).all(|id| streams.get(&id).is_some_and(|s| s.len() >= 10)),
        "residents failed to reach steady decode during warmup"
    );
    let t0 = e.local_now();
    e.submit(req(9, long_input, 1));
    let mut long_done = f64::NAN;
    let mut long_first = f64::NAN;
    while e.has_work() {
        e.step()?;
        for (id, ev) in tap.try_iter() {
            match ev {
                EngineEvent::Token { t, .. } => {
                    if id == 9 && long_first.is_nan() {
                        long_first = t;
                    }
                    streams.entry(id).or_default().push(t);
                }
                EngineEvent::Done { t } if id == 9 => long_done = t,
                _ => {}
            }
        }
    }
    anyhow::ensure!(long_done.is_finite(), "long request must complete");
    let t1 = long_done + window_pad_s;
    let mut worst = 0.0f64;
    for id in 1..=3u64 {
        for w in streams[&id].windows(2) {
            if w[1] > t0 && w[1] <= t1 {
                worst = worst.max(w[1] - w[0]);
            }
        }
    }
    anyhow::ensure!(worst > 0.0, "no resident tokens inside the window");
    let _ = std::fs::remove_dir_all(&dir);
    Ok((worst, long_first - t0))
}

/// Run the prefill cells (shared by `bench-table --table prefill` and the
/// prefill CI tier test). The chunk budget is sized from the timing model so
/// one chunk costs ≤15% of a 3-row decode step — the interleaved resident
/// gap then stays within the 1.2× flatness bound the engine test pins.
pub fn run_prefill_cells(tiny: bool) -> Result<PrefillRuns> {
    let tm = TimingModel::new(&DeviceProfile::agx_orin(), &ModelSetting::s3(), None);
    let baseline_itl_s = tm.decode_step_s(3);
    let chunk_tokens = ((0.15 * baseline_itl_s / tm.prefill_s(1)) as usize).max(1);
    let long_input = if tiny { 1024 } else { 4096 };
    // residents must outlive the whole chunked prefill (plus warmup)
    let resident_out = long_input.div_ceil(chunk_tokens) + 150;
    // window extends past Done: the final tick's resident tokens land just
    // after the long request's Done (prefill spends before decode in a tick)
    let pad = 2.5 * baseline_itl_s;
    let tag = if tiny { "tiny" } else { "full" };
    let (chunked_gap_s, chunked_ttft_s) = prefill_cell(
        chunk_tokens,
        long_input,
        resident_out,
        pad,
        &format!("{tag}_chunk"),
    )?;
    let (mono_gap_s, mono_ttft_s) =
        prefill_cell(0, long_input, resident_out, pad, &format!("{tag}_mono"))?;
    Ok(PrefillRuns {
        chunk_tokens,
        long_input,
        baseline_itl_s,
        chunked_gap_s,
        chunked_ttft_s,
        mono_gap_s,
        mono_ttft_s,
    })
}

/// Chunked-prefill interference: resident decode ITL while a long prompt is
/// admitted, chunking on vs off (DESIGN.md §Chunked prefill & the decode hot
/// path). Chunked holds the resident worst gap near the steady decode step;
/// monolithic stalls residents for the whole prefill. The TTFT column shows
/// the price: chunked first-token latency trails monolithic only by the
/// decode ticks it interleaved. `EDGELORA_PREFILL_TINY=1` shrinks the long
/// prompt — the offline CI prefill tier.
pub fn table_prefill() -> Result<String> {
    let tiny = std::env::var("EDGELORA_PREFILL_TINY").as_deref() == Ok("1");
    let r = run_prefill_cells(tiny)?;
    let row = |label: &str, chunk: String, gap: f64, ttft: f64| {
        vec![
            label.to_string(),
            chunk,
            format!("{:.4}", gap),
            format!("{:.2}x", gap / r.baseline_itl_s),
            format!("{:.3}", ttft),
        ]
    };
    let rows = vec![
        row(
            "chunked",
            r.chunk_tokens.to_string(),
            r.chunked_gap_s,
            r.chunked_ttft_s,
        ),
        row("monolithic", "off".to_string(), r.mono_gap_s, r.mono_ttft_s),
    ];
    Ok(format_table(
        &format!(
            "Prefill: resident ITL during a {}-token admission (S3@AGX, 3 residents)",
            r.long_input
        ),
        &["cell", "chunk toks", "worst gap (s)", "gap vs ITL", "long TTFT (s)"],
        &rows,
    ))
}

/// Shared spec for the distributed table: explicit adapters (prefix hints
/// need them), a hot set so same-adapter prompts recur, and 24–48-token
/// prompts whose ~3/4 system preamble spans whole 16-token KV pages — the
/// prefix cache's operating regime.
fn distributed_spec(tiny: bool) -> ExperimentSpec {
    ExperimentSpec {
        model: ModelSetting::s1(),
        device: DeviceProfile::agx_orin(),
        engine: EngineKind::EdgeLora,
        server: ServerConfig {
            engine: EngineKind::EdgeLora,
            slots: 4,
            ..ServerConfig::default()
        },
        workload: WorkloadConfig {
            n_adapters: 8,
            alpha: 1.0,
            rate: if tiny { 12.0 } else { 30.0 },
            cv: 1.0,
            input_range: (24, 48),
            output_range: (4, 12),
            duration_s: if tiny { 2.0 } else { 8.0 },
            auto_select_fraction: 0.0,
            hot_fraction: 0.5,
            hot_adapters: 2,
            seed: 0xd157,
            ..WorkloadConfig::default()
        },
        tdp_watts: None,
        cache_policy: CachePolicy::Lru,
        router_acc: 0.95,
    }
}

struct DistRow {
    label: String,
    completed: u64,
    throughput_rps: f64,
    p50_s: f64,
    p99_s: f64,
    prefix_hit_rate: f64,
    prefix_routes: u64,
    steals: u64,
    rehomed: u64,
}

impl DistRow {
    fn cells(&self) -> Vec<String> {
        vec![
            self.label.clone(),
            self.completed.to_string(),
            format!("{:.2}", self.throughput_rps),
            format!("{:.3}", self.p50_s),
            format!("{:.3}", self.p99_s),
            format!("{:.3}", self.prefix_hit_rate),
            self.prefix_routes.to_string(),
            self.steals.to_string(),
            self.rehomed.to_string(),
        ]
    }
}

/// Replay `trace` through a real socket fleet: thread-hosted
/// [`NodeServer`] workers on ephemeral loopback ports behind a
/// [`RemoteCluster`] router in this thread. The last `standby` workers
/// start unroutable; at request index `scale_out_at` the router activates
/// one (the mid-trace fleet-topology change the placement ablation
/// needs). Submissions are paced on the wall clock so scoreboard and
/// prefix-hash gossip flows between dispatches.
fn run_distributed_cell(
    cspec: &ClusterSpec,
    trace: &Trace,
    tag: &str,
    label: &str,
    standby: usize,
    scale_out_at: Option<usize>,
) -> Result<DistRow> {
    use crate::experiments::harness::mk_store;
    use crate::net::{NodeServer, RemoteCluster};

    let n = cspec.devices.len();
    let mut addrs = Vec::with_capacity(n);
    let mut stops = Vec::with_capacity(n);
    let mut joins = Vec::with_capacity(n);
    for shard in 0..n {
        let node = NodeServer::bind(cspec, shard, "127.0.0.1:0")?;
        addrs.push(node.local_addr()?.to_string());
        stops.push(node.stop_handle());
        joins.push(std::thread::spawn(move || node.serve()));
    }
    let store = mk_store(&cspec.base, tag)?;
    let mut rc = RemoteCluster::connect(
        &addrs,
        standby,
        cspec.cluster.clone(),
        store,
        cspec.base.workload.n_adapters,
    )?;
    // lint: allow(determinism, reason = "socket-fleet driver paces real TCP workers on the wall clock; results are measured, not replayed")
    let t0 = std::time::Instant::now();
    for (k, req) in trace.requests.iter().enumerate() {
        if scale_out_at == Some(k) {
            rc.scale_out();
        }
        while t0.elapsed().as_secs_f64() < req.arrival_s {
            rc.pump()?;
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let _ = rc.try_dispatch(req.clone())?;
    }
    rc.quiesce()?;
    let r = rc.report();
    rc.close();
    for s in &stops {
        s.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    for j in joins {
        j.join()
            .map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
    }
    Ok(DistRow {
        label: label.to_string(),
        completed: r.summary.requests,
        throughput_rps: r.summary.throughput_rps,
        p50_s: r.summary.p50_latency_s,
        p99_s: r.summary.p99_latency_s,
        prefix_hit_rate: r.prefix_hits as f64 / (r.prefix_lookups.max(1)) as f64,
        prefix_routes: r.prefix_overrides,
        steals: r.steals,
        rehomed: r.rehomed_total,
    })
}

/// The placement-ablation scenario: a fleet of `serving + 1` workers, the
/// last one standby, scaled out mid-trace. Post-scale-out, consistent
/// hashing re-homes part of the adapter population onto the cold new
/// shard; prefix-affinity placement instead keeps following the warm KV
/// chains it gossiped — that gap is the table's headline. Both cells run
/// hash dispatch with stealing off so the *only* difference is the hint.
fn scale_out_ablation_spec(tiny: bool) -> ExperimentSpec {
    let mut spec = distributed_spec(tiny);
    spec.workload.n_adapters = 12;
    spec.workload.rate = if tiny { 30.0 } else { 40.0 };
    spec.workload.duration_s = if tiny { 2.0 } else { 6.0 };
    spec.workload.alpha = 0.5;
    spec.workload.hot_fraction = 0.3;
    spec.workload.hot_adapters = 3;
    spec
}

fn scale_out_cluster(prefix_affinity: bool) -> ClusterConfig {
    ClusterConfig {
        policy: DispatchPolicy::HashOnly,
        stealing: false,
        prefix_affinity,
        ..ClusterConfig::default()
    }
}

/// Distributed serving (DESIGN.md §Distributed serving): the in-process
/// cluster vs the same fleet behind real sockets at N ∈ {2, 4}, on one
/// trace — the socket hop must not lose or duplicate work — plus the
/// prefix-affinity vs hash-only placement ablation under a mid-trace
/// scale-out (prefix hints keep same-prompt requests on the shard already
/// holding the cached KV chain, so the affinity cell's worker-side prefix
/// hit rate comes out strictly higher). `EDGELORA_NET_TINY=1` shrinks it
/// to N=2 on a short trace — the offline CI net tier.
pub fn table_distributed() -> Result<String> {
    let tiny = std::env::var("EDGELORA_NET_TINY").as_deref() == Ok("1");
    let spec = distributed_spec(tiny);
    let trace = generate(&spec.workload);
    let ns: &[usize] = if tiny { &[2] } else { &[2, 4] };
    let mut rows = Vec::new();
    for &n in ns {
        let cspec = ClusterSpec::homogeneous(spec.clone(), n, ClusterConfig::default());
        let mut cluster = build_cluster(&cspec, &format!("dist_local_{n}"))?;
        let r = cluster.run_trace(&trace)?;
        rows.push(
            DistRow {
                label: format!("in-process N={n}"),
                completed: r.summary.requests,
                throughput_rps: r.summary.throughput_rps,
                p50_s: r.summary.p50_latency_s,
                p99_s: r.summary.p99_latency_s,
                prefix_hit_rate: r.summary.prefix_hit_rate,
                prefix_routes: r.prefix_overrides,
                steals: r.steals,
                rehomed: 0,
            }
            .cells(),
        );
        let sock = run_distributed_cell(
            &cspec,
            &trace,
            &format!("dist_sock_{n}"),
            &format!("sockets N={n}"),
            0,
            None,
        )?;
        rows.push(sock.cells());
    }
    // placement ablation: 2 serving + 1 standby activated at the trace
    // midpoint; same trace, same ring, stealing off — the cells differ
    // only in whether the router follows gossiped prefix hashes
    let aspec = scale_out_ablation_spec(tiny);
    let atrace = generate(&aspec.workload);
    let midpoint = atrace.len() / 2;
    for (affinity, label, tag) in [
        (true, "scale-out +1 (prefix-affinity)", "dist_so_aff"),
        (false, "scale-out +1 (hash-only)", "dist_so_hash"),
    ] {
        let cspec = ClusterSpec::homogeneous(aspec.clone(), 3, scale_out_cluster(affinity));
        rows.push(
            run_distributed_cell(&cspec, &atrace, tag, label, 1, Some(midpoint))?.cells(),
        );
    }
    Ok(format_table(
        "Distributed: in-process vs socket fleet, prefix-affinity vs hash-only (S1@AGX)",
        &[
            "cell",
            "completed",
            "thpt (req/s)",
            "p50 (s)",
            "p99 (s)",
            "prefix hit",
            "prefix routes",
            "steals",
            "rehomed",
        ],
        &rows,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Table runners are exercised end-to-end by the bench harness; here we
    // spot-check the fastest ones to keep `cargo test` snappy.

    #[test]
    fn table12_runs_and_router_wins() {
        let out = table12().unwrap();
        assert!(out.contains("Adapter Router (Our Approach)"));
        assert!(out.contains("MMLU-PRO"));
    }

    #[test]
    fn elasticity_autoscale_beats_fixed_floor_and_chaos_conserves() {
        let r = run_elasticity_cells(true).unwrap();
        // conservation: every offered request completes exactly once in all
        // three runs (the shared recorder counts completions)
        assert_eq!(r.fixed.summary.requests as usize, r.offered);
        assert_eq!(r.autoscaled.summary.requests as usize, r.offered);
        assert_eq!(r.chaos.summary.requests as usize, r.offered);
        // the autoscaler actually flexed: spawned under the spike, drained
        // back to the floor on the quiet tail
        assert!(r.autoscaled.spawns >= 1, "no spawn under the spike");
        assert!(r.autoscaled.peak_serving >= 2);
        assert_eq!(
            r.autoscaled.final_serving, r.floor,
            "fleet did not return to the floor"
        );
        assert_eq!(r.fixed.spawns, 0, "autoscale-off fleet must stay fixed");
        // and it beat the fixed floor fleet on tail latency + SLO
        assert!(
            r.autoscaled.summary.slo_attainment > r.fixed.summary.slo_attainment,
            "autoscale SLO {} <= fixed {}",
            r.autoscaled.summary.slo_attainment,
            r.fixed.summary.slo_attainment
        );
        assert!(
            r.autoscaled.summary.p99_latency_s < r.fixed.summary.p99_latency_s,
            "autoscale p99 {} >= fixed {}",
            r.autoscaled.summary.p99_latency_s,
            r.fixed.summary.p99_latency_s
        );
        // chaos cell: the killed shard was healed back into service
        assert_eq!(r.chaos.restarts.iter().sum::<u64>(), 1);
        assert!(
            r.chaos
                .replica_states
                .iter()
                .all(|s| *s == "alive" || *s == "degraded"),
            "healed fleet should be serving again: {:?}",
            r.chaos.replica_states
        );
    }

    #[test]
    fn priority_scheduling_holds_interactive_p99_under_overload() {
        let r = run_slo_cells(true).unwrap();
        let on = &r.qos_on.summary;
        let off = &r.qos_off.summary;
        // conservation under shedding: every offered request terminates
        // exactly once — completed or shed, never both, never neither
        assert_eq!(
            on.requests + on.shed_rate_limit + on.shed_deadline,
            r.offered_overload as u64,
            "QoS run must conserve requests"
        );
        assert_eq!(
            off.requests, r.offered_overload as u64,
            "class-blind ablation must not shed"
        );
        assert_eq!(
            r.unloaded.summary.requests + r.unloaded.summary.shed_deadline,
            r.offered_unloaded as u64
        );
        // both classes complete work in the QoS overload run — priority must
        // not starve Batch outright (WFQ floor)
        assert!(on.interactive.completed > 0, "no interactive completions");
        assert!(on.batch.completed > 0, "batch starved under QoS");
        // the headline: priority scheduling holds the interactive tail under
        // ~3x overload, while the class-blind FIFO ablation lets it blow up
        assert!(
            on.interactive.p99_ttft_s < off.interactive.p99_ttft_s,
            "qos-on interactive p99 {} must beat no-qos {}",
            on.interactive.p99_ttft_s,
            off.interactive.p99_ttft_s
        );
        // and Batch is the class absorbing the pressure
        assert!(
            on.batch.p99_ttft_s > on.interactive.p99_ttft_s,
            "batch p99 {} should exceed interactive p99 {} under overload",
            on.batch.p99_ttft_s,
            on.interactive.p99_ttft_s
        );
        // interactive SLO attainment under overload stays above the ablation's
        assert!(
            on.interactive.slo_attainment >= off.interactive.slo_attainment,
            "qos-on interactive SLO {} < no-qos {}",
            on.interactive.slo_attainment,
            off.interactive.slo_attainment
        );
    }

    #[test]
    fn chunked_prefill_table_cells_hold_the_flatness_bound() {
        let r = run_prefill_cells(true).unwrap();
        // the headline the table exists to show: chunked admission keeps the
        // resident worst gap within the flatness bound the engine test pins,
        // monolithic admission stalls residents for the whole prefill
        assert!(
            r.chunked_gap_s <= 1.2 * r.baseline_itl_s,
            "chunked gap {:.4}s vs baseline ITL {:.4}s",
            r.chunked_gap_s,
            r.baseline_itl_s
        );
        assert!(
            r.mono_gap_s > 3.0 * r.baseline_itl_s,
            "monolithic gap {:.4}s should dwarf baseline {:.4}s",
            r.mono_gap_s,
            r.baseline_itl_s
        );
        // chunking trades a bounded amount of TTFT for the flat tail
        assert!(r.chunked_ttft_s >= r.mono_ttft_s);
    }

    #[test]
    fn distributed_scale_out_prefix_affinity_beats_hash_only() {
        let spec = scale_out_ablation_spec(true);
        let trace = generate(&spec.workload);
        let offered = trace.len() as u64;
        let midpoint = trace.len() / 2;
        let aff_spec = ClusterSpec::homogeneous(spec.clone(), 3, scale_out_cluster(true));
        let aff = run_distributed_cell(
            &aff_spec,
            &trace,
            "dist_t_aff",
            "affinity",
            1,
            Some(midpoint),
        )
        .unwrap();
        let hash_spec = ClusterSpec::homogeneous(spec.clone(), 3, scale_out_cluster(false));
        let ho = run_distributed_cell(
            &hash_spec,
            &trace,
            "dist_t_hash",
            "hash-only",
            1,
            Some(midpoint),
        )
        .unwrap();
        // zero loss, zero duplication across the socket hop in both cells
        assert_eq!(aff.completed, offered, "affinity cell lost/duplicated work");
        assert_eq!(ho.completed, offered, "hash-only cell lost/duplicated work");
        // the table's headline: after the scale-out re-homes part of the
        // adapter population onto the cold new shard, following the warm
        // KV chains must yield a strictly higher worker-side hit rate
        assert!(
            aff.prefix_hit_rate > ho.prefix_hit_rate,
            "prefix affinity hit rate {:.3} must beat hash-only {:.3}",
            aff.prefix_hit_rate,
            ho.prefix_hit_rate
        );
        // and the router actually used the hints to get there
        assert!(aff.prefix_routes > 0, "no prefix-hash routes taken");
        assert_eq!(ho.prefix_routes, 0, "ablation must not take prefix routes");
    }

    #[test]
    fn table14_slots_monotone() {
        std::env::set_var("EDGELORA_FULL_TRACES", "0");
        let out = table14().unwrap();
        assert!(out.contains("slots"));
        // at least 4 data rows
        assert!(out.lines().filter(|l| !l.trim().is_empty()).count() >= 6);
    }
}
