//! Pass 2 — panic freedom (DESIGN.md §Static analysis).
//!
//! `net/` and `server/` parse attacker-controlled bytes and hold the locks
//! every connection shares: a panic there either kills the process or
//! poisons a mutex for everyone. Non-test code in those trees must not
//! call `.unwrap()` / `.expect(...)` or invoke `panic!` / `unreachable!` /
//! `todo!` / `unimplemented!` — errors are values (`WireError`, HTTP 4xx/
//! 5xx), and lock poisoning is recovered with
//! `lock().unwrap_or_else(PoisonError::into_inner)`.
//!
//! `#[cfg(test)]` regions are exempt: a test that unwraps is asserting.

use super::lexer::in_test;
use super::{FileScan, Pass, Violation};

fn in_scope(path: &str) -> bool {
    path.starts_with("net/") || path.starts_with("server/")
}

pub fn check(scan: &FileScan, out: &mut Vec<Violation>) {
    if !in_scope(scan.path) {
        return;
    }
    let toks = &scan.toks;
    for i in 0..toks.len() {
        let t = &toks[i];
        if in_test(&scan.tests, t.line) {
            continue;
        }
        let next = toks.get(i + 1).map(|t| t.text);
        let is_method_call = |name: &str| {
            t.text == "."
                && next == Some(name)
                && toks.get(i + 2).map(|t| t.text) == Some("(")
        };
        if is_method_call("unwrap") || is_method_call("expect") {
            out.push(Violation {
                pass: Pass::Panics,
                file: scan.path.to_string(),
                line: toks[i + 1].line,
                msg: format!(
                    "`.{}()` on a request-handling path — return a typed error instead",
                    toks[i + 1].text
                ),
            });
        } else if matches!(t.text, "panic" | "unreachable" | "todo" | "unimplemented")
            && next == Some("!")
        {
            out.push(Violation {
                pass: Pass::Panics,
                file: scan.path.to_string(),
                line: t.line,
                msg: format!("`{}!` in serving code — a peer must never be able to reach it", t.text),
            });
        }
    }
}
