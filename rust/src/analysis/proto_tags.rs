//! Pass 5 — protocol exhaustiveness (DESIGN.md §Static analysis).
//!
//! The wire ABI in `net/proto.rs` is append-only: every frame tag (`T_*`)
//! and event tag (`E_*`) constant must be consumed by both sides of the
//! codec, or a frame kind exists that one side can produce and the other
//! cannot parse. Encode-side functions are those named `encode*`/`put_*`;
//! decode-side are `decode*`/`read_*`. A tag constant missing from either
//! side's token set is an error at its declaration line.

use std::collections::BTreeSet;

use super::{FileScan, Pass, Violation};

pub const PROTO_FILE: &str = "net/proto.rs";

/// Check the protocol file; returns how many tag constants were found (the
/// caller errors on a full-tree run that found none — the pass must not
/// silently rot if the file moves).
pub fn check(scan: &FileScan, out: &mut Vec<Violation>) -> usize {
    if scan.path != PROTO_FILE {
        return 0;
    }
    let toks = &scan.toks;

    let mut tags: Vec<(&str, u32)> = Vec::new();
    for i in 0..toks.len() {
        if toks[i].text == "const" {
            if let Some(name) = toks.get(i + 1) {
                if name.text.starts_with("T_") || name.text.starts_with("E_") {
                    tags.push((name.text, name.line));
                }
            }
        }
    }

    let mut encode_side: BTreeSet<&str> = BTreeSet::new();
    let mut decode_side: BTreeSet<&str> = BTreeSet::new();
    for span in &scan.fns {
        let set = if span.name.starts_with("encode") || span.name.starts_with("put") {
            &mut encode_side
        } else if span.name.starts_with("decode") || span.name.starts_with("read") {
            &mut decode_side
        } else {
            continue;
        };
        for t in &toks[span.body.0..span.body.1.min(toks.len())] {
            set.insert(t.text);
        }
    }

    for (tag, line) in &tags {
        for (side, set) in [("encode", &encode_side), ("decode", &decode_side)] {
            if !set.contains(tag) {
                out.push(Violation {
                    pass: Pass::Proto,
                    file: scan.path.to_string(),
                    line: *line,
                    msg: format!(
                        "wire tag `{tag}` never appears on the {side} side of the codec — the match is not exhaustive"
                    ),
                });
            }
        }
    }
    tags.len()
}
