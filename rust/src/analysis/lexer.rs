//! Token scanner for the invariant linter (DESIGN.md §Static analysis).
//!
//! Hand-rolled and std-only: Rust source → a flat token stream with line
//! numbers, plus the three structural facts every pass needs — where
//! `#[cfg(test)]` regions begin and end, where each `fn` body lives, and
//! which lines carry `// lint: allow(...)` directives.
//!
//! This is deliberately *not* a parser. Comments, string/char literals and
//! raw strings are skipped (so a forbidden name inside a doc comment or a
//! log message never fires), identifiers and numbers come out as single
//! tokens, and every other byte of punctuation is its own token. All five
//! passes work on short token patterns (`Instant :: now`, `. unwrap (`,
//! `ident . lock (`) over this stream, which keeps the analyzer honest
//! about what it can see: lexical facts, checked exactly.

/// One token: its text slice and the 1-based source line it starts on.
#[derive(Debug, Clone, Copy)]
pub struct Tok<'a> {
    pub text: &'a str,
    pub line: u32,
}

/// Is this token an identifier (or keyword — the lexer does not
/// distinguish)?
pub fn is_ident(t: &str) -> bool {
    let mut chars = t.chars();
    match chars.next() {
        Some(c) if c == '_' || c.is_ascii_alphabetic() => {}
        _ => return false,
    }
    chars.all(|c| c == '_' || c.is_ascii_alphanumeric())
}

/// Lex `src` into tokens, skipping comments and all literal forms.
pub fn lex(src: &str) -> Vec<Tok<'_>> {
    let b = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if b.get(i + 1) == Some(&b'/') => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                // block comments nest in Rust
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => i = skip_string(b, i, &mut line),
            b'\'' => i = skip_char_or_lifetime(b, i, &mut line),
            _ if c == b'_' || c.is_ascii_alphabetic() => {
                // r"..." / r#"..."# / b"..." / br#"..."# / b'x' are literals
                // dressed as identifier starts — detect before lexing an
                // ident
                if let Some(next) = literal_prefix(b, i, &mut line) {
                    i = next;
                    continue;
                }
                let start = i;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
                toks.push(Tok { text: &src[start..i], line });
            }
            _ if c.is_ascii_digit() => {
                let start = i;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
                toks.push(Tok { text: &src[start..i], line });
            }
            _ => {
                // non-ASCII bytes (only legal inside the literals and
                // comments already skipped) are dropped rather than sliced
                // mid-codepoint
                if let Some(t) = src.get(i..i + 1) {
                    toks.push(Tok { text: t, line });
                }
                i += 1;
            }
        }
    }
    toks
}

/// Skip a `"..."` string (escapes honored), returning the index after the
/// closing quote. `i` points at the opening quote.
fn skip_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            b'"' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// Skip a raw string `"..."###` terminated by a quote followed by `hashes`
/// `#`s. `i` points just past the opening quote.
fn skip_raw_string(b: &[u8], mut i: usize, hashes: usize, line: &mut u32) -> usize {
    while i < b.len() {
        if b[i] == b'\n' {
            *line += 1;
            i += 1;
        } else if b[i] == b'"' && b[i + 1..].iter().take(hashes).filter(|&&c| c == b'#').count() == hashes {
            return i + 1 + hashes;
        } else {
            i += 1;
        }
    }
    i
}

/// `'` starts either a char literal or a lifetime; only the former must be
/// skipped as opaque text (a lifetime named `'collect` would be a cruel
/// false positive, so lifetimes are consumed too, emitting nothing).
fn skip_char_or_lifetime(b: &[u8], i: usize, _line: &mut u32) -> usize {
    match b.get(i + 1) {
        Some(b'\\') => {
            // escaped char literal: '\n', '\'', '\u{...}'
            let mut j = i + 2;
            if b.get(j) == Some(&b'u') && b.get(j + 1) == Some(&b'{') {
                j += 2;
                while j < b.len() && b[j] != b'}' {
                    j += 1;
                }
            }
            while j < b.len() && b[j] != b'\'' {
                j += 1;
            }
            j + 1
        }
        Some(&c) if c == b'_' || c.is_ascii_alphanumeric() => {
            // 'x' (closing quote right after one char) is a literal;
            // 'ident with no closing quote is a lifetime
            if b.get(i + 2) == Some(&b'\'') {
                i + 3
            } else {
                let mut j = i + 1;
                while j < b.len() && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                    j += 1;
                }
                j
            }
        }
        // punctuation char literals ('{', '"', ',', …): a spurious brace
        // or quote token here would desync brace matching and string
        // skipping for the rest of the file, so recognize any single byte
        // closed by a quote at i+2
        _ if b.get(i + 2) == Some(&b'\'') => i + 3,
        _ => i + 1,
    }
}

/// If position `i` starts a literal spelled with a letter prefix (`r"`,
/// `r#"`, `b"`, `br"`, `br#"`, `b'`), skip it and return the next index.
fn literal_prefix(b: &[u8], i: usize, line: &mut u32) -> Option<usize> {
    let after = match (b[i], b.get(i + 1)) {
        (b'b', Some(b'\'')) => return Some(skip_char_or_lifetime(b, i + 1, line)),
        (b'b', Some(b'"')) => return Some(skip_string(b, i + 1, line)),
        (b'b', Some(b'r')) => i + 2,
        (b'r', _) => i + 1,
        _ => return None,
    };
    let mut j = after;
    let mut hashes = 0usize;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some(skip_raw_string(b, j + 1, hashes, line))
    } else {
        None // r#ident (raw identifier) or a plain ident starting r/b
    }
}

/// Inclusive line spans covered by a `#[cfg(test)]` or `#[test]`
/// attribute: the attribute line through the closing brace of the item it
/// decorates (or its `;` for brace-less items). Only the literal
/// spellings are recognized — `cfg(not(test))` and friends are not test
/// regions.
pub fn test_regions(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 3 < toks.len() {
        let after_attr = if toks[i].text == "#"
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks.get(i + 3).map(|t| t.text) == Some("(")
            && toks.get(i + 4).map(|t| t.text) == Some("test")
            && toks.get(i + 5).map(|t| t.text) == Some(")")
            && toks.get(i + 6).map(|t| t.text) == Some("]")
        {
            i + 7
        } else if toks[i].text == "#"
            && toks[i + 1].text == "["
            && toks[i + 2].text == "test"
            && toks[i + 3].text == "]"
        {
            i + 4
        } else {
            i += 1;
            continue;
        };
        let start_line = toks[i].line;
        let mut end_line = start_line;
        let mut k = after_attr;
        while k < toks.len() {
            match toks[k].text {
                "{" => {
                    let mut depth = 1usize;
                    k += 1;
                    while k < toks.len() && depth > 0 {
                        match toks[k].text {
                            "{" => depth += 1,
                            "}" => depth -= 1,
                            _ => {}
                        }
                        end_line = toks[k].line;
                        k += 1;
                    }
                    break;
                }
                ";" => {
                    end_line = toks[k].line;
                    break;
                }
                _ => k += 1,
            }
        }
        out.push((start_line, end_line));
        i = k.max(after_attr);
    }
    out
}

/// Is `line` inside any of the given test regions?
pub fn in_test(regions: &[(u32, u32)], line: u32) -> bool {
    regions.iter().any(|&(a, b)| line >= a && line <= b)
}

/// One function's name and body token range (`body.0` is the opening `{`,
/// `body.1` is one past the closing `}`). Nested items appear both inside
/// their parent's range and as their own span.
#[derive(Debug, Clone)]
pub struct FnSpan<'a> {
    pub name: &'a str,
    pub line: u32,
    pub body: (usize, usize),
}

/// Find every `fn name ... { body }` by token scan. Trait-method
/// declarations (signature ending in `;`) have no body and are skipped.
pub fn fn_spans<'a>(toks: &[Tok<'a>]) -> Vec<FnSpan<'a>> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text != "fn" || i + 1 >= toks.len() || !is_ident(toks[i + 1].text) {
            i += 1;
            continue;
        }
        let name = toks[i + 1].text;
        let line = toks[i].line;
        // the body `{` is the first brace at paren depth 0 after the
        // signature; a `;` there instead means declaration-only
        let mut j = i + 2;
        let mut paren = 0i32;
        let mut body_start = None;
        while j < toks.len() {
            match toks[j].text {
                "(" => paren += 1,
                ")" => paren -= 1,
                "{" if paren == 0 => {
                    body_start = Some(j);
                    break;
                }
                ";" if paren == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let Some(start) = body_start else {
            i += 2;
            continue;
        };
        let mut depth = 1usize;
        j = start + 1;
        while j < toks.len() && depth > 0 {
            match toks[j].text {
                "{" => depth += 1,
                "}" => depth -= 1,
                _ => {}
            }
            j += 1;
        }
        out.push(FnSpan { name, line, body: (start, j) });
        i += 2;
    }
    out
}

/// A `// lint: allow(<pass>, reason = "...")` directive. It suppresses a
/// matching pass's violation on its own line or the line below — but only
/// when it carries a nonempty reason string.
#[derive(Debug, Clone)]
pub struct Directive {
    pub line: u32,
    pub pass: String,
    pub has_reason: bool,
}

/// Collect allow directives by raw line scan (they live in comments, which
/// the lexer drops).
pub fn directives(src: &str) -> Vec<Directive> {
    let mut out = Vec::new();
    for (idx, l) in src.lines().enumerate() {
        let Some(comment) = l.find("//") else { continue };
        let Some(p) = l[comment..].find("lint: allow(") else { continue };
        let rest = &l[comment + p + "lint: allow(".len()..];
        let Some(close) = rest.rfind(')') else { continue };
        let inner = &rest[..close];
        let (pass, tail) = match inner.split_once(',') {
            Some((p, t)) => (p.trim(), t.trim()),
            None => (inner.trim(), ""),
        };
        let has_reason = tail
            .strip_prefix("reason")
            .map(|t| t.trim_start())
            .and_then(|t| t.strip_prefix('='))
            .map(|t| t.trim())
            .is_some_and(|t| t.len() > 2 && t.starts_with('"'));
        out.push(Directive {
            line: (idx + 1) as u32,
            pass: pass.to_string(),
            has_reason,
        });
    }
    out
}
