//! Pass 1 — determinism (DESIGN.md §Static analysis).
//!
//! Replay-deterministic modules are the ones whose acceptance results are
//! pinned bit-identical (rehomed streams, socket vs in-process replay):
//! they must never read a wall clock (`Instant::now`, `SystemTime`) or
//! iterate an unordered map (`HashMap`, `HashSet`). Wall clocks are legal
//! only in the sanctioned files (`util/time.rs`, `net/`, `server/http.rs`,
//! `main.rs`); ordered state lives in `BTreeMap`/`BTreeSet`.
//!
//! `net/router.rs` is a special case: it legitimately runs on wall clocks
//! (link health is real time) but its routing state must still be ordered,
//! so it is in the map-ban scope only.

use super::lexer::in_test;
use super::{FileScan, Pass, Violation};

/// Modules whose replay must be bit-identical. A trailing `/` means the
/// whole directory; otherwise the path must match exactly.
pub const DETERMINISTIC_MODULES: &[&str] = &[
    "coordinator/",
    "cluster/",
    "memory/",
    "experiments/",
    "backend/sim.rs",
];

/// Files outside the deterministic set whose *maps* must still be ordered
/// (iteration order feeds routing/rehoming decisions), while wall clocks
/// remain legal.
pub const MAP_ONLY_MODULES: &[&str] = &["net/router.rs"];

fn in_scope(path: &str, manifest: &[&str]) -> bool {
    manifest.iter().any(|m| {
        if let Some(dir) = m.strip_suffix('/') {
            path.starts_with(dir) && path.as_bytes().get(dir.len()) == Some(&b'/')
        } else {
            path == *m
        }
    })
}

pub fn check(scan: &FileScan, out: &mut Vec<Violation>) {
    let full = in_scope(scan.path, DETERMINISTIC_MODULES);
    let maps_only = in_scope(scan.path, MAP_ONLY_MODULES);
    if !full && !maps_only {
        return;
    }
    let toks = &scan.toks;
    for (i, t) in toks.iter().enumerate() {
        if in_test(&scan.tests, t.line) {
            continue;
        }
        match t.text {
            "HashMap" | "HashSet" => out.push(Violation {
                pass: Pass::Determinism,
                file: scan.path.to_string(),
                line: t.line,
                msg: format!(
                    "unordered `{}` in a replay-deterministic module — use BTreeMap/BTreeSet or sorted iteration",
                    t.text
                ),
            }),
            "SystemTime" if full => out.push(Violation {
                pass: Pass::Determinism,
                file: scan.path.to_string(),
                line: t.line,
                msg: "wall clock `SystemTime` in a replay-deterministic module (clocks live in util/time.rs, net/, server/http.rs, main.rs)".to_string(),
            }),
            "Instant"
                if full
                    && toks.get(i + 1).map(|t| t.text) == Some(":")
                    && toks.get(i + 2).map(|t| t.text) == Some(":")
                    && toks.get(i + 3).map(|t| t.text) == Some("now") =>
            {
                out.push(Violation {
                    pass: Pass::Determinism,
                    file: scan.path.to_string(),
                    line: t.line,
                    msg: "wall clock `Instant::now` in a replay-deterministic module (clocks live in util/time.rs, net/, server/http.rs, main.rs)".to_string(),
                })
            }
            _ => {}
        }
    }
}
