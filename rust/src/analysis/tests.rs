//! Fixture tests for the linter itself: for every pass, a snippet that
//! must fire and a minimal fix of the same snippet that must not, plus the
//! allow-directive semantics and the self-clean gate over the repo's own
//! source. Fixtures are lexed, not compiled — they only need to be
//! plausible tokens, so each one stays tiny.

use super::lexer;
use super::{lint_files, run_lint, LintReport, Pass, MAX_ALLOWS};

fn lint_one(path: &str, src: &str) -> LintReport {
    lint_files(&[(path.to_string(), src.to_string())], false)
}

fn passes(r: &LintReport) -> Vec<Pass> {
    r.violations.iter().map(|v| v.pass).collect()
}

// ── pass 1: determinism ────────────────────────────────────────────────────

#[test]
fn determinism_flags_hashmap_in_deterministic_module() {
    let r = lint_one(
        "coordinator/fake.rs",
        "use std::collections::HashMap;\nfn f() { let m: HashMap<u64, u64> = HashMap::new(); }\n",
    );
    assert_eq!(r.violations.len(), 3, "{}", r.render());
    assert!(passes(&r).iter().all(|&p| p == Pass::Determinism));
}

#[test]
fn determinism_accepts_btreemap() {
    let r = lint_one(
        "coordinator/fake.rs",
        "use std::collections::BTreeMap;\nfn f() { let m: BTreeMap<u64, u64> = BTreeMap::new(); }\n",
    );
    assert!(r.clean(), "{}", r.render());
}

#[test]
fn determinism_flags_wall_clock() {
    let r = lint_one(
        "cluster/fake.rs",
        "fn f() -> bool { let t0 = std::time::Instant::now(); t0.elapsed().as_secs() > 1 }\n",
    );
    assert_eq!(r.violations.len(), 1, "{}", r.render());
    assert_eq!(r.violations[0].pass, Pass::Determinism);
    let r = lint_one("memory/fake.rs", "fn f() { let t = SystemTime::now(); }\n");
    assert_eq!(r.violations.len(), 1, "{}", r.render());
}

#[test]
fn determinism_accepts_injected_clock() {
    let r = lint_one(
        "cluster/fake.rs",
        "fn f(clock: &VirtualClock) -> f64 { clock.now_s() }\n",
    );
    assert!(r.clean(), "{}", r.render());
}

#[test]
fn determinism_router_is_map_ban_only() {
    // net/router.rs legitimately runs on wall clocks (link health is real
    // time) but its routing state must still be ordered
    let clock = "fn f() -> Instant { Instant::now() }\n";
    assert!(lint_one("net/router.rs", clock).clean());
    let map = "fn f() { let m = HashMap::new(); }\n";
    assert_eq!(lint_one("net/router.rs", map).violations.len(), 1);
}

#[test]
fn determinism_exempts_cfg_test_regions() {
    let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn g() { let m = HashMap::new(); }\n}\n";
    assert!(lint_one("coordinator/fake.rs", src).clean());
}

#[test]
fn determinism_skips_comments_and_strings() {
    let src = "// a HashMap would be wrong here\nfn f() { let s = \"HashMap\"; let r = r#\"SystemTime HashSet\"#; }\n";
    assert!(lint_one("memory/fake.rs", src).clean());
}

// ── pass 2: panic freedom ──────────────────────────────────────────────────

#[test]
fn panics_flags_unwrap_and_macros_in_serving_code() {
    let r = lint_one(
        "net/fake.rs",
        "fn f(x: Option<u32>) -> u32 { x.unwrap() }\nfn g(y: Result<u32, E>) -> u32 { y.expect(\"y\") }\nfn h() { panic!(\"boom\"); }\nfn k() { unreachable!() }\n",
    );
    assert_eq!(r.violations.len(), 4, "{}", r.render());
    assert!(passes(&r).iter().all(|&p| p == Pass::Panics));
}

#[test]
fn panics_accepts_typed_errors_and_poison_recovery() {
    let r = lint_one(
        "server/fake.rs",
        "fn f(m: &Mutex<u32>) -> u32 { *m.lock().unwrap_or_else(std::sync::PoisonError::into_inner) }\nfn g(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n",
    );
    assert!(r.clean(), "{}", r.render());
}

#[test]
fn panics_exempts_tests_and_other_modules() {
    let test_src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Some(1).unwrap(); }\n}\n";
    assert!(lint_one("server/fake.rs", test_src).clean());
    // unwrap outside net/ + server/ is the other passes' business, not this
    assert!(lint_one("coordinator/fake.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n").clean());
}

// ── pass 3: hot-path allocation ────────────────────────────────────────────

#[test]
fn hotpath_flags_allocation_in_manifested_fn() {
    let r = lint_one(
        "quant/q4_0.rs",
        "pub fn dequantize_into(bytes: &[u8], out: &mut [f32]) { let v = bytes.to_vec(); let s = format!(\"{}\", v.len()); }\n",
    );
    assert_eq!(r.violations.len(), 2, "{}", r.render());
    assert!(passes(&r).iter().all(|&p| p == Pass::Hotpath));
}

#[test]
fn hotpath_accepts_clean_body_and_ignores_unmanifested_fns() {
    let clean = "pub fn dequantize_into(bytes: &[u8], out: &mut [f32]) { for (i, b) in bytes.iter().enumerate() { out[i] = *b as f32; } }\npub fn quantize(vals: &[f32]) -> Vec<u8> { vals.iter().map(|v| *v as u8).collect() }\n";
    assert!(lint_one("quant/q4_0.rs", clean).clean());
    // with_capacity is deliberately legal (bounded up-front reserve)
    let reserve = "pub fn decode(buf: &[u8]) -> Vec<u8> { let mut out = Vec::with_capacity(buf.len()); out }\n";
    assert!(lint_one("net/proto.rs", reserve).clean());
}

// ── pass 4: lock order ─────────────────────────────────────────────────────

#[test]
fn locks_flags_inverted_acquisition_order() {
    let a = "fn f(s: &S) { let _a = s.alpha.lock(); let _b = s.beta.lock(); }\n";
    let b = "fn g(s: &S) { let _b = s.beta.lock(); let _a = s.alpha.lock(); }\n";
    let r = lint_files(
        &[
            ("util/a.rs".to_string(), a.to_string()),
            ("util/b.rs".to_string(), b.to_string()),
        ],
        false,
    );
    assert_eq!(r.violations.len(), 1, "{}", r.render());
    assert_eq!(r.violations[0].pass, Pass::Locks);
    assert!(r.violations[0].msg.contains("alpha"), "{}", r.violations[0].msg);
}

#[test]
fn locks_accepts_consistent_order() {
    let a = "fn f(s: &S) { let _a = s.alpha.lock(); let _b = s.beta.lock(); }\n";
    let b = "fn g(s: &S) { let _a = s.alpha.lock(); drop(_a); let _b = s.beta.lock(); }\n";
    let r = lint_files(
        &[
            ("util/a.rs".to_string(), a.to_string()),
            ("util/b.rs".to_string(), b.to_string()),
        ],
        false,
    );
    assert!(r.clean(), "{}", r.render());
}

#[test]
fn locks_must_not_contradict_declared_nestings() {
    // `subs -> state` is a declared cross-function hold; code taking them
    // in the opposite order in one function closes a cycle
    let src = "fn f(s: &S) { let _st = s.state.lock(); let _su = s.subs.lock(); }\n";
    let r = lint_one("coordinator/fake.rs", src);
    assert_eq!(r.violations.len(), 1, "{}", r.render());
    assert_eq!(r.violations[0].pass, Pass::Locks);
}

// ── pass 5: protocol exhaustiveness ────────────────────────────────────────

#[test]
fn proto_flags_tag_missing_from_one_side() {
    let src = "const T_PING: u8 = 1;\nconst T_PONG: u8 = 2;\nfn encode_into(out: &mut Vec<u8>, f: &Frame) { match f { Frame::Ping => put_u8(out, T_PING), Frame::Pong => put_u8(out, T_PONG) } }\nfn decode(buf: &[u8]) -> u8 { match buf[0] { T_PING => 1, t => t } }\n";
    let r = lint_one("net/proto.rs", src);
    assert_eq!(r.violations.len(), 1, "{}", r.render());
    assert_eq!(r.violations[0].pass, Pass::Proto);
    assert!(r.violations[0].msg.contains("T_PONG"));
}

#[test]
fn proto_accepts_tags_used_on_both_sides() {
    let src = "const T_PING: u8 = 1;\nfn encode_into(out: &mut Vec<u8>) { put_u8(out, T_PING); }\nfn decode(buf: &[u8]) -> u8 { match buf[0] { T_PING => 1, t => t } }\n";
    assert!(lint_one("net/proto.rs", src).clean());
}

// ── allow directives ───────────────────────────────────────────────────────

#[test]
fn reasoned_allow_suppresses_on_own_and_next_line() {
    let above = "// lint: allow(determinism, reason = \"fixture\")\nuse std::collections::HashMap;\n";
    let r = lint_one("memory/fake.rs", above);
    assert!(r.clean(), "{}", r.render());
    assert_eq!((r.suppressed, r.allows_used), (1, 1));
    let same = "use std::collections::HashMap; // lint: allow(determinism, reason = \"fixture\")\n";
    assert!(lint_one("memory/fake.rs", same).clean());
}

#[test]
fn allow_without_reason_suppresses_nothing() {
    let src = "// lint: allow(determinism)\nuse std::collections::HashMap;\n";
    let r = lint_one("memory/fake.rs", src);
    assert_eq!(r.violations.len(), 1, "{}", r.render());
    assert_eq!(r.allows_used, 0);
}

#[test]
fn allow_for_the_wrong_pass_suppresses_nothing() {
    let src = "// lint: allow(panics, reason = \"wrong pass\")\nuse std::collections::HashMap;\n";
    let r = lint_one("memory/fake.rs", src);
    assert_eq!(r.violations.len(), 1, "{}", r.render());
}

#[test]
fn allow_budget_is_enforced() {
    let mut src = String::new();
    for _ in 0..MAX_ALLOWS {
        src.push_str("use std::collections::HashMap; // lint: allow(determinism, reason = \"budget fixture\")\n");
    }
    let r = lint_one("memory/fake.rs", &src);
    assert_eq!(r.violations.len(), 1, "{}", r.render());
    assert_eq!(r.violations[0].pass, Pass::Allows);
    assert_eq!(r.allows_used, MAX_ALLOWS);
}

// ── lexer ──────────────────────────────────────────────────────────────────

#[test]
fn lexer_skips_literals_and_comments() {
    let toks = lexer::lex("let a = \"HashMap\"; // HashMap\n/* HashMap */ let b = 'H'; let c = r#\"HashMap\"#;\nlet l: &'static str = \"x\";\n");
    assert!(toks.iter().all(|t| t.text != "HashMap"));
    // lifetimes are consumed whole (quote + name) so a lifetime named
    // after a forbidden method can never fire a pass
    assert!(toks.iter().all(|t| t.text != "static" && t.text != "'"));
    let lines: Vec<u32> = toks.iter().filter(|t| t.text == "let").map(|t| t.line).collect();
    assert_eq!(lines, vec![1, 2, 2, 3]);
}

#[test]
fn lexer_extracts_fn_spans_and_test_regions() {
    let src = "fn one() { inner(); }\n#[cfg(test)]\nmod tests {\n    fn two() {}\n}\nfn three(x: impl Fn() -> u32) -> u32 { x() }\n";
    let toks = lexer::lex(src);
    let fns = lexer::fn_spans(&toks);
    let names: Vec<&str> = fns.iter().map(|f| f.name).collect();
    assert_eq!(names, vec!["one", "two", "three"]);
    let regions = lexer::test_regions(&toks);
    assert_eq!(regions, vec![(2, 5)]);
    assert!(lexer::in_test(&regions, 4));
    assert!(!lexer::in_test(&regions, 6));
}

#[test]
fn directive_parser_requires_quoted_reason() {
    let ds = lexer::directives(
        "// lint: allow(hotpath, reason = \"scratch reuse (ring buffer)\")\n// lint: allow(locks)\n// lint: allow(proto, reason = )\n",
    );
    assert_eq!(ds.len(), 3);
    assert_eq!((ds[0].pass.as_str(), ds[0].has_reason), ("hotpath", true));
    assert_eq!((ds[1].pass.as_str(), ds[1].has_reason), ("locks", false));
    assert_eq!((ds[2].pass.as_str(), ds[2].has_reason), ("proto", false));
}

// ── self-clean gate ────────────────────────────────────────────────────────

/// `edgelora lint` must exit clean on the repo's own source: the linter,
/// the fixes it demanded, and the (budgeted, reasoned) allows are one
/// consistent state. This is the gate that keeps future PRs honest.
#[test]
fn repo_source_lints_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = run_lint(&root).expect("walk rust/src");
    assert!(report.clean(), "repo must lint clean:\n{}", report.render());
    assert!(report.files > 30, "expected the whole tree, got {} files", report.files);
    assert!(report.allows_used < MAX_ALLOWS);
}
