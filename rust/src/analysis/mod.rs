//! Repo-native invariant linter (DESIGN.md §Static analysis).
//!
//! A std-only, token-level static analyzer over this repo's own source,
//! exposed as `edgelora lint` and run as its own verify tier. Five passes
//! enforce the invariants every shipped acceptance result rests on:
//!
//!  1. **determinism** — replay-deterministic modules never touch wall
//!     clocks or unordered maps ([`determinism`]);
//!  2. **panics** — `net/` + `server/` never panic on peer-controlled
//!     input ([`panics`]);
//!  3. **hotpath** — the manifested hot functions contain no allocating
//!     tokens ([`hotpath`]);
//!  4. **locks** — the global lock-acquisition pair graph is acyclic
//!     ([`locks`]);
//!  5. **proto** — every wire tag constant is consumed by both codec
//!     sides ([`proto_tags`]).
//!
//! A violation can be suppressed by a scoped escape hatch on its own line
//! or the line above:
//!
//! ```text
//! // lint: allow(determinism, reason = "real sockets pace on wall time")
//! ```
//!
//! The reason is mandatory (a reasonless allow suppresses nothing) and the
//! total number of *used* allows across the tree is budgeted at
//! [`MAX_ALLOWS`] — the linter fails itself when annotations start
//! substituting for fixes.

pub mod lexer;

mod determinism;
mod hotpath;
mod locks;
mod panics;
mod proto_tags;
#[cfg(test)]
mod tests;

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::io;
use std::path::Path;

pub use determinism::{DETERMINISTIC_MODULES, MAP_ONLY_MODULES};
pub use hotpath::HOT_FUNCTIONS;
pub use locks::DECLARED_EDGES;

/// Hard ceiling on used `// lint: allow` directives across the tree.
pub const MAX_ALLOWS: usize = 25;

/// Which pass produced a violation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Pass {
    Determinism,
    Panics,
    Hotpath,
    Locks,
    Proto,
    /// meta-pass: the allow budget itself
    Allows,
}

impl Pass {
    pub fn name(self) -> &'static str {
        match self {
            Pass::Determinism => "determinism",
            Pass::Panics => "panics",
            Pass::Hotpath => "hotpath",
            Pass::Locks => "locks",
            Pass::Proto => "proto",
            Pass::Allows => "allows",
        }
    }
}

/// One finding: pass, location, and a human-readable message.
#[derive(Debug, Clone)]
pub struct Violation {
    pub pass: Pass,
    pub file: String,
    pub line: u32,
    pub msg: String,
}

/// Everything a pass needs about one file, computed once.
pub(crate) struct FileScan<'a> {
    pub(crate) path: &'a str,
    pub(crate) toks: Vec<lexer::Tok<'a>>,
    pub(crate) tests: Vec<(u32, u32)>,
    pub(crate) fns: Vec<lexer::FnSpan<'a>>,
}

/// The full lint result.
#[derive(Debug)]
pub struct LintReport {
    /// unsuppressed findings, sorted by (file, line)
    pub violations: Vec<Violation>,
    /// findings silenced by a reasoned allow directive
    pub suppressed: usize,
    /// distinct allow directives that silenced at least one finding
    pub allows_used: usize,
    /// files scanned
    pub files: usize,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Render the report for terminal output (one line per violation plus
    /// a summary line).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for v in &self.violations {
            s.push_str(&format!("{}: {}:{}: {}\n", v.pass.name(), v.file, v.line, v.msg));
        }
        s.push_str(&format!(
            "lint: {} file(s) scanned, {} violation(s), {} suppressed by {} allow(s) (budget {})\n",
            self.files,
            self.violations.len(),
            self.suppressed,
            self.allows_used,
            MAX_ALLOWS
        ));
        s
    }
}

/// Lint an in-memory file set (`(relative path, source)` pairs, forward
/// slashes). `full_tree` additionally enables the completeness checks that
/// only make sense over the whole repo — stale hot-path manifest entries
/// and a tagless protocol file — and is what `run_lint` uses; fixture
/// tests pass `false`.
pub fn lint_files(files: &[(String, String)], full_tree: bool) -> LintReport {
    let scans: Vec<FileScan> = files
        .iter()
        .map(|(path, src)| {
            let toks = lexer::lex(src);
            let tests = lexer::test_regions(&toks);
            let fns = lexer::fn_spans(&toks);
            FileScan { path, toks, tests, fns }
        })
        .collect();

    let mut raw: Vec<Violation> = Vec::new();
    let mut hot_matched = vec![false; hotpath::HOT_FUNCTIONS.len()];
    let mut proto_tags_found = 0usize;
    for scan in &scans {
        determinism::check(scan, &mut raw);
        panics::check(scan, &mut raw);
        hotpath::check(scan, &mut hot_matched, &mut raw);
        proto_tags_found += proto_tags::check(scan, &mut raw);
    }
    locks::check(&scans, &mut raw);

    if full_tree {
        for (i, ok) in hot_matched.iter().enumerate() {
            if !ok {
                let (file, func) = hotpath::HOT_FUNCTIONS[i];
                raw.push(Violation {
                    pass: Pass::Hotpath,
                    file: file.to_string(),
                    line: 0,
                    msg: format!(
                        "hot-path manifest entry `{file}::{func}` matches no function — update the manifest"
                    ),
                });
            }
        }
        if proto_tags_found == 0 {
            raw.push(Violation {
                pass: Pass::Proto,
                file: proto_tags::PROTO_FILE.to_string(),
                line: 0,
                msg: "no wire tag constants found — the protocol pass has nothing to check"
                    .to_string(),
            });
        }
    }

    // apply `// lint: allow(pass, reason = "...")` directives
    let directives: BTreeMap<&str, Vec<lexer::Directive>> = files
        .iter()
        .map(|(path, src)| (path.as_str(), lexer::directives(src)))
        .collect();
    let mut violations = Vec::new();
    let mut suppressed = 0usize;
    let mut used: BTreeSet<(String, u32)> = BTreeSet::new();
    for v in raw {
        let hit = directives.get(v.file.as_str()).and_then(|ds| {
            ds.iter()
                .find(|d| d.has_reason && d.pass == v.pass.name() && (d.line == v.line || d.line + 1 == v.line))
        });
        match hit {
            Some(d) => {
                suppressed += 1;
                used.insert((v.file.clone(), d.line));
            }
            None => violations.push(v),
        }
    }
    if used.len() >= MAX_ALLOWS {
        violations.push(Violation {
            pass: Pass::Allows,
            file: String::from("(global)"),
            line: 0,
            msg: format!(
                "{} allow directives in use — the budget is {MAX_ALLOWS}; fix violations instead of annotating them",
                used.len()
            ),
        });
    }
    violations.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.pass).cmp(&(b.file.as_str(), b.line, b.pass))
    });

    LintReport {
        violations,
        suppressed,
        allows_used: used.len(),
        files: files.len(),
    }
}

/// Lint every `.rs` file under `src_root` (the directory holding
/// `lib.rs`). Paths in the report are relative to it.
pub fn run_lint(src_root: &Path) -> io::Result<LintReport> {
    let mut files = Vec::new();
    walk(src_root, src_root, &mut files)?;
    Ok(lint_files(&files, true))
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<(String, String)>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<io::Result<_>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, root, out)?;
        } else if p.extension().and_then(|s| s.to_str()) == Some("rs") {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            out.push((rel, fs::read_to_string(&p)?));
        }
    }
    Ok(())
}
