//! Pass 3 — hot-path allocation freedom (DESIGN.md §Static analysis).
//!
//! The steady-state decode tick is pinned allocation-free by the bench's
//! scratch-footprint asserts; this pass makes the same property a lexical
//! fact for the named hot functions, so a regression is caught at lint
//! time, not at bench time. The manifest is `(file, fn)`-scoped: a
//! same-named function elsewhere (e.g. the feature-gated PJRT
//! `decode_step_into`, whose device-upload API allocates by contract) is
//! deliberately outside it.
//!
//! `Vec::with_capacity` is *not* forbidden: the wire decoder reserves
//! bounded capacity up front, which is the allocation discipline we want.

use super::lexer::in_test;
use super::{FileScan, Pass, Violation};

/// `(file, function)` pairs whose bodies must contain no allocating token.
pub const HOT_FUNCTIONS: &[(&str, &str)] = &[
    ("coordinator/batcher.rs", "build_into"),
    ("coordinator/batcher.rs", "rebuild_if"),
    ("backend/sim.rs", "decode_step_into"),
    ("memory/paging.rs", "boundary_hashes"),
    ("quant/mod.rs", "dequantize_into"),
    ("quant/q4_0.rs", "dequantize_into"),
    ("quant/q8_0.rs", "dequantize_into"),
    ("net/proto.rs", "encode"),
    ("net/proto.rs", "encode_into"),
    ("net/proto.rs", "decode"),
];

/// Check one file; `matched[i]` is set when manifest entry `i` was found
/// (so the caller can flag stale manifest entries after the full walk).
pub fn check(scan: &FileScan, matched: &mut [bool], out: &mut Vec<Violation>) {
    for (idx, (file, func)) in HOT_FUNCTIONS.iter().enumerate() {
        if scan.path != *file {
            continue;
        }
        for span in scan.fns.iter().filter(|s| s.name == *func) {
            if in_test(&scan.tests, span.line) {
                continue;
            }
            matched[idx] = true;
            scan_body(scan, span.body, file, func, out);
        }
    }
}

fn scan_body(
    scan: &FileScan,
    body: (usize, usize),
    file: &str,
    func: &str,
    out: &mut Vec<Violation>,
) {
    let toks = &scan.toks;
    let mut flag = |line: u32, what: &str| {
        out.push(Violation {
            pass: Pass::Hotpath,
            file: scan.path.to_string(),
            line,
            msg: format!("allocating `{what}` in hot function `{file}::{func}`"),
        });
    };
    for i in body.0..body.1.min(toks.len()) {
        let t = toks[i].text;
        let at = |k: usize| toks.get(i + k).map(|t| t.text);
        match t {
            "Vec" | "Box" if at(1) == Some(":") && at(2) == Some(":") && at(3) == Some("new") => {
                flag(toks[i].line, if t == "Vec" { "Vec::new" } else { "Box::new" })
            }
            "String" if at(1) == Some(":") && at(2) == Some(":") => flag(toks[i].line, "String::"),
            "vec" | "format" if at(1) == Some("!") => {
                flag(toks[i].line, if t == "vec" { "vec!" } else { "format!" })
            }
            "." if at(1) == Some("collect") => flag(toks[i].line, ".collect()"),
            "." if at(1) == Some("to_vec") => flag(toks[i].line, ".to_vec()"),
            _ => {}
        }
    }
}
