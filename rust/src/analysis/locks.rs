//! Pass 4 — lock-order analysis (DESIGN.md §Static analysis).
//!
//! Extracts, per function, the sequence of named `.lock()` acquisitions
//! (the name is the identifier lexically before `.lock()` — `cluster` in
//! `self.cluster.lock()`, `0` in `self.0.lock()`), turns every in-function
//! ordering into a directed edge of a global pair graph, and errors on any
//! cycle. Token-level analysis cannot see cross-function holds (a handler
//! that keeps the `cluster` guard alive while engine code takes `subs`),
//! so the known cross-module holds are declared below and seeded into the
//! same graph; the canonical order is
//! `cluster → subs → state / inner / 0`, with the thread pool's
//! `queue → done_lock` on its own branch.
//!
//! Over-approximations, by design: two acquisitions in one function count
//! as nested even if the first guard was dropped, and same-name pairs are
//! skipped (a re-lock after drop is indistinguishable from self-deadlock
//! at token level).

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{in_test, is_ident};
use super::{FileScan, Pass, Violation};

/// Known cross-function lock nestings (holder → inner), with the call
/// chain that creates each. These cannot be seen lexically; they are part
/// of the checked model and must be updated when a new nesting is
/// introduced.
pub const DECLARED_EDGES: &[(&str, &str, &str)] = &[
    (
        "cluster",
        "subs",
        "service handlers hold the cluster lock while the engine emits events (EventBus locks subs)",
    ),
    (
        "subs",
        "state",
        "EventBus::emit pushes into per-request channels (Chan locks state) under subs",
    ),
    (
        "cluster",
        "state",
        "cluster stepping delivers events into channel state under the cluster lock",
    ),
    (
        "cluster",
        "inner",
        "recorder calls (Recorder locks inner) run under the cluster lock",
    ),
    (
        "cluster",
        "0",
        "paging ops (SharedPages locks its `0` field) run under the cluster lock",
    ),
];

/// Numbers count too: tuple-struct fields lock as `self.0.lock()`.
fn is_lock_name(t: &str) -> bool {
    is_ident(t) || t.bytes().all(|b| b.is_ascii_digit())
}

/// Run over every file at once (the pair graph is global).
pub fn check(scans: &[FileScan], out: &mut Vec<Violation>) {
    // edge → first provenance seen, in deterministic order
    let mut edges: BTreeMap<(String, String), String> = BTreeMap::new();
    for (from, to, why) in DECLARED_EDGES {
        edges.insert((from.to_string(), to.to_string()), format!("declared: {why}"));
    }
    for scan in scans {
        for span in &scan.fns {
            if in_test(&scan.tests, span.line) {
                continue;
            }
            let mut seq: Vec<(&str, u32)> = Vec::new();
            let toks = &scan.toks;
            for i in span.body.0..span.body.1.min(toks.len()) {
                if toks[i].text == "."
                    && toks.get(i + 1).map(|t| t.text) == Some("lock")
                    && toks.get(i + 2).map(|t| t.text) == Some("(")
                    && i > 0
                    && is_lock_name(toks[i - 1].text)
                {
                    seq.push((toks[i - 1].text, toks[i].line));
                }
            }
            for a in 0..seq.len() {
                for b in a + 1..seq.len() {
                    if seq[a].0 != seq[b].0 {
                        edges
                            .entry((seq[a].0.to_string(), seq[b].0.to_string()))
                            .or_insert_with(|| {
                                format!("{}:{} fn {}", scan.path, seq[b].1, span.name)
                            });
                    }
                }
            }
        }
    }

    if let Some(cycle) = find_cycle(&edges) {
        let mut msg = String::from("lock-order cycle: ");
        for w in cycle.windows(2) {
            let why = edges
                .get(&(w[0].clone(), w[1].clone()))
                .map(String::as_str)
                .unwrap_or("?");
            msg.push_str(&format!("`{}` -> `{}` ({}); ", w[0], w[1], why));
        }
        out.push(Violation {
            pass: Pass::Locks,
            file: String::from("(global)"),
            line: 0,
            msg,
        });
    }
}

/// DFS cycle search over the pair graph; returns the cycle as a node path
/// `[a, ..., a]` if one exists. Deterministic: nodes visit in sorted order.
fn find_cycle(edges: &BTreeMap<(String, String), String>) -> Option<Vec<String>> {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (a, b) in edges.keys() {
        adj.entry(a.as_str()).or_default().push(b.as_str());
    }
    let mut done: BTreeSet<&str> = BTreeSet::new();
    let starts: Vec<&str> = adj.keys().copied().collect();
    for start in starts {
        if done.contains(start) {
            continue;
        }
        let mut path: Vec<&str> = Vec::new();
        if let Some(cycle) = dfs(start, &adj, &mut path, &mut done) {
            return Some(cycle);
        }
    }
    None
}

fn dfs<'a>(
    node: &'a str,
    adj: &BTreeMap<&'a str, Vec<&'a str>>,
    path: &mut Vec<&'a str>,
    done: &mut BTreeSet<&'a str>,
) -> Option<Vec<String>> {
    if let Some(pos) = path.iter().position(|&n| n == node) {
        let mut cycle: Vec<String> = path[pos..].iter().map(|s| s.to_string()).collect();
        cycle.push(node.to_string());
        return Some(cycle);
    }
    if done.contains(node) {
        return None;
    }
    path.push(node);
    if let Some(nexts) = adj.get(node) {
        for &n in nexts {
            if let Some(c) = dfs(n, adj, path, done) {
                return Some(c);
            }
        }
    }
    path.pop();
    done.insert(node);
    None
}
