//! The llama.cpp-style baseline engine (§5 Baselines), faithful to the
//! behaviours the paper measures against:
//!
//!  * **preloads every adapter at init** — past the device's memory budget
//!    this fails with OOM, which is exactly Table 4's "OOM" rows;
//!  * **merged-adapter execution**: one adapter is merged into the base
//!    weights at a time; switching costs an unmerge+merge pass
//!    (`switch_adapter_merged`), so consecutive requests with different
//!    adapters serialize behind expensive switches;
//!  * **same-adapter batching only**: the slot machine batches all available
//!    tokens, but only for requests that use the *current* adapter — the
//!    restriction §1 calls out ("llama.cpp can only process requests that
//!    use the same adapters simultaneously").

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;

use crate::backend::{DecodeRow, ModelBackend};
use crate::backend::sim::SimBackend;
use crate::coordinator::slot::{Slot, SlotState};
use crate::metrics::{Recorder, Summary};
use crate::util::time::Clock;
use crate::workload::{Trace, TraceRequest};
use crate::coordinator::engine::synth_prompt;

pub struct LlamaCppEngine {
    backend: Box<dyn ModelBackend>,
    clock: Arc<dyn Clock>,
    slots: Vec<Slot>,
    queue: VecDeque<TraceRequest>,
    /// adapter currently merged into the base weights
    current_adapter: Option<u64>,
    /// reused decode-token buffer (the `_into` API is the only decode path)
    toks: Vec<u32>,
    pub recorder: Arc<Recorder>,
    pub switches: u64,
}

impl LlamaCppEngine {
    /// `n_adapters` are preloaded at init; propagates the backend's OOM.
    pub fn new(
        mut backend: Box<SimBackend>,
        clock: Arc<dyn Clock>,
        slots: usize,
        n_adapters: usize,
    ) -> Result<Self> {
        backend.preload_adapters(n_adapters)?;
        let n_slots = slots.min(backend.decode_batch_width());
        Ok(Self {
            backend,
            clock,
            slots: (0..n_slots).map(|i| Slot::new(i, i)).collect(),
            queue: VecDeque::new(),
            current_adapter: None,
            toks: Vec::new(),
            recorder: Arc::new(Recorder::new()),
            switches: 0,
        })
    }

    pub fn backend(&self) -> &dyn ModelBackend {
        self.backend.as_ref()
    }

    pub fn run_trace(&mut self, trace: &Trace) -> Result<Summary> {
        let mut pending: VecDeque<TraceRequest> = trace.requests.iter().cloned().collect();
        let start = self.clock.now();
        let mut spin = 0u64;
        loop {
            let now = self.clock.now() - start;
            while pending.front().is_some_and(|r| r.arrival_s <= now) {
                self.queue.push_back(pending.pop_front().unwrap());
            }
            self.fill_slots(start)?;
            self.process_new_slots(start)?;
            let worked = self.decode_tick(start)?;
            spin += 1;
            if spin > 50_000_000 {
                panic!(
                    "baseline engine spinning: now={now:.3} pending={} queue={} \
                     current={:?} slots={:?}",
                    pending.len(),
                    self.queue.len(),
                    self.current_adapter,
                    self.slots.iter().map(|s| s.state).collect::<Vec<_>>()
                );
            }
            if !worked && self.queue.is_empty() {
                match pending.front() {
                    Some(r) => {
                        let target = start + r.arrival_s;
                        let now_abs = self.clock.now();
                        if target > now_abs {
                            self.clock.advance(target - now_abs);
                        }
                    }
                    None => break,
                }
            }
        }
        Ok(self
            .recorder
            .summarize(Some(trace.duration_s.max(self.clock.now() - start))))
    }

    /// Admit queued requests, but ONLY those matching the current merged
    /// adapter (or any, if no slot is active — then the head of the queue
    /// dictates the next merge). This is the same-adapter batching limit.
    fn fill_slots(&mut self, start: f64) -> Result<()> {
        // adopt the head-of-queue's adapter when idle
        let active = self.slots.iter().any(|s| !s.is_idle());
        if !active {
            if let Some(head) = self.queue.front() {
                let want = head.explicit_adapter.unwrap_or(head.true_adapter);
                if self.current_adapter != Some(want) {
                    self.backend.switch_adapter_merged(want)?;
                    self.switches += 1;
                    self.current_adapter = Some(want);
                }
            }
        }
        let Some(current) = self.current_adapter else {
            return Ok(());
        };
        for i in 0..self.slots.len() {
            if !self.slots[i].is_idle() {
                continue;
            }
            // find the first queued request for the current adapter
            let pos = self
                .queue
                .iter()
                .position(|r| r.explicit_adapter.unwrap_or(r.true_adapter) == current);
            let Some(pos) = pos else { break };
            let req = self.queue.remove(pos).unwrap();
            let now = self.clock.now() - start;
            let prompt = synth_prompt(&req, self.backend.max_prompt_tokens());
            self.slots[i].admit(
                req.id,
                prompt,
                Some(current),
                req.true_adapter,
                req.output_tokens,
                req.arrival_s,
                now,
            );
        }
        Ok(())
    }

    fn process_new_slots(&mut self, start: f64) -> Result<()> {
        for i in 0..self.slots.len() {
            if self.slots[i].state != SlotState::AdapterSelection {
                continue;
            }
            // merged execution: LoRA is inside W, bank slot 0 unused
            let adapter = self.slots[i].explicit_adapter.expect("baseline is explicit");
            self.slots[i].adapter_selected(adapter, 0, true, false);
            let row = self.slots[i].row;
            let prompt = self.slots[i].prompt.clone();
            let first = self.backend.prefill(row, &prompt, 0)?;
            let now = self.clock.now() - start;
            self.slots[i].prompt_done(first, now);
            if self.slots[i].generated >= self.slots[i].target_tokens {
                self.slots[i].record.finished = now;
                let rec = self.slots[i].release();
                self.backend.release_row(row)?;
                self.recorder.complete(&rec);
            }
        }
        Ok(())
    }

    fn decode_tick(&mut self, start: f64) -> Result<bool> {
        let mut rows = Vec::new();
        let mut slot_of_row = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            if s.state == SlotState::Generation {
                rows.push(DecodeRow {
                    row: s.row,
                    token: s.last_token,
                    pos: s.position() + 1,
                    bank_slot: 0,
                    kv_probe: 0,
                });
                slot_of_row.push(i);
            }
        }
        if rows.is_empty() {
            return Ok(false);
        }
        let mut toks = std::mem::take(&mut self.toks);
        self.backend.decode_step_into(&rows, &mut toks)?;
        let now = self.clock.now() - start;
        for (k, &si) in slot_of_row.iter().enumerate() {
            if self.slots[si].token_generated(toks[k], now) {
                let row = self.slots[si].row;
                let rec = self.slots[si].release();
                self.backend.release_row(row)?;
                self.recorder.complete(&rec);
            }
        }
        self.toks = toks;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::devices::DeviceProfile;
    use crate::config::{ModelSetting, WorkloadConfig};
    use crate::util::time::VirtualClock;
    use crate::workload::generate;

    fn mk(n_adapters: usize, slots: usize) -> Result<(LlamaCppEngine, Arc<VirtualClock>)> {
        let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
        let backend = SimBackend::new(
            DeviceProfile::agx_orin(),
            ModelSetting::s1(),
            clock.clone(),
            slots,
            1,
            None,
        )?;
        let e = LlamaCppEngine::new(Box::new(backend), clock.clone(), slots, n_adapters)?;
        Ok((e, clock))
    }

    fn trace(n_adapters: usize, rate: f64, dur: f64) -> Trace {
        generate(&WorkloadConfig {
            n_adapters,
            rate,
            duration_s: dur,
            input_range: (8, 32),
            output_range: (4, 16),
            auto_select_fraction: 0.0, // baseline needs explicit adapters
            ..WorkloadConfig::default()
        })
    }

    #[test]
    fn ooms_past_memory_budget() {
        // Table 4: 50 ok, 100+ OOM for S1@AGX
        assert!(mk(50, 4).is_ok());
        assert!(mk(2000, 4).is_err());
    }

    #[test]
    fn completes_all_requests() {
        let (mut e, _) = mk(5, 4).unwrap();
        let t = trace(5, 0.5, 60.0);
        let n = t.len() as u64;
        let s = e.run_trace(&t).unwrap();
        assert_eq!(s.requests, n);
    }

    #[test]
    fn switches_cost_time() {
        let (mut e, _) = mk(10, 4).unwrap();
        let t = trace(10, 1.0, 60.0);
        e.run_trace(&t).unwrap();
        assert!(e.switches > 1, "expected adapter switches, got {}", e.switches);
    }

    #[test]
    fn single_adapter_needs_one_switch() {
        let (mut e, _) = mk(1, 4).unwrap();
        let t = trace(1, 1.0, 30.0);
        e.run_trace(&t).unwrap();
        assert_eq!(e.switches, 1);
    }

    #[test]
    fn diverse_adapters_slower_than_single() {
        let run = |n_adapters: usize| {
            let (mut e, _) = mk(n_adapters, 4).unwrap();
            let t = trace(n_adapters, 0.5, 120.0);
            e.run_trace(&t).unwrap().avg_latency_s
        };
        let single = run(1);
        let many = run(20);
        assert!(
            many > single,
            "20-adapter latency {many} should exceed single-adapter {single}"
        );
    }
}
