//! Baseline engines the paper compares against (§5): the llama.cpp-style
//! preload-all / merged-switching / same-adapter-batching server.

pub mod llamacpp;

pub use llamacpp::LlamaCppEngine;
