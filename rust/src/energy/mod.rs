//! Energy accounting (Table 11) and DVFS modes (Table 13).
//!
//! Models the jetson-stats measurement the paper uses: average power over a
//! serving run = busy time at the TDP power draw + idle time at idle draw.
//! The busy-time integral comes from the sim backend's `EnergyAccount`; this
//! module adds the sampler that mimics jetson-stats' 1 Hz polling and the
//! per-run report row.

use crate::backend::devices::DeviceProfile;

/// Power model: piecewise-constant busy/idle draw.
#[derive(Debug, Clone)]
pub struct PowerModel {
    pub idle_w: f64,
    pub busy_w: f64,
}

impl PowerModel {
    pub fn for_device(dev: &DeviceProfile, tdp_watts: Option<f64>) -> Self {
        let busy = tdp_watts.unwrap_or(dev.tdp_modes[0].watts);
        Self {
            idle_w: dev.idle_w,
            busy_w: busy,
        }
    }

    /// Average power over a span with `busy_s` seconds of compute.
    pub fn average(&self, busy_s: f64, span_s: f64) -> f64 {
        if span_s <= 0.0 {
            return self.idle_w;
        }
        let busy = busy_s.clamp(0.0, span_s);
        (busy * self.busy_w + (span_s - busy) * self.idle_w) / span_s
    }

    /// Total energy (joules) over the span.
    pub fn energy_j(&self, busy_s: f64, span_s: f64) -> f64 {
        self.average(busy_s, span_s) * span_s
    }
}

/// 1 Hz sampler à la jetson-stats: quantizes busy intervals into per-second
/// power readings and averages them (what the paper actually reports).
#[derive(Debug, Default)]
pub struct PowerSampler {
    samples: Vec<f64>,
}

impl PowerSampler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sample a run: given the busy fraction of each 1-second window.
    pub fn sample_run(&mut self, model: &PowerModel, busy_per_second: &[f64]) {
        for &frac in busy_per_second {
            let frac = frac.clamp(0.0, 1.0);
            self.samples
                .push(frac * model.busy_w + (1.0 - frac) * model.idle_w);
        }
    }

    pub fn average(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }

    pub fn n_samples(&self) -> usize {
        self.samples.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_power_interpolates() {
        let m = PowerModel {
            idle_w: 10.0,
            busy_w: 50.0,
        };
        assert!((m.average(0.0, 10.0) - 10.0).abs() < 1e-9);
        assert!((m.average(10.0, 10.0) - 50.0).abs() < 1e-9);
        assert!((m.average(5.0, 10.0) - 30.0).abs() < 1e-9);
        // busy beyond span clamps
        assert!((m.average(20.0, 10.0) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn energy_is_power_times_time() {
        let m = PowerModel {
            idle_w: 5.0,
            busy_w: 15.0,
        };
        assert!((m.energy_j(5.0, 10.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn sampler_matches_analytic_average() {
        let m = PowerModel {
            idle_w: 9.0,
            busy_w: 50.0,
        };
        let mut s = PowerSampler::new();
        let busy: Vec<f64> = (0..100).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
        s.sample_run(&m, &busy);
        assert_eq!(s.n_samples(), 100);
        assert!((s.average() - m.average(50.0, 100.0)).abs() < 1e-9);
    }

    #[test]
    fn device_tdp_selection() {
        let dev = DeviceProfile::agx_orin();
        let pm50 = PowerModel::for_device(&dev, Some(50.0));
        let pm15 = PowerModel::for_device(&dev, Some(15.0));
        assert!(pm50.busy_w > pm15.busy_w);
        assert_eq!(pm50.idle_w, pm15.idle_w);
    }
}
