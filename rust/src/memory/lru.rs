//! LRU cache over adapter ids → pool-block handles (§4.2).
//!
//! The paper implements this with `std::list` + `std::unordered_set`; we use
//! the equivalent intrusive doubly-linked list over a slab (indices instead
//! of pointers), giving O(1) touch / insert / evict without unsafe code.

use std::collections::BTreeMap;

use crate::adapters::AdapterId;

const NIL: usize = usize::MAX;

#[derive(Debug, Clone)]
struct Node<V> {
    key: AdapterId,
    value: V,
    prev: usize,
    next: usize,
}

/// O(1) LRU map with fixed capacity. Values are whatever the memory manager
/// wants to associate with a resident adapter (pool block handle + slot id);
/// they are required `Clone` because handles are small and copy-cheap.
#[derive(Debug)]
pub struct LruCache<V: Clone> {
    map: BTreeMap<AdapterId, usize>,
    slab: Vec<Node<V>>,
    free: Vec<usize>,
    head: usize, // most recently used
    tail: usize, // least recently used
    capacity: usize,
}

impl<V: Clone> LruCache<V> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "zero-capacity cache");
        Self {
            map: BTreeMap::new(),
            slab: Vec::with_capacity(capacity),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_full(&self) -> bool {
        self.len() == self.capacity
    }

    pub fn contains(&self, key: AdapterId) -> bool {
        self.map.contains_key(&key)
    }

    /// Peek without touching recency.
    pub fn peek(&self, key: AdapterId) -> Option<&V> {
        self.map.get(&key).map(|&i| &self.slab[i].value)
    }

    /// Get and mark as most-recently-used.
    pub fn get(&mut self, key: AdapterId) -> Option<&V> {
        let &i = self.map.get(&key)?;
        self.detach(i);
        self.attach_front(i);
        Some(&self.slab[i].value)
    }

    /// Insert a new entry as MRU. If the key exists its value is replaced.
    /// If full, evicts the LRU entry and returns `(evicted_key, value)`.
    pub fn insert(&mut self, key: AdapterId, value: V) -> Option<(AdapterId, V)> {
        if let Some(&i) = self.map.get(&key) {
            self.slab[i].value = value;
            self.detach(i);
            self.attach_front(i);
            return None;
        }
        let evicted = if self.is_full() { self.evict_lru() } else { None };
        let node = Node {
            key,
            value,
            prev: NIL,
            next: NIL,
        };
        let i = if let Some(i) = self.free.pop() {
            self.slab[i] = node;
            i
        } else {
            self.slab.push(node);
            self.slab.len() - 1
        };
        self.map.insert(key, i);
        self.attach_front(i);
        evicted
    }

    /// Remove a specific key (e.g. adapter invalidated).
    pub fn remove(&mut self, key: AdapterId) -> Option<V> {
        let i = self.map.remove(&key)?;
        self.detach(i);
        self.free.push(i);
        Some(self.slab[i].value.clone())
    }

    /// Evict the least-recently-used entry.
    pub fn evict_lru(&mut self) -> Option<(AdapterId, V)> {
        self.evict_lru_where(|_| true)
    }

    /// Evict the least-recently-used entry for which `evictable(key)` holds,
    /// walking from the LRU end. Skipped entries (e.g. pinned adapters) keep
    /// their recency untouched.
    pub fn evict_lru_where<F: Fn(AdapterId) -> bool>(
        &mut self,
        evictable: F,
    ) -> Option<(AdapterId, V)> {
        let mut cur = self.tail;
        while cur != NIL {
            let key = self.slab[cur].key;
            if evictable(key) {
                let value = self.slab[cur].value.clone();
                self.detach(cur);
                self.map.remove(&key);
                self.free.push(cur);
                return Some((key, value));
            }
            cur = self.slab[cur].prev;
        }
        None
    }

    /// Resident keys in arbitrary order, allocation-free (scoreboard export).
    pub fn iter_keys(&self) -> impl Iterator<Item = AdapterId> + '_ {
        self.map.keys().copied()
    }

    /// Keys from most- to least-recently-used (diagnostics/tests).
    pub fn keys_mru_order(&self) -> Vec<AdapterId> {
        let mut out = Vec::with_capacity(self.len());
        let mut cur = self.head;
        while cur != NIL {
            out.push(self.slab[cur].key);
            cur = self.slab[cur].next;
        }
        out
    }

    fn detach(&mut self, i: usize) {
        let (prev, next) = (self.slab[i].prev, self.slab[i].next);
        if prev != NIL {
            self.slab[prev].next = next;
        } else if self.head == i {
            self.head = next;
        }
        if next != NIL {
            self.slab[next].prev = prev;
        } else if self.tail == i {
            self.tail = prev;
        }
        self.slab[i].prev = NIL;
        self.slab[i].next = NIL;
    }

    fn attach_front(&mut self, i: usize) {
        self.slab[i].prev = NIL;
        self.slab[i].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_insert_get() {
        let mut c = LruCache::new(2);
        assert!(c.insert(1, "a").is_none());
        assert!(c.insert(2, "b").is_none());
        assert_eq!(c.get(1), Some(&"a"));
        assert_eq!(c.keys_mru_order(), vec![1, 2]);
    }

    #[test]
    fn eviction_order_is_lru() {
        let mut c = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        c.get(1); // 2 becomes LRU
        let evicted = c.insert(3, 30);
        assert_eq!(evicted, Some((2, 20)));
        assert!(c.contains(1) && c.contains(3) && !c.contains(2));
    }

    #[test]
    fn reinsert_updates_value_no_evict() {
        let mut c = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        assert!(c.insert(1, 11).is_none());
        assert_eq!(c.peek(1), Some(&11));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn remove_frees_slot() {
        let mut c = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        assert_eq!(c.remove(1), Some(10));
        assert_eq!(c.len(), 1);
        assert!(c.insert(3, 30).is_none()); // no eviction needed
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn peek_does_not_touch() {
        let mut c = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        c.peek(1);
        let evicted = c.insert(3, 30);
        assert_eq!(evicted, Some((1, 10))); // 1 was still LRU
    }

    #[test]
    fn heavy_churn_keeps_invariants() {
        let mut c = LruCache::new(8);
        for i in 0..1000u64 {
            c.insert(i % 16, i);
            assert!(c.len() <= 8);
            let keys = c.keys_mru_order();
            assert_eq!(keys.len(), c.len());
            // no duplicates
            let mut s = keys.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), keys.len());
        }
    }

    #[test]
    fn evict_lru_where_skips_without_touching_recency() {
        let mut c = LruCache::new(3);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(3, 30); // MRU→LRU order: 3, 2, 1
        let evicted = c.evict_lru_where(|k| k != 1);
        assert_eq!(evicted, Some((2, 20)));
        // skipped entry 1 stays LRU (recency untouched)
        assert_eq!(c.keys_mru_order(), vec![3, 1]);
        // nothing evictable → None, cache intact
        assert_eq!(c.evict_lru_where(|_| false), None);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn evict_lru_explicit() {
        let mut c = LruCache::new(3);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(3, 30);
        assert_eq!(c.evict_lru(), Some((1, 10)));
        assert_eq!(c.len(), 2);
    }
}
