//! Heterogeneous memory manager (§3.3 + §4.2): LRU (or LFU) adapter cache
//! backed by the pre-allocated block pool, fronting the on-disk adapter
//! store. This is the component that makes "thousands of adapters on one
//! edge device" possible: only `capacity` adapters are resident; the rest
//! live on disk and are swapped in on demand.
//!
//! Responsibilities:
//!   * cache lookup + recency/frequency maintenance (hit-rate H = h/h_total)
//!   * eviction: victim's pool block returns to the pool, then is reused for
//!     the incoming adapter (no runtime allocation)
//!   * the disk→memory load itself — a *zero-copy quantized* read: the
//!     on-disk payload lands straight in the pool block
//!     (`AdapterStore::read_raw_into`); dequantization happens exactly once,
//!     at bank-upload time, through a borrowed [`QuantView`]
//!   * asynchronous prefetch: speculative loads for queued requests run on a
//!     background thread pool and overlap with decode (`prefetch` /
//!     `poll_prefetch` / `take_prefetched`)
//!   * bank-slot assignment: each resident adapter owns one slot index in
//!     the L2 model's LoRA bank, so the coordinator can pass slot ids to the
//!     decode artifact directly.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::adapters::{AdapterId, AdapterStore, LoraWeights, QuantView};
use crate::memory::lfu::LfuCache;
use crate::memory::lru::LruCache;
use crate::memory::paging::SharedPages;
use crate::memory::pool::{BlockHandle, MemoryPool};
use crate::memory::prefetch::{Done, Prefetcher};

/// Cache replacement policy (§4.2 discusses both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    Lru,
    Lfu,
}

/// What the cache stores per resident adapter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Resident {
    pub block: BlockHandle,
    /// index into the model's LoRA bank (= pool block index by construction)
    pub bank_slot: usize,
}

/// Cluster-wide bank indirection: bank_slot → (shard, slot). Each replica's
/// memory manager owns one shard of the logical adapter bank;
/// `ClusterEngine::locate` resolves an adapter id to its full (shard, slot)
/// address across the fleet — the seam a cross-device bank upload or
/// adapter-migration path consumes (DESIGN.md §Cluster).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BankRef {
    /// replica/device index within the cluster (0 for single-engine runs)
    pub shard: usize,
    /// bank slot within that shard's device bank
    pub slot: usize,
}

enum CacheImpl {
    Lru(LruCache<Resident>),
    Lfu(LfuCache<Resident>),
}

/// Outcome of `ensure_resident`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// already in cache — zero cost
    Hit(Resident),
    /// loaded from disk into the given block (optionally after evicting)
    Loaded {
        resident: Resident,
        evicted: Option<AdapterId>,
    },
    /// no block can be taken right now: every pool block belongs to a
    /// *pinned* adapter (actively decoding) or an outstanding prefetch —
    /// the caller must retry after some in-flight request completes
    Deferred,
}

impl Residency {
    pub fn resident(&self) -> Resident {
        match self {
            Residency::Hit(r) => *r,
            Residency::Loaded { resident, .. } => *resident,
            Residency::Deferred => panic!("deferred residency has no resident"),
        }
    }
    pub fn is_hit(&self) -> bool {
        matches!(self, Residency::Hit(_))
    }
    pub fn is_deferred(&self) -> bool {
        matches!(self, Residency::Deferred)
    }
}

/// A prefetch successfully claimed by the request that needed it.
#[derive(Debug, Clone, Copy)]
pub struct PrefetchClaim {
    pub resident: Resident,
    /// seconds of load latency already overlapped with other work (issue →
    /// claim); the backend charges only the uncovered remainder
    pub covered_s: f64,
}

/// Statistics for EXPERIMENTS.md and the Tables 7–8 analysis.
#[derive(Debug, Default, Clone)]
pub struct MemoryStats {
    pub lookups: u64,
    pub hits: u64,
    pub loads: u64,
    pub evictions: u64,
    /// background reads issued
    pub prefetch_issued: u64,
    /// misses served by a completed (or awaited) prefetch
    pub prefetch_hits: u64,
    /// prefetched blocks reclaimed unused (pool pressure, read failure,
    /// adapter became resident through another path)
    pub prefetch_dropped: u64,
}

impl MemoryStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }
}

/// One issued-but-unfinished background read.
struct InFlight {
    block: BlockHandle,
    issued_at: f64,
}

/// One finished-but-unclaimed background read (buffer restored to its block).
struct Ready {
    block: BlockHandle,
    issued_at: f64,
}

struct PrefetchState {
    fetcher: Prefetcher,
    in_flight: BTreeMap<AdapterId, InFlight>,
    ready: BTreeMap<AdapterId, Ready>,
    /// max outstanding (in-flight + ready) prefetches
    depth: usize,
}

pub struct AdapterMemoryManager {
    cache: CacheImpl,
    pool: MemoryPool,
    store: Arc<AdapterStore>,
    stats: MemoryStats,
    prefetch: Option<PrefetchState>,
    /// refcounted pins: adapters whose bank slots are live on the device
    /// (a request slot is decoding with them) — never eviction victims
    pins: BTreeMap<AdapterId, u32>,
    /// which cluster shard this manager's bank belongs to (0 standalone)
    shard: usize,
}

impl AdapterMemoryManager {
    /// `capacity` = number of resident adapters = pool blocks = L2 bank
    /// slots. Pool blocks hold the *quantized* payload — resident footprint
    /// is `capacity × payload_bytes`, 4–8× below the old f32-resident pool.
    pub fn new(store: Arc<AdapterStore>, capacity: usize, policy: CachePolicy) -> Self {
        let block_bytes = store.payload_bytes();
        Self::with_pool(store, policy, MemoryPool::new(capacity, block_bytes))
    }

    /// Page-backed manager (DESIGN.md §Unified paging): every pool block
    /// charges `pages_per_block` pages against `shared`, the allocator the
    /// engine's per-slot KV tables also draw from — adapter residency and KV
    /// growth compete for one budget instead of split static reservations.
    pub fn new_paged(
        store: Arc<AdapterStore>,
        capacity: usize,
        policy: CachePolicy,
        shared: SharedPages,
        pages_per_block: usize,
    ) -> Self {
        let block_bytes = store.payload_bytes();
        let pool = MemoryPool::new_paged(capacity, block_bytes, shared, pages_per_block);
        Self::with_pool(store, policy, pool)
    }

    fn with_pool(store: Arc<AdapterStore>, policy: CachePolicy, pool: MemoryPool) -> Self {
        let capacity = pool.n_blocks();
        let cache = match policy {
            CachePolicy::Lru => CacheImpl::Lru(LruCache::new(capacity)),
            CachePolicy::Lfu => CacheImpl::Lfu(LfuCache::new(capacity)),
        };
        Self {
            cache,
            pool,
            store,
            stats: MemoryStats::default(),
            prefetch: None,
            pins: BTreeMap::new(),
            shard: 0,
        }
    }

    /// The unified page allocator behind the pool, if page-backed (cloned
    /// handle — clones share the budget).
    pub fn shared_pages(&self) -> Option<SharedPages> {
        self.pool.shared_pages().cloned()
    }

    /// Tag this manager as shard `shard` of a cluster bank (builder form).
    pub fn with_shard(mut self, shard: usize) -> Self {
        self.shard = shard;
        self
    }

    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Cluster-wide location of a resident adapter: (shard, slot). The slot
    /// half is exactly `peek_slot`; the shard half is this manager's
    /// identity, so a scoreboard entry resolves to one device bank.
    pub fn bank_ref(&self, id: AdapterId) -> Option<BankRef> {
        Some(BankRef {
            shard: self.shard,
            slot: self.peek_slot(id)?,
        })
    }

    /// Resident adapter ids in arbitrary order, allocation-free — the
    /// resident-set export the cluster scoreboard republishes after a
    /// replica steps. Does not touch recency/frequency.
    pub fn resident_iter(&self) -> impl Iterator<Item = AdapterId> + '_ {
        let (lru, lfu) = match &self.cache {
            CacheImpl::Lru(c) => (Some(c.iter_keys()), None),
            CacheImpl::Lfu(c) => (None, Some(c.iter_keys())),
        };
        lru.into_iter().flatten().chain(lfu.into_iter().flatten())
    }

    /// Pin a resident adapter while a request slot actively decodes with it:
    /// pinned adapters are never chosen as eviction victims, so neither a
    /// synchronous miss nor a speculative prefetch can overwrite a bank slot
    /// that live decode rows still reference. Refcounted — pin once per slot.
    pub fn pin(&mut self, id: AdapterId) {
        debug_assert!(self.is_resident(id), "pin of non-resident adapter {id}");
        *self.pins.entry(id).or_insert(0) += 1;
    }

    /// Release one pin on `id` (when the pinning request completes).
    pub fn unpin(&mut self, id: AdapterId) {
        match self.pins.get_mut(&id) {
            Some(n) if *n > 1 => *n -= 1,
            Some(_) => {
                self.pins.remove(&id);
            }
            None => debug_assert!(false, "unpin without pin for {id}"),
        }
    }

    /// Number of distinct pinned adapters.
    pub fn pinned_count(&self) -> usize {
        self.pins.len()
    }

    /// Whether `id` holds at least one pin.
    pub fn is_pinned(&self, id: AdapterId) -> bool {
        self.pins.contains_key(&id)
    }

    /// Drop every trace of a deleted adapter: cache residency (block and
    /// pages back to the pool) and any speculative prefetch. Errors while
    /// the adapter is still pinned — the caller drains in-flight users
    /// first. Returns whether anything was resident.
    pub fn drop_adapter(&mut self, id: AdapterId) -> Result<bool> {
        if self.pins.contains_key(&id) {
            bail!("adapter {id} still pinned by an active request");
        }
        // absorb an in-flight read for this id so its lent buffer comes home
        while self
            .prefetch
            .as_ref()
            .is_some_and(|pf| pf.in_flight.contains_key(&id))
        {
            self.wait_in_flight_completion()?;
        }
        if let Some(pf) = self.prefetch.as_mut() {
            if let Some(ready) = pf.ready.remove(&id) {
                self.pool.release(ready.block);
                self.stats.prefetch_dropped += 1;
            }
        }
        let removed = match &mut self.cache {
            CacheImpl::Lru(c) => c.remove(id),
            CacheImpl::Lfu(c) => c.remove(id),
        };
        match removed {
            Some(res) => {
                self.pool.release(res.block);
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Turn on asynchronous prefetch: `threads` background readers, at most
    /// `depth` outstanding speculative loads.
    pub fn enable_prefetch(&mut self, threads: usize, depth: usize) {
        if depth == 0 || self.pool.n_blocks() < 2 {
            return; // nothing to overlap with a single block
        }
        self.prefetch = Some(PrefetchState {
            fetcher: Prefetcher::new(threads),
            in_flight: BTreeMap::new(),
            ready: BTreeMap::new(),
            depth,
        });
    }

    pub fn prefetch_enabled(&self) -> bool {
        self.prefetch.is_some()
    }

    pub fn capacity(&self) -> usize {
        self.pool.n_blocks()
    }

    pub fn resident_count(&self) -> usize {
        match &self.cache {
            CacheImpl::Lru(c) => c.len(),
            CacheImpl::Lfu(c) => c.len(),
        }
    }

    pub fn stats(&self) -> &MemoryStats {
        &self.stats
    }

    pub fn pool(&self) -> &MemoryPool {
        &self.pool
    }

    pub fn store(&self) -> &Arc<AdapterStore> {
        &self.store
    }

    /// Non-mutating residency check (used by adaptive adapter selection to
    /// prefer cached candidates *without* perturbing recency).
    pub fn is_resident(&self, id: AdapterId) -> bool {
        match &self.cache {
            CacheImpl::Lru(c) => c.contains(id),
            CacheImpl::Lfu(c) => c.contains(id),
        }
    }

    /// Look up the bank slot of a resident adapter without counting a lookup.
    pub fn peek_slot(&self, id: AdapterId) -> Option<usize> {
        match &self.cache {
            CacheImpl::Lru(c) => c.peek(id).map(|r| r.bank_slot),
            CacheImpl::Lfu(c) => c.peek(id).map(|r| r.bank_slot),
        }
    }

    /// Borrow a resident adapter's quantized payload (for bank upload —
    /// the backend dequantizes this exactly once).
    pub fn quant_view(&self, id: AdapterId) -> Option<QuantView<'_>> {
        let slot = self.peek_slot(id)?;
        Some(QuantView {
            bytes: self.pool.bytes(BlockHandle(slot)),
            quant: self.store.quant(),
            shape: self.store.shape(),
        })
    }

    /// Make `id` resident, touching recency. On miss: evict if full, read
    /// the quantized payload from the store straight into the freed block
    /// (zero-copy, no dequantization). Returns what happened so the caller
    /// can account load latency and update the device banks.
    pub fn ensure_resident(&mut self, id: AdapterId) -> Result<Residency> {
        self.stats.lookups += 1;
        // fast path: hit
        let hit = match &mut self.cache {
            CacheImpl::Lru(c) => c.get(id).copied(),
            CacheImpl::Lfu(c) => c.get(id).copied(),
        };
        if let Some(r) = hit {
            self.stats.hits += 1;
            return Ok(Residency::Hit(r));
        }
        if !self.store.contains(id) {
            bail!("adapter {id} not in store");
        }
        // miss: get a block, evicting if needed. A deferred attempt (every
        // block pinned) is not a real lookup — the same request retries —
        // so back the counter out to keep hit-rate denominators comparable.
        let Some((block, evicted)) = self.acquire_block_for_load()? else {
            self.stats.lookups -= 1;
            return Ok(Residency::Deferred);
        };
        // disk read straight into the pool block (one copy, still quantized)
        if let Err(e) = self.store.read_raw_into(id, self.pool.bytes_mut(block)) {
            self.pool.release(block);
            return Err(e);
        }
        self.stats.loads += 1;
        let resident = Resident {
            block,
            bank_slot: block.0,
        };
        match &mut self.cache {
            CacheImpl::Lru(c) => {
                let e = c.insert(id, resident);
                debug_assert!(e.is_none(), "evicted twice");
            }
            CacheImpl::Lfu(c) => {
                let e = c.insert(id, resident);
                debug_assert!(e.is_none(), "evicted twice");
            }
        }
        Ok(Residency::Loaded { resident, evicted })
    }

    /// Evict the coldest *unpinned* resident. Pinned entries are skipped in
    /// place — their recency/frequency standing is untouched.
    fn evict_one_unpinned(&mut self) -> Option<(AdapterId, Resident)> {
        let pins = &self.pins;
        match &mut self.cache {
            CacheImpl::Lru(c) => c.evict_lru_where(|id| !pins.contains_key(&id)),
            CacheImpl::Lfu(c) => c.evict_where(|id| !pins.contains_key(&id)),
        }
    }

    /// Page-pressure shrink (DESIGN.md §Unified paging): evict one unpinned
    /// resident and return its block (and pages) to the pool so the engine's
    /// KV side can grow. The engine prefers this over preempting a request —
    /// a cold adapter is cheaper to reload than a sequence is to recompute.
    pub fn evict_one_for_pressure(&mut self) -> Option<AdapterId> {
        let (victim, res) = self.evict_one_unpinned()?;
        self.stats.evictions += 1;
        self.pool.release(res.block);
        Some(victim)
    }

    /// Page-pressure reclaim of speculative state: absorb every in-flight
    /// background read (so the choice depends on issue order alone — the
    /// same determinism argument as `acquire_block_for_load`), then drop one
    /// finished-but-unclaimed prefetch, freeing its block and pages. Queued
    /// demand outranks speculation.
    pub fn reclaim_one_speculative(&mut self) -> bool {
        while self
            .prefetch
            .as_ref()
            .is_some_and(|pf| !pf.in_flight.is_empty())
        {
            if self.wait_in_flight_completion().is_err() {
                break;
            }
        }
        self.reclaim_one_ready()
    }

    /// Find a free block for a synchronous load: pool first, then unpinned
    /// cache eviction, then reclaiming speculative prefetch blocks. Returns
    /// Ok(None) when every block is pinned by an active request — the caller
    /// must defer and retry after a request completes.
    fn acquire_block_for_load(&mut self) -> Result<Option<(BlockHandle, Option<AdapterId>)>> {
        if let Some(b) = self.pool.acquire() {
            return Ok(Some((b, None)));
        }
        if let Some((victim, res)) = self.evict_one_unpinned() {
            self.stats.evictions += 1;
            self.pool.release(res.block);
            let b = self.pool.acquire().expect("block just freed");
            return Ok(Some((b, Some(victim))));
        }
        // No unpinned resident: reclaim speculative blocks. Absorb *every*
        // outstanding read first so the reclaim choice depends on issue
        // order alone, not on wall-clock completion order — the pressure
        // path stays deterministic on virtual clocks. (Blocking here costs
        // wall-clock microseconds; the path only triggers when all blocks
        // are pinned or speculative.)
        while self
            .prefetch
            .as_ref()
            .is_some_and(|pf| !pf.in_flight.is_empty())
        {
            self.wait_in_flight_completion()?;
        }
        loop {
            if let Some(b) = self.pool.acquire() {
                return Ok(Some((b, None)));
            }
            if !self.reclaim_one_ready() {
                break;
            }
        }
        if self.pins.is_empty() && !self.pool.page_starved() {
            // blocks are conserved: free + resident + speculative == capacity,
            // so this state is unreachable without pins — unless the pool is
            // page-backed and the engine's KV tables hold the pages (the
            // caller defers and retries once decode releases them)
            bail!("pool exhausted but cache empty");
        }
        Ok(None)
    }

    /// Drop one finished-but-unclaimed prefetch, freeing its block. Picks
    /// the youngest-issued (least likely to be claimed next) with an id
    /// tiebreak, so pressure reclaims are deterministic too.
    fn reclaim_one_ready(&mut self) -> bool {
        let Some(pf) = self.prefetch.as_mut() else {
            return false;
        };
        let Some(id) = pf
            .ready
            .iter()
            .max_by(|a, b| {
                a.1.issued_at
                    .partial_cmp(&b.1.issued_at)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.0.cmp(b.0))
            })
            .map(|(&id, _)| id)
        else {
            return false;
        };
        let ready = pf.ready.remove(&id).unwrap();
        self.pool.release(ready.block);
        self.stats.prefetch_dropped += 1;
        true
    }

    /// Block for one in-flight prefetch completion and absorb it (the read
    /// lands in `ready`, or its block is freed if the read failed).
    fn wait_in_flight_completion(&mut self) -> Result<()> {
        let Some(pf) = self.prefetch.as_ref() else {
            bail!("no prefetch in flight to wait for");
        };
        let Some(done) = pf.fetcher.recv_blocking() else {
            bail!("prefetch channel closed");
        };
        self.absorb_completion(done);
        Ok(())
    }

    /// Absorb one completed background read: restore the lent buffer to its
    /// block and move the prefetch to `ready` (or free the block if the read
    /// failed). Single home for the completion bookkeeping shared by the
    /// polling, claiming and reclaiming paths.
    fn absorb_completion(&mut self, done: Done) {
        let Some(pf) = self.prefetch.as_mut() else {
            return;
        };
        let inflight = pf
            .in_flight
            .remove(&done.id)
            .expect("completion for unknown prefetch");
        let block = inflight.block;
        let issued_at = inflight.issued_at;
        let ok = done.ok;
        let id = done.id;
        self.pool.restore(block, done.buf);
        if ok {
            let pf = self.prefetch.as_mut().unwrap();
            pf.ready.insert(id, Ready { block, issued_at });
        } else {
            self.pool.release(block);
            self.stats.prefetch_dropped += 1;
        }
    }

    /// Issue a speculative background load for `id` (no-op unless prefetch
    /// is enabled and worthwhile). `now` is the engine clock, used to credit
    /// the overlapped latency at claim time. At steady state the cache owns
    /// every pool block, so a prefetch may evict the LRU/LFU resident — the
    /// same policy a synchronous miss applies, justified because prefetches
    /// are only issued for adapters that *queued requests* already need.
    /// Returns whether a read was actually issued.
    pub fn prefetch(&mut self, id: AdapterId, now: f64) -> bool {
        // cheap in-memory guards first — this runs per queued request per
        // scheduler tick; the store-membership stat syscall comes last
        if self.is_resident(id) {
            return false;
        }
        let Some(pf) = self.prefetch.as_mut() else {
            return false;
        };
        if pf.in_flight.contains_key(&id) || pf.ready.contains_key(&id) {
            return false;
        }
        if pf.in_flight.len() + pf.ready.len() >= pf.depth {
            return false;
        }
        if !self.store.contains(id) {
            return false;
        }
        let block = match self.pool.acquire() {
            Some(b) => b,
            None => match self.evict_one_unpinned() {
                Some((_, res)) => {
                    self.stats.evictions += 1;
                    self.pool.release(res.block);
                    self.pool.acquire().expect("block just freed")
                }
                // every block pinned or speculative already — nothing to take
                None => return false,
            },
        };
        let buf = self.pool.lend(block);
        let pf = self.prefetch.as_mut().unwrap();
        pf.fetcher.spawn_read(Arc::clone(&self.store), id, buf);
        pf.in_flight.insert(id, InFlight { block, issued_at: now });
        self.stats.prefetch_issued += 1;
        true
    }

    /// True if `id` has a prefetch outstanding (in flight or ready).
    pub fn is_prefetching(&self, id: AdapterId) -> bool {
        self.prefetch
            .as_ref()
            .is_some_and(|pf| pf.in_flight.contains_key(&id) || pf.ready.contains_key(&id))
    }

    /// Outstanding speculative loads (in flight + ready).
    pub fn prefetch_outstanding(&self) -> usize {
        self.prefetch
            .as_ref()
            .map_or(0, |pf| pf.in_flight.len() + pf.ready.len())
    }

    /// Whether another `prefetch` call could be accepted right now (below
    /// the depth cap) — lets planners skip candidate scoring when saturated.
    pub fn prefetch_has_capacity(&self) -> bool {
        self.prefetch
            .as_ref()
            .is_some_and(|pf| pf.in_flight.len() + pf.ready.len() < pf.depth)
    }

    /// Drain completed background reads, restoring their buffers. Cheap;
    /// call once per scheduler iteration.
    pub fn poll_prefetch(&mut self) {
        loop {
            let Some(done) = self.prefetch.as_ref().and_then(|pf| pf.fetcher.try_recv())
            else {
                return;
            };
            self.absorb_completion(done);
        }
    }

    /// Deterministic drain for virtual-time engines: in model time, a read
    /// issued at `t` has certainly finished by `t + min_age_s`, so block for
    /// the (wall-clock µs) completion of every in-flight read whose virtual
    /// age has crossed that bound. This makes adoption order a pure function
    /// of virtual time — same trace + seed reproduces the same tables
    /// regardless of host thread scheduling. Wall-clock engines should use
    /// `poll_prefetch` instead (blocking would forfeit the overlap).
    pub fn settle_prefetch(&mut self, min_age_s: f64, now: f64) {
        self.poll_prefetch();
        loop {
            let due = self.prefetch.as_ref().and_then(|pf| {
                pf.in_flight
                    .iter()
                    .find(|(_, inf)| now - inf.issued_at >= min_age_s)
                    .map(|(&id, _)| id)
            });
            if due.is_none() {
                return;
            }
            if self.wait_in_flight_completion().is_err() {
                return;
            }
        }
    }

    /// Claim a prefetched adapter for a request that now needs it: waits for
    /// an in-flight read if necessary, inserts the adapter into the cache and
    /// reports how much of the load latency was covered by the overlap.
    /// Counts as a (non-hit) cache lookup. Returns None if no usable prefetch
    /// exists (caller falls back to the synchronous `ensure_resident`).
    pub fn take_prefetched(&mut self, id: AdapterId, now: f64) -> Option<PrefetchClaim> {
        self.prefetch.as_ref()?;
        self.poll_prefetch();
        // wait out an in-flight read for exactly this adapter
        while self
            .prefetch
            .as_ref()
            .is_some_and(|pf| pf.in_flight.contains_key(&id))
        {
            let done = self.prefetch.as_ref().unwrap().fetcher.recv_blocking()?;
            self.absorb_completion(done);
        }
        let claim = self.claim_ready(id, now)?;
        self.stats.lookups += 1;
        Some(claim)
    }

    /// Adopt any one finished prefetch whose issue is at least `min_age_s`
    /// old (i.e. whose modeled load latency is fully covered by the
    /// overlap), inserting it into the cache as a bona-fide resident. The
    /// engine loop calls this each iteration so prefetched adapters are
    /// visible to adaptive adapter selection *before* their requests are
    /// scheduled; the caller must still upload the returned resident's bank
    /// slot. Returns None when nothing old enough is ready.
    pub fn take_ready_prefetch(
        &mut self,
        min_age_s: f64,
        now: f64,
    ) -> Option<(AdapterId, PrefetchClaim)> {
        let pf = self.prefetch.as_ref()?;
        // oldest-issued first (id tiebreak): deterministic adoption order
        let id = pf
            .ready
            .iter()
            .filter(|(_, r)| now - r.issued_at >= min_age_s)
            .min_by(|a, b| {
                a.1.issued_at
                    .partial_cmp(&b.1.issued_at)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.0.cmp(b.0))
            })
            .map(|(&id, _)| id)?;
        let claim = self.claim_ready(id, now)?;
        Some((id, claim))
    }

    /// Move a ready prefetch into the cache. Counts the load and the
    /// prefetch hit; the caller decides whether a lookup is also counted.
    fn claim_ready(&mut self, id: AdapterId, now: f64) -> Option<PrefetchClaim> {
        let pf = self.prefetch.as_mut()?;
        let ready = pf.ready.remove(&id)?;
        if self.is_resident(id) {
            // loaded through another path while the prefetch ran — drop it
            self.pool.release(ready.block);
            self.stats.prefetch_dropped += 1;
            return None;
        }
        let resident = Resident {
            block: ready.block,
            bank_slot: ready.block.0,
        };
        match &mut self.cache {
            CacheImpl::Lru(c) => {
                let e = c.insert(id, resident);
                debug_assert!(e.is_none(), "prefetch claim evicted");
            }
            CacheImpl::Lfu(c) => {
                let e = c.insert(id, resident);
                debug_assert!(e.is_none(), "prefetch claim evicted");
            }
        }
        self.stats.loads += 1;
        self.stats.prefetch_hits += 1;
        Some(PrefetchClaim {
            resident,
            covered_s: (now - ready.issued_at).max(0.0),
        })
    }

    /// Read a resident adapter's dequantized weights (compat path for bank
    /// upload through the nested-Vec form; hot paths use `quant_view`).
    pub fn read_weights(&self, id: AdapterId) -> Option<LoraWeights> {
        Some(self.quant_view(id)?.to_weights())
    }

    /// Prefill the cache with the first `n` adapters (server init does this
    /// with random adapters per §4.2; deterministic ids keep tests stable).
    pub fn warm(&mut self, ids: impl IntoIterator<Item = AdapterId>) -> Result<usize> {
        let mut n = 0;
        for id in ids {
            if self.resident_count() == self.capacity() {
                break;
            }
            self.ensure_resident(id)?;
            n += 1;
        }
        // warm-up shouldn't count toward runtime stats
        self.stats = MemoryStats::default();
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::LoraShape;
    use crate::quant::QuantType;

    const SHAPE: LoraShape = LoraShape {
        n_layers: 2,
        d_model: 16,
        rank: 4,
    };

    fn mk_with(
        capacity: usize,
        policy: CachePolicy,
        quant: QuantType,
        tag: &str,
    ) -> AdapterMemoryManager {
        let dir = std::env::temp_dir().join(format!(
            "elra_mgr_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = AdapterStore::create(&dir, SHAPE, quant).unwrap();
        store.populate_synthetic(16).unwrap();
        AdapterMemoryManager::new(Arc::new(store), capacity, policy)
    }

    fn mk(capacity: usize, policy: CachePolicy, tag: &str) -> AdapterMemoryManager {
        mk_with(capacity, policy, QuantType::Q8_0, tag)
    }

    #[test]
    fn hit_after_load() {
        let mut m = mk(2, CachePolicy::Lru, "hit");
        let r1 = m.ensure_resident(3).unwrap();
        assert!(!r1.is_hit());
        let r2 = m.ensure_resident(3).unwrap();
        assert!(r2.is_hit());
        assert_eq!(r1.resident(), r2.resident());
        assert_eq!(m.stats().hit_rate(), 0.5);
    }

    #[test]
    fn eviction_returns_block_to_pool() {
        let mut m = mk(2, CachePolicy::Lru, "evict");
        m.ensure_resident(0).unwrap();
        m.ensure_resident(1).unwrap();
        assert_eq!(m.pool().free_blocks(), 0);
        let r = m.ensure_resident(2).unwrap();
        match r {
            Residency::Loaded { evicted, .. } => assert_eq!(evicted, Some(0)),
            _ => panic!("expected load"),
        }
        assert_eq!(m.resident_count(), 2);
        assert!(!m.is_resident(0));
        assert!(m.is_resident(1) && m.is_resident(2));
        assert_eq!(m.stats().evictions, 1);
    }

    #[test]
    fn bank_slots_unique_and_stable() {
        let mut m = mk(4, CachePolicy::Lru, "slots");
        let mut slots = Vec::new();
        for id in 0..4 {
            slots.push(m.ensure_resident(id).unwrap().resident().bank_slot);
        }
        let mut sorted = slots.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4, "slots must be distinct: {slots:?}");
        assert!(slots.iter().all(|&s| s < 4));
        // slot is reused by the replacement after eviction
        let r = m.ensure_resident(10).unwrap().resident();
        assert!(slots.contains(&r.bank_slot));
    }

    #[test]
    fn weights_roundtrip_through_pool() {
        let mut m = mk(2, CachePolicy::Lru, "weights");
        m.ensure_resident(5).unwrap();
        let w = m.read_weights(5).unwrap();
        // Q8 roundtrip of the synthetic adapter
        let orig = LoraWeights::synthetic(SHAPE, 5);
        let bound = crate::quant::q8_0::error_bound(orig.amax());
        for (x, y) in orig.flatten().iter().zip(w.flatten().iter()) {
            assert!((x - y).abs() <= bound);
        }
    }

    #[test]
    fn zero_copy_path_bit_identical_to_legacy_get() {
        // The tentpole invariant: dequantizing the pool block must equal the
        // old get→unflatten→flatten chain bit-for-bit, for every quant type.
        for (quant, tag) in [
            (QuantType::F32, "zcf32"),
            (QuantType::Q8_0, "zcq8"),
            (QuantType::Q4_0, "zcq4"),
        ] {
            let mut m = mk_with(3, CachePolicy::Lru, quant, tag);
            for id in [0u64, 7, 13] {
                m.ensure_resident(id).unwrap();
                let legacy = m.store().get(id).unwrap().flatten();
                let zero_copy = m.quant_view(id).unwrap().dequantize();
                assert_eq!(legacy, zero_copy, "{tag} id {id}");
            }
        }
    }

    #[test]
    fn shard_indirection_and_resident_export() {
        let mut m = mk(3, CachePolicy::Lru, "shard").with_shard(2);
        assert_eq!(m.shard(), 2);
        m.ensure_resident(4).unwrap();
        m.ensure_resident(9).unwrap();
        // bank_ref carries the shard and agrees with peek_slot
        let r = m.bank_ref(4).unwrap();
        assert_eq!(r.shard, 2);
        assert_eq!(Some(r.slot), m.peek_slot(4));
        assert!(m.bank_ref(7).is_none(), "non-resident has no bank ref");
        // export matches residency exactly and perturbs no recency:
        // 4 is still LRU, so inserting past capacity evicts it
        let mut ids: Vec<u64> = m.resident_iter().collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![4, 9]);
        m.ensure_resident(1).unwrap();
        m.ensure_resident(2).unwrap(); // capacity 3: evicts LRU = 4
        assert!(!m.is_resident(4), "resident_iter must not touch recency");
        // LFU flavor exports too
        let mut f = mk(2, CachePolicy::Lfu, "shardlfu");
        f.ensure_resident(0).unwrap();
        assert_eq!(f.resident_iter().collect::<Vec<_>>(), vec![0]);
        assert_eq!(f.bank_ref(0).unwrap().shard, 0);
    }

    #[test]
    fn drop_adapter_releases_block_and_refuses_pinned() {
        let mut m = mk(3, CachePolicy::Lru, "drop");
        m.ensure_resident(1).unwrap();
        m.ensure_resident(2).unwrap();
        assert_eq!(m.pool().free_blocks(), 1);
        m.pin(1);
        assert!(m.drop_adapter(1).is_err(), "pinned adapter must not drop");
        m.unpin(1);
        assert!(m.drop_adapter(1).unwrap());
        assert!(!m.is_resident(1));
        assert_eq!(m.pool().free_blocks(), 2, "block returned to the pool");
        assert!(!m.drop_adapter(1).unwrap(), "second drop is a no-op");
        // a speculative prefetch is reclaimed by the drop too
        m.enable_prefetch(1, 2);
        assert!(m.prefetch(7, 0.0));
        assert!(!m.drop_adapter(7).unwrap(), "prefetch-only drop: not resident");
        assert!(!m.is_prefetching(7), "speculative read reclaimed by drop");
        // LFU flavor drops as well
        let mut f = mk(2, CachePolicy::Lfu, "droplfu");
        f.ensure_resident(0).unwrap();
        assert!(f.drop_adapter(0).unwrap());
        assert!(!f.is_resident(0));
    }

    #[test]
    fn missing_adapter_errors() {
        let mut m = mk(2, CachePolicy::Lru, "missing");
        assert!(m.ensure_resident(999).is_err());
    }

    #[test]
    fn warm_fills_cache_and_resets_stats() {
        let mut m = mk(3, CachePolicy::Lru, "warm");
        let n = m.warm(0..10).unwrap();
        assert_eq!(n, 3);
        assert_eq!(m.resident_count(), 3);
        assert_eq!(m.stats().lookups, 0);
    }

    #[test]
    fn lfu_policy_keeps_hot_adapter() {
        let mut m = mk(2, CachePolicy::Lfu, "lfu");
        m.ensure_resident(0).unwrap();
        for _ in 0..5 {
            m.ensure_resident(0).unwrap(); // heat up 0
        }
        m.ensure_resident(1).unwrap();
        m.ensure_resident(2).unwrap(); // must evict 1, not hot 0
        assert!(m.is_resident(0));
        assert!(!m.is_resident(1));
    }

    #[test]
    fn is_resident_does_not_count_as_lookup() {
        let mut m = mk(2, CachePolicy::Lru, "peek");
        m.ensure_resident(0).unwrap();
        let lookups = m.stats().lookups;
        let _ = m.is_resident(0);
        let _ = m.peek_slot(0);
        assert_eq!(m.stats().lookups, lookups);
    }

    #[test]
    fn pinned_adapters_survive_eviction_pressure() {
        let mut m = mk(2, CachePolicy::Lru, "pin");
        m.ensure_resident(0).unwrap();
        m.pin(0);
        m.ensure_resident(1).unwrap();
        // pool full; LRU victim would be 0 but it is pinned → evict 1
        m.ensure_resident(2).unwrap();
        assert!(m.is_resident(0) && m.is_resident(2) && !m.is_resident(1));
        m.enable_prefetch(1, 2);
        m.pin(2);
        // every block pinned: sync load defers, prefetch refuses
        assert!(m.ensure_resident(5).unwrap().is_deferred());
        assert!(!m.prefetch(6, 0.0));
        // deferral does not distort lookup stats
        let lookups = m.stats().lookups;
        assert!(m.ensure_resident(5).unwrap().is_deferred());
        assert_eq!(m.stats().lookups, lookups);
        // releasing a pin unblocks the load
        m.unpin(0);
        assert!(!m.ensure_resident(5).unwrap().is_hit());
        assert!(m.is_resident(5) && !m.is_resident(0) && m.is_resident(2));
    }

    #[test]
    fn prefetch_claim_inserts_into_cache() {
        let mut m = mk(4, CachePolicy::Lru, "pfclaim");
        m.enable_prefetch(1, 2);
        assert!(m.prefetch(3, 10.0));
        assert!(m.is_prefetching(3));
        // double-issue is refused
        assert!(!m.prefetch(3, 10.0));
        let claim = m.take_prefetched(3, 12.5).expect("claimable");
        assert!((claim.covered_s - 2.5).abs() < 1e-9);
        assert!(m.is_resident(3));
        assert_eq!(m.stats().prefetch_issued, 1);
        assert_eq!(m.stats().prefetch_hits, 1);
        // subsequent lookup is a plain hit
        assert!(m.ensure_resident(3).unwrap().is_hit());
        // bit-identical payload came through the background path
        let legacy = m.store().get(3).unwrap().flatten();
        assert_eq!(legacy, m.quant_view(3).unwrap().dequantize());
    }

    #[test]
    fn prefetch_respects_depth_and_evicts_at_steady_state() {
        // depth cap
        let mut m2 = mk(8, CachePolicy::Lru, "pfdepth2");
        m2.enable_prefetch(1, 2);
        assert!(m2.prefetch(0, 0.0));
        assert!(m2.prefetch(1, 0.0));
        assert!(!m2.prefetch(2, 0.0), "depth cap");
        // steady state (cache owns every block): prefetch evicts the LRU
        let mut m = mk(3, CachePolicy::Lru, "pfsteady");
        m.enable_prefetch(1, 2);
        m.ensure_resident(0).unwrap();
        m.ensure_resident(1).unwrap();
        m.ensure_resident(2).unwrap();
        assert_eq!(m.pool().free_blocks(), 0);
        assert!(m.prefetch(9, 0.0), "must evict for queued demand");
        assert!(!m.is_resident(0), "LRU resident evicted");
        assert_eq!(m.stats().evictions, 1);
        let claim = m.take_prefetched(9, 1.0).expect("claimable");
        assert!(m.is_resident(9));
        assert!(claim.covered_s > 0.0);
        // all blocks speculative → nothing left to take
        let mut m3 = mk(2, CachePolicy::Lru, "pfall");
        m3.enable_prefetch(1, 8);
        assert!(m3.prefetch(0, 0.0));
        assert!(m3.prefetch(1, 0.0));
        assert!(!m3.prefetch(2, 0.0), "every block already speculative");
    }

    #[test]
    fn sync_loads_evict_around_outstanding_prefetch() {
        // capacity 2: with one block speculatively held, sync loads keep
        // working through the eviction path and never touch the prefetch
        // block, which stays claimable afterwards.
        let mut m = mk(2, CachePolicy::Lru, "pfpressure");
        m.enable_prefetch(1, 4);
        assert!(m.prefetch(0, 0.0));
        m.ensure_resident(1).unwrap(); // uses the last free block
        // pool exhausted, cache has {1}: evicts 1
        m.ensure_resident(2).unwrap();
        // pool exhausted, cache has {2}: evicts 2 — prefetch block untouched
        m.ensure_resident(3).unwrap();
        assert!(m.is_resident(3));
        // the prefetched adapter is still claimable or reclaimable
        m.poll_prefetch();
        let _ = m.take_prefetched(0, 1.0);
    }

    fn mk_paged(
        capacity: usize,
        shared: SharedPages,
        pages_per_block: usize,
        tag: &str,
    ) -> AdapterMemoryManager {
        let dir = std::env::temp_dir().join(format!(
            "elra_mgrpg_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = AdapterStore::create(&dir, SHAPE, QuantType::Q8_0).unwrap();
        store.populate_synthetic(16).unwrap();
        AdapterMemoryManager::new_paged(
            Arc::new(store),
            capacity,
            CachePolicy::Lru,
            shared,
            pages_per_block,
        )
    }

    #[test]
    fn paged_manager_defers_under_kv_pressure_and_sheds_for_it() {
        let shared = SharedPages::new(4, 64);
        let mut m = mk_paged(2, shared.clone(), 2, "kvpress");
        m.ensure_resident(0).unwrap();
        // a KV consumer takes the remaining pages
        let mut kv = Vec::with_capacity(2);
        assert!(shared.alloc_n_into(2, &mut kv));
        // miss under page pressure: the unpinned resident is evicted and its
        // pages immediately re-used for the incoming adapter
        assert!(!m.ensure_resident(1).unwrap().is_hit());
        assert!(m.is_resident(1) && !m.is_resident(0));
        // pinned resident + zero free pages: the load defers (no bail even
        // though only one block slot is occupied)
        m.pin(1);
        assert!(m.ensure_resident(2).unwrap().is_deferred());
        // pressure eviction skips pinned residents, sheds unpinned ones
        assert!(m.evict_one_for_pressure().is_none());
        m.unpin(1);
        assert_eq!(m.evict_one_for_pressure(), Some(1));
        assert_eq!(shared.free_pages(), 2, "shed block returned its pages");
        shared.free_all(&mut kv);
        assert!(!m.ensure_resident(2).unwrap().is_deferred());
    }

    #[test]
    fn paged_manager_empty_cache_page_starvation_defers_not_bails() {
        let shared = SharedPages::new(4, 64);
        let mut kv = Vec::with_capacity(4);
        assert!(shared.alloc_n_into(4, &mut kv));
        let mut m = mk_paged(2, shared.clone(), 2, "kvstarve");
        // nothing resident, nothing pinned, every page held by KV: the old
        // invariant would bail; the paged pool must defer instead
        assert!(m.ensure_resident(0).unwrap().is_deferred());
        shared.free_all(&mut kv);
        assert!(!m.ensure_resident(0).unwrap().is_deferred());
    }

    #[test]
    fn paged_zero_copy_path_still_bit_identical() {
        let shared = SharedPages::new(8, 64);
        let mut m = mk_paged(2, shared, 2, "kvzc");
        m.ensure_resident(3).unwrap();
        let legacy = m.store().get(3).unwrap().flatten();
        assert_eq!(legacy, m.quant_view(3).unwrap().dequantize());
    }

    #[test]
    fn sync_loads_share_pool_with_outstanding_prefetch() {
        // capacity 2, one block speculatively prefetched: sync loads use the
        // remaining block and then the eviction path, never touching the
        // speculative block.
        let mut m = mk(2, CachePolicy::Lru, "pfempty");
        m.enable_prefetch(1, 4);
        assert!(m.prefetch(0, 0.0));
        m.ensure_resident(5).unwrap();
        assert!(m.is_resident(5));
        // now pool exhausted (1 prefetch + 1 resident); evict path works
        m.ensure_resident(6).unwrap();
        assert!(m.is_resident(6) && !m.is_resident(5));
    }
}
