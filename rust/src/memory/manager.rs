//! Heterogeneous memory manager (§3.3 + §4.2): LRU (or LFU) adapter cache
//! backed by the pre-allocated block pool, fronting the on-disk adapter
//! store. This is the component that makes "thousands of adapters on one
//! edge device" possible: only `capacity` adapters are resident; the rest
//! live on disk and are swapped in on demand.
//!
//! Responsibilities:
//!   * cache lookup + recency/frequency maintenance (hit-rate H = h/h_total)
//!   * eviction: victim's pool block returns to the pool, then is reused for
//!     the incoming adapter (no runtime allocation)
//!   * the disk→memory load itself (read + dequantize into the block)
//!   * bank-slot assignment: each resident adapter owns one slot index in
//!     the L2 model's LoRA bank, so the coordinator can pass slot ids to the
//!     decode artifact directly.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::adapters::{AdapterId, AdapterStore, LoraWeights};
use crate::memory::lfu::LfuCache;
use crate::memory::lru::LruCache;
use crate::memory::pool::{BlockHandle, MemoryPool};

/// Cache replacement policy (§4.2 discusses both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    Lru,
    Lfu,
}

/// What the cache stores per resident adapter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Resident {
    pub block: BlockHandle,
    /// index into the model's LoRA bank (= pool block index by construction)
    pub bank_slot: usize,
}

enum CacheImpl {
    Lru(LruCache<Resident>),
    Lfu(LfuCache<Resident>),
}

/// Outcome of `ensure_resident`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// already in cache — zero cost
    Hit(Resident),
    /// loaded from disk into the given block (optionally after evicting)
    Loaded {
        resident: Resident,
        evicted: Option<AdapterId>,
    },
}

impl Residency {
    pub fn resident(&self) -> Resident {
        match self {
            Residency::Hit(r) => *r,
            Residency::Loaded { resident, .. } => *resident,
        }
    }
    pub fn is_hit(&self) -> bool {
        matches!(self, Residency::Hit(_))
    }
}

/// Statistics for EXPERIMENTS.md and the Tables 7–8 analysis.
#[derive(Debug, Default, Clone)]
pub struct MemoryStats {
    pub lookups: u64,
    pub hits: u64,
    pub loads: u64,
    pub evictions: u64,
}

impl MemoryStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }
}

pub struct AdapterMemoryManager {
    cache: CacheImpl,
    pool: MemoryPool,
    store: Arc<AdapterStore>,
    stats: MemoryStats,
}

impl AdapterMemoryManager {
    /// `capacity` = number of resident adapters = pool blocks = L2 bank slots.
    pub fn new(store: Arc<AdapterStore>, capacity: usize, policy: CachePolicy) -> Self {
        let block_elems = store.shape().total_elems();
        let cache = match policy {
            CachePolicy::Lru => CacheImpl::Lru(LruCache::new(capacity)),
            CachePolicy::Lfu => CacheImpl::Lfu(LfuCache::new(capacity)),
        };
        Self {
            cache,
            pool: MemoryPool::new(capacity, block_elems),
            store,
            stats: MemoryStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.pool.n_blocks()
    }

    pub fn resident_count(&self) -> usize {
        match &self.cache {
            CacheImpl::Lru(c) => c.len(),
            CacheImpl::Lfu(c) => c.len(),
        }
    }

    pub fn stats(&self) -> &MemoryStats {
        &self.stats
    }

    pub fn pool(&self) -> &MemoryPool {
        &self.pool
    }

    /// Non-mutating residency check (used by adaptive adapter selection to
    /// prefer cached candidates *without* perturbing recency).
    pub fn is_resident(&self, id: AdapterId) -> bool {
        match &self.cache {
            CacheImpl::Lru(c) => c.contains(id),
            CacheImpl::Lfu(c) => c.contains(id),
        }
    }

    /// Look up the bank slot of a resident adapter without counting a lookup.
    pub fn peek_slot(&self, id: AdapterId) -> Option<usize> {
        match &self.cache {
            CacheImpl::Lru(c) => c.peek(id).map(|r| r.bank_slot),
            CacheImpl::Lfu(c) => c.peek(id).map(|r| r.bank_slot),
        }
    }

    /// Make `id` resident, touching recency. On miss: evict if full, read +
    /// dequantize from the store into the freed block. Returns what happened
    /// so the caller can account load latency and update the device banks.
    pub fn ensure_resident(&mut self, id: AdapterId) -> Result<Residency> {
        self.stats.lookups += 1;
        // fast path: hit
        let hit = match &mut self.cache {
            CacheImpl::Lru(c) => c.get(id).copied(),
            CacheImpl::Lfu(c) => c.get(id).copied(),
        };
        if let Some(r) = hit {
            self.stats.hits += 1;
            return Ok(Residency::Hit(r));
        }
        if !self.store.contains(id) {
            bail!("adapter {id} not in store");
        }
        // miss: get a block, evicting if needed
        let (block, evicted) = match self.pool.acquire() {
            Some(b) => (b, None),
            None => {
                let (victim, res) = match &mut self.cache {
                    CacheImpl::Lru(c) => c.evict_lru(),
                    CacheImpl::Lfu(c) => c.evict(),
                }
                .expect("pool exhausted but cache empty");
                self.stats.evictions += 1;
                self.pool.release(res.block);
                let b = self.pool.acquire().expect("block just freed");
                (b, Some(victim))
            }
        };
        // disk read + dequantize into the pool block
        let weights = self.store.get(id)?;
        self.pool.write(block, &weights.flatten());
        self.stats.loads += 1;
        let resident = Resident {
            block,
            bank_slot: block.0,
        };
        match &mut self.cache {
            CacheImpl::Lru(c) => {
                let e = c.insert(id, resident);
                debug_assert!(e.is_none(), "evicted twice");
            }
            CacheImpl::Lfu(c) => {
                let e = c.insert(id, resident);
                debug_assert!(e.is_none(), "evicted twice");
            }
        }
        Ok(Residency::Loaded { resident, evicted })
    }

    /// Read a resident adapter's dequantized weights (for bank upload).
    pub fn read_weights(&self, id: AdapterId) -> Option<LoraWeights> {
        let slot = self.peek_slot(id)?;
        let flat = self.pool.read(BlockHandle(slot));
        Some(LoraWeights::unflatten(self.store.shape(), flat))
    }

    /// Prefill the cache with the first `n` adapters (server init does this
    /// with random adapters per §4.2; deterministic ids keep tests stable).
    pub fn warm(&mut self, ids: impl IntoIterator<Item = AdapterId>) -> Result<usize> {
        let mut n = 0;
        for id in ids {
            if self.resident_count() == self.capacity() {
                break;
            }
            self.ensure_resident(id)?;
            n += 1;
        }
        // warm-up shouldn't count toward runtime stats
        self.stats = MemoryStats::default();
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::LoraShape;
    use crate::quant::QuantType;

    const SHAPE: LoraShape = LoraShape {
        n_layers: 2,
        d_model: 16,
        rank: 4,
    };

    fn mk(capacity: usize, policy: CachePolicy, tag: &str) -> AdapterMemoryManager {
        let dir = std::env::temp_dir().join(format!(
            "elra_mgr_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = AdapterStore::create(&dir, SHAPE, QuantType::Q8_0).unwrap();
        store.populate_synthetic(16).unwrap();
        AdapterMemoryManager::new(Arc::new(store), capacity, policy)
    }

    #[test]
    fn hit_after_load() {
        let mut m = mk(2, CachePolicy::Lru, "hit");
        let r1 = m.ensure_resident(3).unwrap();
        assert!(!r1.is_hit());
        let r2 = m.ensure_resident(3).unwrap();
        assert!(r2.is_hit());
        assert_eq!(r1.resident(), r2.resident());
        assert_eq!(m.stats().hit_rate(), 0.5);
    }

    #[test]
    fn eviction_returns_block_to_pool() {
        let mut m = mk(2, CachePolicy::Lru, "evict");
        m.ensure_resident(0).unwrap();
        m.ensure_resident(1).unwrap();
        assert_eq!(m.pool().free_blocks(), 0);
        let r = m.ensure_resident(2).unwrap();
        match r {
            Residency::Loaded { evicted, .. } => assert_eq!(evicted, Some(0)),
            _ => panic!("expected load"),
        }
        assert_eq!(m.resident_count(), 2);
        assert!(!m.is_resident(0));
        assert!(m.is_resident(1) && m.is_resident(2));
        assert_eq!(m.stats().evictions, 1);
    }

    #[test]
    fn bank_slots_unique_and_stable() {
        let mut m = mk(4, CachePolicy::Lru, "slots");
        let mut slots = Vec::new();
        for id in 0..4 {
            slots.push(m.ensure_resident(id).unwrap().resident().bank_slot);
        }
        let mut sorted = slots.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4, "slots must be distinct: {slots:?}");
        assert!(slots.iter().all(|&s| s < 4));
        // slot is reused by the replacement after eviction
        let r = m.ensure_resident(10).unwrap().resident();
        assert!(slots.contains(&r.bank_slot));
    }

    #[test]
    fn weights_roundtrip_through_pool() {
        let mut m = mk(2, CachePolicy::Lru, "weights");
        m.ensure_resident(5).unwrap();
        let w = m.read_weights(5).unwrap();
        // Q8 roundtrip of the synthetic adapter
        let orig = LoraWeights::synthetic(SHAPE, 5);
        let bound = crate::quant::q8_0::error_bound(orig.amax());
        for (x, y) in orig.flatten().iter().zip(w.flatten().iter()) {
            assert!((x - y).abs() <= bound);
        }
    }

    #[test]
    fn missing_adapter_errors() {
        let mut m = mk(2, CachePolicy::Lru, "missing");
        assert!(m.ensure_resident(999).is_err());
    }

    #[test]
    fn warm_fills_cache_and_resets_stats() {
        let mut m = mk(3, CachePolicy::Lru, "warm");
        let n = m.warm(0..10).unwrap();
        assert_eq!(n, 3);
        assert_eq!(m.resident_count(), 3);
        assert_eq!(m.stats().lookups, 0);
    }

    #[test]
    fn lfu_policy_keeps_hot_adapter() {
        let mut m = mk(2, CachePolicy::Lfu, "lfu");
        m.ensure_resident(0).unwrap();
        for _ in 0..5 {
            m.ensure_resident(0).unwrap(); // heat up 0
        }
        m.ensure_resident(1).unwrap();
        m.ensure_resident(2).unwrap(); // must evict 1, not hot 0
        assert!(m.is_resident(0));
        assert!(!m.is_resident(1));
    }

    #[test]
    fn is_resident_does_not_count_as_lookup() {
        let mut m = mk(2, CachePolicy::Lru, "peek");
        m.ensure_resident(0).unwrap();
        let lookups = m.stats().lookups;
        let _ = m.is_resident(0);
        let _ = m.peek_slot(0);
        assert_eq!(m.stats().lookups, lookups);
    }
}
