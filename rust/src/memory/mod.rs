//! Heterogeneous memory management (§3.3): adapter cache (LRU/LFU) +
//! pre-allocated fixed-block pool + the manager that fronts the disk store.

pub mod lfu;
pub mod lru;
pub mod manager;
pub mod pool;
pub mod prefetch;

pub use manager::{
    AdapterMemoryManager, BankRef, CachePolicy, MemoryStats, PrefetchClaim, Residency,
    Resident,
};
pub use pool::{BlockHandle, MemoryPool};
