//! Heterogeneous memory management (§3.3): adapter cache (LRU/LFU) +
//! pre-allocated fixed-block pool + the manager that fronts the disk store,
//! all drawing from one unified page allocator when paging is enabled
//! (DESIGN.md §Unified paging — KV caches share the same budget).

pub mod lfu;
pub mod lru;
pub mod manager;
pub mod paging;
pub mod pool;
pub mod prefetch;

pub use manager::{
    AdapterMemoryManager, BankRef, CachePolicy, MemoryStats, PrefetchClaim, Residency,
    Resident,
};
pub use paging::{
    boundary_hashes, kv_entry, pages_for, KvEnsure, KvTable, PageAllocator, PageId,
    PrefixCache, SharedPages,
};
pub use pool::{BlockHandle, MemoryPool};
