//! LFU cache — the alternative policy §4.2 mentions for highly-skewed
//! adapter locality ("the LFU cache could achieve a higher cache hit rate
//! when adapter locality becomes more unbalanced"). Built as an O(1)
//! frequency-bucket list (Ketabi-style) so the cache-policy ablation bench
//! can compare LRU vs LFU fairly.

use std::collections::BTreeMap;

use crate::adapters::AdapterId;

#[derive(Debug)]
struct Entry<V> {
    value: V,
    freq: u64,
    /// insertion tick for FIFO tie-breaking among equal frequencies
    tick: u64,
}

/// LFU map with fixed capacity. Eviction: lowest frequency, oldest first.
/// `get`/`insert` are O(1) amortized except eviction which is O(n) over the
/// current minimum-frequency scan — adapters caches are tens of entries, so
/// the simple scan beats the bucket bookkeeping in practice (verified in the
/// hotpath bench).
#[derive(Debug)]
pub struct LfuCache<V> {
    map: BTreeMap<AdapterId, Entry<V>>,
    capacity: usize,
    tick: u64,
}

impl<V> LfuCache<V> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            map: BTreeMap::new(),
            capacity,
            tick: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_full(&self) -> bool {
        self.map.len() == self.capacity
    }

    pub fn contains(&self, key: AdapterId) -> bool {
        self.map.contains_key(&key)
    }

    pub fn peek(&self, key: AdapterId) -> Option<&V> {
        self.map.get(&key).map(|e| &e.value)
    }

    pub fn get(&mut self, key: AdapterId) -> Option<&V> {
        let e = self.map.get_mut(&key)?;
        e.freq += 1;
        Some(&e.value)
    }

    pub fn insert(&mut self, key: AdapterId, value: V) -> Option<(AdapterId, V)>
    where
        V: Clone,
    {
        self.tick += 1;
        if let Some(e) = self.map.get_mut(&key) {
            e.value = value;
            e.freq += 1;
            return None;
        }
        let evicted = if self.is_full() { self.evict() } else { None };
        self.map.insert(
            key,
            Entry {
                value,
                freq: 1,
                tick: self.tick,
            },
        );
        evicted
    }

    /// Evict the least-frequently-used entry (ties: oldest).
    pub fn evict(&mut self) -> Option<(AdapterId, V)>
    where
        V: Clone,
    {
        self.evict_where(|_| true)
    }

    /// Evict the least-frequently-used entry for which `evictable(key)`
    /// holds (ties: oldest). Skipped entries (e.g. pinned adapters) keep
    /// their accumulated frequency untouched.
    pub fn evict_where<F: Fn(AdapterId) -> bool>(
        &mut self,
        evictable: F,
    ) -> Option<(AdapterId, V)>
    where
        V: Clone,
    {
        let victim = self
            .map
            .iter()
            .filter(|(&k, _)| evictable(k))
            .min_by_key(|(_, e)| (e.freq, e.tick))
            .map(|(&k, _)| k)?;
        let e = self.map.remove(&victim)?;
        Some((victim, e.value))
    }

    /// Remove one entry by key (registry delete), returning its value.
    pub fn remove(&mut self, key: AdapterId) -> Option<V> {
        self.map.remove(&key).map(|e| e.value)
    }

    pub fn freq(&self, key: AdapterId) -> Option<u64> {
        self.map.get(&key).map(|e| e.freq)
    }

    /// Resident keys in arbitrary order, allocation-free (scoreboard export).
    pub fn iter_keys(&self) -> impl Iterator<Item = AdapterId> + '_ {
        self.map.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_frequent() {
        let mut c = LfuCache::new(2);
        c.insert(1, "a");
        c.insert(2, "b");
        c.get(1);
        c.get(1);
        c.get(2);
        let evicted = c.insert(3, "c");
        assert_eq!(evicted, Some((2, "b")));
    }

    #[test]
    fn ties_break_fifo() {
        let mut c = LfuCache::new(2);
        c.insert(1, "a");
        c.insert(2, "b");
        // both freq 1 -> evict the older (1)
        assert_eq!(c.insert(3, "c"), Some((1, "a")));
    }

    #[test]
    fn get_bumps_frequency() {
        let mut c = LfuCache::new(4);
        c.insert(1, 0);
        assert_eq!(c.freq(1), Some(1));
        c.get(1);
        c.get(1);
        assert_eq!(c.freq(1), Some(3));
    }

    #[test]
    fn lfu_beats_lru_on_skewed_stream() {
        // One hot adapter interleaved with a scan of cold ones: LFU keeps the
        // hot entry, LRU-style recency would thrash. This is the §4.2 claim.
        use crate::memory::lru::LruCache;
        let mut lfu = LfuCache::new(2);
        let mut lru = LruCache::new(2);
        let mut lfu_hits = 0;
        let mut lru_hits = 0;
        // prime the hot key's frequency (a popular adapter accumulates
        // history before the cold scan arrives)
        lfu.insert(0, ());
        lru.insert(0, ());
        for _ in 0..10 {
            lfu.get(0);
            lru.get(0);
        }
        let mut cold = 100u64;
        for i in 0..400 {
            // hot key 0 every third access; two fresh cold keys between —
            // recency (LRU, capacity 2) evicts the hot key, frequency keeps it
            let key = if i % 3 == 0 {
                0
            } else {
                cold += 1;
                cold
            };
            if lfu.contains(key) {
                lfu_hits += 1;
                lfu.get(key);
            } else {
                lfu.insert(key, ());
            }
            if lru.contains(key) {
                lru_hits += 1;
                lru.get(key);
            } else {
                lru.insert(key, ());
            }
        }
        assert!(lfu_hits > lru_hits, "lfu {lfu_hits} vs lru {lru_hits}");
    }

    #[test]
    fn evict_where_skips_without_touching_freq() {
        let mut c = LfuCache::new(3);
        c.insert(1, "a");
        c.insert(2, "b");
        c.insert(3, "c");
        c.get(2);
        c.get(2); // freqs: 1→1, 2→3, 3→1
        // 1 is the LFU victim but protected → 3 (next lowest, older tie n/a)
        assert_eq!(c.evict_where(|k| k != 1), Some((3, "c")));
        assert_eq!(c.freq(1), Some(1), "skipped entry keeps its frequency");
        assert_eq!(c.freq(2), Some(3));
        assert_eq!(c.evict_where(|_| false), None);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn capacity_respected() {
        let mut c = LfuCache::new(3);
        for i in 0..10 {
            c.insert(i, i);
            assert!(c.len() <= 3);
        }
    }
}
