//! Background adapter prefetch: the disk half of an adapter swap
//! (`AdapterStore::read_raw_into`) issued on a [`ThreadPool`] so it overlaps
//! with decode instead of head-of-line-blocking the engine loop.
//!
//! Protocol (see `DESIGN.md` §Prefetch):
//!   1. the engine reserves a pool block and *lends* its buffer
//!      (`MemoryPool::lend`) to a read job;
//!   2. the job fills the buffer straight from disk — the same zero-copy
//!      read the synchronous path uses — and sends it back on a channel;
//!   3. the engine drains completions each scheduler iteration
//!      (`AdapterMemoryManager::poll_prefetch`) or blocks for a specific
//!      adapter at claim time (`take_prefetched`).
//!
//! The pool block never changes hands logically: it stays `in_use` and owned
//! by the manager; only the byte buffer travels, so the swap remains one
//! disk read + zero intermediate copies.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;

use crate::adapters::{AdapterId, AdapterStore};
use crate::util::threadpool::ThreadPool;

/// A completed background read, carrying the filled (or failed) buffer back.
pub(crate) struct Done {
    pub id: AdapterId,
    pub buf: Box<[u8]>,
    pub ok: bool,
}

/// Worker pool + completion channel for background adapter reads.
pub(crate) struct Prefetcher {
    workers: ThreadPool,
    tx: Sender<Done>,
    rx: Receiver<Done>,
}

impl Prefetcher {
    pub fn new(threads: usize) -> Self {
        let (tx, rx) = channel();
        Self {
            workers: ThreadPool::new(threads.max(1)),
            tx,
            rx,
        }
    }

    /// Issue one background read of adapter `id` into `buf` (a lent pool
    /// buffer). The buffer always comes back through the channel, success or
    /// not — a lost buffer would permanently disable its pool block.
    pub fn spawn_read(&self, store: Arc<AdapterStore>, id: AdapterId, mut buf: Box<[u8]>) {
        let tx = self.tx.clone();
        self.workers.execute(move || {
            let ok = store.read_raw_into(id, &mut buf).is_ok();
            let _ = tx.send(Done { id, buf, ok });
        });
    }

    /// Non-blocking completion poll.
    pub fn try_recv(&self) -> Option<Done> {
        match self.rx.try_recv() {
            Ok(d) => Some(d),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Block for the next completion. Only call with at least one read in
    /// flight (the sender side lives in `self`, so an empty queue would
    /// block forever otherwise).
    pub fn recv_blocking(&self) -> Option<Done> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::{LoraShape, LoraWeights};
    use crate::quant::QuantType;

    #[test]
    fn background_read_matches_sync_read() {
        let shape = LoraShape { n_layers: 1, d_model: 32, rank: 2 };
        let dir = std::env::temp_dir().join(format!("elra_pf_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(AdapterStore::create(&dir, shape, QuantType::Q8_0).unwrap());
        store.put(3, &LoraWeights::synthetic(shape, 3)).unwrap();

        let pf = Prefetcher::new(1);
        let buf = vec![0u8; store.payload_bytes()].into_boxed_slice();
        pf.spawn_read(Arc::clone(&store), 3, buf);
        let done = pf.recv_blocking().unwrap();
        assert!(done.ok);
        assert_eq!(done.id, 3);
        let mut sync = vec![0u8; store.payload_bytes()];
        store.read_raw_into(3, &mut sync).unwrap();
        assert_eq!(&done.buf[..], &sync[..]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_adapter_comes_back_not_ok() {
        let shape = LoraShape { n_layers: 1, d_model: 32, rank: 2 };
        let dir = std::env::temp_dir().join(format!("elra_pf2_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(AdapterStore::create(&dir, shape, QuantType::Q8_0).unwrap());
        let pf = Prefetcher::new(1);
        let buf = vec![0u8; store.payload_bytes()].into_boxed_slice();
        pf.spawn_read(store, 42, buf);
        let done = pf.recv_blocking().unwrap();
        assert!(!done.ok);
        assert_eq!(done.buf.len() > 0, true, "buffer must come back");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
