//! Unified page-granular memory (DESIGN.md §Unified paging, §Prefix
//! sharing): one free-list page allocator per device shard from which
//! **both** adapter blocks and per-slot KV caches are served, S-LoRA-style
//! (arXiv:2311.03285). Replaces the static worst-case
//! `kv_bytes_for(batch_width)` headroom the sim backend used to reserve:
//! short requests no longer pay for `max_seq` positions they never use, so
//! the reclaimed headroom becomes resident adapters and wider steady-state
//! batches at the same device budget.
//!
//! Layering:
//!   * [`PageAllocator`] — the raw free list, now *refcounted* so several
//!     requests of one adapter can map the same physical prompt page. Pages
//!     are *accounting* units (modeled device bytes) plus a small per-page
//!     content array of modeled KV entries the sim attention reads through
//!     the page table — which is what makes a freed-while-shared page an
//!     observable token-stream corruption instead of a silent bug. Adapter
//!     payload buffers stay where they always were (one contiguous buffer
//!     per [`MemoryPool`] block), keeping the zero-copy `QuantView` path
//!     intact.
//!   * [`SharedPages`] — the allocator behind an `Arc<Mutex<..>>` so the
//!     adapter pool (inside `AdapterMemoryManager`) and the engine's KV
//!     tables draw from one budget. All page traffic happens on the engine
//!     thread; the lock only exists so the engine type stays `Send`.
//!   * [`KvTable`] — one per request slot: pages appended lazily as decode
//!     advances (page-hit = pure arithmetic, page-fault = one free-list
//!     pop), released in bulk at request completion or preemption. A table
//!     may start with a *shared* chain mapped from the [`PrefixCache`]; the
//!     first write into a shared tail page copy-on-write forks it.
//!   * [`PrefixCache`] — the per-(adapter, prompt-prefix-hash) radix of
//!     immutable prompt pages. Admission maps matching chains instead of
//!     allocating; completed requests donate their prompt pages. Entries
//!     are reclaimable only at refcount 1 (held by nobody but the radix).
//!
//! [`MemoryPool`]: crate::memory::pool::MemoryPool

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::util::rng::splitmix64;

/// Handle to one page (index into the allocator's page array). Copy-cheap.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PageId(pub u32);

/// Modeled KV entry for `token` written at cache position `pos` — a pure
/// function of request content, so two requests with the same prompt write
/// bit-identical prompt pages (the property prefix sharing relies on).
#[inline]
pub fn kv_entry(token: u32, pos: usize) -> u64 {
    splitmix64(token as u64 ^ ((pos as u64) << 32) ^ 0x6b76_5eed)
}

/// Fixed-size refcounted free-list page allocator. Never allocates after
/// `new` on the metadata path: the free list and refcount array are
/// preallocated to `n_pages`; per-page content vectors grow to the page's
/// entry count once and keep their capacity across recycling.
#[derive(Debug)]
pub struct PageAllocator {
    free: Vec<PageId>,
    /// references per page: 0 = free, 1 = single owner, >1 = shared
    refs: Vec<u32>,
    /// modeled KV entries per page (see [`kv_entry`]); reads through a page
    /// table make refcount bugs visible as token-stream corruption
    entries: Vec<Vec<u64>>,
    page_bytes: usize,
    /// lifetime counters for diagnostics / the capacity table
    pub allocs: u64,
    pub frees: u64,
}

impl PageAllocator {
    pub fn new(n_pages: usize, page_bytes: usize) -> Self {
        assert!(n_pages > 0 && page_bytes > 0);
        assert!(n_pages <= u32::MAX as usize, "page id overflow");
        Self {
            free: (0..n_pages).rev().map(|i| PageId(i as u32)).collect(),
            refs: vec![0; n_pages],
            entries: (0..n_pages).map(|_| Vec::new()).collect(),
            page_bytes,
            allocs: 0,
            frees: 0,
        }
    }

    pub fn n_pages(&self) -> usize {
        self.refs.len()
    }

    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn total_bytes(&self) -> usize {
        self.refs.len() * self.page_bytes
    }

    /// Take one free page (refcount 1). None when exhausted (caller evicts
    /// or preempts). Stale content from the previous owner is cleared so a
    /// recycled page can never leak entries into a reader.
    pub fn alloc(&mut self) -> Option<PageId> {
        let p = self.free.pop()?;
        debug_assert_eq!(self.refs[p.0 as usize], 0, "free-list corruption");
        self.refs[p.0 as usize] = 1;
        self.entries[p.0 as usize].clear();
        self.allocs += 1;
        Some(p)
    }

    /// All-or-nothing: append `n` pages to `out`, or take none and return
    /// false. `out` must have spare capacity (page tables preallocate).
    pub fn alloc_n_into(&mut self, n: usize, out: &mut Vec<PageId>) -> bool {
        if self.free.len() < n {
            return false;
        }
        for _ in 0..n {
            out.push(self.alloc().expect("length checked"));
        }
        true
    }

    /// Add one reference to a mapped page (a second request mapping a
    /// shared prompt page, or the prefix radix adopting it).
    pub fn retain(&mut self, p: PageId) {
        let r = &mut self.refs[p.0 as usize];
        assert!(*r > 0, "retain of free page {p:?}");
        *r += 1;
    }

    /// Drop one reference; the page returns to the free list at refcount 0.
    /// Panics on over-free (a real bug).
    pub fn free(&mut self, p: PageId) {
        let r = &mut self.refs[p.0 as usize];
        assert!(*r > 0, "double free of page {p:?}");
        *r -= 1;
        if *r == 0 {
            self.free.push(p);
            self.frees += 1;
        }
    }

    /// Drain a page table back into the free list (one reference each).
    pub fn free_all(&mut self, table: &mut Vec<PageId>) {
        while let Some(p) = table.pop() {
            self.free(p);
        }
    }

    /// True if `p` is currently mapped (diagnostics/tests).
    pub fn is_mapped(&self, p: PageId) -> bool {
        self.refs.get(p.0 as usize).copied().unwrap_or(0) > 0
    }

    /// Current reference count of `p` (0 = free).
    pub fn refcount(&self, p: PageId) -> u32 {
        self.refs.get(p.0 as usize).copied().unwrap_or(0)
    }

    /// Write one modeled KV entry into a mapped page.
    pub fn write_entry(&mut self, p: PageId, idx: usize, value: u64) {
        debug_assert!(self.refs[p.0 as usize] > 0, "write to free page {p:?}");
        let v = &mut self.entries[p.0 as usize];
        if idx >= v.len() {
            v.resize(idx + 1, 0);
        }
        v[idx] = value;
    }

    /// Read one modeled KV entry (0 for never-written offsets).
    pub fn read_entry(&self, p: PageId, idx: usize) -> u64 {
        debug_assert!(self.refs[p.0 as usize] > 0, "read of free page {p:?}");
        self.entries[p.0 as usize].get(idx).copied().unwrap_or(0)
    }

    /// Copy the first `n` entries of `src` into `dst` (the COW fork).
    pub fn copy_entries(&mut self, src: PageId, dst: PageId, n: usize) {
        debug_assert!(self.refs[src.0 as usize] > 0 && self.refs[dst.0 as usize] > 0);
        let (s, d) = (src.0 as usize, dst.0 as usize);
        let take: Vec<u64> = self.entries[s].iter().take(n).copied().collect();
        let v = &mut self.entries[d];
        v.clear();
        v.extend_from_slice(&take);
    }
}

/// The page allocator shared between the adapter pool and the KV tables of
/// one device shard. Clones share the same underlying budget.
#[derive(Debug, Clone)]
pub struct SharedPages(Arc<Mutex<PageAllocator>>);

impl SharedPages {
    pub fn new(n_pages: usize, page_bytes: usize) -> Self {
        Self(Arc::new(Mutex::new(PageAllocator::new(n_pages, page_bytes))))
    }

    pub fn n_pages(&self) -> usize {
        self.0.lock().unwrap().n_pages()
    }

    pub fn page_bytes(&self) -> usize {
        self.0.lock().unwrap().page_bytes()
    }

    pub fn free_pages(&self) -> usize {
        self.0.lock().unwrap().free_pages()
    }

    pub fn total_bytes(&self) -> usize {
        self.0.lock().unwrap().total_bytes()
    }

    pub fn alloc(&self) -> Option<PageId> {
        self.0.lock().unwrap().alloc()
    }

    pub fn alloc_n_into(&self, n: usize, out: &mut Vec<PageId>) -> bool {
        self.0.lock().unwrap().alloc_n_into(n, out)
    }

    pub fn retain(&self, p: PageId) {
        self.0.lock().unwrap().retain(p)
    }

    pub fn free(&self, p: PageId) {
        self.0.lock().unwrap().free(p)
    }

    pub fn free_all(&self, table: &mut Vec<PageId>) {
        self.0.lock().unwrap().free_all(table)
    }

    pub fn refcount(&self, p: PageId) -> u32 {
        self.0.lock().unwrap().refcount(p)
    }

    pub fn write_entry(&self, p: PageId, idx: usize, value: u64) {
        self.0.lock().unwrap().write_entry(p, idx, value)
    }

    pub fn read_entry(&self, p: PageId, idx: usize) -> u64 {
        self.0.lock().unwrap().read_entry(p, idx)
    }

    pub fn copy_entries(&self, src: PageId, dst: PageId, n: usize) {
        self.0.lock().unwrap().copy_entries(src, dst, n)
    }

    pub fn allocs(&self) -> u64 {
        self.0.lock().unwrap().allocs
    }
}

/// Pages needed to hold `positions` KV entries at `page_tokens` per page.
pub fn pages_for(positions: usize, page_tokens: usize) -> usize {
    debug_assert!(page_tokens > 0);
    positions.div_ceil(page_tokens)
}

/// Outcome of [`KvTable::ensure_positions`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvEnsure {
    /// the table already covers the requested positions (page-hit)
    Fits,
    /// one page was appended (page-fault, served from the free list)
    Grew,
    /// the shared pool has no free page — caller must evict or preempt
    NoPage,
}

/// One request slot's KV page table: logical pages in append order. The
/// leading `shared` pages may be mapped from the [`PrefixCache`] (refcount
/// shared, immutable); everything after is private to this slot.
#[derive(Debug, Default)]
pub struct KvTable {
    pages: Vec<PageId>,
    /// leading pages mapped shared from the prefix radix
    shared: usize,
    /// prompt positions the shared chain covers (the tail shared page may
    /// be partially filled; writes below this boundary are illegal)
    shared_positions: usize,
}

impl KvTable {
    /// Preallocate for the worst-case request so append never reallocates.
    pub fn with_capacity(max_pages: usize) -> Self {
        Self {
            pages: Vec::with_capacity(max_pages),
            shared: 0,
            shared_positions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.pages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    pub fn page_capacity(&self) -> usize {
        self.pages.capacity()
    }

    /// The table's logical page chain (radix insert reads this).
    pub fn pages(&self) -> &[PageId] {
        &self.pages
    }

    /// Leading pages currently mapped shared from the prefix radix.
    pub fn shared_pages(&self) -> usize {
        self.shared
    }

    /// Prompt positions covered by the shared chain (0 when unshared).
    pub fn shared_positions(&self) -> usize {
        self.shared_positions
    }

    /// Map a shared prompt-prefix chain into an empty table: each page gains
    /// one reference; `covered` is the prompt positions the chain holds.
    pub fn map_shared(&mut self, chain: &[PageId], covered: usize, pages: &SharedPages) {
        assert!(self.pages.is_empty(), "shared chain maps into an empty table");
        assert!(chain.len() <= self.pages.capacity(), "chain exceeds slot capacity");
        for &p in chain {
            pages.retain(p);
            self.pages.push(p);
        }
        self.shared = chain.len();
        self.shared_positions = covered;
    }

    /// Grow to exactly `n_pages` mapped pages (admission reserves prompt
    /// pages + one decode page this way). All-or-nothing; false = no pages.
    pub fn grow_to(&mut self, n_pages: usize, pages: &SharedPages) -> bool {
        if n_pages <= self.pages.len() {
            return true;
        }
        assert!(
            n_pages <= self.pages.capacity(),
            "KV reservation {n_pages} exceeds per-slot page capacity {}",
            self.pages.capacity()
        );
        pages.alloc_n_into(n_pages - self.pages.len(), &mut self.pages)
    }

    /// Make the table cover `positions` KV entries, appending at most one
    /// page (decode adds one position per step). Errors when the request
    /// exceeds the per-slot worst case the table was sized for.
    pub fn ensure_positions(
        &mut self,
        positions: usize,
        page_tokens: usize,
        pages: &SharedPages,
    ) -> anyhow::Result<KvEnsure> {
        let need = pages_for(positions, page_tokens);
        if need <= self.pages.len() {
            return Ok(KvEnsure::Fits);
        }
        if need > self.pages.capacity() {
            anyhow::bail!(
                "request needs {need} KV pages, slot capacity is {}",
                self.pages.capacity()
            );
        }
        debug_assert_eq!(need, self.pages.len() + 1, "decode grows one page at a time");
        match pages.alloc() {
            Some(p) => {
                self.pages.push(p);
                Ok(KvEnsure::Grew)
            }
            None => Ok(KvEnsure::NoPage),
        }
    }

    /// Write the modeled KV entry for position `pos` through the page table.
    /// A write that lands in a shared tail page copy-on-write forks it first
    /// (using the spare page admission reserved at the table's end, so the
    /// fork can never fail for lack of pages). Returns whether a fork
    /// happened.
    pub fn write_pos(
        &mut self,
        pos: usize,
        page_tokens: usize,
        value: u64,
        pages: &SharedPages,
    ) -> bool {
        let idx = pos / page_tokens;
        assert!(idx < self.pages.len(), "write past mapped pages");
        let mut forked = false;
        if idx < self.shared {
            // shared pages are immutable; the only legal write is appending
            // into the partially-filled shared *tail* — fork it
            assert_eq!(idx + 1, self.shared, "write into an interior shared page");
            assert!(
                pos >= self.shared_positions,
                "overwrite of shared prefix content"
            );
            assert!(
                self.pages.len() > self.shared,
                "COW fork needs the admission-reserved spare page"
            );
            let fork_src = self.pages[idx];
            let target = self.pages.pop().expect("len checked");
            // entries below the shared boundary are the donor's prompt
            // content — copy them; everything above is this slot's to write
            let fill = self.shared_positions - idx * page_tokens;
            pages.copy_entries(fork_src, target, fill);
            self.pages[idx] = target;
            pages.free(fork_src);
            self.shared = idx;
            self.shared_positions = idx * page_tokens;
            forked = true;
        }
        pages.write_entry(self.pages[pos / page_tokens], pos % page_tokens, value);
        forked
    }

    /// Read the modeled KV entry for position `pos` through the page table
    /// (this is the sim attention's read path over shared + private pages).
    pub fn read_pos(&self, pos: usize, page_tokens: usize, pages: &SharedPages) -> u64 {
        let idx = pos / page_tokens;
        assert!(idx < self.pages.len(), "read past mapped pages");
        pages.read_entry(self.pages[idx], pos % page_tokens)
    }

    /// Release every page back to the pool (request completion/preemption).
    /// Shared pages lose one reference; they free only when the radix and
    /// every other mapper are gone too.
    pub fn release_all(&mut self, pages: &SharedPages) {
        pages.free_all(&mut self.pages);
        self.shared = 0;
        self.shared_positions = 0;
    }
}

/// Radix key: adapter, page depth, tokens filled in that page, and the
/// rolling hash of every prompt token up to and including the page. `Ord`
/// (adapter-first) gives deterministic reclaim order and cheap per-adapter
/// purges via range scans — a `HashMap` would make eviction order depend on
/// the process's hash seed and break run-to-run determinism.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PrefixKey {
    adapter: u64,
    depth: u32,
    fill: u32,
    hash: u64,
}

#[derive(Debug, Clone, Copy)]
struct PrefixEntry {
    page: PageId,
    /// radix tick of the last lookup hit or insert (LRU reclaim order)
    last_use: u64,
}

/// Default per-shard radix node budget (each node holds one page, so this
/// also bounds the pages the radix can pin against the pool).
pub const PREFIX_DEFAULT_MAX_NODES: usize = 4096;

/// Entries probed per reclaim/eviction scan: bounds the pressure-ladder
/// radix-reclaim cost so a pathological many-unique-prefix trace cannot
/// make one eviction O(cache size).
pub const PREFIX_RECLAIM_SCAN: usize = 256;

/// Batched page-boundary rolling hash: fold the whole prompt once, emitting
/// the running hash at every full page boundary plus (when the prompt does
/// not end on a boundary) the partial tail. `out[d]` is exactly the hash a
/// per-page incremental fold would reach at depth `d`, so keys built from
/// this list are interchangeable with the historical per-chunk computation.
/// One tight scan — a single data-dependent `splitmix64` chain with a
/// counter compare, no per-page slicing or call overhead — shared by
/// admission lookup and donation so the two sides can never disagree on a
/// boundary hash (benched as `prefix/batched hash 4k`).
pub fn boundary_hashes(adapter: u64, tokens: &[u32], page_tokens: usize, out: &mut Vec<u64>) {
    out.clear();
    let mut h = 0xe1f0_5eedu64 ^ splitmix64(adapter);
    let mut fill = 0usize;
    for &t in tokens {
        h = splitmix64(h ^ t as u64);
        fill += 1;
        if fill == page_tokens {
            out.push(h);
            fill = 0;
        }
    }
    if fill > 0 {
        out.push(h);
    }
}

/// The per-(adapter, prompt-prefix-hash) radix of immutable prompt pages
/// (DESIGN.md §Prefix sharing). One per shard, owned by the engine beside
/// its page tables; every page it holds carries one radix reference, so a
/// cached page is reclaimable exactly when its refcount is 1.
#[derive(Debug)]
pub struct PrefixCache {
    map: BTreeMap<PrefixKey, PrefixEntry>,
    tick: u64,
    /// node budget: `insert` evicts an rc-1 LRU entry to stay under it and
    /// refuses the donation when nothing within the scan window is
    /// evictable, so the radix cannot grow without bound
    max_nodes: usize,
    /// resume point of the bounded reclaim scan (clock-style sweep): the
    /// next scan starts after this key, so successive reclaims cover the
    /// whole radix even when each probes only `PREFIX_RECLAIM_SCAN` entries
    cursor: Option<PrefixKey>,
    /// reused boundary-hash buffer for `lookup`/`insert` (allocation-free
    /// once grown to the longest prompt's page count)
    hashes: Vec<u64>,
}

impl Default for PrefixCache {
    fn default() -> Self {
        Self::with_max_nodes(PREFIX_DEFAULT_MAX_NODES)
    }
}

impl PrefixCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Radix with an explicit node budget (`server.prefix_nodes` config).
    pub fn with_max_nodes(max_nodes: usize) -> Self {
        Self {
            map: BTreeMap::new(),
            tick: 0,
            max_nodes: max_nodes.max(1),
            cursor: None,
            hashes: Vec::new(),
        }
    }

    pub fn max_nodes(&self) -> usize {
        self.max_nodes
    }

    /// Distinct pages the radix currently holds (each entry owns one page).
    pub fn pages_held(&self) -> usize {
        self.map.len()
    }

    /// Collect the first-page (depth-0) boundary hashes of every cached
    /// chain into `out`. These are what a distributed scoreboard gossips:
    /// matching a remote request's first boundary hash against a shard's
    /// depth-0 set is exactly the "does that shard hold any of this
    /// prompt's chain" question, without shipping the whole radix. Sorted
    /// (BTreeMap order) and deterministic.
    pub fn first_page_hashes(&self, out: &mut Vec<u64>) {
        out.clear();
        for k in self.map.keys() {
            if k.depth == 0 {
                out.push(k.hash);
            }
        }
    }

    /// Longest cached chain matching `tokens` for `adapter`: full pages
    /// first, then (only on a full-page match all the way) the exact
    /// partial tail. Fills `out` with the page chain and returns the prompt
    /// positions covered. Pages are *not* retained here — the caller maps
    /// them via [`KvTable::map_shared`] (which retains) before anything can
    /// reclaim them. All boundary hashes come from one batched prompt scan
    /// ([`boundary_hashes`]) instead of a per-page incremental fold.
    pub fn lookup(
        &mut self,
        adapter: u64,
        tokens: &[u32],
        page_tokens: usize,
        out: &mut Vec<PageId>,
    ) -> usize {
        out.clear();
        self.tick += 1;
        let tick = self.tick;
        let full = tokens.len() / page_tokens;
        let mut hashes = std::mem::take(&mut self.hashes);
        boundary_hashes(adapter, tokens, page_tokens, &mut hashes);
        let mut covered = 0usize;
        for d in 0..full {
            let key = PrefixKey {
                adapter,
                depth: d as u32,
                fill: page_tokens as u32,
                hash: hashes[d],
            };
            match self.map.get_mut(&key) {
                Some(e) => {
                    e.last_use = tick;
                    out.push(e.page);
                    covered = (d + 1) * page_tokens;
                }
                None => break,
            }
        }
        let rem = tokens.len() - full * page_tokens;
        if rem > 0 && covered == full * page_tokens {
            let key = PrefixKey {
                adapter,
                depth: full as u32,
                fill: rem as u32,
                hash: hashes[full],
            };
            if let Some(e) = self.map.get_mut(&key) {
                e.last_use = tick;
                out.push(e.page);
                covered = tokens.len();
            }
        }
        self.hashes = hashes;
        covered
    }

    /// Donate a prompt's pages after prefill: every full prompt page plus
    /// the partial tail, keyed by the rolling prefix hash. Vacant keys gain
    /// one radix reference on their page; present keys are left alone (the
    /// resident chain is the canonical copy). The donor keeps writing its
    /// *decode* entries above the recorded fill — sharers never read past
    /// it, and a sharer's first write forks, so the prefix part stays
    /// immutable. At the node budget, each donation first evicts an rc-1
    /// LRU entry (bounded scan) and is skipped when nothing is evictable —
    /// live-mapped entries are never displaced by speculative donations.
    pub fn insert(
        &mut self,
        adapter: u64,
        tokens: &[u32],
        page_tokens: usize,
        table_pages: &[PageId],
        pages: &SharedPages,
    ) {
        self.tick += 1;
        let tick = self.tick;
        let full = tokens.len() / page_tokens;
        let mut hashes = std::mem::take(&mut self.hashes);
        boundary_hashes(adapter, tokens, page_tokens, &mut hashes);
        for d in 0..full {
            let key = PrefixKey {
                adapter,
                depth: d as u32,
                fill: page_tokens as u32,
                hash: hashes[d],
            };
            self.donate(key, table_pages[d], tick, pages);
        }
        let rem = tokens.len() - full * page_tokens;
        if rem > 0 && full < table_pages.len() {
            let key = PrefixKey {
                adapter,
                depth: full as u32,
                fill: rem as u32,
                hash: hashes[full],
            };
            self.donate(key, table_pages[full], tick, pages);
        }
        self.hashes = hashes;
    }

    /// One donation: insert `key → page` if vacant and the node budget
    /// holds (evicting one rc-1 entry to make room when at the cap).
    fn donate(&mut self, key: PrefixKey, page: PageId, tick: u64, pages: &SharedPages) {
        if self.map.contains_key(&key) {
            return;
        }
        if self.map.len() >= self.max_nodes && !self.reclaim_one(pages) {
            return; // at budget with nothing evictable: skip the donation
        }
        pages.retain(page);
        self.map.insert(key, PrefixEntry { page, last_use: tick });
    }

    /// Pressure reclaim: drop the least-recently-used entry *within the
    /// scan window* whose page no live request maps (refcount 1 — the
    /// radix's own reference), freeing the page. The scan probes at most
    /// [`PREFIX_RECLAIM_SCAN`] entries starting after the rotating cursor
    /// and wrapping (clock-style approximate LRU), so one eviction is O(1)
    /// in the radix size — the carried-over ROADMAP follow-up to the old
    /// full-map scan, whose shed cascade was O(n_pages²). Radixes at or
    /// under the window get the exact full-scan LRU of before.
    /// Deterministic: cursor state is a pure function of the call history;
    /// ties break on key order. False = nothing evictable in the window.
    pub fn reclaim_one(&mut self, pages: &SharedPages) -> bool {
        use std::ops::Bound::{Excluded, Unbounded};
        let budget = PREFIX_RECLAIM_SCAN.min(self.map.len());
        if budget == 0 {
            return false;
        }
        let start = match self.cursor {
            Some(k) => Excluded(k),
            None => Unbounded,
        };
        let mut victim: Option<(u64, PrefixKey)> = None;
        let mut last: Option<PrefixKey> = None;
        for (k, e) in self
            .map
            .range((start, Unbounded))
            .chain(self.map.iter()) // wrap to the front
            .take(budget)
        {
            last = Some(*k);
            if pages.refcount(e.page) == 1 {
                let cand = (e.last_use, *k);
                if victim.map_or(true, |v| cand < v) {
                    victim = Some(cand);
                }
            }
        }
        self.cursor = last; // next scan resumes after this window
        match victim {
            Some((_, k)) => {
                let e = self.map.remove(&k).expect("victim present");
                pages.free(e.page);
                true
            }
            None => false,
        }
    }

    /// Dead-shard restart (DESIGN.md §Failure model): drop every entry,
    /// releasing the radix reference on each page. Returns entries dropped.
    pub fn clear(&mut self, pages: &SharedPages) -> usize {
        let map = std::mem::take(&mut self.map);
        let n = map.len();
        for (_, e) in map {
            pages.free(e.page);
        }
        self.cursor = None;
        n
    }

    /// Registry delete: drop every cached prefix of `adapter`, releasing
    /// the radix reference on each page (a page still mapped by a live slot
    /// survives until that slot releases it).
    pub fn purge_adapter(&mut self, adapter: u64, pages: &SharedPages) -> usize {
        let lo = PrefixKey { adapter, depth: 0, fill: 0, hash: 0 };
        let hi = PrefixKey { adapter, depth: u32::MAX, fill: u32::MAX, hash: u64::MAX };
        let keys: Vec<PrefixKey> = self.map.range(lo..=hi).map(|(k, _)| *k).collect();
        for k in &keys {
            let e = self.map.remove(k).expect("ranged key present");
            pages.free(e.page);
        }
        keys.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg64;

    /// The historical per-chunk incremental fold, kept as an independent
    /// oracle: batched `boundary_hashes` must emit exactly the hash that
    /// fold reaches at each page boundary, or every radix key changes.
    fn chunk_hash_oracle(adapter: u64, tokens: &[u32], page_tokens: usize) -> Vec<u64> {
        let fold = |mut h: u64, ts: &[u32]| {
            for &t in ts {
                h = splitmix64(h ^ t as u64);
            }
            h
        };
        let mut out = Vec::new();
        let mut h = 0xe1f0_5eedu64 ^ splitmix64(adapter);
        for chunk in tokens.chunks(page_tokens) {
            h = fold(h, chunk);
            out.push(h);
        }
        out
    }

    #[test]
    fn batched_boundary_hashes_match_incremental_fold() {
        // case layout: [adapter, page_tokens, tok...]
        prop_check(
            100,
            0xb0a7d,
            |rng: &mut Pcg64| {
                let n = rng.gen_range_usize(0, 300);
                let mut case = vec![rng.next_u64() % 16, rng.gen_range_usize(1, 40) as u64];
                case.extend((0..n).map(|_| rng.next_u64() % 97));
                case
            },
            |case: &Vec<u64>| {
                if case.len() < 2 {
                    return true; // shrunk below the header: vacuous
                }
                let (adapter, page) = (case[0], (case[1] as usize).max(1));
                let toks: Vec<u32> = case[2..].iter().map(|&t| t as u32).collect();
                let mut got = Vec::new();
                boundary_hashes(adapter, &toks, page, &mut got);
                got == chunk_hash_oracle(adapter, &toks, page)
            },
        );
    }

    #[test]
    fn boundary_hashes_tail_and_exact_multiple() {
        let toks: Vec<u32> = (0..8).collect();
        let mut h = Vec::new();
        boundary_hashes(3, &toks, 4, &mut h);
        assert_eq!(h.len(), 2, "8 tokens / 4 per page: no partial tail");
        boundary_hashes(3, &toks, 3, &mut h);
        assert_eq!(h.len(), 3, "3+3+2: partial tail emitted");
        boundary_hashes(3, &[], 4, &mut h);
        assert!(h.is_empty(), "empty prompt emits nothing");
        // adapter seeds the chain: same tokens, different adapter, all differ
        let mut other = Vec::new();
        boundary_hashes(4, &toks, 4, &mut other);
        boundary_hashes(3, &toks, 4, &mut h);
        assert!(h.iter().zip(&other).all(|(a, b)| a != b));
    }

    #[test]
    fn alloc_free_cycle_conserves() {
        let mut a = PageAllocator::new(4, 64);
        assert_eq!(a.free_pages(), 4);
        let p = a.alloc().unwrap();
        let q = a.alloc().unwrap();
        assert_ne!(p, q);
        assert_eq!(a.free_pages(), 2);
        a.free(p);
        assert_eq!(a.free_pages(), 3);
        let r = a.alloc().unwrap();
        assert_eq!(r, p, "LIFO reuse");
        assert_eq!(a.allocs, 3);
        assert_eq!(a.frees, 1);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = PageAllocator::new(2, 64);
        let p = a.alloc().unwrap();
        a.free(p);
        a.free(p);
    }

    #[test]
    fn retain_defers_free_until_last_reference() {
        let mut a = PageAllocator::new(2, 64);
        let p = a.alloc().unwrap();
        a.retain(p);
        assert_eq!(a.refcount(p), 2);
        a.free(p);
        assert!(a.is_mapped(p), "one reference left");
        assert_eq!(a.free_pages(), 1);
        a.free(p);
        assert!(!a.is_mapped(p));
        assert_eq!(a.free_pages(), 2);
        assert_eq!(a.frees, 1, "frees counts returns to the free list");
    }

    #[test]
    fn entries_cleared_on_recycle_and_survive_capacity() {
        let mut a = PageAllocator::new(1, 64);
        let p = a.alloc().unwrap();
        a.write_entry(p, 3, 42);
        assert_eq!(a.read_entry(p, 3), 42);
        assert_eq!(a.read_entry(p, 0), 0, "unwritten offsets read 0");
        a.free(p);
        let q = a.alloc().unwrap();
        assert_eq!(q, p);
        assert_eq!(a.read_entry(q, 3), 0, "recycled page must not leak content");
    }

    #[test]
    fn alloc_n_into_is_all_or_nothing() {
        let mut a = PageAllocator::new(3, 64);
        let mut t = Vec::with_capacity(8);
        assert!(!a.alloc_n_into(4, &mut t), "over-ask must take nothing");
        assert!(t.is_empty());
        assert_eq!(a.free_pages(), 3);
        assert!(a.alloc_n_into(3, &mut t));
        assert_eq!(t.len(), 3);
        assert_eq!(a.free_pages(), 0);
        a.free_all(&mut t);
        assert_eq!(a.free_pages(), 3);
    }

    /// Satellite property: the allocator never double-maps a page and
    /// conserves the free list across random alloc/free/grow sequences.
    #[test]
    fn prop_allocator_never_double_maps_and_conserves() {
        prop_check(
            48,
            0x9a6e5,
            |rng: &mut Pcg64| {
                let n_pages = rng.gen_range_usize(1, 24);
                let mut ops = vec![n_pages];
                for _ in 0..rng.gen_range_usize(1, 120) {
                    ops.push(rng.gen_range_usize(0, 6)); // op selector
                }
                ops
            },
            |case| {
                let (&n_pages, ops) = case.split_first().unwrap();
                let n_pages = n_pages.max(1);
                let mut a = PageAllocator::new(n_pages, 128);
                let mut held: Vec<PageId> = Vec::new();
                let mut grown: Vec<PageId> = Vec::with_capacity(n_pages);
                for (step, &op) in ops.iter().enumerate() {
                    match op {
                        // single alloc
                        0 | 1 => {
                            if let Some(p) = a.alloc() {
                                if held.contains(&p) || grown.contains(&p) {
                                    return false; // double-mapped
                                }
                                held.push(p);
                            } else if held.len() + grown.len() != n_pages {
                                return false; // spurious exhaustion
                            }
                        }
                        // single free (oldest held)
                        2 | 3 => {
                            if !held.is_empty() {
                                let p = held.remove(step % held.len());
                                a.free(p);
                            }
                        }
                        // grow: all-or-nothing multi-page alloc
                        4 => {
                            let want = 1 + step % 3;
                            let before = grown.len();
                            let ok = a.alloc_n_into(want, &mut grown);
                            if ok {
                                for p in &grown[before..] {
                                    if held.contains(p) || grown[..before].contains(p) {
                                        return false;
                                    }
                                }
                            } else if grown.len() != before {
                                return false; // partial grow leaked pages
                            }
                        }
                        // bulk release of the grown table
                        _ => a.free_all(&mut grown),
                    }
                    // conservation: free + mapped == capacity, every step
                    if a.free_pages() + held.len() + grown.len() != n_pages {
                        return false;
                    }
                    for &p in held.iter().chain(grown.iter()) {
                        if !a.is_mapped(p) {
                            return false;
                        }
                    }
                }
                true
            },
        );
    }

    /// Tentpole property: refcount conservation under random map-shared /
    /// grow / COW-fork / release / reclaim interleavings — no page leaks,
    /// no double-free (the allocator panics on one), and
    /// `free + distinct-mapped == total` at every step.
    #[test]
    fn prop_refcount_conservation_under_fork_release_interleavings() {
        const PT: usize = 4;
        prop_check(
            40,
            0xc0f0e,
            |rng: &mut Pcg64| {
                let mut ops = Vec::new();
                for _ in 0..rng.gen_range_usize(4, 90) {
                    ops.push(rng.gen_range_usize(0, 100));
                }
                ops
            },
            |ops| {
                let n_pages = 24usize;
                let pages = SharedPages::new(n_pages, 64 * PT);
                let mut radix = PrefixCache::new();
                let mut tables: Vec<KvTable> =
                    (0..3).map(|_| KvTable::with_capacity(16)).collect();
                // (prompt tokens, decode positions written) per live table
                let mut live: Vec<Option<(Vec<u32>, usize)>> = vec![None; 3];
                let prompts: [&[u32]; 3] = [
                    &[1, 2, 3, 4, 5, 6],          // 1 full page + tail fill 2
                    &[1, 2, 3, 4, 5, 6],          // identical: shares with ^
                    &[9, 9, 9, 9, 8, 8, 8, 8, 7], // 2 full pages + tail fill 1
                ];
                let check = |tables: &[KvTable], radix: &PrefixCache| -> bool {
                    // distinct mapped pages = union of table pages + radix
                    let mut distinct: Vec<PageId> = Vec::new();
                    for t in tables {
                        for &p in t.pages() {
                            if !distinct.contains(&p) {
                                distinct.push(p);
                            }
                        }
                    }
                    // radix pages are distinct from each other but may alias
                    // table pages; count via refcount bookkeeping instead:
                    // every mapped page must have refcount >= 1 and the free
                    // count must complement the distinct mapped set
                    let mut radix_distinct = 0usize;
                    for t in tables {
                        for &p in t.pages() {
                            if pages.refcount(p) == 0 {
                                return false; // mapped page freed under us
                            }
                        }
                    }
                    // count radix-only pages by scanning all page ids
                    for i in 0..n_pages {
                        let p = PageId(i as u32);
                        if pages.refcount(p) > 0 && !distinct.contains(&p) {
                            radix_distinct += 1;
                        }
                    }
                    let _ = radix;
                    pages.free_pages() + distinct.len() + radix_distinct == n_pages
                };
                for (step, &op) in ops.iter().enumerate() {
                    let slot = step % 3;
                    match op % 5 {
                        // admit: map shared chain + grow private remainder
                        0 => {
                            if live[slot].is_none() {
                                let toks = prompts[slot];
                                let mut chain = Vec::new();
                                let covered =
                                    radix.lookup(7, toks, PT, &mut chain);
                                tables[slot].map_shared(&chain, covered, &pages);
                                let need =
                                    pages_for(toks.len() + 1, PT).max(chain.len() + 1);
                                if tables[slot].grow_to(need, &pages) {
                                    for pos in covered..toks.len() {
                                        tables[slot].write_pos(
                                            pos,
                                            PT,
                                            kv_entry(toks[pos], pos),
                                            &pages,
                                        );
                                    }
                                    radix.insert(7, toks, PT, tables[slot].pages(), &pages);
                                    live[slot] = Some((toks.to_vec(), 0));
                                } else {
                                    tables[slot].release_all(&pages);
                                }
                            }
                        }
                        // decode write (may COW-fork a shared tail);
                        // bounded so positions stay within table capacity
                        1 | 2 => {
                            if let Some((toks, written)) = &mut live[slot] {
                                if *written >= 16 {
                                    continue;
                                }
                                let pos = toks.len() + *written;
                                let need = pages_for(pos + 1, PT);
                                if need <= tables[slot].len()
                                    || matches!(
                                        tables[slot]
                                            .ensure_positions(pos + 1, PT, &pages)
                                            .unwrap(),
                                        KvEnsure::Fits | KvEnsure::Grew
                                    )
                                {
                                    tables[slot].write_pos(pos, PT, kv_entry(1, pos), &pages);
                                    *written += 1;
                                }
                            }
                        }
                        // release (completion/preemption)
                        3 => {
                            if live[slot].take().is_some() {
                                tables[slot].release_all(&pages);
                            }
                        }
                        // pressure reclaim of an unreferenced radix page
                        _ => {
                            radix.reclaim_one(&pages);
                        }
                    }
                    if !check(&tables, &radix) {
                        return false;
                    }
                    // shared prefix content must stay intact for every live
                    // mapper (a bad fork/free would clobber it)
                    for (s, l) in live.iter().enumerate() {
                        if let Some((toks, _)) = l {
                            for (pos, &t) in toks.iter().enumerate() {
                                if tables[s].read_pos(pos, PT, &pages) != kv_entry(t, pos) {
                                    return false;
                                }
                            }
                        }
                    }
                }
                // teardown: release everything; every page must come home
                for (s, l) in live.iter_mut().enumerate() {
                    if l.take().is_some() {
                        tables[s].release_all(&pages);
                    }
                }
                while radix.reclaim_one(&pages) {}
                pages.free_pages() == n_pages
            },
        );
    }

    #[test]
    fn kv_table_hit_grow_and_exhaustion() {
        let pages = SharedPages::new(3, 256);
        let mut t = KvTable::with_capacity(8);
        // admission reservation: 2 pages for prompt+1
        assert!(t.grow_to(2, &pages));
        assert_eq!(t.len(), 2);
        assert_eq!(pages.free_pages(), 1);
        // positions within the mapped pages: page-hit
        assert_eq!(
            t.ensure_positions(8, 4, &pages).unwrap(),
            KvEnsure::Fits
        );
        // crossing into page 3: fault, served
        assert_eq!(
            t.ensure_positions(9, 4, &pages).unwrap(),
            KvEnsure::Grew
        );
        assert_eq!(pages.free_pages(), 0);
        // pool dry: NoPage, table unchanged
        assert_eq!(
            t.ensure_positions(13, 4, &pages).unwrap(),
            KvEnsure::NoPage
        );
        assert_eq!(t.len(), 3);
        t.release_all(&pages);
        assert_eq!(pages.free_pages(), 3);
        assert!(t.is_empty());
    }

    #[test]
    fn kv_table_rejects_over_capacity_request() {
        let pages = SharedPages::new(8, 256);
        let mut t = KvTable::with_capacity(2);
        assert!(t.grow_to(2, &pages));
        assert!(t.ensure_positions(3 * 4, 4, &pages).is_err());
    }

    #[test]
    fn kv_append_is_allocation_free_within_capacity() {
        let pages = SharedPages::new(64, 256);
        let mut t = KvTable::with_capacity(32);
        t.grow_to(1, &pages);
        let cap0 = t.page_capacity();
        let ptr0 = t.pages.as_ptr() as usize;
        for pos in 1..=32 * 4 {
            let r = t.ensure_positions(pos, 4, &pages).unwrap();
            assert_ne!(r, KvEnsure::NoPage);
        }
        assert_eq!(t.page_capacity(), cap0, "append must not reallocate");
        assert_eq!(t.pages.as_ptr() as usize, ptr0);
    }

    #[test]
    fn pages_for_math() {
        assert_eq!(pages_for(1, 16), 1);
        assert_eq!(pages_for(16, 16), 1);
        assert_eq!(pages_for(17, 16), 2);
    }

    /// Build a donor table with prompt `toks` written, donate to the radix.
    fn donate(
        radix: &mut PrefixCache,
        adapter: u64,
        toks: &[u32],
        pt: usize,
        pages: &SharedPages,
    ) -> KvTable {
        let mut t = KvTable::with_capacity(16);
        assert!(t.grow_to(pages_for(toks.len() + 1, pt).max(1), pages));
        for (pos, &tok) in toks.iter().enumerate() {
            t.write_pos(pos, pt, kv_entry(tok, pos), pages);
        }
        radix.insert(adapter, toks, pt, t.pages(), pages);
        t
    }

    #[test]
    fn prefix_lookup_maps_full_and_tail_pages() {
        let pages = SharedPages::new(32, 64);
        let mut radix = PrefixCache::new();
        let toks: Vec<u32> = (1..=10).collect(); // pt 4: 2 full + tail fill 2
        let donor = donate(&mut radix, 5, &toks, 4, &pages);
        assert_eq!(radix.pages_held(), 3);

        // identical prompt: full coverage including the partial tail
        let mut chain = Vec::new();
        let covered = radix.lookup(5, &toks, 4, &mut chain);
        assert_eq!(covered, 10);
        assert_eq!(chain.len(), 3);
        assert_eq!(&chain[..], &donor.pages()[..3]);

        // same tokens, different adapter: no sharing across tenants
        let mut other = Vec::new();
        assert_eq!(radix.lookup(6, &toks, 4, &mut other), 0);
        assert!(other.is_empty());

        // diverging after page 1: only the matching full page maps
        let mut part: Vec<u32> = toks.clone();
        part[5] = 99;
        let mut chain2 = Vec::new();
        assert_eq!(radix.lookup(5, &part, 4, &mut chain2), 4);
        assert_eq!(chain2.len(), 1);

        // shorter prompt that is a page-aligned prefix: full page only (the
        // donor's tail covers a different fill)
        let mut chain3 = Vec::new();
        assert_eq!(radix.lookup(5, &toks[..8], 4, &mut chain3), 8);
        assert_eq!(chain3.len(), 2);
    }

    #[test]
    fn cow_fork_preserves_prefix_and_isolates_writers() {
        let pt = 4usize;
        let pages = SharedPages::new(32, 64);
        let mut radix = PrefixCache::new();
        let toks: Vec<u32> = (1..=6).collect(); // 1 full page + tail fill 2
        let donor = donate(&mut radix, 1, &toks, pt, &pages);
        let donor_tail = donor.pages()[1];
        assert_eq!(pages.refcount(donor_tail), 2, "donor + radix");

        // sharer maps the whole prompt, reserves its decode page, forks on
        // the first decode write
        let mut chain = Vec::new();
        let covered = radix.lookup(1, &toks, pt, &mut chain);
        assert_eq!(covered, 6);
        let mut sharer = KvTable::with_capacity(16);
        sharer.map_shared(&chain, covered, &pages);
        assert_eq!(pages.refcount(donor_tail), 3);
        assert!(sharer.grow_to(chain.len() + 1, &pages));
        let forked = sharer.write_pos(6, pt, kv_entry(77, 6), &pages);
        assert!(forked, "first write into the shared tail must fork");
        assert_eq!(sharer.shared_pages(), 1, "tail became private");
        assert_eq!(pages.refcount(donor_tail), 2, "sharer dropped the tail");
        // prefix content identical through both tables; suffixes diverge
        for pos in 0..6 {
            assert_eq!(
                sharer.read_pos(pos, pt, &pages),
                donor.read_pos(pos, pt, &pages),
                "fork must preserve prefix entries"
            );
        }
        assert_eq!(sharer.read_pos(6, pt, &pages), kv_entry(77, 6));
        // a second write does not fork again
        assert!(!sharer.write_pos(7, pt, kv_entry(78, 7), &pages));
    }

    #[test]
    fn reclaim_frees_only_unreferenced_pages_and_purge_drops_adapter() {
        let pt = 4usize;
        let pages = SharedPages::new(32, 64);
        let mut radix = PrefixCache::new();
        let toks: Vec<u32> = (1..=8).collect(); // 2 full pages, no tail
        let mut donor = donate(&mut radix, 3, &toks, pt, &pages);
        assert_eq!(radix.pages_held(), 2);
        // donor still maps everything: refcounts 2 ⇒ nothing reclaimable
        assert!(!radix.reclaim_one(&pages));
        donor.release_all(&pages);
        let free_before = pages.free_pages();
        assert!(radix.reclaim_one(&pages), "rc==1 pages reclaim");
        assert_eq!(pages.free_pages(), free_before + 1);
        // purge drops the rest of the adapter's chains
        let purged = radix.purge_adapter(3, &pages);
        assert_eq!(purged, 1);
        assert_eq!(radix.pages_held(), 0);
        assert_eq!(pages.free_pages(), 32);
    }

    /// Satellite (radix budget cap): the node count never exceeds the
    /// budget; at the cap a donation evicts an rc-1 LRU entry, and when
    /// every cached page is still live-mapped the donation is refused
    /// rather than displacing anything.
    #[test]
    fn radix_node_budget_caps_growth_and_never_displaces_live_pages() {
        let pt = 4usize;
        let pages = SharedPages::new(64, 64);
        let mut radix = PrefixCache::with_max_nodes(3);
        assert_eq!(radix.max_nodes(), 3);
        // three single-page prompts fill the budget; release the donors so
        // the radix holds the only reference
        let mut donors: Vec<KvTable> = (0..3)
            .map(|a| donate(&mut radix, a as u64, &[1, 2, 3, 4], pt, &pages))
            .collect();
        for d in &mut donors {
            d.release_all(&pages);
        }
        assert_eq!(radix.pages_held(), 3);
        // a fourth unique prompt evicts the LRU entry instead of growing
        let mut d4 = donate(&mut radix, 9, &[5, 6, 7, 8], pt, &pages);
        assert_eq!(radix.pages_held(), 3, "budget must hold");
        let mut chain = Vec::new();
        assert_eq!(radix.lookup(9, &[5, 6, 7, 8], pt, &mut chain), 4);
        assert_eq!(radix.lookup(0, &[1, 2, 3, 4], pt, &mut chain), 0, "LRU evicted");
        d4.release_all(&pages);

        // every cached page live-mapped ⇒ a new donation is refused
        let pages2 = SharedPages::new(64, 64);
        let mut full = PrefixCache::with_max_nodes(2);
        let _live1 = donate(&mut full, 0, &[1, 2, 3, 4], pt, &pages2);
        let _live2 = donate(&mut full, 1, &[1, 2, 3, 4], pt, &pages2);
        assert_eq!(full.pages_held(), 2);
        let mut refused = donate(&mut full, 2, &[9, 9, 9, 9], pt, &pages2);
        assert_eq!(full.pages_held(), 2, "live pages must not be displaced");
        assert_eq!(full.lookup(2, &[9, 9, 9, 9], pt, &mut chain), 0);
        refused.release_all(&pages2);
    }

    /// Satellite (bounded reclaim scan): successive bounded scans sweep the
    /// whole radix via the rotating cursor, so every rc-1 page is
    /// eventually reclaimed even when one scan covers only a window; and
    /// `clear` drops everything at once (dead-shard restart).
    #[test]
    fn bounded_reclaim_sweeps_everything_and_clear_frees_all() {
        let pt = 4usize;
        let pages = SharedPages::new(64, 64);
        let mut radix = PrefixCache::new();
        let mut donors: Vec<KvTable> = (0..10)
            .map(|a| donate(&mut radix, a as u64, &[1, 2, 3, 4], pt, &pages))
            .collect();
        for d in &mut donors {
            d.release_all(&pages);
        }
        assert_eq!(radix.pages_held(), 10);
        let mut reclaimed = 0;
        while radix.reclaim_one(&pages) {
            reclaimed += 1;
            assert!(reclaimed <= 10, "reclaim must terminate");
        }
        assert_eq!(reclaimed, 10, "the rotating scan must reach every entry");
        assert_eq!(pages.free_pages(), 64);

        // clear: drop everything in one call
        let mut donors2: Vec<KvTable> = (0..4)
            .map(|a| donate(&mut radix, a as u64, &[1, 2, 3, 4], pt, &pages))
            .collect();
        for d in &mut donors2 {
            d.release_all(&pages);
        }
        assert_eq!(radix.clear(&pages), 4);
        assert_eq!(radix.pages_held(), 0);
        assert_eq!(pages.free_pages(), 64);
    }
}
