//! Unified page-granular memory (DESIGN.md §Unified paging): one free-list
//! page allocator per device shard from which **both** adapter blocks and
//! per-slot KV caches are served, S-LoRA-style (arXiv:2311.03285). Replaces
//! the static worst-case `kv_bytes_for(batch_width)` headroom the sim
//! backend used to reserve: short requests no longer pay for `max_seq`
//! positions they never use, so the reclaimed headroom becomes resident
//! adapters and wider steady-state batches at the same device budget.
//!
//! Layering:
//!   * [`PageAllocator`] — the raw free list. Pages are *accounting* units
//!     (modeled device bytes); payload buffers stay where they always were
//!     (one contiguous buffer per [`MemoryPool`] block), which is what keeps
//!     the zero-copy `QuantView` path intact: an adapter occupies N
//!     contiguous-*logical* pages recorded in a page table, not N scattered
//!     physical buffers.
//!   * [`SharedPages`] — the allocator behind an `Arc<Mutex<..>>` so the
//!     adapter pool (inside `AdapterMemoryManager`) and the engine's KV
//!     tables draw from one budget. All page traffic happens on the engine
//!     thread; the lock only exists so the engine type stays `Send`.
//!   * [`KvTable`] — one per request slot: pages appended lazily as decode
//!     advances (page-hit = pure arithmetic, page-fault = one free-list
//!     pop), released in bulk at request completion or preemption. Capacity
//!     is preallocated to `max_positions / page_tokens`, so the steady-state
//!     KV-append path never touches the heap.
//!
//! [`MemoryPool`]: crate::memory::pool::MemoryPool

use std::sync::{Arc, Mutex};

/// Handle to one page (index into the allocator's page array). Copy-cheap.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PageId(pub u32);

/// Fixed-size free-list page allocator. Never allocates after `new`:
/// the free list and the in-use bitmap are preallocated to `n_pages`.
#[derive(Debug)]
pub struct PageAllocator {
    free: Vec<PageId>,
    in_use: Vec<bool>,
    page_bytes: usize,
    /// lifetime counters for diagnostics / the capacity table
    pub allocs: u64,
    pub frees: u64,
}

impl PageAllocator {
    pub fn new(n_pages: usize, page_bytes: usize) -> Self {
        assert!(n_pages > 0 && page_bytes > 0);
        assert!(n_pages <= u32::MAX as usize, "page id overflow");
        Self {
            free: (0..n_pages).rev().map(|i| PageId(i as u32)).collect(),
            in_use: vec![false; n_pages],
            page_bytes,
            allocs: 0,
            frees: 0,
        }
    }

    pub fn n_pages(&self) -> usize {
        self.in_use.len()
    }

    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn total_bytes(&self) -> usize {
        self.in_use.len() * self.page_bytes
    }

    /// Take one free page. None when exhausted (caller evicts or preempts).
    pub fn alloc(&mut self) -> Option<PageId> {
        let p = self.free.pop()?;
        debug_assert!(!self.in_use[p.0 as usize], "free-list corruption");
        self.in_use[p.0 as usize] = true;
        self.allocs += 1;
        Some(p)
    }

    /// All-or-nothing: append `n` pages to `out`, or take none and return
    /// false. `out` must have spare capacity (page tables preallocate).
    pub fn alloc_n_into(&mut self, n: usize, out: &mut Vec<PageId>) -> bool {
        if self.free.len() < n {
            return false;
        }
        for _ in 0..n {
            out.push(self.alloc().expect("length checked"));
        }
        true
    }

    /// Return a page. Panics on double-free (a real bug).
    pub fn free(&mut self, p: PageId) {
        let slot = &mut self.in_use[p.0 as usize];
        assert!(*slot, "double free of page {p:?}");
        *slot = false;
        self.free.push(p);
        self.frees += 1;
    }

    /// Drain a page table back into the free list.
    pub fn free_all(&mut self, table: &mut Vec<PageId>) {
        while let Some(p) = table.pop() {
            self.free(p);
        }
    }

    /// True if `p` is currently mapped (diagnostics/tests).
    pub fn is_mapped(&self, p: PageId) -> bool {
        self.in_use.get(p.0 as usize).copied().unwrap_or(false)
    }
}

/// The page allocator shared between the adapter pool and the KV tables of
/// one device shard. Clones share the same underlying budget.
#[derive(Debug, Clone)]
pub struct SharedPages(Arc<Mutex<PageAllocator>>);

impl SharedPages {
    pub fn new(n_pages: usize, page_bytes: usize) -> Self {
        Self(Arc::new(Mutex::new(PageAllocator::new(n_pages, page_bytes))))
    }

    pub fn n_pages(&self) -> usize {
        self.0.lock().unwrap().n_pages()
    }

    pub fn page_bytes(&self) -> usize {
        self.0.lock().unwrap().page_bytes()
    }

    pub fn free_pages(&self) -> usize {
        self.0.lock().unwrap().free_pages()
    }

    pub fn total_bytes(&self) -> usize {
        self.0.lock().unwrap().total_bytes()
    }

    pub fn alloc(&self) -> Option<PageId> {
        self.0.lock().unwrap().alloc()
    }

    pub fn alloc_n_into(&self, n: usize, out: &mut Vec<PageId>) -> bool {
        self.0.lock().unwrap().alloc_n_into(n, out)
    }

    pub fn free(&self, p: PageId) {
        self.0.lock().unwrap().free(p)
    }

    pub fn free_all(&self, table: &mut Vec<PageId>) {
        self.0.lock().unwrap().free_all(table)
    }

    pub fn allocs(&self) -> u64 {
        self.0.lock().unwrap().allocs
    }
}

/// Pages needed to hold `positions` KV entries at `page_tokens` per page.
pub fn pages_for(positions: usize, page_tokens: usize) -> usize {
    debug_assert!(page_tokens > 0);
    positions.div_ceil(page_tokens)
}

/// Outcome of [`KvTable::ensure_positions`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvEnsure {
    /// the table already covers the requested positions (page-hit)
    Fits,
    /// one page was appended (page-fault, served from the free list)
    Grew,
    /// the shared pool has no free page — caller must evict or preempt
    NoPage,
}

/// One request slot's KV page table: logical pages in append order.
#[derive(Debug, Default)]
pub struct KvTable {
    pages: Vec<PageId>,
}

impl KvTable {
    /// Preallocate for the worst-case request so append never reallocates.
    pub fn with_capacity(max_pages: usize) -> Self {
        Self {
            pages: Vec::with_capacity(max_pages),
        }
    }

    pub fn len(&self) -> usize {
        self.pages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    pub fn page_capacity(&self) -> usize {
        self.pages.capacity()
    }

    /// Grow to exactly `n_pages` mapped pages (admission reserves prompt
    /// pages + one decode page this way). All-or-nothing; false = no pages.
    pub fn grow_to(&mut self, n_pages: usize, pages: &SharedPages) -> bool {
        if n_pages <= self.pages.len() {
            return true;
        }
        assert!(
            n_pages <= self.pages.capacity(),
            "KV reservation {n_pages} exceeds per-slot page capacity {}",
            self.pages.capacity()
        );
        pages.alloc_n_into(n_pages - self.pages.len(), &mut self.pages)
    }

    /// Make the table cover `positions` KV entries, appending at most one
    /// page (decode adds one position per step). Errors when the request
    /// exceeds the per-slot worst case the table was sized for.
    pub fn ensure_positions(
        &mut self,
        positions: usize,
        page_tokens: usize,
        pages: &SharedPages,
    ) -> anyhow::Result<KvEnsure> {
        let need = pages_for(positions, page_tokens);
        if need <= self.pages.len() {
            return Ok(KvEnsure::Fits);
        }
        if need > self.pages.capacity() {
            anyhow::bail!(
                "request needs {need} KV pages, slot capacity is {}",
                self.pages.capacity()
            );
        }
        debug_assert_eq!(need, self.pages.len() + 1, "decode grows one page at a time");
        match pages.alloc() {
            Some(p) => {
                self.pages.push(p);
                Ok(KvEnsure::Grew)
            }
            None => Ok(KvEnsure::NoPage),
        }
    }

    /// Release every page back to the pool (request completion/preemption).
    pub fn release_all(&mut self, pages: &SharedPages) {
        pages.free_all(&mut self.pages);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg64;

    #[test]
    fn alloc_free_cycle_conserves() {
        let mut a = PageAllocator::new(4, 64);
        assert_eq!(a.free_pages(), 4);
        let p = a.alloc().unwrap();
        let q = a.alloc().unwrap();
        assert_ne!(p, q);
        assert_eq!(a.free_pages(), 2);
        a.free(p);
        assert_eq!(a.free_pages(), 3);
        let r = a.alloc().unwrap();
        assert_eq!(r, p, "LIFO reuse");
        assert_eq!(a.allocs, 3);
        assert_eq!(a.frees, 1);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = PageAllocator::new(2, 64);
        let p = a.alloc().unwrap();
        a.free(p);
        a.free(p);
    }

    #[test]
    fn alloc_n_into_is_all_or_nothing() {
        let mut a = PageAllocator::new(3, 64);
        let mut t = Vec::with_capacity(8);
        assert!(!a.alloc_n_into(4, &mut t), "over-ask must take nothing");
        assert!(t.is_empty());
        assert_eq!(a.free_pages(), 3);
        assert!(a.alloc_n_into(3, &mut t));
        assert_eq!(t.len(), 3);
        assert_eq!(a.free_pages(), 0);
        a.free_all(&mut t);
        assert_eq!(a.free_pages(), 3);
    }

    /// Satellite property: the allocator never double-maps a page and
    /// conserves the free list across random alloc/free/grow sequences.
    #[test]
    fn prop_allocator_never_double_maps_and_conserves() {
        prop_check(
            48,
            0x9a6e5,
            |rng: &mut Pcg64| {
                let n_pages = rng.gen_range_usize(1, 24);
                let mut ops = vec![n_pages];
                for _ in 0..rng.gen_range_usize(1, 120) {
                    ops.push(rng.gen_range_usize(0, 6)); // op selector
                }
                ops
            },
            |case| {
                let (&n_pages, ops) = case.split_first().unwrap();
                let n_pages = n_pages.max(1);
                let mut a = PageAllocator::new(n_pages, 128);
                let mut held: Vec<PageId> = Vec::new();
                let mut grown: Vec<PageId> = Vec::with_capacity(n_pages);
                for (step, &op) in ops.iter().enumerate() {
                    match op {
                        // single alloc
                        0 | 1 => {
                            if let Some(p) = a.alloc() {
                                if held.contains(&p) || grown.contains(&p) {
                                    return false; // double-mapped
                                }
                                held.push(p);
                            } else if held.len() + grown.len() != n_pages {
                                return false; // spurious exhaustion
                            }
                        }
                        // single free (oldest held)
                        2 | 3 => {
                            if !held.is_empty() {
                                let p = held.remove(step % held.len());
                                a.free(p);
                            }
                        }
                        // grow: all-or-nothing multi-page alloc
                        4 => {
                            let want = 1 + step % 3;
                            let before = grown.len();
                            let ok = a.alloc_n_into(want, &mut grown);
                            if ok {
                                for p in &grown[before..] {
                                    if held.contains(p) || grown[..before].contains(p) {
                                        return false;
                                    }
                                }
                            } else if grown.len() != before {
                                return false; // partial grow leaked pages
                            }
                        }
                        // bulk release of the grown table
                        _ => a.free_all(&mut grown),
                    }
                    // conservation: free + mapped == capacity, every step
                    if a.free_pages() + held.len() + grown.len() != n_pages {
                        return false;
                    }
                    for &p in held.iter().chain(grown.iter()) {
                        if !a.is_mapped(p) {
                            return false;
                        }
                    }
                }
                true
            },
        );
    }

    #[test]
    fn kv_table_hit_grow_and_exhaustion() {
        let pages = SharedPages::new(3, 256);
        let mut t = KvTable::with_capacity(8);
        // admission reservation: 2 pages for prompt+1
        assert!(t.grow_to(2, &pages));
        assert_eq!(t.len(), 2);
        assert_eq!(pages.free_pages(), 1);
        // positions within the mapped pages: page-hit
        assert_eq!(
            t.ensure_positions(8, 4, &pages).unwrap(),
            KvEnsure::Fits
        );
        // crossing into page 3: fault, served
        assert_eq!(
            t.ensure_positions(9, 4, &pages).unwrap(),
            KvEnsure::Grew
        );
        assert_eq!(pages.free_pages(), 0);
        // pool dry: NoPage, table unchanged
        assert_eq!(
            t.ensure_positions(13, 4, &pages).unwrap(),
            KvEnsure::NoPage
        );
        assert_eq!(t.len(), 3);
        t.release_all(&pages);
        assert_eq!(pages.free_pages(), 3);
        assert!(t.is_empty());
    }

    #[test]
    fn kv_table_rejects_over_capacity_request() {
        let pages = SharedPages::new(8, 256);
        let mut t = KvTable::with_capacity(2);
        assert!(t.grow_to(2, &pages));
        assert!(t.ensure_positions(3 * 4, 4, &pages).is_err());
    }

    #[test]
    fn kv_append_is_allocation_free_within_capacity() {
        let pages = SharedPages::new(64, 256);
        let mut t = KvTable::with_capacity(32);
        t.grow_to(1, &pages);
        let cap0 = t.page_capacity();
        let ptr0 = t.pages.as_ptr() as usize;
        for pos in 1..=32 * 4 {
            let r = t.ensure_positions(pos, 4, &pages).unwrap();
            assert_ne!(r, KvEnsure::NoPage);
        }
        assert_eq!(t.page_capacity(), cap0, "append must not reallocate");
        assert_eq!(t.pages.as_ptr() as usize, ptr0);
    }

    #[test]
    fn pages_for_math() {
        assert_eq!(pages_for(1, 16), 1);
        assert_eq!(pages_for(16, 16), 1);
        assert_eq!(pages_for(17, 16), 2);
    }
}
