//! Pre-allocated memory pool (§3.3): fixed-size blocks, each sized for one
//! *quantized* adapter payload, reserved at server initialization. Loading
//! an adapter reads the on-disk payload straight into a free block (no
//! runtime allocation, no dequantization on the swap path); evicting returns
//! the block. Dequantization happens exactly once, at bank-upload time,
//! reading from the block through a borrowed [`QuantView`]
//! (see `DESIGN.md` §Adapter data path).
//!
//! Blocks can be *lent out* (`lend`/`restore`) so a background prefetch
//! thread can fill a block's buffer off the engine thread without sharing
//! the pool itself: the buffer travels to the worker as an owned `Box<[u8]>`
//! and comes back through a channel.
//!
//! When the pool is *page-backed* (DESIGN.md §Unified paging), every block
//! additionally charges `pages_per_block` pages of modeled device memory
//! against the [`SharedPages`] allocator it shares with the engine's KV
//! tables — so acquiring a block can fail under KV pressure even while
//! block slots are free (`page_starved`), and releasing a block returns its
//! pages for KV growth. The payload buffer itself stays one contiguous
//! allocation per block (the N pages are contiguous-*logical*, recorded in
//! a per-block page table), which keeps the zero-copy `QuantView` path
//! byte-identical to the unpaged pool.
//!
//! [`QuantView`]: crate::adapters::QuantView
//! [`SharedPages`]: crate::memory::paging::SharedPages

use crate::memory::paging::{PageId, SharedPages};

/// Handle to one pool block (index into the slab). Copy-cheap.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockHandle(pub usize);

#[derive(Debug)]
struct Block {
    /// `None` while the buffer is lent to a prefetch worker.
    buf: Option<Box<[u8]>>,
    in_use: bool,
}

/// Page accounting for a page-backed pool: the shared allocator plus one
/// preallocated page table per block.
#[derive(Debug)]
struct PoolPaging {
    shared: SharedPages,
    pages_per_block: usize,
    tables: Vec<Vec<PageId>>,
}

/// Fixed-block pool. Every block holds `block_bytes` of quantized payload.
#[derive(Debug)]
pub struct MemoryPool {
    blocks: Vec<Block>,
    free: Vec<BlockHandle>,
    block_bytes: usize,
    paging: Option<PoolPaging>,
    /// lifetime counters for diagnostics / EXPERIMENTS.md
    pub allocs: u64,
    pub frees: u64,
}

impl MemoryPool {
    /// Pre-allocate `n_blocks` blocks of `block_bytes` each. This is the
    /// only place the pool allocates; `acquire`/`release` never touch the
    /// system allocator.
    pub fn new(n_blocks: usize, block_bytes: usize) -> Self {
        assert!(n_blocks > 0 && block_bytes > 0);
        let blocks = (0..n_blocks)
            .map(|_| Block {
                buf: Some(vec![0u8; block_bytes].into_boxed_slice()),
                in_use: false,
            })
            .collect();
        let free = (0..n_blocks).rev().map(BlockHandle).collect();
        Self {
            blocks,
            free,
            block_bytes,
            paging: None,
            allocs: 0,
            frees: 0,
        }
    }

    /// Page-backed pool: each block acquisition charges `pages_per_block`
    /// pages (modeled device bytes) against `shared`, the allocator the
    /// engine's KV tables also draw from. `pages_per_block` is a *modeled*
    /// quantity (`adapter_resident_bytes / page_bytes`), decoupled from the
    /// real `block_bytes` payload buffers the experiment stores use.
    pub fn new_paged(
        n_blocks: usize,
        block_bytes: usize,
        shared: SharedPages,
        pages_per_block: usize,
    ) -> Self {
        assert!(pages_per_block > 0, "paged pool needs at least one page per block");
        let mut pool = Self::new(n_blocks, block_bytes);
        pool.paging = Some(PoolPaging {
            shared,
            pages_per_block,
            tables: (0..n_blocks)
                .map(|_| Vec::with_capacity(pages_per_block))
                .collect(),
        });
        pool
    }

    /// The shared page allocator backing this pool, if page-backed.
    pub fn shared_pages(&self) -> Option<&SharedPages> {
        self.paging.as_ref().map(|p| &p.shared)
    }

    /// Modeled pages charged per block (0 when unpaged).
    pub fn pages_per_block(&self) -> usize {
        self.paging.as_ref().map_or(0, |p| p.pages_per_block)
    }

    /// True when a free block *slot* exists but the shared allocator cannot
    /// supply its pages (KV pressure) — the caller should defer rather than
    /// treat the pool as misconfigured.
    pub fn page_starved(&self) -> bool {
        match &self.paging {
            Some(p) => {
                !self.free.is_empty() && p.shared.free_pages() < p.pages_per_block
            }
            None => false,
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    pub fn total_bytes(&self) -> usize {
        self.blocks.len() * self.block_bytes
    }

    /// Take a free block. Returns None if the pool is exhausted — no free
    /// block slot, or (page-backed) the shared allocator cannot supply the
    /// block's pages; the caller must evict (or the engine preempt) first.
    pub fn acquire(&mut self) -> Option<BlockHandle> {
        let &h = self.free.last()?;
        if let Some(p) = &mut self.paging {
            debug_assert!(p.tables[h.0].is_empty(), "stale page table");
            if !p.shared.alloc_n_into(p.pages_per_block, &mut p.tables[h.0]) {
                return None;
            }
        }
        self.free.pop();
        debug_assert!(!self.blocks[h.0].in_use, "free-list corruption");
        self.blocks[h.0].in_use = true;
        self.allocs += 1;
        Some(h)
    }

    /// Return a block to the pool. Panics on double-free (a real bug) and on
    /// releasing a block whose buffer is still lent out.
    pub fn release(&mut self, h: BlockHandle) {
        let b = &mut self.blocks[h.0];
        assert!(b.in_use, "double release of block {h:?}");
        assert!(b.buf.is_some(), "release of block {h:?} while buffer lent");
        b.in_use = false;
        if let Some(p) = &mut self.paging {
            p.shared.free_all(&mut p.tables[h.0]);
        }
        self.free.push(h);
        self.frees += 1;
    }

    /// Copy `data` into an acquired block (tests / eager paths; the serving
    /// path writes through `bytes_mut` with `read_raw_into` instead).
    pub fn write(&mut self, h: BlockHandle, data: &[u8]) {
        assert!(data.len() <= self.block_bytes, "data overflows block");
        let b = &mut self.blocks[h.0];
        assert!(b.in_use, "write to free block");
        let buf = b.buf.as_mut().expect("write to lent block");
        buf[..data.len()].copy_from_slice(data);
    }

    /// Borrow an acquired block's bytes mutably (e.g. as the destination of
    /// `AdapterStore::read_raw_into`).
    pub fn bytes_mut(&mut self, h: BlockHandle) -> &mut [u8] {
        let b = &mut self.blocks[h.0];
        assert!(b.in_use, "write to free block");
        b.buf.as_mut().expect("block buffer lent out")
    }

    /// Borrow an acquired block's bytes.
    pub fn bytes(&self, h: BlockHandle) -> &[u8] {
        let b = &self.blocks[h.0];
        assert!(b.in_use, "read of free block");
        b.buf.as_deref().expect("block buffer lent out")
    }

    /// Take ownership of an acquired block's buffer so a worker thread can
    /// fill it. The block stays `in_use`; `restore` must return the buffer
    /// before the block can be read, written, or released.
    pub fn lend(&mut self, h: BlockHandle) -> Box<[u8]> {
        let b = &mut self.blocks[h.0];
        assert!(b.in_use, "lend of free block");
        b.buf.take().expect("block buffer already lent")
    }

    /// Return a buffer previously taken with `lend`.
    pub fn restore(&mut self, h: BlockHandle, buf: Box<[u8]>) {
        let b = &mut self.blocks[h.0];
        assert!(b.in_use, "restore to free block");
        assert!(b.buf.is_none(), "restore to block that was never lent");
        assert_eq!(buf.len(), self.block_bytes, "restored buffer wrong size");
        b.buf = Some(buf);
    }

    /// True if the handle currently holds live data.
    pub fn is_live(&self, h: BlockHandle) -> bool {
        self.blocks.get(h.0).is_some_and(|b| b.in_use)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let mut p = MemoryPool::new(2, 8);
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        assert!(p.acquire().is_none());
        p.release(a);
        let c = p.acquire().unwrap();
        assert_eq!(c, a); // LIFO reuse
        assert_ne!(b, c);
        assert_eq!(p.allocs, 3);
        assert_eq!(p.frees, 1);
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_free_panics() {
        let mut p = MemoryPool::new(1, 4);
        let h = p.acquire().unwrap();
        p.release(h);
        p.release(h);
    }

    #[test]
    fn write_read_roundtrip() {
        let mut p = MemoryPool::new(1, 4);
        let h = p.acquire().unwrap();
        p.write(h, &[1, 2, 3]);
        assert_eq!(&p.bytes(h)[..3], &[1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "overflows block")]
    fn oversized_write_panics() {
        let mut p = MemoryPool::new(1, 2);
        let h = p.acquire().unwrap();
        p.write(h, &[0u8; 3]);
    }

    #[test]
    fn lend_restore_roundtrip() {
        let mut p = MemoryPool::new(1, 4);
        let h = p.acquire().unwrap();
        let mut buf = p.lend(h);
        buf[0] = 7;
        p.restore(h, buf);
        assert_eq!(p.bytes(h)[0], 7);
        p.release(h);
    }

    #[test]
    #[should_panic(expected = "lent")]
    fn read_while_lent_panics() {
        let mut p = MemoryPool::new(1, 4);
        let h = p.acquire().unwrap();
        let _buf = p.lend(h);
        let _ = p.bytes(h);
    }

    #[test]
    #[should_panic(expected = "buffer lent")]
    fn release_while_lent_panics() {
        let mut p = MemoryPool::new(1, 4);
        let h = p.acquire().unwrap();
        let _buf = p.lend(h);
        p.release(h);
    }

    #[test]
    fn no_allocation_after_init() {
        // proxy: every block's buffer pointer/length never changes
        let mut p = MemoryPool::new(4, 16);
        let ids: Vec<(usize, usize)> = p
            .blocks
            .iter()
            .map(|b| {
                let s = b.buf.as_deref().unwrap();
                (s.as_ptr() as usize, s.len())
            })
            .collect();
        for _ in 0..100 {
            let h = p.acquire().unwrap();
            p.write(h, &[1u8; 16]);
            p.release(h);
        }
        let ids2: Vec<(usize, usize)> = p
            .blocks
            .iter()
            .map(|b| {
                let s = b.buf.as_deref().unwrap();
                (s.as_ptr() as usize, s.len())
            })
            .collect();
        assert_eq!(ids, ids2);
    }

    #[test]
    fn total_bytes() {
        let p = MemoryPool::new(3, 100);
        assert_eq!(p.total_bytes(), 300);
    }

    #[test]
    fn paged_pool_charges_and_returns_pages() {
        let shared = SharedPages::new(10, 64);
        let mut p = MemoryPool::new_paged(3, 8, shared.clone(), 3);
        assert_eq!(p.pages_per_block(), 3);
        let a = p.acquire().unwrap();
        assert_eq!(shared.free_pages(), 7);
        let _b = p.acquire().unwrap();
        assert_eq!(shared.free_pages(), 4);
        // a third block slot is free but only 4 pages remain... 3 fit
        let c = p.acquire().unwrap();
        assert_eq!(shared.free_pages(), 1);
        p.release(a);
        assert_eq!(shared.free_pages(), 4);
        p.release(c);
        assert_eq!(shared.free_pages(), 7);
    }

    #[test]
    fn paged_pool_starves_under_kv_pressure_and_recovers() {
        let shared = SharedPages::new(4, 64);
        // KV side takes 3 pages: one block (2 pages) no longer fits
        let mut kv = Vec::with_capacity(4);
        assert!(shared.alloc_n_into(3, &mut kv));
        let mut p = MemoryPool::new_paged(2, 8, shared.clone(), 2);
        assert!(p.page_starved(), "free slots exist but pages do not");
        assert!(p.acquire().is_none(), "page pressure must fail acquire");
        assert_eq!(p.free_blocks(), 2, "failed acquire leaves the free list intact");
        // KV releases → pool recovers
        shared.free_all(&mut kv);
        assert!(!p.page_starved());
        let h = p.acquire().unwrap();
        assert_eq!(shared.free_pages(), 2);
        p.release(h);
        assert_eq!(shared.free_pages(), 4);
    }

    #[test]
    fn unpaged_pool_never_reports_page_starvation() {
        let mut p = MemoryPool::new(1, 8);
        let _h = p.acquire().unwrap();
        assert!(!p.page_starved());
        assert!(p.shared_pages().is_none());
        assert_eq!(p.pages_per_block(), 0);
    }
}
