//! Pre-allocated memory pool (§3.3): fixed-size blocks, each sized for one
//! dequantized adapter, reserved at server initialization. Loading an
//! adapter takes a free block (no runtime allocation on the hot path);
//! evicting returns the block. The paper represents this as
//! `std::stack<std::shared_ptr<adapter>>`; we use a slab of `Vec<f32>`
//! buffers plus a free-list of handles.

/// Handle to one pool block (index into the slab). Copy-cheap.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockHandle(pub usize);

#[derive(Debug)]
struct Block {
    buf: Vec<f32>,
    in_use: bool,
}

/// Fixed-block pool. Every block holds `block_elems` f32 values.
#[derive(Debug)]
pub struct MemoryPool {
    blocks: Vec<Block>,
    free: Vec<BlockHandle>,
    block_elems: usize,
    /// lifetime counters for diagnostics / EXPERIMENTS.md
    pub allocs: u64,
    pub frees: u64,
}

impl MemoryPool {
    /// Pre-allocate `n_blocks` blocks of `block_elems` f32 each. This is the
    /// only place the pool allocates; `acquire`/`release` never touch the
    /// system allocator.
    pub fn new(n_blocks: usize, block_elems: usize) -> Self {
        assert!(n_blocks > 0 && block_elems > 0);
        let blocks = (0..n_blocks)
            .map(|_| Block {
                buf: vec![0.0; block_elems],
                in_use: false,
            })
            .collect();
        let free = (0..n_blocks).rev().map(BlockHandle).collect();
        Self {
            blocks,
            free,
            block_elems,
            allocs: 0,
            frees: 0,
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn block_elems(&self) -> usize {
        self.block_elems
    }

    pub fn total_bytes(&self) -> usize {
        self.blocks.len() * self.block_elems * 4
    }

    /// Take a free block. Returns None if the pool is exhausted (caller must
    /// evict first).
    pub fn acquire(&mut self) -> Option<BlockHandle> {
        let h = self.free.pop()?;
        debug_assert!(!self.blocks[h.0].in_use, "free-list corruption");
        self.blocks[h.0].in_use = true;
        self.allocs += 1;
        Some(h)
    }

    /// Return a block to the pool. Panics on double-free (a real bug).
    pub fn release(&mut self, h: BlockHandle) {
        let b = &mut self.blocks[h.0];
        assert!(b.in_use, "double release of block {h:?}");
        b.in_use = false;
        self.free.push(h);
        self.frees += 1;
    }

    pub fn write(&mut self, h: BlockHandle, data: &[f32]) {
        assert!(data.len() <= self.block_elems, "data overflows block");
        let b = &mut self.blocks[h.0];
        assert!(b.in_use, "write to free block");
        b.buf[..data.len()].copy_from_slice(data);
    }

    pub fn read(&self, h: BlockHandle) -> &[f32] {
        let b = &self.blocks[h.0];
        assert!(b.in_use, "read of free block");
        &b.buf
    }

    /// True if the handle currently holds live data.
    pub fn is_live(&self, h: BlockHandle) -> bool {
        self.blocks.get(h.0).is_some_and(|b| b.in_use)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let mut p = MemoryPool::new(2, 8);
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        assert!(p.acquire().is_none());
        p.release(a);
        let c = p.acquire().unwrap();
        assert_eq!(c, a); // LIFO reuse
        assert_ne!(b, c);
        assert_eq!(p.allocs, 3);
        assert_eq!(p.frees, 1);
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_free_panics() {
        let mut p = MemoryPool::new(1, 4);
        let h = p.acquire().unwrap();
        p.release(h);
        p.release(h);
    }

    #[test]
    fn write_read_roundtrip() {
        let mut p = MemoryPool::new(1, 4);
        let h = p.acquire().unwrap();
        p.write(h, &[1.0, 2.0, 3.0]);
        assert_eq!(&p.read(h)[..3], &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "overflows block")]
    fn oversized_write_panics() {
        let mut p = MemoryPool::new(1, 2);
        let h = p.acquire().unwrap();
        p.write(h, &[0.0; 3]);
    }

    #[test]
    fn no_allocation_after_init() {
        // proxy: capacity of every block buffer never changes
        let mut p = MemoryPool::new(4, 16);
        let caps: Vec<usize> = p.blocks.iter().map(|b| b.buf.capacity()).collect();
        for _ in 0..100 {
            let h = p.acquire().unwrap();
            p.write(h, &[1.0; 16]);
            p.release(h);
        }
        let caps2: Vec<usize> = p.blocks.iter().map(|b| b.buf.capacity()).collect();
        assert_eq!(caps, caps2);
    }

    #[test]
    fn total_bytes() {
        let p = MemoryPool::new(3, 100);
        assert_eq!(p.total_bytes(), 1200);
    }
}
