//! Profiling-based router training (Algorithm 1, lines 3–7) and the §5.2
//! evaluation protocol (80/20 split, per-task accuracy, Table 12).
//!
//! Training data generation mirrors the paper: evaluate every adapter on
//! every dataset (here: sampled prompts graded by the task world), estimate
//! the per-(adapter, task) performance matrix, and fit the router. The
//! "classifier accuracy" knob stands in for how well the learned head maps
//! prompts to tasks (the paper's LoRA-finetuned Llama head is very good at
//! this; we default to 0.95 and sweep it in the ablation bench).

use crate::router::confidence::{TaskModelRouter, TaskWorld};
use crate::router::AdapterRouter;
use crate::util::rng::Pcg64;

/// Profiling pass: estimate acc[adapter][task] from `samples_per_cell`
/// graded evaluations (Algorithm 1 lines 4–6).
pub fn profile_adapters(
    world: &TaskWorld,
    samples_per_cell: usize,
    seed: u64,
) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::new(seed);
    (0..world.n_adapters())
        .map(|a| {
            (0..world.n_tasks())
                .map(|t| {
                    let correct = (0..samples_per_cell)
                        .filter(|_| world.grade(a, t, &mut rng))
                        .count();
                    correct as f64 / samples_per_cell as f64
                })
                .collect()
        })
        .collect()
}

/// Train the router: profile, then wrap the estimated matrix in the
/// task-model router with the given prompt-classifier accuracy.
pub fn train_router(
    world: &TaskWorld,
    samples_per_cell: usize,
    classifier_acc: f64,
    seed: u64,
) -> TaskModelRouter {
    let est = profile_adapters(world, samples_per_cell, seed);
    TaskModelRouter::new(est, classifier_acc, seed ^ 0x0007_0b07)
}

/// One row of the Table 12 reproduction.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub name: String,
    /// accuracy per task (%), then the average
    pub per_task: Vec<f64>,
    pub average: f64,
}

/// Evaluate a *fixed* adapter on the held-out test prompts.
pub fn eval_fixed_adapter(
    world: &TaskWorld,
    adapter: usize,
    prompts_per_task: usize,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Pcg64::new(seed);
    (0..world.n_tasks())
        .map(|t| {
            let correct = (0..prompts_per_task)
                .filter(|_| world.grade(adapter, t, &mut rng))
                .count();
            100.0 * correct as f64 / prompts_per_task as f64
        })
        .collect()
}

/// Evaluate the router end-to-end: for each test prompt, the router picks
/// the top-1 adapter, the world grades the answer.
pub fn eval_router(
    world: &TaskWorld,
    router: &dyn AdapterRouter,
    prompts_per_task: usize,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Pcg64::new(seed);
    (0..world.n_tasks())
        .map(|t| {
            let mut correct = 0;
            for _ in 0..prompts_per_task {
                let prompt = world.sample_prompt(t, 32, &mut rng);
                let choice = router.top_k(&prompt, 1)[0] as usize;
                if world.grade(choice, t, &mut rng) {
                    correct += 1;
                }
            }
            100.0 * correct as f64 / prompts_per_task as f64
        })
        .collect()
}

/// Full §5.2 experiment: every fixed adapter + the trained router.
pub fn table12_experiment(
    world: &TaskWorld,
    names: &[&str],
    prompts_per_task: usize,
    classifier_acc: f64,
    seed: u64,
) -> Vec<EvalRow> {
    let mut rows = Vec::new();
    for (a, name) in names.iter().enumerate() {
        let per_task = eval_fixed_adapter(world, a, prompts_per_task, seed + a as u64);
        let average = per_task.iter().sum::<f64>() / per_task.len() as f64;
        rows.push(EvalRow {
            name: name.to_string(),
            per_task,
            average,
        });
    }
    let router = train_router(world, 2000, classifier_acc, seed);
    let per_task = eval_router(world, &router, prompts_per_task, seed + 99);
    let average = per_task.iter().sum::<f64>() / per_task.len() as f64;
    rows.push(EvalRow {
        name: "Adapter Router (Our Approach)".into(),
        per_task,
        average,
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiling_recovers_matrix() {
        let world = TaskWorld::table12();
        let est = profile_adapters(&world, 2000, 7);
        for (a, row) in est.iter().enumerate() {
            for (t, &e) in row.iter().enumerate() {
                assert!(
                    (e - world.acc[a][t]).abs() < 0.04,
                    "cell ({a},{t}): est {e} vs true {}",
                    world.acc[a][t]
                );
            }
        }
    }

    #[test]
    fn router_beats_best_single_adapter() {
        // The §5.2 headline: router average > every individual adapter's
        // average (Table 12: 38.22 vs 37.10 best single).
        let world = TaskWorld::table12();
        let router = train_router(&world, 2000, 0.98, 13);
        let per_task = eval_router(&world, &router, 3000, 17);
        let router_avg = per_task.iter().sum::<f64>() / per_task.len() as f64;
        let (_, best_single) = world.best_single_adapter();
        assert!(
            router_avg > best_single * 100.0,
            "router {router_avg:.2} vs best single {:.2}",
            best_single * 100.0
        );
        // and is bounded by the oracle ceiling (+ sampling noise)
        assert!(router_avg <= world.oracle_accuracy() * 100.0 + 2.0);
    }

    #[test]
    fn table12_experiment_shape() {
        let world = TaskWorld::table12();
        let rows = table12_experiment(
            &world,
            &crate::router::confidence::TABLE12_ADAPTERS,
            400,
            0.95,
            23,
        );
        assert_eq!(rows.len(), 8); // 7 adapters + router
        assert_eq!(rows[0].per_task.len(), 5);
        let router_row = rows.last().unwrap();
        assert!(router_row.name.contains("Router"));
        // router's average within striking distance of the paper's 38.22
        assert!(
            (34.0..42.0).contains(&router_row.average),
            "router avg {}",
            router_row.average
        );
    }

    #[test]
    fn degraded_classifier_hurts() {
        let world = TaskWorld::table12();
        let good = train_router(&world, 1000, 0.95, 31);
        let bad = train_router(&world, 1000, 0.2, 31);
        let g = eval_router(&world, &good, 2000, 37);
        let b = eval_router(&world, &bad, 2000, 37);
        let ga = g.iter().sum::<f64>() / 5.0;
        let ba = b.iter().sum::<f64>() / 5.0;
        assert!(ga > ba, "good {ga} vs bad {ba}");
    }
}
