//! PJRT-backed router head: maps the learned head's raw scores (one sigmoid
//! per head output, from the `router_head` HLO artifact) onto logical
//! adapter ids.
//!
//! The head has a fixed width (`n_router_outputs` baked into the artifact);
//! a server can know about more adapters than head outputs, so the mapping
//! `adapter id → head output` is explicit. Adapters without a head output
//! score 0 (never auto-selected — the paper's router likewise only scores
//! the adapters it was trained on).

use std::collections::HashMap;

use crate::adapters::AdapterId;
use crate::router::{AdapterRouter, RouterPrompt};

/// Router that serves scores computed by the backend's `router_pass`
/// (the engine calls the backend, then hands raw head outputs here).
pub struct HeadScoreMapper {
    /// adapter id -> head output index
    map: HashMap<AdapterId, usize>,
    n_adapters: usize,
}

impl HeadScoreMapper {
    /// Identity-ish mapping for the common case: adapter i -> output i,
    /// for the first `min(n_adapters, head_width)` adapters.
    pub fn identity(n_adapters: usize, head_width: usize) -> Self {
        let map = (0..n_adapters.min(head_width) as u64)
            .map(|i| (i, i as usize))
            .collect();
        Self { map, n_adapters }
    }

    pub fn with_mapping(map: HashMap<AdapterId, usize>, n_adapters: usize) -> Self {
        Self { map, n_adapters }
    }

    /// Expand raw head outputs into per-adapter scores.
    pub fn expand(&self, head_scores: &[f32]) -> Vec<f32> {
        (0..self.n_adapters as u64)
            .map(|id| {
                self.map
                    .get(&id)
                    .and_then(|&i| head_scores.get(i))
                    .copied()
                    .unwrap_or(0.0)
            })
            .collect()
    }
}

/// An `AdapterRouter` over a fixed score vector (what the engine builds
/// right after a `router_pass` returns raw scores for one prompt).
pub struct SnapshotRouter {
    pub scores: Vec<f32>,
}

impl AdapterRouter for SnapshotRouter {
    fn scores(&self, _prompt: &RouterPrompt) -> Vec<f32> {
        self.scores.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_mapping_clamps() {
        let m = HeadScoreMapper::identity(10, 4);
        let scores = m.expand(&[0.9, 0.8, 0.7, 0.6]);
        assert_eq!(scores.len(), 10);
        assert_eq!(scores[0], 0.9);
        assert_eq!(scores[3], 0.6);
        assert_eq!(scores[4], 0.0); // beyond head width
    }

    #[test]
    fn custom_mapping() {
        let mut map = HashMap::new();
        map.insert(5u64, 0usize);
        map.insert(2u64, 1usize);
        let m = HeadScoreMapper::with_mapping(map, 6);
        let s = m.expand(&[0.4, 0.9]);
        assert_eq!(s[5], 0.4);
        assert_eq!(s[2], 0.9);
        assert_eq!(s[0], 0.0);
    }

    #[test]
    fn snapshot_router_top_k() {
        let r = SnapshotRouter {
            scores: vec![0.1, 0.5, 0.3],
        };
        let p = RouterPrompt {
            tokens: vec![],
            latent_task: None,
        };
        assert_eq!(r.top_k(&p, 2), vec![1, 2]);
    }
}
