//! Synthetic task-model router: the evaluation substrate for §5.2.
//!
//! The paper profiles six fine-tuned adapters on five benchmark suites
//! (IFEval, BBH, MATH, GPQA, MMLU-PRO) and trains a multi-label classifier
//! on the results. We reproduce the *mechanism* with a synthetic model:
//!
//!  * a ground-truth accuracy matrix `acc[adapter][task]` seeded from the
//!    paper's measured Table 12 values;
//!  * prompts carry a latent task; answering a task-t prompt with adapter j
//!    is correct with probability `acc[j][t]`;
//!  * the trained router estimates the matrix from observed correctness
//!    (profiling) and a noisy task classifier models imperfect prompt→task
//!    inference (the router head's finite accuracy).

use crate::router::{AdapterRouter, RouterPrompt};
use crate::util::rng::Pcg64;

/// Table 12's measured accuracies (%), rows = adapters, cols = suites
/// [IFEval, BBH, MATH, GPQA, MMLU-PRO]. Row order matches the paper.
pub const TABLE12_ACC: [[f64; 5]; 7] = [
    // Llama-3.1-8B-Instruct (the pretrained base, row 0)
    [41.84, 51.22, 13.82, 34.95, 37.85],
    // Llama-Spark
    [43.45, 52.30, 13.45, 31.79, 38.91],
    // Defne-llama3.1-8B
    [40.92, 53.10, 14.56, 32.42, 38.82],
    // Hercules-6.1-Llama-3.1-8B
    [47.13, 51.09, 13.54, 32.63, 37.42],
    // Llama3.1-8B-ShiningValiant2
    [18.16, 44.08, 8.53, 32.11, 32.62],
    // Llama-3.1-8B-German-ORPO
    [41.38, 50.10, 0.19, 32.95, 33.72],
    // Llama-3.1-SauerkrautLM-8b-Instruct
    [45.52, 51.85, 15.40, 33.16, 39.57],
];

pub const TABLE12_ADAPTERS: [&str; 7] = [
    "Llama-3.1-8B-Instruct",
    "Llama-Spark",
    "Defne-llama3.1-8B",
    "Hercules-6.1-Llama-3.1-8B",
    "Llama3.1-8B-ShiningValiant2",
    "Llama-3.1-8B-German-ORPO",
    "Llama-3.1-SauerkrautLM-8b-Instruct",
];

pub const TABLE12_TASKS: [&str; 5] = ["IFEval", "BBH", "MATH", "GPQA", "MMLU-PRO"];

/// Ground-truth task world: accuracy matrix + prompt sampling + grading.
#[derive(Debug, Clone)]
pub struct TaskWorld {
    /// acc[adapter][task] in [0,1]
    pub acc: Vec<Vec<f64>>,
}

impl TaskWorld {
    /// The §5.2 world: Table 12's six fine-tuned adapters (we include the
    /// base-instruct row as adapter 0, as the paper's table does).
    pub fn table12() -> Self {
        Self {
            acc: TABLE12_ACC
                .iter()
                .map(|row| row.iter().map(|&x| x / 100.0).collect())
                .collect(),
        }
    }

    /// Synthetic world with `n_adapters`, each specialized on task
    /// `i % n_tasks` — used for scaling experiments beyond six adapters.
    pub fn synthetic(n_adapters: usize, n_tasks: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let acc = (0..n_adapters)
            .map(|a| {
                (0..n_tasks)
                    .map(|t| {
                        let base = 0.25 + 0.1 * rng.next_f64();
                        if a % n_tasks == t {
                            base + 0.35 // specialization bump
                        } else {
                            base
                        }
                    })
                    .collect()
            })
            .collect();
        Self { acc }
    }

    pub fn n_adapters(&self) -> usize {
        self.acc.len()
    }

    pub fn n_tasks(&self) -> usize {
        self.acc[0].len()
    }

    /// Sample a prompt for task `t`: tokens whose distribution weakly encodes
    /// the task (so a learned classifier *could* recover it).
    pub fn sample_prompt(&self, task: usize, len: usize, rng: &mut Pcg64) -> RouterPrompt {
        let tokens = (0..len.max(1))
            .map(|_| {
                // task-specific vocabulary band + common band
                if rng.next_f64() < 0.6 {
                    (1000 + task * 97 + rng.gen_range_usize(0, 50)) as u32
                } else {
                    rng.gen_range_u64(1, 999) as u32
                }
            })
            .collect();
        RouterPrompt {
            tokens,
            latent_task: Some(task),
        }
    }

    /// Grade: did adapter `a` answer a task-`t` prompt correctly?
    pub fn grade(&self, adapter: usize, task: usize, rng: &mut Pcg64) -> bool {
        rng.next_f64() < self.acc[adapter][task]
    }

    /// Best single adapter by average accuracy (the router's baseline).
    pub fn best_single_adapter(&self) -> (usize, f64) {
        let mut best = (0, 0.0);
        for (a, row) in self.acc.iter().enumerate() {
            let avg = row.iter().sum::<f64>() / row.len() as f64;
            if avg > best.1 {
                best = (a, avg);
            }
        }
        best
    }

    /// Oracle ceiling: per-task best adapter, averaged (paper: "the ceiling
    /// is determined by the optimal adapter selection for each prompt").
    pub fn oracle_accuracy(&self) -> f64 {
        let n_tasks = self.n_tasks();
        (0..n_tasks)
            .map(|t| {
                self.acc
                    .iter()
                    .map(|row| row[t])
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / n_tasks as f64
    }
}

/// The trained router: estimated accuracy matrix + task-classifier accuracy.
///
/// `scores(prompt)` = estimated per-adapter accuracy under the router's
/// (possibly wrong) belief about the prompt's task — reproducing the §4.1
/// construction where the head outputs one sigmoid score per adapter.
pub struct TaskModelRouter {
    /// est[adapter][task]
    pub est: Vec<Vec<f64>>,
    /// probability the prompt's task is classified correctly
    pub classifier_acc: f64,
    seed: u64,
}

impl TaskModelRouter {
    pub fn new(est: Vec<Vec<f64>>, classifier_acc: f64, seed: u64) -> Self {
        assert!(!est.is_empty());
        assert!((0.0..=1.0).contains(&classifier_acc));
        Self {
            est,
            classifier_acc,
            seed,
        }
    }

    pub fn n_tasks(&self) -> usize {
        self.est[0].len()
    }

    /// The task the router believes the prompt belongs to. Deterministic per
    /// prompt (hash-seeded), wrong with prob 1-classifier_acc.
    pub fn classify(&self, prompt: &RouterPrompt) -> usize {
        let truth = prompt.latent_task.unwrap_or(0) % self.n_tasks();
        // deterministic per-prompt noise
        let mut h = self.seed ^ 0x9e3779b97f4a7c15;
        for &t in prompt.tokens.iter().take(8) {
            h = h.wrapping_mul(0x100000001b3).wrapping_add(t as u64);
        }
        let mut rng = Pcg64::new(h);
        if rng.next_f64() < self.classifier_acc || self.n_tasks() < 2 {
            truth
        } else {
            // confuse with a uniformly-random *other* task (gen_range is
            // inclusive: n_tasks-1 candidates, skip `truth` by shifting)
            let other = rng.gen_range_usize(0, self.n_tasks() - 2);
            if other >= truth {
                other + 1
            } else {
                other
            }
        }
    }
}

impl AdapterRouter for TaskModelRouter {
    fn scores(&self, prompt: &RouterPrompt) -> Vec<f32> {
        let task = self.classify(prompt);
        self.est.iter().map(|row| row[task] as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table12_world_shape() {
        let w = TaskWorld::table12();
        assert_eq!(w.n_adapters(), 7);
        assert_eq!(w.n_tasks(), 5);
        // SauerkrautLM has the best single-adapter average (37.10%)
        let (best, avg) = w.best_single_adapter();
        assert_eq!(best, 6);
        assert!((avg - 0.3710).abs() < 0.001, "avg {avg}");
        // oracle ceiling beats any single adapter
        assert!(w.oracle_accuracy() > avg);
    }

    #[test]
    fn oracle_value_matches_paper_math() {
        // per-task maxima: IFEval 47.13 (Hercules), BBH 53.10 (Defne),
        // MATH 15.40 (Sauerkraut), GPQA 34.95 (base), MMLU-PRO 39.57.
        let w = TaskWorld::table12();
        let oracle = w.oracle_accuracy() * 100.0;
        assert!((oracle - (47.13 + 53.10 + 15.40 + 34.95 + 39.57) / 5.0).abs() < 0.01);
    }

    #[test]
    fn grading_matches_accuracy() {
        let w = TaskWorld::table12();
        let mut rng = Pcg64::new(5);
        let n = 20_000;
        let correct = (0..n).filter(|_| w.grade(6, 1, &mut rng)).count();
        let emp = correct as f64 / n as f64;
        assert!((emp - 0.5185).abs() < 0.015, "emp {emp}");
    }

    #[test]
    fn perfect_router_picks_per_task_best() {
        let w = TaskWorld::table12();
        let router = TaskModelRouter::new(w.acc.clone(), 1.0, 1);
        let mut rng = Pcg64::new(9);
        // task 0 = IFEval -> Hercules (index 3)
        let p = w.sample_prompt(0, 32, &mut rng);
        assert_eq!(router.top_k(&p, 1), vec![3]);
        // task 2 = MATH -> Sauerkraut (index 6)
        let p = w.sample_prompt(2, 32, &mut rng);
        assert_eq!(router.top_k(&p, 1), vec![6]);
    }

    #[test]
    fn classifier_noise_degrades_selection() {
        let w = TaskWorld::table12();
        let sharp = TaskModelRouter::new(w.acc.clone(), 1.0, 2);
        let blunt = TaskModelRouter::new(w.acc.clone(), 0.2, 2);
        let mut rng = Pcg64::new(11);
        let mut sharp_right = 0;
        let mut blunt_right = 0;
        for i in 0..500 {
            let task = i % 5;
            let p = w.sample_prompt(task, 16, &mut rng);
            if sharp.classify(&p) == task {
                sharp_right += 1;
            }
            if blunt.classify(&p) == task {
                blunt_right += 1;
            }
        }
        assert!(sharp_right > blunt_right + 100);
    }

    #[test]
    fn synthetic_world_specialization() {
        let w = TaskWorld::synthetic(12, 4, 3);
        assert_eq!(w.n_adapters(), 12);
        // adapter a is best (among its row) on task a % 4
        for (a, row) in w.acc.iter().enumerate() {
            let best_t = row
                .iter()
                .enumerate()
                .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(best_t, a % 4);
        }
    }
}
