//! Adapter router (§3.2 / §4.1): scores every adapter's suitability for a
//! prompt, enabling adaptive adapter selection.
//!
//! Two implementations:
//!  * [`pjrt`]-backed: the real path — prefill hidden state × router head
//!    HLO (the learned multi-label classifier of §4.1).
//!  * [`confidence::TaskModelRouter`]: the evaluation path — a synthetic
//!    benchmark-suite model seeded from the paper's own Table 12 accuracy
//!    matrix, with the profiling-based training loop of Algorithm 1
//!    (lines 3–7) reproduced in [`trainer`].

pub mod confidence;
pub mod pjrt;
pub mod trainer;

use crate::adapters::AdapterId;

/// A prompt as the router sees it: token ids plus (for the synthetic task
/// model) the latent task that generated it. Real routers ignore
/// `latent_task`; the synthetic router's *training* protocol never reads it
/// directly either — it only sees correctness observations, like the paper's
/// profiling over evaluation datasets.
#[derive(Debug, Clone)]
pub struct RouterPrompt {
    pub tokens: Vec<u32>,
    pub latent_task: Option<usize>,
}

/// Scores adapters for a prompt; higher = more suitable (paper: s_j ∈ [0,1]).
pub trait AdapterRouter: Send {
    /// Confidence score per adapter id in [0, n_adapters).
    fn scores(&self, prompt: &RouterPrompt) -> Vec<f32>;

    /// Top-k adapter ids by score, descending (Algorithm 1 line 9).
    fn top_k(&self, prompt: &RouterPrompt, k: usize) -> Vec<AdapterId> {
        let scores = self.scores(prompt);
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| {
            scores[b]
                .partial_cmp(&scores[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.into_iter().take(k).map(|i| i as AdapterId).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Fixed(Vec<f32>);
    impl AdapterRouter for Fixed {
        fn scores(&self, _p: &RouterPrompt) -> Vec<f32> {
            self.0.clone()
        }
    }

    #[test]
    fn top_k_orders_by_score() {
        let r = Fixed(vec![0.1, 0.9, 0.5, 0.7]);
        let p = RouterPrompt { tokens: vec![], latent_task: None };
        assert_eq!(r.top_k(&p, 3), vec![1, 3, 2]);
        assert_eq!(r.top_k(&p, 10).len(), 4);
    }

    #[test]
    fn top_k_ties_break_by_id() {
        let r = Fixed(vec![0.5, 0.5, 0.5]);
        let p = RouterPrompt { tokens: vec![], latent_task: None };
        assert_eq!(r.top_k(&p, 2), vec![0, 1]);
    }
}
