//! On-disk adapter store: our GGUF-stand-in binary format plus a registry.
//!
//! File layout (little-endian):
//!   magic "ELRA" | version u32 | adapter_id u64 | n_layers u32 | d_model u32
//!   | rank u32 | quant u32 (0=F32,1=Q8_0,2=Q4_0) | payload_len u64 | payload
//!
//! The payload is the flattened adapter (see `LoraWeights::flatten`) in the
//! chosen quantization. The store writes/reads these files under a root
//! directory (`adapter_000042.elra`), which is what the memory manager swaps
//! against — disk→memory load cost is real file I/O + dequantization.

use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::adapters::lora::{LoraShape, LoraWeights, QuantView};
use crate::quant::QuantType;

const MAGIC: &[u8; 4] = b"ELRA";
const VERSION: u32 = 1;

/// Fixed wire-header size preceding the quantized payload.
pub const HEADER_BYTES: usize = 40;

/// Parsed + validated wire header.
#[derive(Debug, Clone, Copy)]
pub struct Header {
    pub id: u64,
    pub shape: LoraShape,
    pub quant: QuantType,
    pub payload_len: usize,
}

impl Header {
    /// Parse and validate the fixed-size header (magic, version, shape/size
    /// consistency). Shared by `decode` and the zero-copy `read_raw_into`.
    pub fn parse(bytes: &[u8; HEADER_BYTES]) -> Result<Self> {
        if &bytes[0..4] != MAGIC {
            bail!("not an ELRA adapter file");
        }
        let rd_u32 = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let rd_u64 = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
        let version = rd_u32(4);
        if version != VERSION {
            bail!("unsupported version {version}");
        }
        let id = rd_u64(8);
        let shape = LoraShape {
            n_layers: rd_u32(16) as usize,
            d_model: rd_u32(20) as usize,
            rank: rd_u32(24) as usize,
        };
        let quant = quant_from_code(rd_u32(28))?;
        let payload_len = rd_u64(32) as usize;
        let n = shape.total_elems();
        if quant.storage_bytes(n) != payload_len {
            bail!("payload size {payload_len} inconsistent with shape ({n} elems)");
        }
        Ok(Self {
            id,
            shape,
            quant,
            payload_len,
        })
    }
}

fn quant_code(q: QuantType) -> u32 {
    match q {
        QuantType::F32 => 0,
        QuantType::Q8_0 => 1,
        QuantType::Q4_0 => 2,
    }
}

fn quant_from_code(c: u32) -> Result<QuantType> {
    Ok(match c {
        0 => QuantType::F32,
        1 => QuantType::Q8_0,
        2 => QuantType::Q4_0,
        _ => bail!("unknown quant code {c}"),
    })
}

/// Serialize an adapter to the wire format.
pub fn encode(w: &LoraWeights, id: u64, quant: QuantType) -> Vec<u8> {
    let flat = w.flatten();
    let payload = quant.quantize(&flat);
    let mut out = Vec::with_capacity(40 + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(w.shape.n_layers as u32).to_le_bytes());
    out.extend_from_slice(&(w.shape.d_model as u32).to_le_bytes());
    out.extend_from_slice(&(w.shape.rank as u32).to_le_bytes());
    out.extend_from_slice(&quant_code(quant).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Parse the wire format back into (id, quant, weights).
pub fn decode(bytes: &[u8]) -> Result<(u64, QuantType, LoraWeights)> {
    if bytes.len() < HEADER_BYTES {
        bail!("not an ELRA adapter file");
    }
    let header: &[u8; HEADER_BYTES] = bytes[..HEADER_BYTES].try_into().unwrap();
    let h = Header::parse(header)?;
    let payload = &bytes[HEADER_BYTES..];
    if payload.len() != h.payload_len {
        bail!(
            "payload length mismatch: {} vs {}",
            payload.len(),
            h.payload_len
        );
    }
    let view = QuantView {
        bytes: payload,
        quant: h.quant,
        shape: h.shape,
    };
    Ok((h.id, h.quant, view.to_weights()))
}

/// Directory-backed adapter registry.
pub struct AdapterStore {
    root: PathBuf,
    shape: LoraShape,
    quant: QuantType,
}

impl AdapterStore {
    pub fn create(root: impl AsRef<Path>, shape: LoraShape, quant: QuantType) -> Result<Self> {
        fs::create_dir_all(root.as_ref())
            .with_context(|| format!("creating {}", root.as_ref().display()))?;
        Ok(Self {
            root: root.as_ref().to_path_buf(),
            shape,
            quant,
        })
    }

    pub fn shape(&self) -> LoraShape {
        self.shape
    }

    pub fn quant(&self) -> QuantType {
        self.quant
    }

    fn path(&self, id: u64) -> PathBuf {
        self.root.join(format!("adapter_{id:06}.elra"))
    }

    /// Write a synthetic adapter set (ids 0..n) — server initialization.
    pub fn populate_synthetic(&self, n: usize) -> Result<()> {
        for id in 0..n as u64 {
            if self.path(id).exists() {
                continue;
            }
            let w = LoraWeights::synthetic(self.shape, id);
            self.put(id, &w)?;
        }
        Ok(())
    }

    pub fn put(&self, id: u64, w: &LoraWeights) -> Result<()> {
        let bytes = encode(w, id, self.quant);
        self.write_atomic(id, &bytes)
    }

    fn write_atomic(&self, id: u64, bytes: &[u8]) -> Result<()> {
        let tmp = self.path(id).with_extension("tmp");
        let mut f = fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all().ok();
        fs::rename(&tmp, self.path(id))?;
        Ok(())
    }

    /// Register a synthetic adapter under `id` (the runtime registry's
    /// default when `POST /v1/adapters` names no source file).
    pub fn put_synthetic(&self, id: u64) -> Result<()> {
        self.put(id, &LoraWeights::synthetic(self.shape, id))
    }

    /// Register an adapter at runtime from an existing `.elra` file:
    /// validate its header against the store's shape/quant (and the claimed
    /// id), then copy it into the registry atomically.
    pub fn import(&self, id: u64, src: impl AsRef<Path>) -> Result<()> {
        let bytes = fs::read(src.as_ref())
            .with_context(|| format!("reading {}", src.as_ref().display()))?;
        if bytes.len() < HEADER_BYTES {
            bail!("not an ELRA adapter file");
        }
        let header: &[u8; HEADER_BYTES] = bytes[..HEADER_BYTES].try_into().unwrap();
        let h = Header::parse(header)?;
        if h.id != id {
            bail!("file is adapter {}, not {id}", h.id);
        }
        if h.shape != self.shape || h.quant != self.quant {
            bail!(
                "adapter {id} shape/quant ({:?}, {}) does not match store ({:?}, {})",
                h.shape,
                h.quant.name(),
                self.shape,
                self.quant.name()
            );
        }
        if bytes.len() != HEADER_BYTES + h.payload_len {
            bail!("truncated payload");
        }
        self.write_atomic(id, &bytes)
    }

    /// Unregister an adapter (delete its file). Ok(false) when absent.
    pub fn remove(&self, id: u64) -> Result<bool> {
        match fs::remove_file(self.path(id)) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e.into()),
        }
    }

    /// Sorted ids of every registered adapter (registry listing).
    pub fn ids(&self) -> Vec<u64> {
        let mut out: Vec<u64> = fs::read_dir(&self.root)
            .map(|rd| {
                rd.filter_map(|e| {
                    let name = e.ok()?.file_name();
                    let name = name.to_str()?;
                    name.strip_prefix("adapter_")?
                        .strip_suffix(".elra")?
                        .parse()
                        .ok()
                })
                .collect()
            })
            .unwrap_or_default();
        out.sort_unstable();
        out
    }

    /// Read + dequantize an adapter (legacy/eager path; materializes the
    /// nested-Vec form). The serving hot path uses `read_raw_into` instead.
    pub fn get(&self, id: u64) -> Result<LoraWeights> {
        let mut bytes = Vec::new();
        fs::File::open(self.path(id))
            .with_context(|| format!("adapter {id} not in store"))?
            .read_to_end(&mut bytes)?;
        let (got_id, _, w) = decode(&bytes)?;
        if got_id != id {
            bail!("adapter file id mismatch: {got_id} != {id}");
        }
        Ok(w)
    }

    /// Quantized payload bytes of one stored adapter — the pool's block size.
    pub fn payload_bytes(&self) -> usize {
        self.quant.storage_bytes(self.shape.total_elems())
    }

    /// Zero-copy disk half of an adapter swap: validate the header, then
    /// read the quantized payload *straight into* `dst` (typically a memory
    /// pool block) with no intermediate allocation and no dequantization.
    /// `dst.len()` must equal `payload_bytes()`.
    pub fn read_raw_into(&self, id: u64, dst: &mut [u8]) -> Result<()> {
        let mut f = fs::File::open(self.path(id))
            .with_context(|| format!("adapter {id} not in store"))?;
        let mut header = [0u8; HEADER_BYTES];
        f.read_exact(&mut header)
            .with_context(|| format!("adapter {id}: short header"))?;
        let h = Header::parse(&header)?;
        if h.id != id {
            bail!("adapter file id mismatch: {} != {id}", h.id);
        }
        if h.shape != self.shape || h.quant != self.quant {
            bail!(
                "adapter {id} shape/quant ({:?}, {}) does not match store ({:?}, {})",
                h.shape,
                h.quant.name(),
                self.shape,
                self.quant.name()
            );
        }
        if dst.len() != h.payload_len {
            bail!(
                "destination is {} bytes but payload is {}",
                dst.len(),
                h.payload_len
            );
        }
        f.read_exact(dst)
            .with_context(|| format!("adapter {id}: truncated payload"))?;
        Ok(())
    }

    pub fn contains(&self, id: u64) -> bool {
        self.path(id).exists()
    }

    pub fn count(&self) -> usize {
        fs::read_dir(&self.root)
            .map(|rd| {
                rd.filter(|e| {
                    e.as_ref()
                        .map(|e| e.path().extension().is_some_and(|x| x == "elra"))
                        .unwrap_or(false)
                })
                .count()
            })
            .unwrap_or(0)
    }

    /// On-disk bytes of one stored adapter.
    pub fn file_bytes(&self) -> usize {
        HEADER_BYTES + self.payload_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::q8_0;

    const SHAPE: LoraShape = LoraShape {
        n_layers: 2,
        d_model: 32,
        rank: 4,
    };

    fn tmpdir(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("elra_test_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn encode_decode_f32_exact() {
        let w = LoraWeights::synthetic(SHAPE, 1);
        let bytes = encode(&w, 1, QuantType::F32);
        let (id, q, back) = decode(&bytes).unwrap();
        assert_eq!(id, 1);
        assert_eq!(q, QuantType::F32);
        assert_eq!(w.a, back.a);
        assert_eq!(w.b, back.b);
    }

    #[test]
    fn encode_decode_q8_bounded_error() {
        let w = LoraWeights::synthetic(SHAPE, 2);
        let (_, _, back) = decode(&encode(&w, 2, QuantType::Q8_0)).unwrap();
        let bound = q8_0::error_bound(w.amax());
        let flat = w.flatten();
        let bflat = back.flatten();
        for (x, y) in flat.iter().zip(&bflat) {
            assert!((x - y).abs() <= bound);
        }
    }

    #[test]
    fn rejects_corrupt() {
        let w = LoraWeights::synthetic(SHAPE, 3);
        let mut bytes = encode(&w, 3, QuantType::Q4_0);
        bytes[0] = b'X';
        assert!(decode(&bytes).is_err());
        let bytes2 = encode(&w, 3, QuantType::Q4_0);
        assert!(decode(&bytes2[..bytes2.len() - 3]).is_err());
    }

    #[test]
    fn store_roundtrip_and_count() {
        let dir = tmpdir("store");
        let store = AdapterStore::create(&dir, SHAPE, QuantType::Q8_0).unwrap();
        store.populate_synthetic(5).unwrap();
        assert_eq!(store.count(), 5);
        assert!(store.contains(4));
        assert!(!store.contains(5));
        let w = store.get(3).unwrap();
        assert_eq!(w.shape, SHAPE);
        // file size is header + quantized payload
        let meta = fs::metadata(dir.join("adapter_000003.elra")).unwrap();
        assert_eq!(meta.len() as usize, store.file_bytes());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_raw_into_matches_payload() {
        let dir = tmpdir("raw");
        let store = AdapterStore::create(&dir, SHAPE, QuantType::Q4_0).unwrap();
        let w = LoraWeights::synthetic(SHAPE, 9);
        store.put(9, &w).unwrap();
        let mut raw = vec![0u8; store.payload_bytes()];
        store.read_raw_into(9, &mut raw).unwrap();
        // payload must be byte-identical to what encode produced
        let encoded = encode(&w, 9, QuantType::Q4_0);
        assert_eq!(&encoded[HEADER_BYTES..], &raw[..]);
        // wrong destination size is rejected
        let mut short = vec![0u8; store.payload_bytes() - 1];
        assert!(store.read_raw_into(9, &mut short).is_err());
        // missing adapter is rejected
        assert!(store.read_raw_into(99, &mut raw).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn runtime_registry_import_remove_and_ids() {
        let dir = tmpdir("registry");
        let store = AdapterStore::create(&dir, SHAPE, QuantType::Q8_0).unwrap();
        store.populate_synthetic(3).unwrap();
        assert_eq!(store.ids(), vec![0, 1, 2]);
        // synthetic runtime registration
        store.put_synthetic(9).unwrap();
        assert!(store.contains(9));
        assert_eq!(store.ids(), vec![0, 1, 2, 9]);
        // import from a valid external file
        let w = LoraWeights::synthetic(SHAPE, 7);
        let src = dir.join("incoming.bin");
        fs::write(&src, encode(&w, 7, QuantType::Q8_0)).unwrap();
        store.import(7, &src).unwrap();
        assert!(store.contains(7));
        let got = store.get(7).unwrap();
        assert_eq!(got.shape, SHAPE);
        // id mismatch, wrong quant, and garbage are all rejected
        assert!(store.import(8, &src).is_err(), "embedded id must match");
        let src_q4 = dir.join("incoming_q4.bin");
        fs::write(&src_q4, encode(&w, 7, QuantType::Q4_0)).unwrap();
        assert!(store.import(7, &src_q4).is_err(), "quant must match store");
        let junk = dir.join("junk.bin");
        fs::write(&junk, b"junk").unwrap();
        assert!(store.import(5, &junk).is_err());
        // remove unregisters; second remove reports absence
        assert!(store.remove(9).unwrap());
        assert!(!store.contains(9));
        assert!(!store.remove(9).unwrap());
        assert_eq!(store.ids(), vec![0, 1, 2, 7]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn q4_files_are_smaller_than_q8() {
        let dir_a = tmpdir("q8");
        let dir_b = tmpdir("q4");
        let s8 = AdapterStore::create(&dir_a, SHAPE, QuantType::Q8_0).unwrap();
        let s4 = AdapterStore::create(&dir_b, SHAPE, QuantType::Q4_0).unwrap();
        assert!(s4.file_bytes() < s8.file_bytes());
        let _ = fs::remove_dir_all(&dir_a);
        let _ = fs::remove_dir_all(&dir_b);
    }
}
