//! Adapter subsystem: LoRA weight layout, the on-disk quantized store, and
//! the registry of adapters a server instance knows about.

pub mod lora;
pub mod store;

pub use lora::{LoraShape, LoraWeights, QuantBuf, QuantView, PROJECTIONS};
pub use store::AdapterStore;

/// Logical adapter identifier (stable across cache/pool churn).
pub type AdapterId = u64;
