//! LoRA adapter weights: per-layer, per-projection A/B low-rank pairs in the
//! layout the L2 model's bank parameters expect
//! (`a_bank[layer, proj, slot, r, d]`, `b_bank[layer, proj, slot, d, r]`).

use crate::quant::QuantType;
use crate::util::rng::Pcg64;

/// The four adapted projections, matching the L2 bank's axis-1 order.
pub const PROJECTIONS: [&str; 4] = ["q", "k", "v", "o"];

/// Shape metadata for one adapter (constant across the adapter set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoraShape {
    pub n_layers: usize,
    pub d_model: usize,
    pub rank: usize,
}

impl LoraShape {
    /// f32 elements in one adapter's A (or B) tensor for one (layer, proj).
    pub fn elems_per_mat(&self) -> usize {
        self.rank * self.d_model
    }

    /// Total f32 elements in a full adapter (A and B, 4 projections/layer).
    pub fn total_elems(&self) -> usize {
        self.n_layers * PROJECTIONS.len() * 2 * self.elems_per_mat()
    }

    /// Bytes of one adapter held in memory (dequantized f32).
    pub fn resident_bytes(&self) -> usize {
        self.total_elems() * 4
    }
}

/// One adapter's dequantized weights, ready to be written into a bank slot.
///
/// Layout: `a[layer][proj]` is row-major `[rank, d_model]`,
/// `b[layer][proj]` is row-major `[d_model, rank]`.
#[derive(Debug, Clone)]
pub struct LoraWeights {
    pub shape: LoraShape,
    pub a: Vec<Vec<Vec<f32>>>,
    pub b: Vec<Vec<Vec<f32>>>,
}

impl LoraWeights {
    /// Deterministic synthetic adapter, unique per id (what the paper gets
    /// from fine-tuning, we get from a seeded PRNG — scheduling behaviour
    /// only depends on sizes and ids). B is near-zero-scaled like a fresh
    /// LoRA init so stacking adapters across layers stays numerically tame.
    pub fn synthetic(shape: LoraShape, adapter_id: u64) -> Self {
        Self::synthetic_scaled(shape, adapter_id, 0.01)
    }

    /// Synthetic adapter with an explicit B scale — larger values make the
    /// adapter's effect on logits visible (used by tests that assert two
    /// adapters actually change the generated tokens).
    pub fn synthetic_scaled(shape: LoraShape, adapter_id: u64, b_scale: f32) -> Self {
        let mut rng = Pcg64::new(0x10ad_0000 ^ adapter_id);
        let scale_a = 1.0 / (shape.d_model as f32).sqrt();
        let mk = |rng: &mut Pcg64, n: usize, scale: f32| -> Vec<f32> {
            (0..n)
                .map(|_| (rng.next_f32() - 0.5) * 2.0 * scale)
                .collect()
        };
        let mut a = Vec::with_capacity(shape.n_layers);
        let mut b = Vec::with_capacity(shape.n_layers);
        for _ in 0..shape.n_layers {
            let mut al = Vec::with_capacity(PROJECTIONS.len());
            let mut bl = Vec::with_capacity(PROJECTIONS.len());
            for _ in 0..PROJECTIONS.len() {
                al.push(mk(&mut rng, shape.elems_per_mat(), scale_a));
                bl.push(mk(&mut rng, shape.elems_per_mat(), b_scale));
            }
            a.push(al);
            b.push(bl);
        }
        Self { shape, a, b }
    }

    /// Flatten to the order the store serializes: for each layer, for each
    /// projection: A then B.
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.shape.total_elems());
        for l in 0..self.shape.n_layers {
            for p in 0..PROJECTIONS.len() {
                out.extend_from_slice(&self.a[l][p]);
                out.extend_from_slice(&self.b[l][p]);
            }
        }
        out
    }

    /// Rebuild from the flat serialized order.
    pub fn unflatten(shape: LoraShape, flat: &[f32]) -> Self {
        assert_eq!(flat.len(), shape.total_elems());
        let m = shape.elems_per_mat();
        let mut it = flat.chunks_exact(m);
        let mut a = Vec::with_capacity(shape.n_layers);
        let mut b = Vec::with_capacity(shape.n_layers);
        for _ in 0..shape.n_layers {
            let mut al = Vec::new();
            let mut bl = Vec::new();
            for _ in 0..PROJECTIONS.len() {
                al.push(it.next().unwrap().to_vec());
                bl.push(it.next().unwrap().to_vec());
            }
            a.push(al);
            b.push(bl);
        }
        Self { shape, a, b }
    }

    /// Quantize into an owned buffer (`QuantBuf`), e.g. to hand a synthetic
    /// adapter to [`crate::backend::ModelBackend::load_adapter`] in tests.
    pub fn to_quant(&self, quant: QuantType) -> QuantBuf {
        QuantBuf {
            bytes: quant.quantize(&self.flatten()),
            quant,
            shape: self.shape,
        }
    }

    /// Max |value| across all tensors (for quantization error asserts).
    pub fn amax(&self) -> f32 {
        let mut m = 0.0f32;
        for l in &self.a {
            for p in l {
                for &v in p {
                    m = m.max(v.abs());
                }
            }
        }
        for l in &self.b {
            for p in l {
                for &v in p {
                    m = m.max(v.abs());
                }
            }
        }
        m
    }
}

/// Borrowed view of one adapter's *quantized* payload (the flattened-order
/// bytes the store writes and the memory pool holds). This is what travels
/// the zero-copy swap path: the backend dequantizes it exactly once, at
/// bank-upload time — no intermediate `LoraWeights`, no `flatten`/`unflatten`
/// round trips.
#[derive(Debug, Clone, Copy)]
pub struct QuantView<'a> {
    pub bytes: &'a [u8],
    pub quant: QuantType,
    pub shape: LoraShape,
}

impl<'a> QuantView<'a> {
    /// Dequantize the full payload in flattened order (allocating).
    pub fn dequantize(&self) -> Vec<f32> {
        self.quant.dequantize(self.bytes, self.shape.total_elems())
    }

    /// Dequantize into a caller-provided buffer of `shape.total_elems()`.
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.shape.total_elems());
        self.quant.dequantize_into(self.bytes, out);
    }

    /// Materialize the nested-Vec form (compat / non-hot-path callers).
    pub fn to_weights(&self) -> LoraWeights {
        LoraWeights::unflatten(self.shape, &self.dequantize())
    }
}

/// Owned quantized adapter payload; `view()` borrows it as a [`QuantView`].
#[derive(Debug, Clone)]
pub struct QuantBuf {
    pub bytes: Vec<u8>,
    pub quant: QuantType,
    pub shape: LoraShape,
}

impl QuantBuf {
    pub fn view(&self) -> QuantView<'_> {
        QuantView {
            bytes: &self.bytes,
            quant: self.quant,
            shape: self.shape,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SHAPE: LoraShape = LoraShape {
        n_layers: 2,
        d_model: 16,
        rank: 4,
    };

    #[test]
    fn shape_math() {
        assert_eq!(SHAPE.elems_per_mat(), 64);
        assert_eq!(SHAPE.total_elems(), 2 * 4 * 2 * 64);
        assert_eq!(SHAPE.resident_bytes(), SHAPE.total_elems() * 4);
    }

    #[test]
    fn synthetic_is_deterministic_and_unique() {
        let w1 = LoraWeights::synthetic(SHAPE, 7);
        let w2 = LoraWeights::synthetic(SHAPE, 7);
        let w3 = LoraWeights::synthetic(SHAPE, 8);
        assert_eq!(w1.a[0][0], w2.a[0][0]);
        assert_ne!(w1.a[0][0], w3.a[0][0]);
    }

    #[test]
    fn flatten_roundtrip() {
        let w = LoraWeights::synthetic(SHAPE, 3);
        let flat = w.flatten();
        assert_eq!(flat.len(), SHAPE.total_elems());
        let back = LoraWeights::unflatten(SHAPE, &flat);
        assert_eq!(w.a, back.a);
        assert_eq!(w.b, back.b);
    }

    #[test]
    fn quant_view_roundtrips_f32_exact() {
        let w = LoraWeights::synthetic(SHAPE, 4);
        let buf = w.to_quant(QuantType::F32);
        let view = buf.view();
        assert_eq!(view.dequantize(), w.flatten());
        let back = view.to_weights();
        assert_eq!(back.a, w.a);
        assert_eq!(back.b, w.b);
    }

    #[test]
    fn quant_view_dequantize_into_matches() {
        let w = LoraWeights::synthetic(SHAPE, 5);
        for q in [QuantType::F32, QuantType::Q8_0, QuantType::Q4_0] {
            let buf = w.to_quant(q);
            let via_vec = buf.view().dequantize();
            let mut via_slice = vec![0.0f32; SHAPE.total_elems()];
            buf.view().dequantize_into(&mut via_slice);
            assert_eq!(via_vec, via_slice);
        }
    }
}
