//! Substrate utilities built from scratch for the offline environment:
//! PRNG + workload distributions, JSON, clocks, a thread pool, a mini
//! property-testing framework, and a logger.

pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod threadpool;
pub mod time;
