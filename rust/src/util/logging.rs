//! Tiny env-controlled logger backing the `log` crate facade.
//!
//! `EDGELORA_LOG=debug cargo run …` — levels: error, warn, info (default),
//! debug, trace. Timestamps are seconds since process start.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::OnceCell;

struct Logger {
    start: Instant,
    counter: AtomicU64,
}

static LOGGER: OnceCell<Logger> = OnceCell::new();

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        self.counter.fetch_add(1, Ordering::Relaxed);
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERR",
            Level::Warn => "WRN",
            Level::Info => "INF",
            Level::Debug => "DBG",
            Level::Trace => "TRC",
        };
        eprintln!("[{t:9.3} {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Level from `EDGELORA_LOG`.
pub fn init() {
    let logger = LOGGER.get_or_init(|| Logger {
        start: Instant::now(),
        counter: AtomicU64::new(0),
    });
    if log::set_logger(logger).is_ok() {
        let level = match std::env::var("EDGELORA_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Info,
        };
        log::set_max_level(level);
    }
}

/// Number of records emitted so far (used by tests).
pub fn emitted() -> u64 {
    LOGGER
        .get()
        .map(|l| l.counter.load(Ordering::Relaxed))
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent_and_logs() {
        init();
        init();
        let before = emitted();
        log::info!("test message");
        assert!(emitted() >= before);
    }
}
