//! Mini property-testing framework (proptest is not in the offline vendor
//! set). Generates random cases from a seeded PCG, runs the property, and on
//! failure greedily shrinks the case before reporting — enough machinery for
//! the coordinator invariants this repo checks (routing, batching, cache and
//! pool state).
//!
//! Usage:
//! ```ignore
//! prop_check(200, gen_plan_case, |case| {
//!     let plan = UBatchPlan::build(&case.slots);
//!     plan_is_permutation(&plan)
//! });
//! ```

use crate::util::rng::Pcg64;

/// A generated case plus how to shrink it.
pub trait Shrink: Clone + std::fmt::Debug {
    /// Candidate smaller versions of `self`, most aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for Vec<usize> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halves — only when both are strictly shorter than self, otherwise
        // the candidate equals self and the shrink loop would never terminate
        if self.len() >= 2 {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[self.len() / 2..].to_vec());
        }
        // drop one element
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
        }
        // zero one element
        for i in 0..self.len().min(16) {
            if self[i] != 0 {
                let mut v = self.clone();
                v[i] = 0;
                out.push(v);
            }
        }
        out
    }
}

impl Shrink for Vec<u64> {
    fn shrink(&self) -> Vec<Self> {
        let as_usize: Vec<usize> = self.iter().map(|&x| x as usize).collect();
        as_usize
            .shrink()
            .into_iter()
            .map(|v| v.into_iter().map(|x| x as u64).collect())
            .collect()
    }
}

/// Run `property` over `n` random cases from `generate`; on failure, shrink
/// and panic with the minimal counterexample. Seed is fixed per call site
/// (pass your own for reruns).
pub fn prop_check<T, G, P>(n: usize, seed: u64, mut generate: G, mut property: P)
where
    T: Shrink,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Pcg64::new(seed);
    for i in 0..n {
        let case = generate(&mut rng);
        if !property(&case) {
            let minimal = shrink_to_minimal(case, &mut property);
            panic!(
                "property failed on case {i} (seed {seed});\n  minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_to_minimal<T: Shrink, P: FnMut(&T) -> bool>(
    mut case: T,
    property: &mut P,
) -> T {
    'outer: loop {
        for candidate in case.shrink() {
            if !property(&candidate) {
                case = candidate;
                continue 'outer;
            }
        }
        return case;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        prop_check(100, 1, |rng| {
            (0..rng.gen_range_usize(0, 20))
                .map(|_| rng.gen_range_usize(0, 100))
                .collect::<Vec<usize>>()
        }, |v| v.iter().sum::<usize>() <= 100 * v.len());
    }

    #[test]
    fn finds_and_shrinks_counterexample() {
        let result = std::panic::catch_unwind(|| {
            prop_check(
                1000,
                2,
                |rng| {
                    (0..rng.gen_range_usize(0, 30))
                        .map(|_| rng.gen_range_usize(0, 10))
                        .collect::<Vec<usize>>()
                },
                // fails whenever a 7 appears
                |v| !v.contains(&7),
            );
        });
        let msg = match result {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        // the minimal counterexample is exactly [7]
        assert!(msg.contains("[7]"), "msg: {msg}");
    }
}
