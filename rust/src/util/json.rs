//! Minimal JSON parser + writer (no serde in the offline vendor set).
//!
//! Parses the AOT `manifest.json` and the HTTP API request bodies; writes
//! API responses and bench-result records. Supports the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bool, null) with
//! location-carrying errors.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.field.path` convenience: `j.path(&["config", "d_model"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("bad surrogate pair"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // re-assemble UTF-8 multibyte sequences
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let len = if b >= 0xF0 {
                            4
                        } else if b >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Ergonomic object builder for response writing.
#[derive(Default)]
pub struct ObjBuilder(BTreeMap<String, Json>);

impl ObjBuilder {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn str(mut self, k: &str, v: impl Into<String>) -> Self {
        self.0.insert(k.into(), Json::Str(v.into()));
        self
    }
    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.0.insert(k.into(), Json::Num(v));
        self
    }
    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.0.insert(k.into(), Json::Bool(v));
        self
    }
    pub fn val(mut self, k: &str, v: Json) -> Self {
        self.0.insert(k.into(), v);
        self
    }
    pub fn build(self) -> Json {
        Json::Obj(self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"c"}],"d":{"e":null}}"#).unwrap();
        assert_eq!(j.path(&["d", "e"]), Some(&Json::Null));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parses_unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let j = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"x"],"n":-3,"o":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn builder() {
        let j = ObjBuilder::new()
            .str("model", "S1")
            .num("n", 20.0)
            .bool("ok", true)
            .build();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(20));
        assert!(j.to_string().contains("\"model\":\"S1\""));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let j = Json::parse(&text).unwrap();
            assert!(j.get("artifacts").unwrap().as_arr().unwrap().len() >= 4);
            assert!(j.path(&["config", "d_model"]).unwrap().as_usize().unwrap() > 0);
        }
    }
}
