//! Clock abstraction: wall time for the real serving path, virtual time for
//! the device simulation.
//!
//! Every latency-bearing component (scheduler, memory manager, backends,
//! energy sampler) takes a `&dyn Clock` so the same coordinator code runs
//! both against PJRT in real time and against the device model in simulated
//! time. The virtual clock lets a 5-minute paper trace replay in
//! milliseconds, which is what makes regenerating all of Tables 4–14 cheap.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic time source, in seconds since an arbitrary epoch.
pub trait Clock: Send + Sync {
    fn now(&self) -> f64;
    /// Advance past `seconds` of work. The wall clock actually sleeps only
    /// when asked to (serving); the virtual clock just jumps.
    fn advance(&self, seconds: f64);
    fn is_virtual(&self) -> bool {
        false
    }
}

/// Real time; `advance` sleeps (used by the trace replayer when pacing
/// request arrivals against the PJRT backend).
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn advance(&self, seconds: f64) {
        if seconds > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(seconds));
        }
    }
}

/// Discrete-event virtual clock: time moves only via `advance`/`advance_to`.
/// Stored as integer nanoseconds in an atomic so it is shareable and cheap.
pub struct VirtualClock {
    nanos: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self {
            nanos: AtomicU64::new(0),
        }
    }

    pub fn advance_to(&self, t: f64) {
        let target = (t * 1e9) as u64;
        // monotonic: never move backwards
        self.nanos.fetch_max(target, Ordering::SeqCst);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.nanos.load(Ordering::SeqCst) as f64 / 1e9
    }

    fn advance(&self, seconds: f64) {
        if seconds > 0.0 {
            // Round UP: truncation would let `advance(t_target - now)` land
            // a fraction of a nanosecond short of t_target, after which the
            // next advance truncates to 0 and a scheduler waiting for
            // `now >= t_target` spins forever.
            self.nanos
                .fetch_add((seconds * 1e9).ceil() as u64, Ordering::SeqCst);
        }
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance_to(1.0); // must not go backwards
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance_to(3.0);
        assert!((c.now() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn wall_clock_monotone() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn trait_object_dispatch() {
        let v = VirtualClock::new();
        let c: &dyn Clock = &v;
        c.advance(2.0);
        assert!(c.is_virtual());
        assert!((c.now() - 2.0).abs() < 1e-9);
    }
}
