//! Fixed-size thread pool with a lock-based MPMC queue.
//!
//! Stands in for tokio in the serving front-end (the offline vendor set has
//! no async runtime): the HTTP listener hands each accepted connection to
//! the pool, and the engine uses it for background adapter loads.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n_threads: usize) -> Self {
        assert!(n_threads > 0);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..n_threads)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("edgelora-worker-{i}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Enqueue a job. Panics if called after shutdown.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        assert!(
            !self.shared.shutdown.load(Ordering::SeqCst),
            "pool is shut down"
        );
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Box::new(job));
        self.shared.available.notify_one();
    }

    /// Block until every enqueued job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) > 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    pub fn pending(&self) -> usize {
        self.shared.in_flight.load(Ordering::SeqCst)
    }
}

fn worker_loop(s: Arc<Shared>) {
    loop {
        let job = {
            let mut q = s.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if s.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = s.available.wait(q).unwrap();
            }
        };
        // A panicking job must not wedge `wait_idle`, so decrement through a
        // drop guard.
        struct Guard<'a>(&'a Shared);
        impl Drop for Guard<'_> {
            fn drop(&mut self) {
                self.0.in_flight.fetch_sub(1, Ordering::SeqCst);
                let _g = self.0.done_lock.lock().unwrap();
                self.0.done.notify_all();
            }
        }
        let _guard = Guard(&s);
        job();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU32::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_with_no_jobs_returns() {
        let pool = ThreadPool::new(1);
        pool.wait_idle();
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU32::new(0));
        pool.execute(|| panic!("boom"));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU32::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }
}
