//! Deterministic PRNG + the distributions the workload generator needs.
//!
//! The offline vendor set has no `rand` crate, so this module implements
//! PCG64 (O'Neill 2014, XSL-RR variant) plus the samplers the paper's
//! synthetic workload requires: Gamma arrivals (Marsaglia–Tsang squeeze) for
//! burstiness control via the coefficient of variation, the power-law
//! adapter-popularity distribution (Zipf with exponent α), and uniform
//! input/output token lengths.

/// splitmix64 — cheap, well-mixed stateless 64-bit hash. The single mixing
/// primitive shared by the cluster dispatcher's consistent hash, the prefix
/// radix's rolling prompt hash, and the sim backend's deterministic token
/// synthesis (tokens must be pure functions of request content so prefix
/// sharing and preemption recompute stay bit-identical).
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// PCG64 XSL-RR: 128-bit LCG state, 64-bit xor-shift/rotate output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        // SplitMix-style expansion of the 64-bit seed into state + stream.
        let mut s = seed as u128 ^ 0x9e37_79b9_7f4a_7c15_f39c_c060_5ced_c834;
        s = s.wrapping_mul(PCG_MULT).wrapping_add(1);
        let inc = (s << 1) | 1;
        let mut rng = Self { state: s, inc };
        rng.next_u64();
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [lo, hi] (inclusive). Uses Lemire-style rejection
    /// to avoid modulo bias.
    pub fn gen_range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "empty range");
        let span = hi - lo + 1;
        if span == 0 {
            return self.next_u64(); // full 2^64 range
        }
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + v % span;
            }
        }
    }

    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range_u64(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller (polar form avoided to stay branch-lean).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate λ (mean 1/λ).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Gamma(shape k, scale θ) via Marsaglia–Tsang (2000); for k < 1 uses the
    /// boost trick Gamma(k) = Gamma(k+1) · U^(1/k).
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            let boost = loop {
                let u = self.next_f64();
                if u > 0.0 {
                    break u.powf(1.0 / shape);
                }
            };
            return boost * self.gamma(shape + 1.0, scale);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.next_f64();
            // squeeze then full acceptance test
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3 * scale;
            }
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range_usize(0, i);
            xs.swap(i, j);
        }
    }
}

/// The paper's adapter-popularity model (§5.1): P(i) ∝ i^(−α) over adapters
/// sorted by frequency. Lower α ⇒ flatter; higher α ⇒ heavier head.
///
/// NOTE on the paper's wording: the text says "a lower α leads to higher
/// locality" while defining P(i) ∝ i^(−α), under which *higher* α
/// concentrates mass on fewer adapters. We implement the formula as printed;
/// the locality sweep (Tables 7–8) spans α ∈ {0.5, 0.75, 1} either way and
/// the conclusion (both systems insensitive) is direction-agnostic.
#[derive(Debug, Clone)]
pub struct PowerLaw {
    /// Cumulative distribution over adapter ranks (len = n).
    cdf: Vec<f64>,
}

impl PowerLaw {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 1..=n {
            acc += (i as f64).powf(-alpha);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// Sample an adapter rank in [0, n).
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        // binary search the CDF
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability mass of rank i.
    pub fn pmf(&self, i: usize) -> f64 {
        if i == 0 {
            self.cdf[0]
        } else {
            self.cdf[i] - self.cdf[i - 1]
        }
    }
}

/// Gamma arrival process (§5.1): inter-arrival ~ Gamma(shape 1/cv²,
/// scale cv²/R). cv = 1 degenerates to exponential (Poisson arrivals);
/// cv > 1 is burstier than Poisson.
#[derive(Debug, Clone)]
pub struct GammaArrivals {
    shape: f64,
    scale: f64,
}

impl GammaArrivals {
    pub fn new(rate: f64, cv: f64) -> Self {
        assert!(rate > 0.0 && cv > 0.0);
        let cv2 = cv * cv;
        Self {
            shape: 1.0 / cv2,
            scale: cv2 / rate,
        }
    }

    /// Next inter-arrival gap in seconds.
    pub fn next_gap(&self, rng: &mut Pcg64) -> f64 {
        rng.gamma(self.shape, self.scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_inclusive_bounds_hit() {
        let mut rng = Pcg64::new(9);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[rng.gen_range_usize(0, 4)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_moments_match_shape_scale() {
        let mut rng = Pcg64::new(13);
        for &(k, theta) in &[(0.5, 2.0), (1.0, 1.0), (4.0, 0.25), (9.0, 3.0)] {
            let n = 30_000;
            let (mut s, mut s2) = (0.0, 0.0);
            for _ in 0..n {
                let x = rng.gamma(k, theta);
                assert!(x > 0.0);
                s += x;
                s2 += x * x;
            }
            let mean = s / n as f64;
            let var = s2 / n as f64 - mean * mean;
            let (want_mean, want_var) = (k * theta, k * theta * theta);
            assert!(
                (mean - want_mean).abs() / want_mean < 0.05,
                "k={k} θ={theta} mean={mean} want={want_mean}"
            );
            assert!(
                (var - want_var).abs() / want_var < 0.15,
                "k={k} θ={theta} var={var} want={want_var}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(15);
        let n = 30_000;
        let mut s = 0.0;
        for _ in 0..n {
            s += rng.exponential(2.0);
        }
        assert!((s / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn gamma_arrivals_cv1_is_exponential_rate() {
        // cv=1 ⇒ shape 1 ⇒ exponential with mean 1/R.
        let arr = GammaArrivals::new(0.5, 1.0);
        let mut rng = Pcg64::new(17);
        let n = 30_000;
        let mut s = 0.0;
        for _ in 0..n {
            s += arr.next_gap(&mut rng);
        }
        assert!((s / n as f64 - 2.0).abs() < 0.1);
    }

    #[test]
    fn gamma_arrivals_cv_controls_variance() {
        let mut rng = Pcg64::new(19);
        let measure = |cv: f64, rng: &mut Pcg64| {
            let arr = GammaArrivals::new(1.0, cv);
            let n = 30_000;
            let (mut s, mut s2) = (0.0, 0.0);
            for _ in 0..n {
                let x = arr.next_gap(rng);
                s += x;
                s2 += x * x;
            }
            let mean = s / n as f64;
            ((s2 / n as f64 - mean * mean).sqrt()) / mean // empirical cv
        };
        let cv1 = measure(1.0, &mut rng);
        let cv2 = measure(2.0, &mut rng);
        assert!((cv1 - 1.0).abs() < 0.1, "cv1={cv1}");
        assert!((cv2 - 2.0).abs() < 0.2, "cv2={cv2}");
    }

    #[test]
    fn power_law_pmf_sums_to_one_and_is_monotone() {
        let pl = PowerLaw::new(100, 1.0);
        let total: f64 = (0..100).map(|i| pl.pmf(i)).sum();
        assert!((total - 1.0).abs() < 1e-9);
        for i in 1..100 {
            assert!(pl.pmf(i) <= pl.pmf(i - 1) + 1e-12);
        }
    }

    #[test]
    fn power_law_alpha_controls_concentration() {
        // Top-10% mass grows with α (P(i) ∝ i^-α as printed in the paper).
        let mass_top10 = |alpha: f64| {
            let pl = PowerLaw::new(100, alpha);
            (0..10).map(|i| pl.pmf(i)).sum::<f64>()
        };
        assert!(mass_top10(2.0) > mass_top10(1.0));
        assert!(mass_top10(1.0) > mass_top10(0.5));
    }

    #[test]
    fn power_law_sampling_matches_pmf() {
        let pl = PowerLaw::new(10, 1.0);
        let mut rng = Pcg64::new(23);
        let n = 100_000;
        let mut counts = [0usize; 10];
        for _ in 0..n {
            counts[pl.sample(&mut rng)] += 1;
        }
        for i in 0..10 {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - pl.pmf(i)).abs() < 0.01,
                "rank {i}: emp={emp} pmf={}",
                pl.pmf(i)
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(29);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
