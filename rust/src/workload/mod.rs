//! Synthetic workload subsystem: the paper's Gamma/power-law trace model
//! (§5.1), trace records with CSV I/O, and the replay client that drives an
//! engine from a trace.

pub mod generator;
pub mod replay;
pub mod trace;

pub use generator::{generate, try_generate, WorkloadError};
pub use trace::{QosClass, Trace, TraceRequest};
