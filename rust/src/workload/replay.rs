//! Trace replay utilities: split a trace into warm-up/measurement windows,
//! compute offered-load statistics, and build the per-second busy profile
//! the power sampler consumes. The engines consume traces directly
//! (`run_trace`); this module carries the analysis around those runs.

use crate::workload::{Trace, TraceRequest};

/// Offered-load statistics of a trace (what the client *sent*, independent
/// of how the server coped).
#[derive(Debug, Clone)]
pub struct OfferedLoad {
    pub requests: usize,
    pub rate_rps: f64,
    pub mean_input_tokens: f64,
    pub mean_output_tokens: f64,
    /// empirical coefficient of variation of inter-arrival gaps
    pub arrival_cv: f64,
    /// share of requests going to the top 10% most-requested adapters
    pub top_decile_share: f64,
}

pub fn offered_load(trace: &Trace) -> OfferedLoad {
    let n = trace.len();
    if n == 0 {
        return OfferedLoad {
            requests: 0,
            rate_rps: 0.0,
            mean_input_tokens: 0.0,
            mean_output_tokens: 0.0,
            arrival_cv: 0.0,
            top_decile_share: 0.0,
        };
    }
    let mut gaps = Vec::with_capacity(n);
    let mut prev = 0.0;
    for r in &trace.requests {
        gaps.push(r.arrival_s - prev);
        prev = r.arrival_s;
    }
    let mean_gap = gaps.iter().sum::<f64>() / gaps.len() as f64;
    let var = gaps.iter().map(|g| (g - mean_gap).powi(2)).sum::<f64>() / gaps.len() as f64;
    let arrival_cv = if mean_gap > 0.0 {
        var.sqrt() / mean_gap
    } else {
        0.0
    };

    let mut counts = std::collections::HashMap::new();
    for r in &trace.requests {
        *counts.entry(r.true_adapter).or_insert(0usize) += 1;
    }
    let mut by_count: Vec<usize> = counts.values().copied().collect();
    by_count.sort_unstable_by(|a, b| b.cmp(a));
    let top_k = (counts.len().max(10) / 10).max(1);
    let top: usize = by_count.iter().take(top_k).sum();

    OfferedLoad {
        requests: n,
        rate_rps: n as f64 / trace.duration_s.max(1e-9),
        mean_input_tokens: trace.requests.iter().map(|r| r.input_tokens).sum::<usize>() as f64
            / n as f64,
        mean_output_tokens: trace.requests.iter().map(|r| r.output_tokens).sum::<usize>() as f64
            / n as f64,
        arrival_cv,
        top_decile_share: top as f64 / n as f64,
    }
}

/// Split a trace at `t`: [0, t) becomes the warm-up window, [t, end) the
/// measurement window (arrival times are re-based to the split point).
pub fn split_at(trace: &Trace, t: f64) -> (Trace, Trace) {
    let mut warm = Vec::new();
    let mut main = Vec::new();
    for r in &trace.requests {
        if r.arrival_s < t {
            warm.push(r.clone());
        } else {
            main.push(TraceRequest {
                arrival_s: r.arrival_s - t,
                ..r.clone()
            });
        }
    }
    (
        Trace {
            requests: warm,
            duration_s: t.min(trace.duration_s),
            n_adapters: trace.n_adapters,
        },
        Trace {
            requests: main,
            duration_s: (trace.duration_s - t).max(0.0),
            n_adapters: trace.n_adapters,
        },
    )
}

/// Per-second arrival histogram (for busy-profile estimation / plots).
pub fn arrivals_per_second(trace: &Trace) -> Vec<usize> {
    let secs = trace.duration_s.ceil() as usize;
    let mut out = vec![0usize; secs.max(1)];
    for r in &trace.requests {
        let s = (r.arrival_s as usize).min(out.len() - 1);
        out[s] += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::workload::generate;

    fn trace() -> Trace {
        generate(&WorkloadConfig {
            n_adapters: 20,
            rate: 2.0,
            duration_s: 100.0,
            ..WorkloadConfig::default()
        })
    }

    #[test]
    fn offered_load_matches_config() {
        let t = trace();
        let ol = offered_load(&t);
        assert_eq!(ol.requests, t.len());
        assert!((ol.rate_rps - 2.0).abs() < 0.3, "rate {}", ol.rate_rps);
        assert!((ol.arrival_cv - 1.0).abs() < 0.2, "cv {}", ol.arrival_cv);
        assert!(ol.mean_input_tokens >= 8.0);
        assert!(ol.top_decile_share > 0.05);
    }

    #[test]
    fn split_preserves_all_requests() {
        let t = trace();
        let (warm, main) = split_at(&t, 30.0);
        assert_eq!(warm.len() + main.len(), t.len());
        assert!(warm.requests.iter().all(|r| r.arrival_s < 30.0));
        assert!(main.requests.iter().all(|r| r.arrival_s >= 0.0));
        main.validate().unwrap();
        assert!((main.duration_s - 70.0).abs() < 1e-9);
    }

    #[test]
    fn arrivals_histogram_sums() {
        let t = trace();
        let h = arrivals_per_second(&t);
        assert_eq!(h.iter().sum::<usize>(), t.len());
        assert_eq!(h.len(), 100);
    }

    #[test]
    fn empty_trace_offered_load() {
        let ol = offered_load(&Trace {
            requests: vec![],
            duration_s: 10.0,
            n_adapters: 1,
        });
        assert_eq!(ol.requests, 0);
    }
}
