//! Request traces: the records the synthetic generator emits and the replay
//! client consumes, with CSV save/load so traces can be pinned and shared.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::adapters::AdapterId;

/// Service class of a request (DESIGN.md §QoS & overload). `Interactive`
/// sorts before `Batch` (derived `Ord`), so a stable sort by class yields
/// the priority order that preemption victim selection and dead-shard
/// rehoming use: Batch absorbs pressure first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum QosClass {
    /// Latency-sensitive traffic: protected under overload (the default —
    /// a class-less request behaves exactly like the pre-QoS system).
    #[default]
    Interactive,
    /// Throughput traffic: first preemption victim, first to be shed.
    Batch,
}

impl QosClass {
    pub fn name(&self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Batch => "batch",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "interactive" => Some(QosClass::Interactive),
            "batch" => Some(QosClass::Batch),
            _ => None,
        }
    }
}

/// One request in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    pub id: u64,
    /// arrival time in seconds from trace start
    pub arrival_s: f64,
    /// the *ground-truth* best adapter for this request (what the power-law
    /// sampled); requests with `explicit_adapter = None` leave selection to
    /// the engine's adaptive adapter selection.
    pub true_adapter: AdapterId,
    pub explicit_adapter: Option<AdapterId>,
    pub input_tokens: usize,
    pub output_tokens: usize,
    /// service class (DESIGN.md §QoS & overload); Batch absorbs pressure
    pub qos: QosClass,
    /// optional first-token deadline, seconds after arrival — admission
    /// sheds a request that provably cannot meet it (None = best-effort)
    pub deadline_s: Option<f64>,
}

/// A full synthetic trace plus the parameters that generated it.
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
    pub duration_s: f64,
    pub n_adapters: usize,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Sanity invariants every generated/loaded trace must satisfy.
    pub fn validate(&self) -> Result<()> {
        let mut prev = 0.0f64;
        for r in &self.requests {
            if r.arrival_s < prev {
                bail!("arrivals not sorted at request {}", r.id);
            }
            prev = r.arrival_s;
            if r.true_adapter as usize >= self.n_adapters {
                bail!("adapter {} out of range", r.true_adapter);
            }
            if let Some(e) = r.explicit_adapter {
                if e as usize >= self.n_adapters {
                    bail!("explicit adapter {e} out of range");
                }
            }
            if r.input_tokens == 0 || r.output_tokens == 0 {
                bail!("request {} has zero-length input/output", r.id);
            }
            if let Some(d) = r.deadline_s {
                if !d.is_finite() || d <= 0.0 {
                    bail!("request {} has non-positive deadline {d}", r.id);
                }
            }
        }
        Ok(())
    }

    pub fn save_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut out = String::new();
        writeln!(
            out,
            "# edgelora trace v2 duration_s={} n_adapters={}",
            self.duration_s, self.n_adapters
        )?;
        writeln!(
            out,
            "id,arrival_s,true_adapter,explicit_adapter,input_tokens,output_tokens,qos,deadline_s"
        )?;
        for r in &self.requests {
            writeln!(
                out,
                "{},{:.6},{},{},{},{},{},{}",
                r.id,
                r.arrival_s,
                r.true_adapter,
                r.explicit_adapter.map_or(String::from(""), |e| e.to_string()),
                r.input_tokens,
                r.output_tokens,
                r.qos.name(),
                r.deadline_s.map_or(String::from(""), |d| format!("{d:.6}"))
            )?;
        }
        fs::write(path.as_ref(), out)
            .with_context(|| format!("writing {}", path.as_ref().display()))?;
        Ok(())
    }

    pub fn load_csv(path: impl AsRef<Path>) -> Result<Self> {
        let text = fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let mut lines = text.lines();
        let header = lines.next().context("empty trace file")?;
        let mut duration_s = 0.0;
        let mut n_adapters = 0;
        for tok in header.split_whitespace() {
            if let Some(v) = tok.strip_prefix("duration_s=") {
                duration_s = v.parse()?;
            }
            if let Some(v) = tok.strip_prefix("n_adapters=") {
                n_adapters = v.parse()?;
            }
        }
        let mut requests = Vec::new();
        for line in lines.skip(1) {
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            // v1 rows carry 6 columns (class-less: Interactive, no
            // deadline); v2 appends qos + deadline_s
            if f.len() != 6 && f.len() != 8 {
                bail!("bad trace row: {line}");
            }
            requests.push(TraceRequest {
                id: f[0].parse()?,
                arrival_s: f[1].parse()?,
                true_adapter: f[2].parse()?,
                explicit_adapter: if f[3].is_empty() {
                    None
                } else {
                    Some(f[3].parse()?)
                },
                input_tokens: f[4].parse()?,
                output_tokens: f[5].parse()?,
                qos: if f.len() > 6 {
                    QosClass::from_name(f[6])
                        .ok_or_else(|| anyhow::anyhow!("bad qos class: {}", f[6]))?
                } else {
                    QosClass::Interactive
                },
                deadline_s: if f.len() > 7 && !f[7].is_empty() {
                    Some(f[7].parse()?)
                } else {
                    None
                },
            });
        }
        let t = Self {
            requests,
            duration_s,
            n_adapters,
        };
        t.validate()?;
        Ok(t)
    }

    /// Distinct adapters actually requested (diversity of the trace).
    pub fn distinct_adapters(&self) -> usize {
        let mut seen = std::collections::HashSet::new();
        for r in &self.requests {
            seen.insert(r.true_adapter);
        }
        seen.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        Trace {
            requests: vec![
                TraceRequest {
                    id: 0,
                    arrival_s: 0.5,
                    true_adapter: 1,
                    explicit_adapter: None,
                    input_tokens: 10,
                    output_tokens: 20,
                    qos: QosClass::Interactive,
                    deadline_s: Some(1.5),
                },
                TraceRequest {
                    id: 1,
                    arrival_s: 1.25,
                    true_adapter: 0,
                    explicit_adapter: Some(0),
                    input_tokens: 30,
                    output_tokens: 5,
                    qos: QosClass::Batch,
                    deadline_s: None,
                },
            ],
            duration_s: 10.0,
            n_adapters: 3,
        }
    }

    #[test]
    fn validate_accepts_good() {
        sample().validate().unwrap();
    }

    #[test]
    fn validate_rejects_unsorted() {
        let mut t = sample();
        t.requests[1].arrival_s = 0.1;
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_rejects_out_of_range_adapter() {
        let mut t = sample();
        t.requests[0].true_adapter = 99;
        assert!(t.validate().is_err());
    }

    #[test]
    fn csv_roundtrip() {
        let t = sample();
        let path = std::env::temp_dir().join(format!(
            "elra_trace_{}.csv",
            std::process::id()
        ));
        t.save_csv(&path).unwrap();
        let back = Trace::load_csv(&path).unwrap();
        assert_eq!(back.requests, t.requests);
        assert_eq!(back.n_adapters, 3);
        assert!((back.duration_s - 10.0).abs() < 1e-9);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_accepts_v1_rows_as_interactive_no_deadline() {
        let path = std::env::temp_dir().join(format!(
            "elra_trace_v1_{}.csv",
            std::process::id()
        ));
        std::fs::write(
            &path,
            "# edgelora trace v1 duration_s=5 n_adapters=2\n\
             id,arrival_s,true_adapter,explicit_adapter,input_tokens,output_tokens\n\
             0,0.100000,1,,8,4\n\
             1,0.900000,0,0,16,8\n",
        )
        .unwrap();
        let t = Trace::load_csv(&path).unwrap();
        assert_eq!(t.len(), 2);
        assert!(t
            .requests
            .iter()
            .all(|r| r.qos == QosClass::Interactive && r.deadline_s.is_none()));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn qos_class_names_roundtrip_and_order() {
        assert_eq!(QosClass::from_name("Interactive"), Some(QosClass::Interactive));
        assert_eq!(QosClass::from_name("BATCH"), Some(QosClass::Batch));
        assert_eq!(QosClass::from_name("gold"), None);
        assert!(QosClass::Interactive < QosClass::Batch, "sort puts Interactive first");
        assert_eq!(QosClass::default(), QosClass::Interactive);
    }

    #[test]
    fn validate_rejects_non_positive_deadline() {
        let mut t = sample();
        t.requests[0].deadline_s = Some(0.0);
        assert!(t.validate().is_err());
        t.requests[0].deadline_s = Some(f64::NAN);
        assert!(t.validate().is_err());
    }
}
