//! Synthetic workload generator (paper §5.1): Gamma-process arrivals with
//! burstiness `cv`, power-law adapter popularity with exponent `alpha`,
//! uniform input/output lengths — the exact model behind Tables 4–10 and
//! the edge_lora.js experiment client in the artifact.
//!
//! Beyond the paper: `hot_fraction`/`hot_adapters` superimpose a skewed
//! per-tenant mix on the power law (a fraction of requests pinned to the
//! hottest tenants), the regime the cluster's work stealing exists for
//! (`bench-table --table scaling`).

use crate::config::WorkloadConfig;
use crate::util::rng::{GammaArrivals, Pcg64, PowerLaw};
use crate::workload::trace::{QosClass, Trace, TraceRequest};

/// Typed workload-config rejection (ISSUE 7 satellite): the old `generate`
/// asserted two invariants and silently produced garbage for the rest.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadError {
    NoAdapters,
    /// a knob that must be a finite positive (or, for window offsets,
    /// non-negative) number is not
    NonPositive { name: &'static str, value: f64 },
    /// a probability knob is NaN or outside [0, 1]
    FractionOutOfRange { name: &'static str, value: f64 },
    /// token-length bounds with `lo == 0` or `lo > hi`
    BadTokenRange { name: &'static str, lo: usize, hi: usize },
}

impl std::fmt::Display for WorkloadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkloadError::NoAdapters => write!(f, "workload needs at least one adapter"),
            WorkloadError::NonPositive { name, value } => {
                write!(f, "workload.{name} must be a finite positive number, got {value}")
            }
            WorkloadError::FractionOutOfRange { name, value } => {
                write!(f, "workload.{name} must be in [0, 1], got {value}")
            }
            WorkloadError::BadTokenRange { name, lo, hi } => {
                write!(f, "workload.{name} must satisfy 1 <= lo <= hi, got ({lo}, {hi})")
            }
        }
    }
}

impl std::error::Error for WorkloadError {}

/// Generate a trace from the workload config. Deterministic in `cfg.seed`.
/// Rejects invalid configs with a typed [`WorkloadError`] instead of
/// asserting or emitting silent garbage.
pub fn try_generate(cfg: &WorkloadConfig) -> Result<Trace, WorkloadError> {
    cfg.validate()?;
    let mut rng = Pcg64::new(cfg.seed);
    let arrivals = GammaArrivals::new(cfg.rate, cfg.cv);
    let popularity = PowerLaw::new(cfg.n_adapters, cfg.alpha);

    // Map popularity *rank* onto a shuffled adapter id so the hottest
    // adapter is not always id 0 (matters for cache-layout realism).
    let mut rank_to_id: Vec<u64> = (0..cfg.n_adapters as u64).collect();
    rng.shuffle(&mut rank_to_id);

    let hot_adapters = cfg.hot_adapters.clamp(1, cfg.n_adapters);
    let spike_end = cfg.spike_start_s + cfg.spike_len_s;
    let mut requests = Vec::new();
    let mut t = 0.0f64;
    let mut id = 0u64;
    loop {
        // diurnal spike: inside the window the offered rate is multiplied
        // by spike_mult — the *drawn* gap is scaled, so a disabled spike
        // (mult = 1.0) consumes exactly the same RNG draws
        let mut gap = arrivals.next_gap(&mut rng);
        let in_spike = cfg.spike_mult > 1.0 && t >= cfg.spike_start_s && t < spike_end;
        if in_spike {
            gap /= cfg.spike_mult;
        }
        t += gap;
        if t >= cfg.duration_s {
            break;
        }
        // flash crowd: inside the spike window a flash_fraction slice of
        // the traffic all lands on the single hottest tenant. The draw
        // happens only while the knob is active (RNG-draw conservation).
        let flash = in_spike
            && cfg.flash_fraction > 0.0
            && rng.next_f64() < cfg.flash_fraction;
        // skewed tenant mix: a hot_fraction slice of the traffic lands on
        // the top-popularity ranks, the rest follows the power law
        let rank = if flash {
            0
        } else if cfg.hot_fraction > 0.0 && rng.next_f64() < cfg.hot_fraction {
            rng.gen_range_usize(0, hot_adapters - 1)
        } else {
            popularity.sample(&mut rng)
        };
        // tenant churn: the rank→adapter mapping rotates every
        // churn_period_s, so "who is hot" drifts over the trace
        let adapter = if cfg.churn_period_s > 0.0 {
            let shift = (t / cfg.churn_period_s) as usize % cfg.n_adapters;
            rank_to_id[(rank + shift) % cfg.n_adapters]
        } else {
            rank_to_id[rank]
        };
        let explicit = if rng.next_f64() < cfg.auto_select_fraction {
            None
        } else {
            Some(adapter)
        };
        let input_tokens = rng.gen_range_usize(cfg.input_range.0, cfg.input_range.1);
        let output_tokens = rng.gen_range_usize(cfg.output_range.0, cfg.output_range.1);
        // QoS class: drawn last so batch_fraction = 0.0 (all Interactive)
        // reproduces the class-less trace bit-for-bit
        let qos = if cfg.batch_fraction > 0.0 && rng.next_f64() < cfg.batch_fraction {
            QosClass::Batch
        } else {
            QosClass::Interactive
        };
        let deadline_s = (qos == QosClass::Interactive && cfg.deadline_s > 0.0)
            .then_some(cfg.deadline_s);
        requests.push(TraceRequest {
            id,
            arrival_s: t,
            true_adapter: adapter,
            explicit_adapter: explicit,
            input_tokens,
            output_tokens,
            qos,
            deadline_s,
        });
        id += 1;
    }
    let trace = Trace {
        requests,
        duration_s: cfg.duration_s,
        n_adapters: cfg.n_adapters,
    };
    debug_assert!(trace.validate().is_ok());
    Ok(trace)
}

/// [`try_generate`], panicking on an invalid config (the pre-validation
/// API shape every internal call site uses with known-good configs).
pub fn generate(cfg: &WorkloadConfig) -> Trace {
    try_generate(cfg).expect("invalid workload config")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> WorkloadConfig {
        WorkloadConfig {
            n_adapters: 50,
            alpha: 1.0,
            rate: 2.0,
            cv: 1.0,
            input_range: (8, 256),
            output_range: (8, 128),
            duration_s: 600.0,
            auto_select_fraction: 1.0,
            seed: 42,
            ..WorkloadConfig::default()
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(&base_cfg());
        let b = generate(&base_cfg());
        assert_eq!(a.requests, b.requests);
    }

    #[test]
    fn rate_is_respected() {
        let t = generate(&base_cfg());
        let emp_rate = t.len() as f64 / t.duration_s;
        assert!((emp_rate - 2.0).abs() / 2.0 < 0.1, "rate {emp_rate}");
    }

    #[test]
    fn arrivals_sorted_and_within_duration() {
        let t = generate(&base_cfg());
        t.validate().unwrap();
        assert!(t.requests.last().unwrap().arrival_s < t.duration_s);
    }

    #[test]
    fn lengths_within_bounds() {
        let t = generate(&base_cfg());
        for r in &t.requests {
            assert!((8..=256).contains(&r.input_tokens));
            assert!((8..=128).contains(&r.output_tokens));
        }
    }

    #[test]
    fn alpha_controls_adapter_concentration() {
        // top-10% adapters' share of requests grows with alpha
        let share = |alpha: f64| {
            let cfg = WorkloadConfig {
                alpha,
                duration_s: 2000.0,
                ..base_cfg()
            };
            let t = generate(&cfg);
            let mut counts = std::collections::HashMap::new();
            for r in &t.requests {
                *counts.entry(r.true_adapter).or_insert(0usize) += 1;
            }
            let mut v: Vec<usize> = counts.values().copied().collect();
            v.sort_unstable_by(|a, b| b.cmp(a));
            let top: usize = v.iter().take(5).sum();
            top as f64 / t.len() as f64
        };
        assert!(share(2.0) > share(0.5) + 0.1);
    }

    #[test]
    fn cv_controls_burstiness() {
        let gaps = |cv: f64| {
            let cfg = WorkloadConfig {
                cv,
                duration_s: 3000.0,
                ..base_cfg()
            };
            let t = generate(&cfg);
            let mut prev = 0.0;
            let mut g = Vec::new();
            for r in &t.requests {
                g.push(r.arrival_s - prev);
                prev = r.arrival_s;
            }
            let mean = g.iter().sum::<f64>() / g.len() as f64;
            let var = g.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / g.len() as f64;
            var.sqrt() / mean
        };
        let c1 = gaps(1.0);
        let c2 = gaps(2.0);
        assert!(c2 > c1 * 1.5, "cv1={c1} cv2={c2}");
    }

    #[test]
    fn auto_select_fraction_zero_means_all_explicit() {
        let cfg = WorkloadConfig {
            auto_select_fraction: 0.0,
            ..base_cfg()
        };
        let t = generate(&cfg);
        assert!(t.requests.iter().all(|r| r.explicit_adapter.is_some()));
        let cfg1 = WorkloadConfig {
            auto_select_fraction: 1.0,
            ..base_cfg()
        };
        let t1 = generate(&cfg1);
        assert!(t1.requests.iter().all(|r| r.explicit_adapter.is_none()));
    }

    #[test]
    fn hot_fraction_concentrates_traffic() {
        let share_of_top = |hot: f64, hot_n: usize| {
            let cfg = WorkloadConfig {
                hot_fraction: hot,
                hot_adapters: hot_n,
                duration_s: 1500.0,
                ..base_cfg()
            };
            let t = generate(&cfg);
            let mut counts = std::collections::HashMap::new();
            for r in &t.requests {
                *counts.entry(r.true_adapter).or_insert(0usize) += 1;
            }
            let mut v: Vec<usize> = counts.values().copied().collect();
            v.sort_unstable_by(|a, b| b.cmp(a));
            v.iter().take(hot_n).sum::<usize>() as f64 / t.len() as f64
        };
        // 90% pinned on one adapter ⇒ that adapter dominates
        assert!(share_of_top(0.9, 1) > 0.85);
        // pure power law (alpha=1, n=50): the top adapter is well below that
        assert!(share_of_top(0.0, 1) < 0.5);
        // the hot slice spreads over hot_adapters, not just rank 0
        let cfg = WorkloadConfig {
            hot_fraction: 1.0,
            hot_adapters: 3,
            duration_s: 500.0,
            ..base_cfg()
        };
        let t = generate(&cfg);
        assert_eq!(t.distinct_adapters(), 3);
    }

    #[test]
    fn hot_fraction_zero_is_the_pure_power_law() {
        // hot_fraction = 0.0 must not consume extra rng draws: the trace is
        // unchanged from the pre-knob generator for any seed
        let a = generate(&base_cfg());
        let b = generate(&WorkloadConfig {
            hot_fraction: 0.0,
            hot_adapters: 7,
            ..base_cfg()
        });
        assert_eq!(a.requests, b.requests);
    }

    #[test]
    fn single_adapter_degenerate_case() {
        let cfg = WorkloadConfig {
            n_adapters: 1,
            duration_s: 50.0,
            ..base_cfg()
        };
        let t = generate(&cfg);
        assert!(t.requests.iter().all(|r| r.true_adapter == 0));
    }

    #[test]
    fn try_generate_rejects_bad_configs_with_typed_errors() {
        let err = try_generate(&WorkloadConfig {
            hot_fraction: f64::NAN,
            ..base_cfg()
        })
        .unwrap_err();
        assert!(matches!(err, WorkloadError::FractionOutOfRange { name: "hot_fraction", .. }));
        let err = try_generate(&WorkloadConfig { rate: 0.0, ..base_cfg() }).unwrap_err();
        assert!(matches!(err, WorkloadError::NonPositive { name: "rate", .. }));
        let err = try_generate(&WorkloadConfig { duration_s: 0.0, ..base_cfg() }).unwrap_err();
        assert!(matches!(err, WorkloadError::NonPositive { name: "duration_s", .. }));
    }

    #[test]
    fn disabled_qos_and_spike_knobs_consume_no_rng_draws() {
        // RNG-draw conservation: every new knob at its default must
        // reproduce the pre-knob trace bit-for-bit for any seed
        let a = generate(&base_cfg());
        let b = generate(&WorkloadConfig {
            batch_fraction: 0.0,
            deadline_s: 0.0,
            spike_start_s: 100.0,
            spike_len_s: 100.0,
            spike_mult: 1.0,
            flash_fraction: 0.0,
            churn_period_s: 0.0,
            ..base_cfg()
        });
        assert_eq!(a.requests, b.requests);
    }

    #[test]
    fn batch_fraction_splits_classes_and_deadline_tags_interactive() {
        let cfg = WorkloadConfig {
            batch_fraction: 0.7,
            deadline_s: 4.0,
            duration_s: 1000.0,
            ..base_cfg()
        };
        let t = generate(&cfg);
        let batch = t.requests.iter().filter(|r| r.qos == QosClass::Batch).count();
        let frac = batch as f64 / t.len() as f64;
        assert!((frac - 0.7).abs() < 0.05, "batch fraction {frac}");
        for r in &t.requests {
            match r.qos {
                QosClass::Interactive => assert_eq!(r.deadline_s, Some(4.0)),
                QosClass::Batch => assert_eq!(r.deadline_s, None),
            }
        }
    }

    #[test]
    fn spike_window_multiplies_the_offered_rate() {
        let cfg = WorkloadConfig {
            spike_start_s: 200.0,
            spike_len_s: 100.0,
            spike_mult: 5.0,
            duration_s: 600.0,
            ..base_cfg()
        };
        let t = generate(&cfg);
        let in_window = t
            .requests
            .iter()
            .filter(|r| (200.0..300.0).contains(&r.arrival_s))
            .count() as f64
            / 100.0;
        let outside = t
            .requests
            .iter()
            .filter(|r| r.arrival_s < 200.0)
            .count() as f64
            / 200.0;
        assert!(
            in_window > 3.0 * outside,
            "spike rate {in_window} vs base {outside}"
        );
    }

    #[test]
    fn flash_crowd_concentrates_spike_traffic_on_one_adapter() {
        let cfg = WorkloadConfig {
            spike_start_s: 100.0,
            spike_len_s: 200.0,
            spike_mult: 4.0,
            flash_fraction: 0.9,
            duration_s: 400.0,
            ..base_cfg()
        };
        let t = generate(&cfg);
        let window: Vec<_> = t
            .requests
            .iter()
            .filter(|r| (100.0..300.0).contains(&r.arrival_s))
            .collect();
        let mut counts = std::collections::HashMap::new();
        for r in &window {
            *counts.entry(r.true_adapter).or_insert(0usize) += 1;
        }
        let top = *counts.values().max().unwrap();
        assert!(
            top as f64 > 0.8 * window.len() as f64,
            "flash crowd must dominate the window: top {top} of {}",
            window.len()
        );
    }

    #[test]
    fn tenant_churn_rotates_the_hot_set() {
        let cfg = WorkloadConfig {
            hot_fraction: 1.0,
            hot_adapters: 1,
            churn_period_s: 100.0,
            duration_s: 300.0,
            ..base_cfg()
        };
        let t = generate(&cfg);
        let hot_in = |lo: f64, hi: f64| {
            let mut counts = std::collections::HashMap::new();
            for r in t.requests.iter().filter(|r| (lo..hi).contains(&r.arrival_s)) {
                *counts.entry(r.true_adapter).or_insert(0usize) += 1;
            }
            counts.into_iter().max_by_key(|&(_, c)| c).unwrap().0
        };
        // all traffic is pinned to rank 0, but the adapter behind rank 0
        // changes every churn period
        assert_ne!(hot_in(0.0, 100.0), hot_in(100.0, 200.0));
    }
}
