//! Synthetic workload generator (paper §5.1): Gamma-process arrivals with
//! burstiness `cv`, power-law adapter popularity with exponent `alpha`,
//! uniform input/output lengths — the exact model behind Tables 4–10 and
//! the edge_lora.js experiment client in the artifact.
//!
//! Beyond the paper: `hot_fraction`/`hot_adapters` superimpose a skewed
//! per-tenant mix on the power law (a fraction of requests pinned to the
//! hottest tenants), the regime the cluster's work stealing exists for
//! (`bench-table --table scaling`).

use crate::config::WorkloadConfig;
use crate::util::rng::{GammaArrivals, Pcg64, PowerLaw};
use crate::workload::trace::{Trace, TraceRequest};

/// Generate a trace from the workload config. Deterministic in `cfg.seed`.
pub fn generate(cfg: &WorkloadConfig) -> Trace {
    assert!(cfg.n_adapters > 0, "need at least one adapter");
    assert!(cfg.input_range.0 <= cfg.input_range.1);
    assert!(cfg.output_range.0 <= cfg.output_range.1);
    let mut rng = Pcg64::new(cfg.seed);
    let arrivals = GammaArrivals::new(cfg.rate, cfg.cv);
    let popularity = PowerLaw::new(cfg.n_adapters, cfg.alpha);

    // Map popularity *rank* onto a shuffled adapter id so the hottest
    // adapter is not always id 0 (matters for cache-layout realism).
    let mut rank_to_id: Vec<u64> = (0..cfg.n_adapters as u64).collect();
    rng.shuffle(&mut rank_to_id);

    let hot_adapters = cfg.hot_adapters.clamp(1, cfg.n_adapters);
    let mut requests = Vec::new();
    let mut t = 0.0f64;
    let mut id = 0u64;
    loop {
        t += arrivals.next_gap(&mut rng);
        if t >= cfg.duration_s {
            break;
        }
        // skewed tenant mix: a hot_fraction slice of the traffic lands on
        // the top-popularity ranks, the rest follows the power law
        let rank = if cfg.hot_fraction > 0.0 && rng.next_f64() < cfg.hot_fraction {
            rng.gen_range_usize(0, hot_adapters - 1)
        } else {
            popularity.sample(&mut rng)
        };
        let adapter = rank_to_id[rank];
        let explicit = if rng.next_f64() < cfg.auto_select_fraction {
            None
        } else {
            Some(adapter)
        };
        requests.push(TraceRequest {
            id,
            arrival_s: t,
            true_adapter: adapter,
            explicit_adapter: explicit,
            input_tokens: rng.gen_range_usize(cfg.input_range.0, cfg.input_range.1),
            output_tokens: rng.gen_range_usize(cfg.output_range.0, cfg.output_range.1),
        });
        id += 1;
    }
    let trace = Trace {
        requests,
        duration_s: cfg.duration_s,
        n_adapters: cfg.n_adapters,
    };
    debug_assert!(trace.validate().is_ok());
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> WorkloadConfig {
        WorkloadConfig {
            n_adapters: 50,
            alpha: 1.0,
            rate: 2.0,
            cv: 1.0,
            input_range: (8, 256),
            output_range: (8, 128),
            duration_s: 600.0,
            auto_select_fraction: 1.0,
            seed: 42,
            ..WorkloadConfig::default()
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(&base_cfg());
        let b = generate(&base_cfg());
        assert_eq!(a.requests, b.requests);
    }

    #[test]
    fn rate_is_respected() {
        let t = generate(&base_cfg());
        let emp_rate = t.len() as f64 / t.duration_s;
        assert!((emp_rate - 2.0).abs() / 2.0 < 0.1, "rate {emp_rate}");
    }

    #[test]
    fn arrivals_sorted_and_within_duration() {
        let t = generate(&base_cfg());
        t.validate().unwrap();
        assert!(t.requests.last().unwrap().arrival_s < t.duration_s);
    }

    #[test]
    fn lengths_within_bounds() {
        let t = generate(&base_cfg());
        for r in &t.requests {
            assert!((8..=256).contains(&r.input_tokens));
            assert!((8..=128).contains(&r.output_tokens));
        }
    }

    #[test]
    fn alpha_controls_adapter_concentration() {
        // top-10% adapters' share of requests grows with alpha
        let share = |alpha: f64| {
            let cfg = WorkloadConfig {
                alpha,
                duration_s: 2000.0,
                ..base_cfg()
            };
            let t = generate(&cfg);
            let mut counts = std::collections::HashMap::new();
            for r in &t.requests {
                *counts.entry(r.true_adapter).or_insert(0usize) += 1;
            }
            let mut v: Vec<usize> = counts.values().copied().collect();
            v.sort_unstable_by(|a, b| b.cmp(a));
            let top: usize = v.iter().take(5).sum();
            top as f64 / t.len() as f64
        };
        assert!(share(2.0) > share(0.5) + 0.1);
    }

    #[test]
    fn cv_controls_burstiness() {
        let gaps = |cv: f64| {
            let cfg = WorkloadConfig {
                cv,
                duration_s: 3000.0,
                ..base_cfg()
            };
            let t = generate(&cfg);
            let mut prev = 0.0;
            let mut g = Vec::new();
            for r in &t.requests {
                g.push(r.arrival_s - prev);
                prev = r.arrival_s;
            }
            let mean = g.iter().sum::<f64>() / g.len() as f64;
            let var = g.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / g.len() as f64;
            var.sqrt() / mean
        };
        let c1 = gaps(1.0);
        let c2 = gaps(2.0);
        assert!(c2 > c1 * 1.5, "cv1={c1} cv2={c2}");
    }

    #[test]
    fn auto_select_fraction_zero_means_all_explicit() {
        let cfg = WorkloadConfig {
            auto_select_fraction: 0.0,
            ..base_cfg()
        };
        let t = generate(&cfg);
        assert!(t.requests.iter().all(|r| r.explicit_adapter.is_some()));
        let cfg1 = WorkloadConfig {
            auto_select_fraction: 1.0,
            ..base_cfg()
        };
        let t1 = generate(&cfg1);
        assert!(t1.requests.iter().all(|r| r.explicit_adapter.is_none()));
    }

    #[test]
    fn hot_fraction_concentrates_traffic() {
        let share_of_top = |hot: f64, hot_n: usize| {
            let cfg = WorkloadConfig {
                hot_fraction: hot,
                hot_adapters: hot_n,
                duration_s: 1500.0,
                ..base_cfg()
            };
            let t = generate(&cfg);
            let mut counts = std::collections::HashMap::new();
            for r in &t.requests {
                *counts.entry(r.true_adapter).or_insert(0usize) += 1;
            }
            let mut v: Vec<usize> = counts.values().copied().collect();
            v.sort_unstable_by(|a, b| b.cmp(a));
            v.iter().take(hot_n).sum::<usize>() as f64 / t.len() as f64
        };
        // 90% pinned on one adapter ⇒ that adapter dominates
        assert!(share_of_top(0.9, 1) > 0.85);
        // pure power law (alpha=1, n=50): the top adapter is well below that
        assert!(share_of_top(0.0, 1) < 0.5);
        // the hot slice spreads over hot_adapters, not just rank 0
        let cfg = WorkloadConfig {
            hot_fraction: 1.0,
            hot_adapters: 3,
            duration_s: 500.0,
            ..base_cfg()
        };
        let t = generate(&cfg);
        assert_eq!(t.distinct_adapters(), 3);
    }

    #[test]
    fn hot_fraction_zero_is_the_pure_power_law() {
        // hot_fraction = 0.0 must not consume extra rng draws: the trace is
        // unchanged from the pre-knob generator for any seed
        let a = generate(&base_cfg());
        let b = generate(&WorkloadConfig {
            hot_fraction: 0.0,
            hot_adapters: 7,
            ..base_cfg()
        });
        assert_eq!(a.requests, b.requests);
    }

    #[test]
    fn single_adapter_degenerate_case() {
        let cfg = WorkloadConfig {
            n_adapters: 1,
            duration_s: 50.0,
            ..base_cfg()
        };
        let t = generate(&cfg);
        assert!(t.requests.iter().all(|r| r.true_adapter == 0));
    }
}
