//! U-batch planner (§3.4): given the active decode rows and their adapter
//! bank slots, build the gather → per-adapter group GEMM → scatter plan.
//!
//! On the PJRT path the Pallas kernel consumes the *sorted* row order (rows
//! grouped by bank slot maximize VMEM block reuse across consecutive grid
//! steps); on the sim path the plan's group count feeds the timing model.
//! Either way the plan must be a permutation — scatter(gather(x)) == x —
//! which the property tests pin down.

use crate::backend::DecodeRow;

/// One adapter group inside a batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UBatchGroup {
    pub bank_slot: usize,
    /// indices into the *original* row array
    pub members: Vec<usize>,
}

/// The full plan for one decode step.
#[derive(Debug, Clone)]
pub struct UBatchPlan {
    /// groups sorted by bank slot
    pub groups: Vec<UBatchGroup>,
    /// permutation: sorted position -> original index
    pub order: Vec<usize>,
    /// inverse permutation: original index -> sorted position
    pub inverse: Vec<usize>,
}

impl UBatchPlan {
    /// Build the plan. Stable within groups (original order preserved), so
    /// repeated planning of the same rows is deterministic.
    pub fn build(rows: &[DecodeRow]) -> Self {
        let mut order: Vec<usize> = (0..rows.len()).collect();
        order.sort_by_key(|&i| (rows[i].bank_slot, i));
        let mut inverse = vec![0usize; rows.len()];
        for (pos, &orig) in order.iter().enumerate() {
            inverse[orig] = pos;
        }
        let mut groups: Vec<UBatchGroup> = Vec::new();
        for &i in &order {
            match groups.last_mut() {
                Some(g) if g.bank_slot == rows[i].bank_slot => g.members.push(i),
                _ => groups.push(UBatchGroup {
                    bank_slot: rows[i].bank_slot,
                    members: vec![i],
                }),
            }
        }
        Self {
            groups,
            order,
            inverse,
        }
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Largest group size (the paper's win case: many rows share an adapter).
    pub fn max_group(&self) -> usize {
        self.groups.iter().map(|g| g.members.len()).max().unwrap_or(0)
    }

    /// Gather: reorder per-row payloads into sorted (grouped) order.
    pub fn gather<T: Copy>(&self, xs: &[T]) -> Vec<T> {
        assert_eq!(xs.len(), self.order.len());
        self.order.iter().map(|&i| xs[i]).collect()
    }

    /// Scatter: inverse of gather.
    pub fn scatter<T: Copy>(&self, ys: &[T]) -> Vec<T> {
        assert_eq!(ys.len(), self.inverse.len());
        self.inverse.iter().map(|&p| ys[p]).collect()
    }

    /// Rows in grouped order (what the PJRT backend feeds the kernel).
    pub fn sorted_rows(&self, rows: &[DecodeRow]) -> Vec<DecodeRow> {
        self.gather(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg64;

    fn row(i: usize, slot: usize) -> DecodeRow {
        DecodeRow {
            row: i,
            token: i as u32,
            pos: 0,
            bank_slot: slot,
        }
    }

    #[test]
    fn groups_by_slot() {
        let rows = vec![row(0, 2), row(1, 0), row(2, 2), row(3, 1)];
        let plan = UBatchPlan::build(&rows);
        assert_eq!(plan.n_groups(), 3);
        assert_eq!(plan.groups[0].bank_slot, 0);
        assert_eq!(plan.groups[1].bank_slot, 1);
        assert_eq!(plan.groups[2].bank_slot, 2);
        assert_eq!(plan.groups[2].members, vec![0, 2]);
        assert_eq!(plan.max_group(), 2);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let rows = vec![row(0, 3), row(1, 1), row(2, 3), row(3, 0), row(4, 1)];
        let plan = UBatchPlan::build(&rows);
        let payload: Vec<u32> = vec![10, 11, 12, 13, 14];
        let gathered = plan.gather(&payload);
        let back = plan.scatter(&gathered);
        assert_eq!(back, payload);
    }

    #[test]
    fn sorted_rows_are_grouped() {
        let rows = vec![row(0, 5), row(1, 1), row(2, 5), row(3, 1)];
        let plan = UBatchPlan::build(&rows);
        let sorted = plan.sorted_rows(&rows);
        let slots: Vec<usize> = sorted.iter().map(|r| r.bank_slot).collect();
        let mut expected = slots.clone();
        expected.sort_unstable();
        assert_eq!(slots, expected, "sorted rows must be non-decreasing");
    }

    #[test]
    fn empty_batch() {
        let plan = UBatchPlan::build(&[]);
        assert_eq!(plan.n_groups(), 0);
        assert_eq!(plan.max_group(), 0);
        let empty: Vec<u32> = plan.gather(&[]);
        assert!(empty.is_empty());
    }

    #[test]
    fn all_same_adapter_single_group() {
        let rows: Vec<DecodeRow> = (0..6).map(|i| row(i, 4)).collect();
        let plan = UBatchPlan::build(&rows);
        assert_eq!(plan.n_groups(), 1);
        assert_eq!(plan.max_group(), 6);
        // stable: original order preserved within group
        assert_eq!(plan.groups[0].members, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn prop_plan_is_permutation() {
        prop_check(
            300,
            0xba7c4,
            |rng: &mut Pcg64| {
                let n = rng.gen_range_usize(0, 24);
                (0..n).map(|_| rng.gen_range_usize(0, 6)).collect::<Vec<usize>>()
            },
            |slots| {
                let rows: Vec<DecodeRow> =
                    slots.iter().enumerate().map(|(i, &s)| row(i, s)).collect();
                let plan = UBatchPlan::build(&rows);
                // order is a permutation of 0..n
                let mut o = plan.order.clone();
                o.sort_unstable();
                if o != (0..rows.len()).collect::<Vec<_>>() {
                    return false;
                }
                // scatter ∘ gather == id
                let payload: Vec<usize> = (0..rows.len()).collect();
                if plan.scatter(&plan.gather(&payload)) != payload {
                    return false;
                }
                // group membership covers every index exactly once
                let mut seen = vec![false; rows.len()];
                for g in &plan.groups {
                    for &m in &g.members {
                        if seen[m] {
                            return false;
                        }
                        seen[m] = true;
                        if rows[m].bank_slot != g.bank_slot {
                            return false;
                        }
                    }
                }
                seen.iter().all(|&s| s)
            },
        );
    }

    #[test]
    fn prop_group_count_le_distinct_slots() {
        prop_check(
            200,
            0xba7c5,
            |rng: &mut Pcg64| {
                let n = rng.gen_range_usize(1, 32);
                (0..n).map(|_| rng.gen_range_usize(0, 8)).collect::<Vec<usize>>()
            },
            |slots| {
                let rows: Vec<DecodeRow> =
                    slots.iter().enumerate().map(|(i, &s)| row(i, s)).collect();
                let plan = UBatchPlan::build(&rows);
                let mut d = slots.clone();
                d.sort_unstable();
                d.dedup();
                plan.n_groups() == d.len()
            },
        );
    }
}
