//! U-batch planner (§3.4): given the active decode rows and their adapter
//! bank slots, build the gather → per-adapter group GEMM → scatter plan.
//!
//! On the PJRT path the Pallas kernel consumes the *sorted* row order (rows
//! grouped by bank slot maximize VMEM block reuse across consecutive grid
//! steps); on the sim path the plan's group count feeds the timing model.
//! Either way the plan must be a permutation — scatter(gather(x)) == x —
//! which the property tests pin down.
//!
//! The plan is designed to be *reused* across decode ticks: `build_into`
//! rewrites an existing plan in place and groups are (start, len) ranges
//! into the sorted order rather than per-group Vecs, so a steady-state
//! decode tick performs no heap allocation (see `DecodeScratch` in the
//! engine).

use crate::backend::DecodeRow;

/// One adapter group inside a batch: the rows at `start..start+len` of the
/// sorted order share `bank_slot`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UBatchGroup {
    pub bank_slot: usize,
    /// offset into `order` (the sorted row permutation)
    pub start: usize,
    pub len: usize,
}

/// The full plan for one decode step.
#[derive(Debug, Clone, Default)]
pub struct UBatchPlan {
    /// groups in ascending bank-slot order, tiling `order` exactly
    pub groups: Vec<UBatchGroup>,
    /// permutation: sorted position -> original index
    pub order: Vec<usize>,
    /// inverse permutation: original index -> sorted position
    pub inverse: Vec<usize>,
}

impl UBatchPlan {
    /// Build a fresh plan. Stable within groups (original order preserved),
    /// so repeated planning of the same rows is deterministic.
    pub fn build(rows: &[DecodeRow]) -> Self {
        let mut plan = Self::default();
        plan.build_into(rows);
        plan
    }

    /// Rebuild this plan in place for `rows`, reusing all three buffers —
    /// allocation-free once the buffers have grown to the batch width.
    pub fn build_into(&mut self, rows: &[DecodeRow]) {
        self.order.clear();
        self.order.extend(0..rows.len());
        self.order.sort_unstable_by_key(|&i| (rows[i].bank_slot, i));
        self.inverse.clear();
        self.inverse.resize(rows.len(), 0);
        for (pos, &orig) in self.order.iter().enumerate() {
            self.inverse[orig] = pos;
        }
        self.groups.clear();
        for (pos, &i) in self.order.iter().enumerate() {
            let slot = rows[i].bank_slot;
            match self.groups.last_mut() {
                Some(g) if g.bank_slot == slot => g.len += 1,
                _ => self.groups.push(UBatchGroup {
                    bank_slot: slot,
                    start: pos,
                    len: 1,
                }),
            }
        }
    }

    /// Rebuild only when the caller marked the plan `dirty` (a slot entered
    /// or left Generation) or the batch width changed; otherwise the cached
    /// permutation is reused untouched. Sound because the plan is a pure
    /// function of the rows' `(bank_slot, index)` keys, which are fixed for
    /// a stable Generation set — the per-tick fields (`token`, `pos`,
    /// `kv_probe`) never enter the sort. Returns whether a rebuild ran.
    pub fn rebuild_if(&mut self, rows: &[DecodeRow], dirty: bool) -> bool {
        if dirty || self.order.len() != rows.len() {
            self.build_into(rows);
            true
        } else {
            false
        }
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Largest group size (the paper's win case: many rows share an adapter).
    pub fn max_group(&self) -> usize {
        self.groups.iter().map(|g| g.len).max().unwrap_or(0)
    }

    /// Original-row indices of group `g`, in stable order.
    pub fn members(&self, g: usize) -> &[usize] {
        let g = &self.groups[g];
        &self.order[g.start..g.start + g.len]
    }

    /// Gather: reorder per-row payloads into sorted (grouped) order, written
    /// into a reused buffer (cleared first). The allocating Vec-returning
    /// `gather`/`scatter`/`sorted_rows` variants were removed — the `_into`
    /// forms are the only (de)permutation API, so the steady-state decode
    /// tick cannot regress into per-step allocation.
    pub fn gather_into<T: Copy>(&self, xs: &[T], out: &mut Vec<T>) {
        assert_eq!(xs.len(), self.order.len());
        out.clear();
        out.extend(self.order.iter().map(|&i| xs[i]));
    }

    /// Scatter: inverse of gather, into a reused buffer (cleared first).
    pub fn scatter_into<T: Copy>(&self, ys: &[T], out: &mut Vec<T>) {
        assert_eq!(ys.len(), self.inverse.len());
        out.clear();
        out.extend(self.inverse.iter().map(|&p| ys[p]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg64;

    fn row(i: usize, slot: usize) -> DecodeRow {
        DecodeRow {
            row: i,
            token: i as u32,
            pos: 0,
            bank_slot: slot,
            kv_probe: 0,
        }
    }

    /// Test shims over the `_into`-only API.
    fn gather<T: Copy>(plan: &UBatchPlan, xs: &[T]) -> Vec<T> {
        let mut out = Vec::new();
        plan.gather_into(xs, &mut out);
        out
    }

    fn scatter<T: Copy>(plan: &UBatchPlan, ys: &[T]) -> Vec<T> {
        let mut out = Vec::new();
        plan.scatter_into(ys, &mut out);
        out
    }

    #[test]
    fn groups_by_slot() {
        let rows = vec![row(0, 2), row(1, 0), row(2, 2), row(3, 1)];
        let plan = UBatchPlan::build(&rows);
        assert_eq!(plan.n_groups(), 3);
        assert_eq!(plan.groups[0].bank_slot, 0);
        assert_eq!(plan.groups[1].bank_slot, 1);
        assert_eq!(plan.groups[2].bank_slot, 2);
        assert_eq!(plan.members(2), &[0, 2]);
        assert_eq!(plan.max_group(), 2);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let rows = vec![row(0, 3), row(1, 1), row(2, 3), row(3, 0), row(4, 1)];
        let plan = UBatchPlan::build(&rows);
        let payload: Vec<u32> = vec![10, 11, 12, 13, 14];
        let gathered = gather(&plan, &payload);
        let back = scatter(&plan, &gathered);
        assert_eq!(back, payload);
    }

    #[test]
    fn sorted_rows_are_grouped() {
        let rows = vec![row(0, 5), row(1, 1), row(2, 5), row(3, 1)];
        let plan = UBatchPlan::build(&rows);
        let sorted = gather(&plan, &rows);
        let slots: Vec<usize> = sorted.iter().map(|r| r.bank_slot).collect();
        let mut expected = slots.clone();
        expected.sort_unstable();
        assert_eq!(slots, expected, "sorted rows must be non-decreasing");
    }

    #[test]
    fn empty_batch() {
        let plan = UBatchPlan::build(&[]);
        assert_eq!(plan.n_groups(), 0);
        assert_eq!(plan.max_group(), 0);
        let empty: Vec<u32> = gather(&plan, &[]);
        assert!(empty.is_empty());
    }

    #[test]
    fn all_same_adapter_single_group() {
        let rows: Vec<DecodeRow> = (0..6).map(|i| row(i, 4)).collect();
        let plan = UBatchPlan::build(&rows);
        assert_eq!(plan.n_groups(), 1);
        assert_eq!(plan.max_group(), 6);
        // stable: original order preserved within group
        assert_eq!(plan.members(0), &[0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn build_into_reuses_buffers_and_matches_build() {
        let mut reused = UBatchPlan::default();
        // grow once to the largest batch, then capacities must stay put
        let big: Vec<DecodeRow> = (0..32).map(|i| row(i, i % 5)).collect();
        reused.build_into(&big);
        let caps = (
            reused.order.capacity(),
            reused.inverse.capacity(),
            reused.groups.capacity(),
        );
        let mut rng = Pcg64::new(0xbeef);
        for _ in 0..50 {
            let n = rng.gen_range_usize(0, 33);
            let rows: Vec<DecodeRow> = (0..n)
                .map(|i| row(i, rng.gen_range_usize(0, 5)))
                .collect();
            reused.build_into(&rows);
            let fresh = UBatchPlan::build(&rows);
            assert_eq!(reused.order, fresh.order);
            assert_eq!(reused.inverse, fresh.inverse);
            assert_eq!(reused.groups, fresh.groups);
        }
        assert_eq!(
            caps,
            (
                reused.order.capacity(),
                reused.inverse.capacity(),
                reused.groups.capacity()
            ),
            "steady-state replanning must not reallocate"
        );
    }

    #[test]
    fn rebuild_if_reuses_clean_plan() {
        let rows = vec![row(0, 2), row(1, 0), row(2, 2)];
        let mut plan = UBatchPlan::default();
        assert!(plan.rebuild_if(&rows, false), "width change forces a build");
        let order = plan.order.clone();
        // clean + same width: cached permutation reused verbatim
        assert!(!plan.rebuild_if(&rows, false));
        assert_eq!(plan.order, order);
        // dirty forces a rebuild even at the same width
        let moved = vec![row(0, 0), row(1, 2), row(2, 1)];
        assert!(plan.rebuild_if(&moved, true));
        assert_eq!(plan.order, UBatchPlan::build(&moved).order);
        // width change alone also rebuilds (a slot left Generation)
        let shrunk = vec![row(0, 0), row(1, 2)];
        assert!(plan.rebuild_if(&shrunk, false));
        assert_eq!(plan.groups, UBatchPlan::build(&shrunk).groups);
    }

    #[test]
    fn prop_plan_is_permutation() {
        prop_check(
            300,
            0xba7c4,
            |rng: &mut Pcg64| {
                let n = rng.gen_range_usize(0, 24);
                (0..n).map(|_| rng.gen_range_usize(0, 6)).collect::<Vec<usize>>()
            },
            |slots| {
                let rows: Vec<DecodeRow> =
                    slots.iter().enumerate().map(|(i, &s)| row(i, s)).collect();
                let plan = UBatchPlan::build(&rows);
                // order is a permutation of 0..n
                let mut o = plan.order.clone();
                o.sort_unstable();
                if o != (0..rows.len()).collect::<Vec<_>>() {
                    return false;
                }
                // scatter ∘ gather == id
                let payload: Vec<usize> = (0..rows.len()).collect();
                if scatter(&plan, &gather(&plan, &payload)) != payload {
                    return false;
                }
                // group ranges tile `order` and cover every index exactly once
                let mut seen = vec![false; rows.len()];
                let mut expected_start = 0;
                for g in 0..plan.n_groups() {
                    if plan.groups[g].start != expected_start {
                        return false;
                    }
                    expected_start += plan.groups[g].len;
                    for &m in plan.members(g) {
                        if seen[m] {
                            return false;
                        }
                        seen[m] = true;
                        if rows[m].bank_slot != plan.groups[g].bank_slot {
                            return false;
                        }
                    }
                }
                expected_start == rows.len() && seen.iter().all(|&s| s)
            },
        );
    }

    #[test]
    fn prop_group_count_le_distinct_slots() {
        prop_check(
            200,
            0xba7c5,
            |rng: &mut Pcg64| {
                let n = rng.gen_range_usize(1, 32);
                (0..n).map(|_| rng.gen_range_usize(0, 8)).collect::<Vec<usize>>()
            },
            |slots| {
                let rows: Vec<DecodeRow> =
                    slots.iter().enumerate().map(|(i, &s)| row(i, s)).collect();
                let plan = UBatchPlan::build(&rows);
                let mut d = slots.clone();
                d.sort_unstable();
                d.dedup();
                plan.n_groups() == d.len()
            },
        );
    }
}
