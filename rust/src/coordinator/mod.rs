//! The paper's system contribution, L3: slot state machine (§4), adaptive
//! adapter selection (§3.2, Algorithm 1), u-batch planning for batch LoRA
//! inference (§3.4), and the serving engine that drives a [`ModelBackend`]
//! through request traces.

pub mod batcher;
pub mod engine;
pub mod events;
pub mod selection;
pub mod slot;

pub use batcher::{UBatchGroup, UBatchPlan};
pub use engine::{synth_prompt, synth_prompt_into, EdgeLoraEngine, EngineStats};
pub use events::{EngineEvent, EventBus, EventRx, RecvError, RequestId, ShedReason, TapRx};
pub use selection::{select_adapter, Selection};
pub use slot::{Slot, SlotState};
