//! Slot state machine (§4, Figure 7): each concurrent request owns a slot
//! that moves Idle → AdapterSelection → PromptProcessing → Generation → Idle.
//! The engine loop drives transitions; this module owns the states, the
//! per-slot bookkeeping, and the legality of transitions.

use crate::adapters::AdapterId;
use crate::metrics::RequestRecord;

/// Slot lifecycle states, as in the paper's Figure 7 — plus `Prefilling`,
/// the chunked-prefill extension (DESIGN.md §Chunked prefill): a long
/// prompt's uncovered suffix is consumed across several engine ticks
/// instead of one monolithic backend call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    Idle,
    /// request admitted; adapter not yet chosen (Algorithm 1 pending)
    AdapterSelection,
    /// adapter resident; prompt not yet processed
    PromptProcessing,
    /// adapter resident + pinned; prompt partially prefilled. `next_offset`
    /// is the first prompt position not yet processed (prefix-cache-covered
    /// positions count as processed). The slot still holds its KV pages and
    /// its adapter pin, so preemption/cancel treat it like Generation.
    Prefilling {
        next_offset: usize,
    },
    /// generating tokens
    Generation,
}

/// One request slot. `row` is the backend decode-batch row this slot owns.
#[derive(Debug, Clone)]
pub struct Slot {
    pub index: usize,
    pub state: SlotState,
    pub row: usize,
    // --- request context (valid when not Idle) ---
    pub request_id: u64,
    pub prompt: Vec<u32>,
    pub explicit_adapter: Option<AdapterId>,
    pub true_adapter: AdapterId,
    pub target_tokens: usize,
    pub generated: usize,
    /// chosen adapter + its bank slot (valid from PromptProcessing on)
    pub adapter: AdapterId,
    pub bank_slot: usize,
    /// decode position = prompt_len + generated (cache write index)
    pub prompt_len: usize,
    pub last_token: u32,
    /// engine-relative instant the last token was produced (prefill or
    /// decode) — the inter-token-latency anchor for the next Token event
    pub last_token_at: f64,
    pub record: RequestRecord,
}

impl Slot {
    pub fn new(index: usize, row: usize) -> Self {
        Self {
            index,
            state: SlotState::Idle,
            row,
            request_id: 0,
            prompt: Vec::new(),
            explicit_adapter: None,
            true_adapter: 0,
            target_tokens: 0,
            generated: 0,
            adapter: 0,
            bank_slot: 0,
            prompt_len: 0,
            last_token: 0,
            last_token_at: 0.0,
            record: RequestRecord::default(),
        }
    }

    pub fn is_idle(&self) -> bool {
        self.state == SlotState::Idle
    }

    /// Admit a request into an idle slot.
    pub fn admit(
        &mut self,
        request_id: u64,
        prompt: Vec<u32>,
        explicit_adapter: Option<AdapterId>,
        true_adapter: AdapterId,
        target_tokens: usize,
        arrival: f64,
        now: f64,
    ) {
        assert!(self.is_idle(), "admit into non-idle slot {}", self.index);
        assert!(!prompt.is_empty() && target_tokens > 0);
        self.state = SlotState::AdapterSelection;
        self.request_id = request_id;
        self.prompt_len = prompt.len();
        self.prompt = prompt;
        self.explicit_adapter = explicit_adapter;
        self.true_adapter = true_adapter;
        self.target_tokens = target_tokens;
        self.generated = 0;
        self.record = RequestRecord {
            id: request_id,
            adapter: true_adapter as usize,
            arrival,
            scheduled: now,
            input_tokens: self.prompt_len,
            output_tokens: target_tokens,
            ..Default::default()
        };
    }

    /// Adapter chosen (Algorithm 1 done) → ready for prompt processing.
    pub fn adapter_selected(
        &mut self,
        adapter: AdapterId,
        bank_slot: usize,
        cache_hit: bool,
        auto: bool,
    ) {
        assert_eq!(self.state, SlotState::AdapterSelection);
        self.adapter = adapter;
        self.bank_slot = bank_slot;
        self.record.cache_hit = cache_hit;
        self.record.auto_selected = auto;
        self.state = SlotState::PromptProcessing;
    }

    /// Enter or advance chunked prefill: `next_offset` prompt positions are
    /// now processed (prefix-cache covered + chunks so far); the remainder
    /// waits for future ticks. Legal from PromptProcessing (first chunk) or
    /// Prefilling (later chunks), and must leave a non-empty suffix — the
    /// final chunk goes through `prompt_done` instead.
    pub fn prefill_progress(&mut self, next_offset: usize) {
        assert!(
            matches!(
                self.state,
                SlotState::PromptProcessing | SlotState::Prefilling { .. }
            ),
            "prefill progress on slot {} in {:?}",
            self.index,
            self.state
        );
        if let SlotState::Prefilling { next_offset: prev } = self.state {
            assert!(next_offset > prev, "chunked prefill must advance");
        }
        assert!(
            next_offset < self.prompt_len,
            "chunk offset {next_offset} must leave a final chunk (prompt {})",
            self.prompt_len
        );
        self.state = SlotState::Prefilling { next_offset };
    }

    /// Prompt processed (monolithically, or the final chunk); first token
    /// produced.
    pub fn prompt_done(&mut self, first_token: u32, now: f64) {
        assert!(
            matches!(
                self.state,
                SlotState::PromptProcessing | SlotState::Prefilling { .. }
            ),
            "prompt_done on slot {} in {:?}",
            self.index,
            self.state
        );
        self.last_token = first_token;
        self.last_token_at = now;
        self.generated = 1;
        self.record.first_token = now;
        self.state = SlotState::Generation;
    }

    /// A decode step produced this slot's next token. Returns true when the
    /// request just completed.
    pub fn token_generated(&mut self, token: u32, now: f64) -> bool {
        assert_eq!(self.state, SlotState::Generation);
        self.last_token = token;
        self.last_token_at = now;
        self.generated += 1;
        if self.generated >= self.target_tokens {
            self.record.finished = now;
            true
        } else {
            false
        }
    }

    /// Current decode position: next cache write index.
    pub fn position(&self) -> u32 {
        (self.prompt_len + self.generated - 1) as u32
    }

    /// Preemption (DESIGN.md §Unified paging): abandon the request from any
    /// non-idle state and return the slot to Idle. The engine rebuilds a
    /// `TraceRequest` from the slot fields first and re-queues it; nothing
    /// is recorded — the request's record restarts at its next admission,
    /// and its tokens are recomputed deterministically.
    pub fn abort(&mut self) {
        assert!(!self.is_idle(), "abort of idle slot {}", self.index);
        self.state = SlotState::Idle;
        self.prompt.clear();
        self.generated = 0;
        self.record = RequestRecord::default();
    }

    /// Finish: emit the record and return to Idle.
    pub fn release(&mut self) -> RequestRecord {
        assert_eq!(self.state, SlotState::Generation);
        assert!(self.generated >= self.target_tokens);
        self.state = SlotState::Idle;
        self.prompt.clear();
        std::mem::take(&mut self.record)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn admitted() -> Slot {
        let mut s = Slot::new(0, 0);
        s.admit(7, vec![1, 2, 3], None, 4, 2, 1.0, 1.5);
        s
    }

    #[test]
    fn full_lifecycle() {
        let mut s = admitted();
        assert_eq!(s.state, SlotState::AdapterSelection);
        assert_eq!(s.record.scheduled, 1.5);
        s.adapter_selected(4, 2, true, true);
        assert_eq!(s.state, SlotState::PromptProcessing);
        s.prompt_done(42, 2.0);
        assert_eq!(s.state, SlotState::Generation);
        assert_eq!(s.record.first_token, 2.0);
        assert_eq!(s.position(), 3); // prompt 3 tokens, 1 generated
        assert!(s.token_generated(43, 2.5)); // target 2 -> done
        let rec = s.release();
        assert!(s.is_idle());
        assert_eq!(rec.id, 7);
        assert!((rec.latency() - 1.5).abs() < 1e-9);
        assert!((rec.first_token_latency() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn position_advances_with_tokens() {
        let mut s = admitted();
        s.adapter_selected(4, 0, false, false);
        s.prompt_done(1, 2.0);
        assert_eq!(s.position(), 3);
        s.target_tokens = 5;
        assert!((s.last_token_at - 2.0).abs() < 1e-12, "prefill anchors ITL");
        s.token_generated(2, 2.1);
        assert_eq!(s.position(), 4);
        assert!((s.last_token_at - 2.1).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "admit into non-idle")]
    fn cannot_double_admit() {
        let mut s = admitted();
        s.admit(8, vec![1], None, 0, 1, 0.0, 0.0);
    }

    #[test]
    #[should_panic]
    fn cannot_skip_selection() {
        let mut s = admitted();
        s.prompt_done(1, 0.0);
    }

    #[test]
    fn abort_returns_slot_to_idle_from_any_state() {
        let mut s = admitted();
        s.abort();
        assert!(s.is_idle());
        s.admit(8, vec![1, 2], None, 1, 3, 2.0, 2.0);
        s.adapter_selected(1, 0, false, false);
        s.prompt_done(5, 2.5);
        assert_eq!(s.state, SlotState::Generation);
        s.abort();
        assert!(s.is_idle());
        assert_eq!(s.generated, 0);
        // reusable after abort
        s.admit(9, vec![1], Some(0), 0, 1, 3.0, 3.0);
        assert_eq!(s.state, SlotState::AdapterSelection);
    }

    #[test]
    #[should_panic(expected = "abort of idle")]
    fn abort_of_idle_slot_panics() {
        let mut s = Slot::new(0, 0);
        s.abort();
    }

    #[test]
    fn chunked_prefill_transitions() {
        let mut s = Slot::new(0, 0);
        s.admit(7, (1..=10).collect(), None, 4, 2, 1.0, 1.5);
        s.adapter_selected(4, 2, true, false);
        s.prefill_progress(4);
        assert_eq!(s.state, SlotState::Prefilling { next_offset: 4 });
        s.prefill_progress(8);
        assert_eq!(s.state, SlotState::Prefilling { next_offset: 8 });
        // final chunk completes through prompt_done, same as monolithic
        s.prompt_done(42, 2.0);
        assert_eq!(s.state, SlotState::Generation);
        assert_eq!(s.generated, 1);
        // preemption aborts from Prefilling like any non-idle state
        let mut p = Slot::new(1, 1);
        p.admit(8, (1..=10).collect(), None, 0, 2, 0.0, 0.0);
        p.adapter_selected(0, 0, false, false);
        p.prefill_progress(4);
        p.abort();
        assert!(p.is_idle());
    }

    #[test]
    #[should_panic(expected = "must advance")]
    fn chunked_prefill_cannot_stall() {
        let mut s = Slot::new(0, 0);
        s.admit(7, (1..=10).collect(), None, 4, 2, 1.0, 1.5);
        s.adapter_selected(4, 2, true, false);
        s.prefill_progress(4);
        s.prefill_progress(4);
    }

    #[test]
    #[should_panic(expected = "final chunk")]
    fn chunked_prefill_last_chunk_goes_through_prompt_done() {
        let mut s = Slot::new(0, 0);
        s.admit(7, (1..=10).collect(), None, 4, 2, 1.0, 1.5);
        s.adapter_selected(4, 2, true, false);
        s.prefill_progress(10);
    }

    #[test]
    fn single_token_request_completes_at_prefill() {
        let mut s = Slot::new(1, 1);
        s.admit(9, vec![5, 6], None, 0, 1, 0.0, 0.0);
        s.adapter_selected(0, 0, true, false);
        s.prompt_done(11, 0.5);
        // generated == target already; engine checks and releases
        assert!(s.generated >= s.target_tokens);
        s.record.finished = 0.5;
        // release requires Generation state with target met
        let rec = {
            s.state = SlotState::Generation;
            s.release()
        };
        assert_eq!(rec.output_tokens, 1);
    }
}
