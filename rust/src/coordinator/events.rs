//! Request-lifecycle events (DESIGN.md §Serving API): every request admitted
//! through [`EdgeLoraEngine::submit`](crate::coordinator::EdgeLoraEngine)
//! produces an ordered stream of [`EngineEvent`]s — Queued → Admitted →
//! Token… → Done, with Preempted/Requeued interleaved under page pressure
//! and Cancelled/Truncated as the deviation terminals. The HTTP layer turns
//! this stream into SSE frames; tests fold Token events into the engine's
//! `token_checksum` to pin streamed == non-streamed bit-identity.
//!
//! The [`EventBus`] is the delivery fabric: per-request channels plus an
//! optional global tap (all events, in emission order — the order the
//! checksum folds in). Cluster replicas share one bus the same way they
//! share one `Recorder`, so a request's events arrive on a single stream no
//! matter which shard serves (or steals) it.
//!
//! Backpressure: every channel is **bounded**. A subscriber that stops
//! draining (a stalled SSE client) can hold at most its capacity — when a
//! channel is full, the oldest buffered *Token* event is coalesced away
//! first (consumers already deduplicate/skip by `index`, so a gap reads as
//! dropped intermediate tokens), then the oldest non-terminal lifecycle
//! event (a preempt-thrashing request emits those without bound). Only the
//! terminal `Done`/`Cancelled` is sacred on a per-request channel — it may
//! exceed the capacity by exactly one entry; the diagnostic tap is lossy
//! across the board and never exceeds its capacity at all. The pre-bound
//! design buffered every Token forever (ROADMAP: "the client socket
//! provides the only flow control").
//!
//! Emission is free when nobody listens: `emit` first checks an atomic
//! subscriber count, so trace replays and benches pay one relaxed load per
//! token and never touch a lock.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Engine-assigned request identifier (the trace/request id).
pub type RequestId = u64;

/// Default capacity of one request's event channel. Generous for a live
/// client (a few screens of tokens) while bounding a dead one.
pub const REQUEST_CHANNEL_CAP: usize = 1024;

/// Default capacity of the global tap. Sized for whole-trace test taps;
/// still a hard bound for an abandoned one.
pub const TAP_CHANNEL_CAP: usize = 65536;

/// Why admission refused a request (DESIGN.md §QoS & overload).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// the tenant's token bucket was empty — per-tenant rate limit
    RateLimit,
    /// the queueing-delay estimate provably exceeds the request's deadline
    Deadline,
    /// no worker behind the router is routable (all Suspect/Dead) — the
    /// cluster edge refuses rather than queue into a black hole
    /// (DESIGN.md §Distributed serving)
    Unreachable,
}

impl ShedReason {
    pub fn name(&self) -> &'static str {
        match self {
            ShedReason::RateLimit => "rate_limit",
            ShedReason::Deadline => "deadline",
            ShedReason::Unreachable => "unreachable",
        }
    }
}

/// One step of a request's lifecycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EngineEvent {
    /// Accepted into a replica's admission queue.
    Queued { replica: usize },
    /// Left the queue for a slot (engine-relative time `t`); prompt
    /// processing begins.
    Admitted { replica: usize, t: f64 },
    /// Generation target clamped to the backend's context window.
    Truncated { target: usize },
    /// One generated token; `index` 0 is the prefill token. After a
    /// preemption the deterministic recompute re-emits earlier indices —
    /// consumers deduplicate by `index`. A slow consumer may also see
    /// index *gaps* where overflow coalescing dropped intermediate tokens.
    Token { index: u32, token: u32, t: f64 },
    /// Evicted from its slot under page pressure (KV pages + pins released).
    Preempted,
    /// Back at the head of the queue for deterministic recompute.
    Requeued,
    /// Re-dispatched from a dead shard onto a live one (dead-shard
    /// recovery, DESIGN.md §Failure model). Follows the thief shard's
    /// `Queued` — the stream narrates the move, like a steal.
    Rehomed { from: usize, to: usize },
    /// Every target token delivered.
    Done { t: f64 },
    /// Cancelled by the client; slot, KV pages and pool pins released.
    Cancelled,
    /// Refused at admission (rate limit or hopeless deadline) before any
    /// resource was reserved — terminal, exactly one per shed request
    /// (DESIGN.md §QoS & overload).
    Shed { reason: ShedReason },
}

impl EngineEvent {
    /// SSE event name.
    pub fn name(&self) -> &'static str {
        match self {
            EngineEvent::Queued { .. } => "queued",
            EngineEvent::Admitted { .. } => "admitted",
            EngineEvent::Truncated { .. } => "truncated",
            EngineEvent::Token { .. } => "token",
            EngineEvent::Preempted => "preempted",
            EngineEvent::Requeued => "requeued",
            EngineEvent::Rehomed { .. } => "rehomed",
            EngineEvent::Done { .. } => "done",
            EngineEvent::Cancelled => "cancelled",
            EngineEvent::Shed { .. } => "shed",
        }
    }

    /// Whether this event ends the request's stream.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            EngineEvent::Done { .. } | EngineEvent::Cancelled | EngineEvent::Shed { .. }
        )
    }
}

/// Overflow classes of a bounded channel: Token events coalesce first
/// (`droppable`), non-terminal lifecycle events go next, and terminals
/// (`sacred`) are never discarded by a per-request channel — they are the
/// one class whose loss wedges a consumer forever.
trait Coalesce {
    /// preferred overflow victim (Token events)
    fn droppable(&self) -> bool;
    /// must never be dropped on a per-request channel (Done/Cancelled)
    fn sacred(&self) -> bool;
}

impl Coalesce for EngineEvent {
    fn droppable(&self) -> bool {
        matches!(self, EngineEvent::Token { .. })
    }
    fn sacred(&self) -> bool {
        self.is_terminal()
    }
}

impl Coalesce for (RequestId, EngineEvent) {
    fn droppable(&self) -> bool {
        self.1.droppable()
    }
    fn sacred(&self) -> bool {
        self.1.sacred()
    }
}

struct ChanState<T> {
    buf: VecDeque<T>,
    rx_alive: bool,
    tx_alive: bool,
    /// Token events coalesced away under overflow
    coalesced: u64,
}

/// A bounded MPSC-ish channel with Token coalescing on overflow. The bus
/// holds the sending side; [`BoundedRx`] is the receiving handle.
struct Chan<T> {
    state: Mutex<ChanState<T>>,
    cv: Condvar,
    cap: usize,
    /// overflow policy when nothing droppable is buffered: a *lossy*
    /// channel (the diagnostic tap) drops its oldest event outright and
    /// stays hard-bounded; a per-request channel instead grows past `cap`
    /// by the handful of lifecycle events one request emits, so its
    /// terminal can never be lost
    lossy: bool,
}

impl<T: Coalesce> Chan<T> {
    fn new(cap: usize, lossy: bool) -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(ChanState {
                buf: VecDeque::new(),
                rx_alive: true,
                tx_alive: true,
                coalesced: 0,
            }),
            cv: Condvar::new(),
            cap: cap.max(1),
            lossy,
        })
    }

    /// Deliver one item. False = the receiver is gone (caller prunes).
    fn push(&self, item: T) -> bool {
        let mut g = self.state.lock().unwrap();
        if !g.rx_alive {
            return false;
        }
        if g.buf.len() >= self.cap {
            if let Some(i) = g.buf.iter().position(|e| e.droppable()) {
                // coalesce: the oldest buffered token makes room — the
                // consumer sees an index gap, never a lost terminal
                g.buf.remove(i);
                g.coalesced += 1;
            } else if self.lossy {
                // lossy tap: a diagnostic stream drops its oldest event
                // outright, whatever the class — it must stay hard-bounded
                // even though terminals scale with total request count
                g.buf.pop_front();
                g.coalesced += 1;
            } else if let Some(i) = g.buf.iter().position(|e| !e.sacred()) {
                // no tokens left: the oldest non-terminal lifecycle event
                // goes next (a preempt-thrashing request emits these
                // without bound — they must not grow the buffer)
                g.buf.remove(i);
                g.coalesced += 1;
            } else if !item.sacred() {
                // buffer is all terminals (per-request: at most one):
                // shed the incoming non-terminal instead of growing
                g.coalesced += 1;
                return true;
            }
            // incoming terminal over an all-terminal buffer: grow — a
            // per-request channel holds at most one terminal, so this
            // bounds the buffer at cap + 1
        }
        g.buf.push_back(item);
        drop(g);
        self.cv.notify_one();
        true
    }

    /// Sender side is going away (unsubscribe/terminal prune): wake any
    /// blocked receiver so `recv_timeout` can observe the disconnect.
    fn close_tx(&self) {
        self.state.lock().unwrap().tx_alive = false;
        self.cv.notify_all();
    }
}

/// Receive errors, mirroring `std::sync::mpsc` shapes (call sites only
/// match on Ok/Err).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvError {
    /// nothing buffered right now (try_recv) / within the timeout
    Empty,
    /// nothing buffered and the sending side is gone
    Disconnected,
}

/// Receiving half of a bounded event channel.
pub struct BoundedRx<T>(Arc<Chan<T>>);

pub type EventRx = BoundedRx<EngineEvent>;
pub type TapRx = BoundedRx<(RequestId, EngineEvent)>;

impl<T: Coalesce> BoundedRx<T> {
    pub fn try_recv(&self) -> Result<T, RecvError> {
        let mut g = self.0.state.lock().unwrap();
        match g.buf.pop_front() {
            Some(v) => Ok(v),
            None if g.tx_alive => Err(RecvError::Empty),
            None => Err(RecvError::Disconnected),
        }
    }

    /// Block up to `timeout` for the next event.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvError> {
        let mut g = self.0.state.lock().unwrap();
        loop {
            if let Some(v) = g.buf.pop_front() {
                return Ok(v);
            }
            if !g.tx_alive {
                return Err(RecvError::Disconnected);
            }
            let (ng, res) = self.0.cv.wait_timeout(g, timeout).unwrap();
            g = ng;
            if res.timed_out() {
                return match g.buf.pop_front() {
                    Some(v) => Ok(v),
                    None => Err(RecvError::Empty),
                };
            }
        }
    }

    /// Drain everything currently buffered (non-blocking iterator).
    pub fn try_iter(&self) -> TryIter<'_, T> {
        TryIter(self)
    }

    /// Events currently buffered (the bounded-channel regression tests
    /// assert this cannot grow past capacity + the lifecycle slack).
    pub fn len(&self) -> usize {
        self.0.state.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Token events coalesced away because this receiver stopped draining.
    pub fn coalesced(&self) -> u64 {
        self.0.state.lock().unwrap().coalesced
    }
}

impl<T> Drop for BoundedRx<T> {
    fn drop(&mut self) {
        // emit()'s next push sees rx_alive=false and prunes the sender
        self.0.state.lock().unwrap().rx_alive = false;
    }
}

/// Iterator over currently-buffered events (see [`BoundedRx::try_iter`]).
pub struct TryIter<'a, T>(&'a BoundedRx<T>);

impl<T: Coalesce> Iterator for TryIter<'_, T> {
    type Item = T;
    fn next(&mut self) -> Option<T> {
        self.0.try_recv().ok()
    }
}

struct Subs {
    by_request: BTreeMap<RequestId, Arc<Chan<EngineEvent>>>,
    tap: Option<Arc<Chan<(RequestId, EngineEvent)>>>,
}

/// Per-request event channels + a global tap, shared across cluster replicas.
pub struct EventBus {
    subs: Mutex<Subs>,
    /// live subscriptions (per-request + tap) — emit's lock-free fast path
    active: AtomicUsize,
}

impl Default for EventBus {
    fn default() -> Self {
        Self::new()
    }
}

impl EventBus {
    pub fn new() -> Self {
        Self {
            subs: Mutex::new(Subs {
                by_request: BTreeMap::new(),
                tap: None,
            }),
            active: AtomicUsize::new(0),
        }
    }

    /// Open the event stream for one request (capacity
    /// [`REQUEST_CHANNEL_CAP`]). Subscribe *before* submitting the request
    /// or its Queued event is lost. A second subscription for the same id
    /// replaces the first.
    pub fn subscribe(&self, id: RequestId) -> EventRx {
        self.subscribe_with_capacity(id, REQUEST_CHANNEL_CAP)
    }

    /// [`Self::subscribe`] with an explicit channel capacity (tests pin the
    /// coalescing behavior with tiny bounds).
    pub fn subscribe_with_capacity(&self, id: RequestId, cap: usize) -> EventRx {
        let chan = Chan::new(cap, false);
        let rx = BoundedRx(Arc::clone(&chan));
        let mut g = self.subs.lock().unwrap();
        if let Some(old) = g.by_request.insert(id, chan) {
            old.close_tx();
        } else {
            self.active.fetch_add(1, Ordering::Relaxed);
        }
        rx
    }

    /// Drop a request's subscription (terminal event seen, or the client
    /// went away). Idempotent.
    pub fn unsubscribe(&self, id: RequestId) {
        let mut g = self.subs.lock().unwrap();
        if let Some(chan) = g.by_request.remove(&id) {
            chan.close_tx();
            self.active.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Global tap: every event of every request, in emission order (the
    /// order `token_checksum` folds in), capacity [`TAP_CHANNEL_CAP`]. One
    /// tap at a time — a new tap replaces the previous one.
    pub fn tap(&self) -> TapRx {
        self.tap_with_capacity(TAP_CHANNEL_CAP)
    }

    /// [`Self::tap`] with an explicit capacity. The tap is *lossy*: it is
    /// a diagnostic stream, so once its buffer is full the oldest event
    /// goes (tokens first) — it can never grow past `cap`, unlike the
    /// per-request channels whose terminals are sacred.
    pub fn tap_with_capacity(&self, cap: usize) -> TapRx {
        let chan = Chan::new(cap, true);
        let rx = BoundedRx(Arc::clone(&chan));
        let mut g = self.subs.lock().unwrap();
        if let Some(old) = g.tap.replace(chan) {
            old.close_tx();
        } else {
            self.active.fetch_add(1, Ordering::Relaxed);
        }
        rx
    }

    /// Deliver one event. Dropped receivers are pruned here, so an
    /// abandoned stream costs one failed send and then nothing.
    pub fn emit(&self, id: RequestId, ev: EngineEvent) {
        if self.active.load(Ordering::Relaxed) == 0 {
            return;
        }
        let mut g = self.subs.lock().unwrap();
        if let Some(tx) = g.tap.as_ref() {
            if !tx.push((id, ev)) {
                g.tap = None;
                self.active.fetch_sub(1, Ordering::Relaxed);
            }
        }
        let dead = match g.by_request.get(&id) {
            Some(tx) => !tx.push(ev),
            None => false,
        };
        if dead {
            g.by_request.remove(&id);
            self.active.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Live subscriptions (per-request channels + tap).
    pub fn subscriber_count(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subscribe_emit_receive_in_order() {
        let bus = EventBus::new();
        let rx = bus.subscribe(7);
        assert_eq!(bus.subscriber_count(), 1);
        bus.emit(7, EngineEvent::Queued { replica: 0 });
        bus.emit(7, EngineEvent::Token { index: 0, token: 42, t: 0.5 });
        bus.emit(8, EngineEvent::Queued { replica: 1 }); // not subscribed
        bus.emit(7, EngineEvent::Done { t: 1.0 });
        let evs: Vec<EngineEvent> = rx.try_iter().collect();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0], EngineEvent::Queued { replica: 0 });
        assert!(evs[2].is_terminal());
        assert_eq!(evs[1].name(), "token");
    }

    #[test]
    fn dropped_receiver_is_pruned_and_unsubscribe_idempotent() {
        let bus = EventBus::new();
        let rx = bus.subscribe(1);
        drop(rx);
        bus.emit(1, EngineEvent::Cancelled); // prunes the dead channel
        assert_eq!(bus.subscriber_count(), 0);
        bus.unsubscribe(1);
        bus.unsubscribe(1);
        assert_eq!(bus.subscriber_count(), 0);
    }

    #[test]
    fn tap_sees_every_request_in_emission_order() {
        let bus = EventBus::new();
        let tap = bus.tap();
        bus.emit(1, EngineEvent::Queued { replica: 0 });
        bus.emit(2, EngineEvent::Queued { replica: 1 });
        bus.emit(1, EngineEvent::Done { t: 0.0 });
        let all: Vec<(u64, EngineEvent)> = tap.try_iter().collect();
        assert_eq!(all.len(), 3);
        assert_eq!(all[0].0, 1);
        assert_eq!(all[1].0, 2);
        assert_eq!(all[2].0, 1);
    }

    #[test]
    fn shed_is_terminal_and_sacred_under_overflow() {
        let shed = EngineEvent::Shed { reason: ShedReason::RateLimit };
        assert!(shed.is_terminal());
        assert_eq!(shed.name(), "shed");
        assert_eq!(ShedReason::Deadline.name(), "deadline");
        // a full channel must still deliver the Shed terminal
        let bus = EventBus::new();
        let rx = bus.subscribe_with_capacity(11, 2);
        bus.emit(11, EngineEvent::Queued { replica: 0 });
        bus.emit(11, EngineEvent::Requeued);
        bus.emit(11, EngineEvent::Requeued);
        bus.emit(11, shed);
        let evs: Vec<EngineEvent> = rx.try_iter().collect();
        assert!(matches!(evs.last(), Some(EngineEvent::Shed { .. })), "{evs:?}");
    }

    #[test]
    fn emit_without_subscribers_is_a_noop() {
        let bus = EventBus::new();
        bus.emit(5, EngineEvent::Done { t: 0.0 }); // must not panic or leak
        assert_eq!(bus.subscriber_count(), 0);
    }

    #[test]
    fn overflow_coalesces_oldest_tokens_and_keeps_terminals() {
        let bus = EventBus::new();
        let rx = bus.subscribe_with_capacity(3, 4);
        bus.emit(3, EngineEvent::Queued { replica: 0 });
        for i in 0..10u32 {
            bus.emit(3, EngineEvent::Token { index: i, token: 100 + i, t: i as f64 });
        }
        bus.emit(3, EngineEvent::Done { t: 10.0 });
        // never grew past cap + the lifecycle slack (Done over a full buffer)
        assert!(rx.len() <= 5, "buffer grew to {}", rx.len());
        assert!(rx.coalesced() > 0, "overflow must coalesce");
        let evs: Vec<EngineEvent> = rx.try_iter().collect();
        assert_eq!(evs[0], EngineEvent::Queued { replica: 0 });
        assert!(matches!(evs.last(), Some(EngineEvent::Done { .. })), "{evs:?}");
        // surviving tokens are the *freshest*, still in order
        let idx: Vec<u32> = evs
            .iter()
            .filter_map(|e| match e {
                EngineEvent::Token { index, .. } => Some(*index),
                _ => None,
            })
            .collect();
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "out of order: {idx:?}");
        assert_eq!(*idx.last().unwrap(), 9, "freshest token survives");
    }

    #[test]
    fn overflow_preserves_terminal_and_bounds_lifecycle_thrash() {
        // a preempt-thrashing request emits non-terminal lifecycle events
        // without bound — the channel must stay at its cap (they displace
        // each other) and the terminal must still arrive
        let bus = EventBus::new();
        let rx = bus.subscribe_with_capacity(9, 4);
        bus.emit(9, EngineEvent::Queued { replica: 0 });
        for _ in 0..50 {
            bus.emit(9, EngineEvent::Preempted);
            bus.emit(9, EngineEvent::Requeued);
        }
        bus.emit(9, EngineEvent::Done { t: 1.0 });
        assert!(rx.len() <= 4, "lifecycle thrash grew the buffer to {}", rx.len());
        assert!(rx.coalesced() >= 96, "coalesced {}", rx.coalesced());
        let evs: Vec<EngineEvent> = rx.try_iter().collect();
        assert!(matches!(evs.last(), Some(EngineEvent::Done { .. })), "{evs:?}");
    }

    #[test]
    fn recv_timeout_wakes_on_event_and_reports_disconnect() {
        let bus = Arc::new(EventBus::new());
        let rx = bus.subscribe(4);
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(10)),
            Err(RecvError::Empty)
        );
        bus.emit(4, EngineEvent::Done { t: 0.0 });
        assert!(rx.recv_timeout(Duration::from_millis(10)).is_ok());
        bus.unsubscribe(4);
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(10)),
            Err(RecvError::Disconnected)
        );
    }

    #[test]
    fn tap_overflow_is_bounded_too() {
        let bus = EventBus::new();
        let tap = bus.tap_with_capacity(8);
        for i in 0..100u32 {
            bus.emit(1, EngineEvent::Token { index: i, token: i, t: 0.0 });
        }
        bus.emit(1, EngineEvent::Done { t: 1.0 });
        assert!(tap.len() <= 8, "tap grew to {}", tap.len());
        assert!(tap.coalesced() >= 92);
        let all: Vec<(u64, EngineEvent)> = tap.try_iter().collect();
        assert!(matches!(all.last(), Some((1, EngineEvent::Done { .. }))));
    }

    #[test]
    fn lossy_tap_stays_hard_bounded_under_lifecycle_only_traffic() {
        // the tap must not grow with total request count: once its tokens
        // are gone, lifecycle events displace the oldest events instead of
        // growing past cap (per-request channels keep their terminals)
        let bus = EventBus::new();
        let tap = bus.tap_with_capacity(4);
        for id in 0..50u64 {
            bus.emit(id, EngineEvent::Queued { replica: 0 });
            bus.emit(id, EngineEvent::Done { t: 0.0 });
        }
        assert_eq!(tap.len(), 4, "lossy tap must never exceed its cap");
        let all: Vec<(u64, EngineEvent)> = tap.try_iter().collect();
        assert_eq!(all.last().unwrap().0, 49, "freshest events survive");
    }
}
