//! Request-lifecycle events (DESIGN.md §Serving API): every request admitted
//! through [`EdgeLoraEngine::submit`](crate::coordinator::EdgeLoraEngine)
//! produces an ordered stream of [`EngineEvent`]s — Queued → Admitted →
//! Token… → Done, with Preempted/Requeued interleaved under page pressure
//! and Cancelled/Truncated as the deviation terminals. The HTTP layer turns
//! this stream into SSE frames; tests fold Token events into the engine's
//! `token_checksum` to pin streamed == non-streamed bit-identity.
//!
//! The [`EventBus`] is the delivery fabric: per-request mpsc channels plus
//! an optional global tap (all events, in emission order — the order the
//! checksum folds in). Cluster replicas share one bus the same way they
//! share one `Recorder`, so a request's events arrive on a single stream no
//! matter which shard serves (or steals) it.
//!
//! Emission is free when nobody listens: `emit` first checks an atomic
//! subscriber count, so trace replays and benches pay one relaxed load per
//! token and never touch the lock.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

/// Engine-assigned request identifier (the trace/request id).
pub type RequestId = u64;

/// One step of a request's lifecycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EngineEvent {
    /// Accepted into a replica's admission queue.
    Queued { replica: usize },
    /// Left the queue for a slot (engine-relative time `t`); prompt
    /// processing begins.
    Admitted { replica: usize, t: f64 },
    /// Generation target clamped to the backend's context window.
    Truncated { target: usize },
    /// One generated token; `index` 0 is the prefill token. After a
    /// preemption the deterministic recompute re-emits earlier indices —
    /// consumers deduplicate by `index`.
    Token { index: u32, token: u32, t: f64 },
    /// Evicted from its slot under page pressure (KV pages + pins released).
    Preempted,
    /// Back at the head of the queue for deterministic recompute.
    Requeued,
    /// Every target token delivered.
    Done { t: f64 },
    /// Cancelled by the client; slot, KV pages and pool pins released.
    Cancelled,
}

impl EngineEvent {
    /// SSE event name.
    pub fn name(&self) -> &'static str {
        match self {
            EngineEvent::Queued { .. } => "queued",
            EngineEvent::Admitted { .. } => "admitted",
            EngineEvent::Truncated { .. } => "truncated",
            EngineEvent::Token { .. } => "token",
            EngineEvent::Preempted => "preempted",
            EngineEvent::Requeued => "requeued",
            EngineEvent::Done { .. } => "done",
            EngineEvent::Cancelled => "cancelled",
        }
    }

    /// Whether this event ends the request's stream.
    pub fn is_terminal(&self) -> bool {
        matches!(self, EngineEvent::Done { .. } | EngineEvent::Cancelled)
    }
}

struct Subs {
    by_request: HashMap<RequestId, Sender<EngineEvent>>,
    tap: Option<Sender<(RequestId, EngineEvent)>>,
}

/// Per-request event channels + a global tap, shared across cluster replicas.
pub struct EventBus {
    subs: Mutex<Subs>,
    /// live subscriptions (per-request + tap) — emit's lock-free fast path
    active: AtomicUsize,
}

impl Default for EventBus {
    fn default() -> Self {
        Self::new()
    }
}

impl EventBus {
    pub fn new() -> Self {
        Self {
            subs: Mutex::new(Subs {
                by_request: HashMap::new(),
                tap: None,
            }),
            active: AtomicUsize::new(0),
        }
    }

    /// Open the event stream for one request. Subscribe *before* submitting
    /// the request or its Queued event is lost. A second subscription for the
    /// same id replaces the first.
    pub fn subscribe(&self, id: RequestId) -> Receiver<EngineEvent> {
        let (tx, rx) = channel();
        let mut g = self.subs.lock().unwrap();
        if g.by_request.insert(id, tx).is_none() {
            self.active.fetch_add(1, Ordering::Relaxed);
        }
        rx
    }

    /// Drop a request's subscription (terminal event seen, or the client
    /// went away). Idempotent.
    pub fn unsubscribe(&self, id: RequestId) {
        let mut g = self.subs.lock().unwrap();
        if g.by_request.remove(&id).is_some() {
            self.active.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Global tap: every event of every request, in emission order (the
    /// order `token_checksum` folds in). One tap at a time — a new tap
    /// replaces the previous one.
    pub fn tap(&self) -> Receiver<(RequestId, EngineEvent)> {
        let (tx, rx) = channel();
        let mut g = self.subs.lock().unwrap();
        if g.tap.replace(tx).is_none() {
            self.active.fetch_add(1, Ordering::Relaxed);
        }
        rx
    }

    /// Deliver one event. Dropped receivers are pruned here, so an
    /// abandoned stream costs one failed send and then nothing.
    pub fn emit(&self, id: RequestId, ev: EngineEvent) {
        if self.active.load(Ordering::Relaxed) == 0 {
            return;
        }
        let mut g = self.subs.lock().unwrap();
        if let Some(tx) = g.tap.as_ref() {
            if tx.send((id, ev)).is_err() {
                g.tap = None;
                self.active.fetch_sub(1, Ordering::Relaxed);
            }
        }
        let dead = match g.by_request.get(&id) {
            Some(tx) => tx.send(ev).is_err(),
            None => false,
        };
        if dead {
            g.by_request.remove(&id);
            self.active.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Live subscriptions (per-request channels + tap).
    pub fn subscriber_count(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subscribe_emit_receive_in_order() {
        let bus = EventBus::new();
        let rx = bus.subscribe(7);
        assert_eq!(bus.subscriber_count(), 1);
        bus.emit(7, EngineEvent::Queued { replica: 0 });
        bus.emit(7, EngineEvent::Token { index: 0, token: 42, t: 0.5 });
        bus.emit(8, EngineEvent::Queued { replica: 1 }); // not subscribed
        bus.emit(7, EngineEvent::Done { t: 1.0 });
        let evs: Vec<EngineEvent> = rx.try_iter().collect();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0], EngineEvent::Queued { replica: 0 });
        assert!(evs[2].is_terminal());
        assert_eq!(evs[1].name(), "token");
    }

    #[test]
    fn dropped_receiver_is_pruned_and_unsubscribe_idempotent() {
        let bus = EventBus::new();
        let rx = bus.subscribe(1);
        drop(rx);
        bus.emit(1, EngineEvent::Cancelled); // prunes the dead channel
        assert_eq!(bus.subscriber_count(), 0);
        bus.unsubscribe(1);
        bus.unsubscribe(1);
        assert_eq!(bus.subscriber_count(), 0);
    }

    #[test]
    fn tap_sees_every_request_in_emission_order() {
        let bus = EventBus::new();
        let tap = bus.tap();
        bus.emit(1, EngineEvent::Queued { replica: 0 });
        bus.emit(2, EngineEvent::Queued { replica: 1 });
        bus.emit(1, EngineEvent::Done { t: 0.0 });
        let all: Vec<(u64, EngineEvent)> = tap.try_iter().collect();
        assert_eq!(all.len(), 3);
        assert_eq!(all[0].0, 1);
        assert_eq!(all[1].0, 2);
        assert_eq!(all[2].0, 1);
    }

    #[test]
    fn emit_without_subscribers_is_a_noop() {
        let bus = EventBus::new();
        bus.emit(5, EngineEvent::Done { t: 0.0 }); // must not panic or leak
        assert_eq!(bus.subscriber_count(), 0);
    }
}
