//! The EdgeLoRA serving engine: ties the slot state machine, adaptive
//! adapter selection, the heterogeneous memory manager and the u-batch
//! planner to a [`ModelBackend`], and runs request traces through it.
//!
//! The loop is a discrete-event scheduler over the engine's [`Clock`]:
//! against the sim backend time is virtual (5-minute traces replay in
//! milliseconds); against the PJRT backend the same loop runs in wall time
//! with real compute. One iteration = admit arrivals → run adapter
//! selection + prompt processing for newly-admitted slots → one batched
//! decode step for every generating slot.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;

use crate::backend::{DecodeRow, ModelBackend};
use crate::config::{EngineKind, ServerConfig};
use crate::coordinator::batcher::UBatchPlan;
use crate::coordinator::selection::{select_adapter, Selection};
use crate::coordinator::slot::{Slot, SlotState};
use crate::memory::{AdapterMemoryManager, Residency};
use crate::metrics::{Recorder, Summary};
use crate::router::{AdapterRouter, RouterPrompt};
use crate::util::time::Clock;
use crate::workload::{Trace, TraceRequest};

/// Aggregate engine statistics beyond the per-request recorder.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub decode_steps: u64,
    pub decode_rows: u64,
    pub ubatch_groups: u64,
    pub router_passes: u64,
    pub adapter_loads: u64,
}

impl EngineStats {
    /// Mean decode batch occupancy (the quantity batching LoRA inference
    /// exists to maximize).
    pub fn mean_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_rows as f64 / self.decode_steps as f64
        }
    }
}

pub struct EdgeLoraEngine {
    backend: Box<dyn ModelBackend>,
    memory: AdapterMemoryManager,
    router: Box<dyn AdapterRouter>,
    clock: Arc<dyn Clock>,
    cfg: ServerConfig,
    slots: Vec<Slot>,
    queue: VecDeque<TraceRequest>,
    pub recorder: Arc<Recorder>,
    pub stats: EngineStats,
}

impl EdgeLoraEngine {
    pub fn new(
        backend: Box<dyn ModelBackend>,
        memory: AdapterMemoryManager,
        router: Box<dyn AdapterRouter>,
        clock: Arc<dyn Clock>,
        cfg: ServerConfig,
    ) -> Self {
        let width = backend.decode_batch_width();
        let n_slots = cfg.slots.min(width);
        assert!(n_slots > 0, "no slots");
        let slots = (0..n_slots).map(|i| Slot::new(i, i)).collect();
        Self {
            backend,
            memory,
            router,
            clock,
            cfg,
            slots,
            queue: VecDeque::new(),
            recorder: Arc::new(Recorder::new()),
            stats: EngineStats::default(),
        }
    }

    pub fn memory(&self) -> &AdapterMemoryManager {
        &self.memory
    }

    pub fn backend(&self) -> &dyn ModelBackend {
        self.backend.as_ref()
    }

    pub fn backend_mut(&mut self) -> &mut Box<dyn ModelBackend> {
        &mut self.backend
    }

    /// Warm the cache with the first `n` adapters (server init, §4.2).
    pub fn warm_cache(&mut self, ids: impl IntoIterator<Item = u64>) -> Result<()> {
        let resident: Vec<u64> = ids
            .into_iter()
            .take(self.memory.capacity())
            .collect();
        for id in resident {
            if let Residency::Loaded { resident, .. } = self.memory.ensure_resident(id)? {
                let w = self.memory.read_weights(id).expect("just loaded");
                self.backend.load_adapter(resident.bank_slot, &w)?;
            }
        }
        Ok(())
    }

    /// Run a whole trace to completion; returns the paper's summary metrics.
    pub fn run_trace(&mut self, trace: &Trace) -> Result<Summary> {
        let mut pending: VecDeque<TraceRequest> = trace.requests.iter().cloned().collect();
        let start = self.clock.now();
        loop {
            let now = self.clock.now() - start;
            // 1. admit arrivals whose time has come
            while pending
                .front()
                .is_some_and(|r| r.arrival_s <= now)
            {
                self.queue.push_back(pending.pop_front().unwrap());
            }
            // 2. move queued requests into idle slots
            self.fill_slots(start)?;
            // 3. adapter selection + prompt processing for admitted slots
            self.process_new_slots(start)?;
            // 4. one decode step over all generating slots
            let worked = self.decode_tick(start)?;
            // 5. if nothing is active, jump to the next arrival
            if !worked && self.queue.is_empty() {
                match pending.front() {
                    Some(r) => {
                        let target = start + r.arrival_s;
                        let now_abs = self.clock.now();
                        if target > now_abs {
                            self.clock.advance(target - now_abs);
                        }
                    }
                    None => break, // drained
                }
            }
        }
        Ok(self.recorder.summarize(Some(trace.duration_s.max(
            self.clock.now() - start,
        ))))
    }

    fn fill_slots(&mut self, start: f64) -> Result<()> {
        for i in 0..self.slots.len() {
            if self.queue.is_empty() {
                break;
            }
            if self.slots[i].is_idle() {
                let req = self.queue.pop_front().unwrap();
                let now = self.clock.now() - start;
                let prompt = synth_prompt(&req, self.backend.max_prompt_tokens());
                let explicit = match self.cfg.engine {
                    // w/o AAS: every request must name its adapter (§5
                    // baseline definition) — the trace's ground truth.
                    EngineKind::EdgeLoraNoAas => {
                        Some(req.explicit_adapter.unwrap_or(req.true_adapter))
                    }
                    _ => req.explicit_adapter,
                };
                self.slots[i].admit(
                    req.id,
                    prompt,
                    explicit,
                    req.true_adapter,
                    req.output_tokens,
                    req.arrival_s,
                    now,
                );
            }
        }
        Ok(())
    }

    fn process_new_slots(&mut self, start: f64) -> Result<()> {
        for i in 0..self.slots.len() {
            if self.slots[i].state != SlotState::AdapterSelection {
                continue;
            }
            // --- Algorithm 1 ---
            let prompt = RouterPrompt {
                tokens: self.slots[i].prompt.clone(),
                latent_task: Some(self.slots[i].true_adapter as usize),
            };
            let explicit = self.slots[i].explicit_adapter;
            let selection = if explicit.is_none() {
                // the router forward pass costs one prompt decode (§4.1)
                self.stats.router_passes += 1;
                let head = self.backend.router_pass(&prompt.tokens)?;
                match head {
                    Some(raw) => {
                        // map head outputs onto logical adapter ids (the head
                        // width is a static artifact property; the adapter
                        // set size comes from the configured router)
                        let n_adapters = self.router.scores(&prompt).len();
                        let mapper = crate::router::pjrt::HeadScoreMapper::identity(
                            n_adapters,
                            raw.len(),
                        );
                        let snap = crate::router::pjrt::SnapshotRouter {
                            scores: mapper.expand(&raw),
                        };
                        select_adapter(&prompt, None, &snap, &self.memory, self.cfg.top_k)
                    }
                    None => select_adapter(
                        &prompt,
                        None,
                        self.router.as_ref(),
                        &self.memory,
                        self.cfg.top_k,
                    ),
                }
            } else {
                select_adapter(
                    &prompt,
                    explicit,
                    self.router.as_ref(),
                    &self.memory,
                    self.cfg.top_k,
                )
            };
            let bank_slot = self.ensure_loaded(&selection)?;
            let auto = selection.auto;
            let cached = selection.cached;
            self.slots[i].adapter_selected(selection.adapter, bank_slot, cached, auto);

            // --- prompt processing ---
            let row = self.slots[i].row;
            let first =
                self.backend
                    .prefill(row, &self.slots[i].prompt.clone(), bank_slot)?;
            let now = self.clock.now() - start;
            self.slots[i].prompt_done(first, now);
            // single-token requests complete at prefill
            if self.slots[i].generated >= self.slots[i].target_tokens {
                self.slots[i].record.finished = now;
                let rec = self.slots[i].release();
                self.backend.release_row(row)?;
                self.recorder.complete(&rec);
            }
        }
        Ok(())
    }

    /// Make the selected adapter resident + uploaded; returns its bank slot.
    fn ensure_loaded(&mut self, sel: &Selection) -> Result<usize> {
        match self.memory.ensure_resident(sel.adapter)? {
            Residency::Hit(r) => Ok(r.bank_slot),
            Residency::Loaded { resident, .. } => {
                self.stats.adapter_loads += 1;
                let w = self
                    .memory
                    .read_weights(sel.adapter)
                    .expect("just loaded");
                self.backend.load_adapter(resident.bank_slot, &w)?;
                Ok(resident.bank_slot)
            }
        }
    }

    /// One batched decode step. Returns whether any work happened.
    fn decode_tick(&mut self, start: f64) -> Result<bool> {
        let mut rows: Vec<DecodeRow> = Vec::new();
        let mut slot_of_row: Vec<usize> = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            if s.state == SlotState::Generation {
                rows.push(DecodeRow {
                    row: s.row,
                    token: s.last_token,
                    pos: s.position() + 1,
                    bank_slot: s.bank_slot,
                });
                slot_of_row.push(i);
            }
        }
        if rows.is_empty() {
            return Ok(false);
        }
        // §3.4: group rows by adapter (u-batches) before the backend call.
        let plan = UBatchPlan::build(&rows);
        self.stats.decode_steps += 1;
        self.stats.decode_rows += rows.len() as u64;
        self.stats.ubatch_groups += plan.n_groups() as u64;
        let sorted = plan.sorted_rows(&rows);
        let toks_sorted = self.backend.decode_step(&sorted)?;
        let toks = plan.scatter(&toks_sorted);
        let now = self.clock.now() - start;
        for (k, &slot_idx) in slot_of_row.iter().enumerate() {
            let done = self.slots[slot_idx].token_generated(toks[k], now);
            if done {
                let row = self.slots[slot_idx].row;
                let rec = self.slots[slot_idx].release();
                self.backend.release_row(row)?;
                self.recorder.complete(&rec);
            }
        }
        Ok(true)
    }
}

/// Deterministic synthetic prompt for a trace request (token values don't
/// affect scheduling; the *length* does). Task-banded like
/// `TaskWorld::sample_prompt` so the PJRT router head sees structure.
pub fn synth_prompt(req: &TraceRequest, max_len: usize) -> Vec<u32> {
    let len = req.input_tokens.clamp(1, max_len);
    let mut h = 0x5eedu64 ^ req.id;
    (0..len)
        .map(|_| {
            h = h.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (1 + (req.true_adapter * 97) as u64 + (h >> 33) % 50) as u32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::{AdapterStore, LoraShape};
    use crate::backend::devices::DeviceProfile;
    use crate::backend::sim::SimBackend;
    use crate::config::{ModelSetting, WorkloadConfig};
    use crate::memory::CachePolicy;
    use crate::quant::QuantType;
    use crate::router::confidence::{TaskModelRouter, TaskWorld};
    use crate::util::time::VirtualClock;
    use crate::workload::generate;

    const SHAPE: LoraShape = LoraShape {
        n_layers: 2,
        d_model: 16,
        rank: 4,
    };

    fn mk_engine(
        n_adapters: usize,
        slots: usize,
        engine: EngineKind,
        tag: &str,
    ) -> EdgeLoraEngine {
        let dir = std::env::temp_dir().join(format!(
            "elra_engine_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = AdapterStore::create(&dir, SHAPE, QuantType::Q8_0).unwrap();
        store.populate_synthetic(n_adapters).unwrap();
        let store = Arc::new(store);
        let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
        let cache_cap = 8usize.min(n_adapters).max(2);
        let backend = SimBackend::new(
            DeviceProfile::agx_orin(),
            ModelSetting::s3(),
            clock.clone(),
            slots,
            cache_cap,
            None,
        )
        .unwrap();
        let memory = AdapterMemoryManager::new(store, cache_cap, CachePolicy::Lru);
        let world = TaskWorld::synthetic(n_adapters, 4, 1);
        let router = TaskModelRouter::new(world.acc.clone(), 0.95, 2);
        EdgeLoraEngine::new(
            Box::new(backend),
            memory,
            Box::new(router),
            clock,
            ServerConfig {
                slots,
                top_k: 3,
                cache_capacity: Some(cache_cap),
                engine,
            },
        )
    }

    fn short_trace(n_adapters: usize, rate: f64, dur: f64) -> Trace {
        generate(&WorkloadConfig {
            n_adapters,
            rate,
            duration_s: dur,
            input_range: (8, 32),
            output_range: (4, 16),
            ..WorkloadConfig::default()
        })
    }

    #[test]
    fn completes_every_request() {
        let mut e = mk_engine(10, 4, EngineKind::EdgeLora, "complete");
        let trace = short_trace(10, 2.0, 30.0);
        let n = trace.len() as u64;
        let summary = e.run_trace(&trace).unwrap();
        assert_eq!(summary.requests, n, "no request may be lost");
        assert!(summary.throughput_rps > 0.0);
        assert!(summary.avg_latency_s > 0.0);
        assert!(summary.avg_first_token_s <= summary.avg_latency_s);
    }

    #[test]
    fn batching_occurs_under_load() {
        // offered load well above single-slot capacity ⇒ slots fill up and
        // decode steps carry multiple rows (batch LoRA inference engaged)
        let mut e = mk_engine(4, 8, EngineKind::EdgeLora, "batch");
        let trace = short_trace(4, 60.0, 10.0);
        e.run_trace(&trace).unwrap();
        assert!(
            e.stats.mean_batch() > 1.5,
            "mean batch {} too small under 60 req/s",
            e.stats.mean_batch()
        );
    }

    #[test]
    fn no_aas_uses_true_adapter_and_skips_router() {
        let mut e = mk_engine(10, 4, EngineKind::EdgeLoraNoAas, "noaas");
        let trace = short_trace(10, 1.0, 20.0);
        e.run_trace(&trace).unwrap();
        assert_eq!(e.stats.router_passes, 0);
    }

    #[test]
    fn aas_runs_router_per_auto_request() {
        let mut e = mk_engine(10, 4, EngineKind::EdgeLora, "aas");
        let trace = short_trace(10, 1.0, 20.0);
        let n = trace.len() as u64;
        e.run_trace(&trace).unwrap();
        assert_eq!(e.stats.router_passes, n);
    }

    #[test]
    fn cache_hit_rate_rises_with_locality() {
        let run = |alpha: f64| {
            let mut e = mk_engine(32, 4, EngineKind::EdgeLoraNoAas, &format!("loc{alpha}"));
            let trace = generate(&WorkloadConfig {
                n_adapters: 32,
                alpha,
                rate: 2.0,
                duration_s: 60.0,
                input_range: (8, 16),
                output_range: (4, 8),
                ..WorkloadConfig::default()
            });
            e.run_trace(&trace).unwrap().cache_hit_rate
        };
        let low = run(0.1);
        let high = run(3.0);
        assert!(high > low, "hit rate: alpha3 {high} vs alpha0.1 {low}");
    }

    #[test]
    fn warm_cache_preloads() {
        let mut e = mk_engine(10, 4, EngineKind::EdgeLora, "warm");
        e.warm_cache(0..8).unwrap();
        assert_eq!(e.memory().resident_count(), 8);
    }

    #[test]
    fn more_slots_more_throughput() {
        // overload: a single slot cannot drain the queue within the trace,
        // so the run stretches past the nominal duration and throughput
        // (n / actual span) drops — Table 14's mechanism.
        let run = |slots: usize| {
            let mut e = mk_engine(8, slots, EngineKind::EdgeLoraNoAas, &format!("sl{slots}"));
            let trace = short_trace(8, 40.0, 20.0);
            e.run_trace(&trace).unwrap()
        };
        let t1 = run(1);
        let t8 = run(8);
        assert!(
            t8.throughput_rps > t1.throughput_rps,
            "slots 8 {} vs 1 {}",
            t8.throughput_rps,
            t1.throughput_rps
        );
        assert!(t8.avg_latency_s < t1.avg_latency_s);
    }

    #[test]
    fn empty_trace_is_fine() {
        let mut e = mk_engine(4, 2, EngineKind::EdgeLora, "empty");
        let trace = Trace {
            requests: vec![],
            duration_s: 1.0,
            n_adapters: 4,
        };
        let s = e.run_trace(&trace).unwrap();
        assert_eq!(s.requests, 0);
    }
}
