//! The EdgeLoRA serving engine: ties the slot state machine, adaptive
//! adapter selection, the heterogeneous memory manager and the u-batch
//! planner to a [`ModelBackend`], and runs request traces through it.
//!
//! The loop is a discrete-event scheduler over the engine's [`Clock`]:
//! against the sim backend time is virtual (5-minute traces replay in
//! milliseconds); against the PJRT backend the same loop runs in wall time
//! with real compute. One iteration = admit arrivals → adopt/issue adapter
//! prefetches for queued requests → run adapter selection + prompt
//! processing for newly-admitted slots → one batched decode step for every
//! generating slot.
//!
//! Two hot-path properties this module maintains (DESIGN.md §Perf):
//!   * an adapter cache miss is *zero-copy quantized*: one disk read into a
//!     pool block + one dequantize at bank upload — no `flatten`/`unflatten`
//!     round trips (see [`AdapterMemoryManager`]);
//!   * a steady-state decode tick performs no heap allocation: all per-tick
//!     buffers live in a reused [`DecodeScratch`].
//!
//! Since the cluster refactor (DESIGN.md §Cluster) the loop is externally
//! steppable: [`EdgeLoraEngine::push_request`] enqueues work,
//! [`EdgeLoraEngine::step`] runs one scheduler iteration, and
//! [`EdgeLoraEngine::drain`] runs to quiescence. `run_trace` is now a thin
//! driver over that API; the cluster scheduler interleaves many engines
//! event-by-event in clock order through the same methods.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::backend::{DecodeRow, ModelBackend};
use crate::config::{EngineKind, ServerConfig};
use crate::coordinator::batcher::UBatchPlan;
use crate::coordinator::events::{EngineEvent, EventBus, RequestId};
use crate::coordinator::selection::{select_adapter, Selection};
use crate::coordinator::slot::{Slot, SlotState};
use crate::memory::{
    kv_entry, pages_for, AdapterMemoryManager, KvEnsure, KvTable, PageId, PrefixCache,
    Residency, SharedPages,
};
use crate::metrics::{Recorder, Summary};
use crate::router::{AdapterRouter, RouterPrompt};
use crate::util::rng::splitmix64;
use crate::util::time::Clock;
use crate::workload::{QosClass, Trace, TraceRequest};

/// Aggregate engine statistics beyond the per-request recorder.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub decode_steps: u64,
    pub decode_rows: u64,
    pub ubatch_groups: u64,
    pub router_passes: u64,
    pub adapter_loads: u64,
    /// background adapter reads issued for queued requests
    pub prefetch_issued: u64,
    /// loads whose disk half was (partly) covered by a prefetch overlap
    pub prefetch_hits: u64,
    /// KV appends by decoding rows (paged mode; one per row per tick)
    pub kv_appends: u64,
    /// KV appends that crossed a page boundary and took a page off the
    /// unified free list
    pub kv_page_faults: u64,
    /// admissions deferred because the page pool could not cover
    /// prompt-pages + one decode page after shrinking the adapter cache
    pub kv_admission_deferrals: u64,
    /// requests preempted-and-requeued under page pressure (last resort
    /// after adapter-cache shrinking; recomputed deterministically)
    pub preemptions: u64,
    /// requests cancelled by the client (queue or slot; resources released)
    pub cancelled: u64,
    /// admissions that consulted the prefix radix (paged + sharing enabled
    /// + adapter known at admission)
    pub prefix_lookups: u64,
    /// admissions that mapped at least one shared prompt page
    pub prefix_hits: u64,
    /// cumulative prompt pages mapped shared instead of allocated
    pub shared_prompt_pages: u64,
    /// cumulative pages newly reserved at admission (the quantity prefix
    /// sharing shrinks — the capacity ablation's headline column)
    pub prompt_pages_charged: u64,
    /// shared tail pages copy-on-write forked by a first decode write
    pub cow_forks: u64,
    /// radix pages reclaimed by the pressure ladder (refcount-1 only)
    pub prefix_reclaims: u64,
    /// order-sensitive checksum of every token the engine emitted — the
    /// bit-identity witness for the preempt-and-recompute determinism test
    pub token_checksum: u64,
}

impl EngineStats {
    /// Mean decode batch occupancy (the quantity batching LoRA inference
    /// exists to maximize).
    pub fn mean_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_rows as f64 / self.decode_steps as f64
        }
    }

    /// Fraction of sharing-eligible admissions that mapped a cached prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }
}

/// Per-tick buffers reused across decode steps so the steady-state loop
/// never touches the allocator (asserted by `scratch_footprint` tests and
/// the `engine/decode_tick` bench).
#[derive(Default)]
struct DecodeScratch {
    rows: Vec<DecodeRow>,
    slot_of_row: Vec<usize>,
    plan: UBatchPlan,
    /// u-batch plan invalidation flag: the plan depends only on which slots
    /// are generating and their bank slots, so it is rebuilt only when a
    /// slot enters or leaves Generation (prefill done, completion, cancel,
    /// preempt) — steady-state ticks reuse the cached grouping for free
    /// (`batcher/plan reuse` bench)
    plan_dirty: bool,
    sorted: Vec<DecodeRow>,
    toks_sorted: Vec<u32>,
    toks: Vec<u32>,
    /// inter-token gaps of this tick (tagged with the emitting slot's QoS
    /// class), flushed to the recorder in one lock acquisition (never lock
    /// the shared recorder per token)
    itl: Vec<(f64, QosClass)>,
}

/// Unified-paging state (DESIGN.md §Unified paging): the page allocator the
/// adapter pool shares, the page geometry, and one lazily-grown KV page
/// table per slot. Present only when the memory manager was built
/// page-backed and the backend exposes its per-token KV cost.
struct KvPaging {
    pages: SharedPages,
    /// KV positions per page (page_bytes / backend.kv_bytes_per_token())
    page_tokens: usize,
    /// per-slot page tables, preallocated to the worst-case request so the
    /// steady-state append path never heap-allocates
    tables: Vec<KvTable>,
    /// per-(adapter, prompt-prefix-hash) radix of immutable prompt pages
    /// (DESIGN.md §Prefix sharing): admission maps matching chains instead
    /// of allocating; prefill donates its prompt pages back
    prefix: PrefixCache,
    /// `cfg.prefix_share` — sharing off keeps the radix empty (ablation)
    share: bool,
    /// reusable lookup scratch (the matched page chain)
    chain: Vec<PageId>,
}

pub struct EdgeLoraEngine {
    backend: Box<dyn ModelBackend>,
    memory: AdapterMemoryManager,
    router: Box<dyn AdapterRouter>,
    clock: Arc<dyn Clock>,
    cfg: ServerConfig,
    slots: Vec<Slot>,
    queue: VecDeque<TraceRequest>,
    scratch: DecodeScratch,
    /// unified paged memory: Some iff the pool is page-backed, the backend
    /// reports a KV cost, and `cfg.paged` is set
    kv: Option<KvPaging>,
    /// auto (AAS) requests the prefetch planner already scored, mapped to
    /// the candidate it chose — avoids re-scoring every iteration while
    /// still letting a dropped/refused speculative read be re-issued cheaply
    prefetch_planned: BTreeMap<u64, u64>,
    /// per-slot selection awaiting a pool block (`Residency::Deferred`): the
    /// router pass is charged once, not once per retry
    deferred_selection: Vec<Option<Selection>>,
    /// true when the backend carries a learned router head: AAS selection
    /// then ignores the fallback router, so speculative prefetch planning
    /// (which only has the fallback) stands down. Seeded from the backend's
    /// capability and also latched if a head unexpectedly produces scores.
    router_head_active: bool,
    /// clock value at trace start: request-relative timestamps subtract this
    origin: f64,
    /// request-lifecycle event fabric (DESIGN.md §Serving API); cluster
    /// replicas share one bus the same way they share one recorder
    events: Arc<EventBus>,
    /// adapters pinned through the registry (`POST /v1/adapters/{id}/pin`):
    /// tracked separately from per-request pins so an unpin can never
    /// release a pin a live slot still depends on
    registry_pins: BTreeSet<u64>,
    /// weighted-fair-queueing virtual-finish counters: admissions charged
    /// per class (DESIGN.md §QoS & overload); only consulted while the
    /// queue holds both classes, so single-class traces are untouched
    served_interactive: u64,
    served_batch: u64,
    /// EWMA of observed first-token latency (0 until the first completion)
    /// — the evidence the cluster's deadline admission check consumes
    ewma_ttft_s: f64,
    pub recorder: Arc<Recorder>,
    pub stats: EngineStats,
}

impl EdgeLoraEngine {
    pub fn new(
        backend: Box<dyn ModelBackend>,
        mut memory: AdapterMemoryManager,
        router: Box<dyn AdapterRouter>,
        clock: Arc<dyn Clock>,
        cfg: ServerConfig,
    ) -> Self {
        let width = backend.decode_batch_width();
        let backend_has_head = backend.has_router_head();
        let n_slots = cfg.slots.min(width);
        assert!(n_slots > 0, "no slots");
        let slots = (0..n_slots).map(|i| Slot::new(i, i)).collect();
        if cfg.prefetch {
            let depth = cfg
                .prefetch_depth
                .min(memory.capacity().saturating_sub(1))
                .max(1);
            memory.enable_prefetch(2, depth);
        }
        // Unified paging engages when the pool is page-backed and the
        // backend prices KV positions; otherwise the engine keeps the
        // static-headroom behavior (legacy pools, PJRT).
        let kv = if cfg.paged {
            memory.shared_pages().and_then(|pages| {
                let kv_tok = backend.kv_bytes_per_token();
                if kv_tok == 0 {
                    return None;
                }
                let page_tokens = (pages.page_bytes() / kv_tok).max(1);
                let per_slot = backend.max_positions().div_ceil(page_tokens) + 1;
                Some(KvPaging {
                    pages,
                    page_tokens,
                    tables: (0..n_slots).map(|_| KvTable::with_capacity(per_slot)).collect(),
                    prefix: PrefixCache::new(),
                    share: cfg.prefix_share,
                    chain: Vec::with_capacity(per_slot),
                })
            })
        } else {
            None
        };
        Self {
            backend,
            memory,
            router,
            clock,
            cfg,
            queue: VecDeque::new(),
            scratch: DecodeScratch::default(),
            kv,
            prefetch_planned: BTreeMap::new(),
            deferred_selection: vec![None; n_slots],
            router_head_active: backend_has_head,
            origin: 0.0,
            events: Arc::new(EventBus::new()),
            registry_pins: BTreeSet::new(),
            served_interactive: 0,
            served_batch: 0,
            ewma_ttft_s: 0.0,
            slots,
            recorder: Arc::new(Recorder::new()),
            stats: EngineStats::default(),
        }
    }

    pub fn memory(&self) -> &AdapterMemoryManager {
        &self.memory
    }

    /// Whether unified paged memory is active for this engine.
    pub fn paged(&self) -> bool {
        self.kv.is_some()
    }

    /// Free pages in the unified allocator (0 when unpaged). Published to
    /// the cluster scoreboard and `GET /cluster`.
    pub fn free_pages(&self) -> usize {
        self.memory
            .shared_pages()
            .map_or(0, |p| p.free_pages())
    }

    /// Total pages in the unified allocator (0 when unpaged).
    pub fn total_pages(&self) -> usize {
        self.memory.shared_pages().map_or(0, |p| p.n_pages())
    }

    /// Pages currently mapped by slot KV tables.
    pub fn kv_pages_in_use(&self) -> usize {
        self.kv
            .as_ref()
            .map_or(0, |kv| kv.tables.iter().map(|t| t.len()).sum())
    }

    /// Pages currently held by the prefix radix (each carries one radix
    /// reference; reclaimable under pressure only at refcount 1).
    pub fn prefix_pages_held(&self) -> usize {
        self.kv.as_ref().map_or(0, |kv| kv.prefix.pages_held())
    }

    /// Fraction of sharing-eligible admissions that hit the prefix radix.
    pub fn prefix_hit_rate(&self) -> f64 {
        self.stats.prefix_hit_rate()
    }

    /// First-page boundary hashes of every cached prefix chain — the
    /// prefix-affinity scoreboard entry (DESIGN.md §Distributed serving).
    /// Empty when paging is off. Clears `out` first.
    pub fn prefix_first_page_hashes(&self, out: &mut Vec<u64>) {
        out.clear();
        if let Some(kv) = self.kv.as_ref() {
            kv.prefix.first_page_hashes(out);
        }
    }

    /// KV positions per unified page (0 when unpaged) — the cluster's
    /// steal gate uses this to price a stolen request's prompt.
    pub fn kv_page_tokens(&self) -> usize {
        self.kv.as_ref().map_or(0, |kv| kv.page_tokens)
    }

    /// The request `steal_newest` would take, if any (steal planning).
    pub fn peek_newest(&self) -> Option<&TraceRequest> {
        self.queue.back()
    }

    /// Capacities of every KV page table — the steady-state KV-append path
    /// must leave these untouched (no per-append heap allocation), the
    /// paging analogue of `scratch_footprint`.
    pub fn kv_footprint(&self) -> Vec<usize> {
        self.kv
            .as_ref()
            .map_or_else(Vec::new, |kv| {
                kv.tables.iter().map(|t| t.page_capacity()).collect()
            })
    }

    pub fn backend(&self) -> &dyn ModelBackend {
        self.backend.as_ref()
    }

    pub fn backend_mut(&mut self) -> &mut Box<dyn ModelBackend> {
        &mut self.backend
    }

    // --- dynamic adapter registry (DESIGN.md §Serving API) ---

    /// Registry pin: make `id` resident, upload its bank slot, and exclude
    /// it from eviction until `unpin_adapter`. Ok(false) = the load must
    /// defer (every pool block pinned right now) — the caller may retry.
    /// Idempotent: pinning a registry-pinned adapter is a no-op success.
    pub fn pin_adapter(&mut self, id: u64) -> Result<bool> {
        if self.registry_pins.contains(&id) {
            return Ok(true);
        }
        match self.memory.ensure_resident(id)? {
            Residency::Hit(_) => {}
            Residency::Loaded { resident, .. } => {
                self.stats.adapter_loads += 1;
                let view = self.memory.quant_view(id).expect("just loaded");
                self.backend.load_adapter(resident.bank_slot, &view)?;
            }
            Residency::Deferred => return Ok(false),
        }
        self.memory.pin(id);
        self.registry_pins.insert(id);
        Ok(true)
    }

    /// Release a registry pin (per-request pins are untouched). Returns
    /// whether a registry pin existed.
    pub fn unpin_adapter(&mut self, id: u64) -> bool {
        if self.registry_pins.remove(&id) {
            self.memory.unpin(id);
            true
        } else {
            false
        }
    }

    /// Whether the registry holds a pin on `id` for this replica.
    pub fn registry_pinned(&self, id: u64) -> bool {
        self.registry_pins.contains(&id)
    }

    /// Remove a deleted adapter from this replica: drops cache residency
    /// (block and pages back to the pool) and any speculative prefetch.
    /// The caller drains in-flight users first (a per-request pin makes
    /// this error) and releases registry pins via `unpin_adapter`. Returns
    /// whether anything was resident here.
    pub fn purge_adapter(&mut self, id: u64) -> Result<bool> {
        debug_assert!(
            !self.registry_pins.contains(&id),
            "purge of registry-pinned adapter {id}"
        );
        // the prefix radix holds the deleted adapter's prompt pages too
        if let Some(kv) = &mut self.kv {
            kv.prefix.purge_adapter(id, &kv.pages);
        }
        self.memory.drop_adapter(id)
    }

    /// Warm the cache with the first `n` adapters (server init, §4.2).
    pub fn warm_cache(&mut self, ids: impl IntoIterator<Item = u64>) -> Result<()> {
        let resident: Vec<u64> = ids
            .into_iter()
            .take(self.memory.capacity())
            .collect();
        for id in resident {
            if let Residency::Loaded { resident, .. } = self.memory.ensure_resident(id)? {
                let view = self.memory.quant_view(id).expect("just loaded");
                self.backend.load_adapter(resident.bank_slot, &view)?;
            }
        }
        Ok(())
    }

    // --- externally-steppable API (the cluster scheduler drives this) ---

    /// Mark the current clock value as t=0 for request-relative timestamps.
    /// Replicas built on fresh virtual clocks can skip this (origin 0).
    pub fn begin(&mut self) {
        self.origin = self.clock.now();
    }

    /// Engine-relative current time (seconds since `begin`).
    pub fn local_now(&self) -> f64 {
        self.clock.now() - self.origin
    }

    /// Enqueue one request. Admission bookkeeping assumes `req.arrival_s` is
    /// not in the engine-relative future — the caller advances the clock to
    /// the arrival instant before pushing (see `ClusterEngine::dispatch`).
    /// Emits `Queued` on the engine's event bus (so a stolen request shows a
    /// second `Queued` on the thief's shard — the stream narrates the move).
    pub fn push_request(&mut self, req: TraceRequest) {
        self.events
            .emit(req.id, EngineEvent::Queued { replica: self.memory.shard() });
        self.queue.push_back(req);
    }

    /// Submit one request to the streaming lifecycle API: subscribe to the
    /// returned id on [`Self::events`] *before* calling this to observe the
    /// full Queued → Admitted → Token… → Done stream. The one-shot
    /// `push_request` contract rides the same path — `submit` is the
    /// front-door name the HTTP layer and cluster dispatch use.
    pub fn submit(&mut self, req: TraceRequest) -> RequestId {
        let id = req.id;
        self.push_request(req);
        id
    }

    /// Cancel a queued or in-flight request, releasing its slot, KV pages
    /// and pool pins deterministically. Returns false when the id is not
    /// present (already completed, cancelled, or never submitted). Emits
    /// `Cancelled`; nothing reaches the completion recorder.
    pub fn cancel(&mut self, id: RequestId) -> Result<bool> {
        if let Some(pos) = self.queue.iter().position(|r| r.id == id) {
            let _ = self.queue.remove(pos);
            self.prefetch_planned.remove(&id);
            self.stats.cancelled += 1;
            self.events.emit(id, EngineEvent::Cancelled);
            return Ok(true);
        }
        for i in 0..self.slots.len() {
            if self.slots[i].is_idle() || self.slots[i].request_id != id {
                continue;
            }
            match self.slots[i].state {
                SlotState::Generation
                | SlotState::PromptProcessing
                | SlotState::Prefilling { .. } => {
                    // mirror preempt_slot: the pin and the decode row are
                    // only held from prompt processing on
                    let adapter = self.slots[i].adapter;
                    let row = self.slots[i].row;
                    self.memory.unpin(adapter);
                    self.backend.release_row(row)?;
                }
                SlotState::AdapterSelection => {
                    self.deferred_selection[i] = None;
                }
                SlotState::Idle => unreachable!("checked non-idle above"),
            }
            self.slots[i].abort();
            self.scratch.plan_dirty = true;
            self.release_kv_pages(i);
            self.stats.cancelled += 1;
            self.events.emit(id, EngineEvent::Cancelled);
            return Ok(true);
        }
        Ok(false)
    }

    /// The engine's event bus (shared-`Arc` handle): subscribe per request
    /// id, or tap the whole stream.
    pub fn events(&self) -> Arc<EventBus> {
        Arc::clone(&self.events)
    }

    /// Replace the event bus — cluster replicas share one bus so a
    /// request's events arrive on a single stream regardless of which shard
    /// serves or steals it (mirror of `share_recorder`).
    pub fn share_events(&mut self, events: Arc<EventBus>) {
        self.events = events;
    }

    /// One scheduler iteration: admit queued → prefetch pump → adapter
    /// selection + prompt processing → one batched decode step. Returns
    /// whether a decode step ran. If `has_work()`, a step always advances
    /// the clock eventually: admission leads to a prefill; a deferred
    /// selection either waits on a pinned (i.e. decoding) slot, or — in
    /// paged mode, where pages can be held with nothing pinned — is
    /// resolved by the deadlock-breaking preemption in `process_new_slots`
    /// (preempt peers until the block fits, or bail when alone), so no
    /// defer state can spin without the clock moving.
    pub fn step(&mut self) -> Result<bool> {
        self.fill_slots()?;
        self.pump_prefetch()?;
        // §Chunked prefill: one shared per-tick prompt-token budget, drained
        // first by slots already mid-prefill, then by fresh admissions —
        // a long prompt never monopolizes a tick against an older one
        let mut prefill_budget = self.tick_prefill_budget();
        self.pump_prefill(&mut prefill_budget)?;
        self.process_new_slots(&mut prefill_budget)?;
        let decoded = self.decode_tick()?;
        // a tick that advanced a chunked prefill is forward progress even
        // with nothing decoding: run_trace must not jump the clock over (or
        // exit under) a request mid-prefill
        Ok(decoded
            || self
                .slots
                .iter()
                .any(|s| matches!(s.state, SlotState::Prefilling { .. })))
    }

    /// Prompt tokens prefillable this tick: `cfg.prefill_chunk_tokens` when
    /// chunking is active (cap configured + backend resumable), else
    /// unbounded (monolithic prefill, the pre-chunking behavior — also the
    /// PJRT path, whose AOT prefill buckets cannot pause mid-prompt).
    fn tick_prefill_budget(&self) -> usize {
        if self.cfg.prefill_chunk_tokens > 0 && self.backend.supports_chunked_prefill() {
            self.cfg.prefill_chunk_tokens
        } else {
            usize::MAX
        }
    }

    /// Continue every slot parked in `Prefilling`, oldest slot index first:
    /// spend up to `budget` more prompt tokens. Intermediate chunks go
    /// through `prefill_chunk` (no token emitted); the final chunk rides
    /// `prefill_with_cached_prefix` with everything-so-far as the cached
    /// prefix, so the emitted first token is bit-identical to a monolithic
    /// prefill of the same prompt by construction.
    fn pump_prefill(&mut self, budget: &mut usize) -> Result<()> {
        for i in 0..self.slots.len() {
            let SlotState::Prefilling { next_offset } = self.slots[i].state else {
                continue;
            };
            if *budget == 0 {
                break;
            }
            let row = self.slots[i].row;
            let bank_slot = self.slots[i].bank_slot;
            let suffix = self.slots[i].prompt.len() - next_offset;
            let tokens = std::mem::take(&mut self.slots[i].prompt);
            if suffix <= *budget {
                let first = self
                    .backend
                    .prefill_with_cached_prefix(row, &tokens, bank_slot, next_offset)?;
                self.slots[i].prompt = tokens;
                *budget -= suffix;
                self.finish_prefill(i, first)?;
            } else {
                let chunk = *budget;
                self.backend.prefill_chunk(
                    row,
                    &tokens[next_offset..next_offset + chunk],
                    next_offset,
                    bank_slot,
                )?;
                self.slots[i].prompt = tokens;
                self.slots[i].prefill_progress(next_offset + chunk);
                *budget = 0;
            }
        }
        Ok(())
    }

    /// Everything that happens when a slot's prompt finishes prefilling
    /// (monolithically or via its final chunk): donate prompt pages to the
    /// prefix radix, transition to Generation, fold the first token into
    /// the checksum, record TTFT, emit the Token event, and complete
    /// single-token requests on the spot. The slot's prompt must already be
    /// restored.
    fn finish_prefill(&mut self, i: usize, first: u32) -> Result<()> {
        let adapter = self.slots[i].adapter;
        let row = self.slots[i].row;
        // donate the prompt's pages to the radix so later same-adapter
        // requests with this prefix map them instead of recomputing
        if let Some(kv) = &mut self.kv {
            if kv.share {
                kv.prefix.insert(
                    adapter,
                    &self.slots[i].prompt,
                    kv.page_tokens,
                    kv.tables[i].pages(),
                    &kv.pages,
                );
            }
        }
        let now = self.local_now();
        self.slots[i].prompt_done(first, now);
        self.scratch.plan_dirty = true;
        self.stats.token_checksum =
            self.stats.token_checksum.rotate_left(1) ^ first as u64;
        let rid = self.slots[i].request_id;
        let ttft = (now - self.slots[i].record.arrival).max(0.0);
        // evidence for deadline admission: EWMA (α = 0.2) of observed
        // first-token latency, seeded by the first observation
        self.ewma_ttft_s = if self.ewma_ttft_s == 0.0 {
            ttft
        } else {
            0.8 * self.ewma_ttft_s + 0.2 * ttft
        };
        self.recorder.record_ttft(ttft, self.slots[i].record.qos);
        self.events
            .emit(rid, EngineEvent::Token { index: 0, token: first, t: now });
        // single-token requests complete at prefill
        if self.slots[i].generated >= self.slots[i].target_tokens {
            self.slots[i].record.finished = now;
            let rec = self.slots[i].release();
            self.memory.unpin(adapter);
            self.backend.release_row(row)?;
            self.release_kv_pages(i);
            self.recorder.complete(&rec);
            self.events.emit(rid, EngineEvent::Done { t: now });
        }
        Ok(())
    }

    /// Whether any request is queued or occupying a slot.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(|s| !s.is_idle())
    }

    /// Requests admitted to the engine but not yet in a slot.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Queued requests that would be served *before* a new arrival of class
    /// `qos` (the deadline-admission predictor's queue term). With QoS off
    /// everything is FIFO, so the whole queue is ahead; with QoS on, an
    /// Interactive arrival only waits on the other Interactive requests —
    /// counting the (mostly Batch) backlog would over-shed the very class
    /// the scheduler protects, and shedding must stay conservative.
    pub fn queue_len_ahead(&self, qos: QosClass) -> usize {
        if !self.cfg.qos || qos == QosClass::Batch {
            return self.queue.len();
        }
        self.queue
            .iter()
            .filter(|r| r.qos == QosClass::Interactive)
            .count()
    }

    /// Slots currently occupied by admitted requests.
    pub fn active_slots(&self) -> usize {
        self.slots.iter().filter(|s| !s.is_idle()).count()
    }

    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// EWMA of observed first-token latency, 0 until the first prefill
    /// completes. The cluster's deadline-aware admission reads this: a cold
    /// engine (0) never sheds — evidence before denial.
    pub fn ewma_ttft_s(&self) -> f64 {
        self.ewma_ttft_s
    }

    /// Give up the most recently queued request (work stealing donates from
    /// the queue tail: those requests have waited least and carry no engine
    /// state yet). Keeps the prefetch planner consistent.
    pub fn steal_newest(&mut self) -> Option<TraceRequest> {
        let req = self.queue.pop_back()?;
        self.prefetch_planned.remove(&req.id);
        Some(req)
    }

    /// Dead-shard evacuation (DESIGN.md §Failure model): preempt every
    /// occupied slot through the standard preempt→requeue teardown (pins,
    /// decode rows and KV pages all released; `Preempted`/`Requeued`
    /// emitted), then take the whole queue. The cluster re-dispatches the
    /// returned requests onto live shards; recompute is deterministic, so a
    /// rehomed request's token stream is bit-identical to an undisturbed
    /// run. Queue order: preempted slots land at the front (newest-admitted
    /// first, the `preempt_slot` contract), ahead of the never-admitted
    /// backlog.
    pub fn evacuate(&mut self) -> Result<Vec<TraceRequest>> {
        for j in 0..self.slots.len() {
            if !self.slots[j].is_idle() {
                self.preempt_slot(j)?;
            }
        }
        self.reset_transients();
        Ok(self.queue.drain(..).collect())
    }

    /// Drop every prefix-radix entry, releasing the radix reference on each
    /// page (dead-shard restart: the radix is rebuilt on demand — a page
    /// still mapped by a live slot survives until that slot releases it).
    /// Returns entries dropped; no-op when unpaged.
    pub fn clear_prefix_cache(&mut self) -> usize {
        match &mut self.kv {
            Some(kv) => kv.prefix.clear(&kv.pages),
            None => 0,
        }
    }

    /// Cluster-aware prefetch hint: the dispatcher calls this on the chosen
    /// replica *before* pushing the request, so the adapter's disk read
    /// overlaps the queueing delay instead of waiting for the replica's own
    /// planner to reach the request. Explicit requests hint their adapter;
    /// AAS requests score the router's top-k and hint the top candidate
    /// unless one is already resident or in flight (same policy as
    /// `pump_prefetch`, whose head-router guard also applies).
    pub fn prefetch_hint(&mut self, req: &TraceRequest) {
        if !self.memory.prefetch_enabled() {
            return;
        }
        let now = self.clock.now();
        self.plan_request_prefetch(req, now);
    }

    /// The speculation policy for one queued request — the single home
    /// shared by the per-step planner (`pump_prefetch`) and the cluster's
    /// dispatch-time hint (`prefetch_hint`). Explicit requests issue their
    /// adapter; AAS requests reuse an earlier scoring if present, otherwise
    /// score the router's top-k and fetch the top candidate unless one is
    /// already resident or in flight. Stands down when the backend carries a
    /// learned router head (selection would use a different model).
    fn plan_request_prefetch(&mut self, req: &TraceRequest, now: f64) {
        match self.effective_adapter(req) {
            Some(id) => {
                if self.memory.prefetch(id, now) {
                    self.stats.prefetch_issued += 1;
                }
            }
            None => {
                if self.router_head_active {
                    return; // selection will use the learned head, not this router
                }
                if let Some(&cand) = self.prefetch_planned.get(&req.id) {
                    // already scored: cheaply re-issue if the earlier
                    // speculative read was refused or dropped under
                    // pressure (prefetch() dedups residents/in-flight)
                    if self.memory.prefetch(cand, now) {
                        self.stats.prefetch_issued += 1;
                    }
                    return;
                }
                let prompt = RouterPrompt {
                    tokens: synth_prompt(req, self.backend.max_prompt_tokens()),
                    latent_task: Some(req.true_adapter as usize),
                };
                let candidates = self.router.top_k(&prompt, self.cfg.top_k.max(1));
                let covered = candidates
                    .iter()
                    .any(|&c| self.memory.is_resident(c) || self.memory.is_prefetching(c));
                self.prefetch_planned.insert(req.id, candidates[0]);
                if !covered && self.memory.prefetch(candidates[0], now) {
                    self.stats.prefetch_issued += 1;
                }
            }
        }
    }

    /// Step until nothing is queued or in flight, then clear per-trace
    /// planner state.
    pub fn drain(&mut self) -> Result<()> {
        while self.has_work() {
            self.step()?;
        }
        self.reset_transients();
        Ok(())
    }

    fn reset_transients(&mut self) {
        self.prefetch_planned.clear();
        for d in &mut self.deferred_selection {
            *d = None;
        }
    }

    /// Replace the recorder — cluster replicas share one `Recorder` so
    /// latency percentiles aggregate across the whole fleet.
    pub fn share_recorder(&mut self, recorder: Arc<Recorder>) {
        self.recorder = recorder;
    }

    /// Run a whole trace to completion; returns the paper's summary metrics.
    /// A thin driver over the steppable API: admit due arrivals, step, and
    /// jump the clock across idle gaps.
    pub fn run_trace(&mut self, trace: &Trace) -> Result<Summary> {
        let mut pending: VecDeque<TraceRequest> = trace.requests.iter().cloned().collect();
        self.begin();
        loop {
            let now = self.local_now();
            // admit arrivals whose time has come
            while pending
                .front()
                .is_some_and(|r| r.arrival_s <= now)
            {
                self.push_request(pending.pop_front().unwrap());
            }
            let worked = self.step()?;
            // if nothing is active, jump to the next arrival
            if !worked && self.queue.is_empty() {
                match pending.front() {
                    Some(r) => {
                        let target = self.origin + r.arrival_s;
                        let now_abs = self.clock.now();
                        if target > now_abs {
                            self.clock.advance(target - now_abs);
                        }
                    }
                    None => break, // drained
                }
            }
        }
        self.reset_transients();
        let mut summary = self
            .recorder
            .summarize(Some(trace.duration_s.max(self.local_now())));
        summary.prefix_hit_rate = self.prefix_hit_rate();
        summary.shared_kv_pages = self.stats.shared_prompt_pages;
        Ok(summary)
    }

    /// The adapter a request is bound to before selection runs: its explicit
    /// id, or (w/o AAS, §5 baseline definition) the trace's ground truth.
    /// None = adaptive adapter selection decides at schedule time.
    fn effective_adapter(&self, req: &TraceRequest) -> Option<u64> {
        match self.cfg.engine {
            EngineKind::EdgeLoraNoAas => {
                Some(req.explicit_adapter.unwrap_or(req.true_adapter))
            }
            _ => req.explicit_adapter,
        }
    }

    /// The queue position the next admission takes. With `cfg.qos` off —
    /// or whenever the queue holds a single class — this is the head (FIFO,
    /// bit-identical to the pre-QoS engine). With both classes queued,
    /// weighted fair queueing picks the class whose virtual finish time
    /// `(served + 1) / weight` is smallest (Interactive weight 1, Batch
    /// `cfg.batch_weight`), then takes that class's front-most request —
    /// arrival order survives within each class, and Batch keeps a
    /// guaranteed floor of `batch_weight / (1 + batch_weight)` of
    /// admissions instead of starving.
    fn next_queue_index(&self) -> usize {
        if !self.cfg.qos || self.queue.is_empty() {
            return 0;
        }
        let front = self.queue.front().unwrap().qos;
        if self.queue.iter().all(|r| r.qos == front) {
            return 0;
        }
        let bw = self.cfg.batch_weight.max(1e-9);
        let cost_i = (self.served_interactive + 1) as f64;
        let cost_b = (self.served_batch + 1) as f64 / bw;
        let pick = if cost_i <= cost_b {
            QosClass::Interactive
        } else {
            QosClass::Batch
        };
        self.queue
            .iter()
            .position(|r| r.qos == pick)
            .expect("both classes present")
    }

    fn fill_slots(&mut self) -> Result<()> {
        for i in 0..self.slots.len() {
            if self.queue.is_empty() {
                break;
            }
            if !self.slots[i].is_idle() {
                continue;
            }
            let qi = self.next_queue_index();
            let head = self.queue[qi].clone();
            let prompt = synth_prompt(&head, self.backend.max_prompt_tokens());
            // KV-aware admission (DESIGN.md §Unified paging): reserve the
            // pages the *prompt* needs plus one decode page — not the
            // worst-case context the static headroom used to charge — and
            // map any cached prefix chain instead of allocating (§Prefix
            // sharing; only *unshared* pages are charged). If the pool
            // cannot cover that even after shrinking the adapter cache,
            // the request stays queued and admission retries next
            // iteration, after decode completes something.
            if self.kv.is_some() {
                let key = self.effective_adapter(&head);
                if !self.reserve_admission_pages(i, key, &prompt)? {
                    self.stats.kv_admission_deferrals += 1;
                    break;
                }
            }
            let req = self.queue.remove(qi).unwrap();
            // the prefetch planner can never see this request again
            self.prefetch_planned.remove(&req.id);
            match req.qos {
                QosClass::Interactive => self.served_interactive += 1,
                QosClass::Batch => self.served_batch += 1,
            }
            let now = self.local_now();
            // cap generation to the backend's KV capacity (llama.cpp-style
            // n_ctx truncation): a request whose prompt + output exceeds
            // max_positions must not be able to run the engine past the
            // per-slot page capacity mid-decode
            let target = req
                .output_tokens
                .min(self.backend.max_positions() - prompt.len())
                .max(1);
            let explicit = self.effective_adapter(&req);
            self.slots[i].admit(
                req.id,
                prompt,
                explicit,
                req.true_adapter,
                target,
                req.arrival_s,
                now,
            );
            // class + deadline ride on the record so preemption teardown and
            // per-class metrics see them (0 deadline = best-effort)
            self.slots[i].record.qos = req.qos;
            self.slots[i].record.deadline_s = req.deadline_s.unwrap_or(0.0);
            self.events.emit(
                req.id,
                EngineEvent::Admitted { replica: self.memory.shard(), t: now },
            );
            if target < req.output_tokens {
                self.events.emit(req.id, EngineEvent::Truncated { target });
            }
        }
        Ok(())
    }

    /// Reserve slot `slot`'s KV pages for a prompt of `prompt.len()` tokens
    /// plus one decode page, mapping any cached prefix chain first (§Prefix
    /// sharing: only the *unshared* remainder is charged) and shedding
    /// radix pages, adapter cache (coldest unpinned first) and speculative
    /// prefetch blocks under page pressure. Ok(false) = defer the
    /// admission; errors only when the pool is too small for the request
    /// even with everything freeable freed — a sizing bug, not a transient.
    ///
    /// Hysteresis: beyond the request's own pages, admission must leave one
    /// free page per *generating* slot — otherwise a just-preempted request
    /// re-admits into a pool its preemptor immediately drains again,
    /// ping-ponging one preempt/re-admit cycle per page fault and burning
    /// an adapter reload + prefill each time. One page of headroom per
    /// decoder covers their next fault, so a re-admitted request survives
    /// at least a full page worth of ticks.
    fn reserve_admission_pages(
        &mut self,
        slot: usize,
        adapter_key: Option<u64>,
        prompt: &[u32],
    ) -> Result<bool> {
        let positions = prompt.len() + 1;
        // 1) radix lookup + shared mapping *before* any shedding: mapping
        //    retains each chain page (refcount ≥ 2), so the pressure
        //    ladder's radix rung can never reclaim a page this admission is
        //    about to read through.
        let (eligible, mut covered) = {
            let kv = self.kv.as_mut().expect("paged admission");
            let eligible = kv.share && adapter_key.is_some();
            let covered = match adapter_key {
                Some(a) if kv.share => {
                    let mut chain = std::mem::take(&mut kv.chain);
                    let c = kv.prefix.lookup(a, prompt, kv.page_tokens, &mut chain);
                    if c > 0 {
                        kv.tables[slot].map_shared(&chain, c, &kv.pages);
                    }
                    kv.chain = chain;
                    c
                }
                _ => 0,
            };
            (eligible, covered)
        };
        loop {
            let (need_total, shared_n, free) = {
                let kv = self.kv.as_ref().unwrap();
                (
                    pages_for(positions, kv.page_tokens),
                    kv.tables[slot].shared_pages(),
                    kv.pages.free_pages(),
                )
            };
            // always reserve ≥ 1 fresh page: the decode page on a full
            // prefix hit doubles as the COW-fork target for the shared tail
            let new_need = need_total.saturating_sub(shared_n).max(1);
            let reserve = self
                .slots
                .iter()
                .filter(|s| {
                    matches!(
                        s.state,
                        SlotState::Generation | SlotState::Prefilling { .. }
                    )
                })
                .count();
            if free >= new_need + reserve {
                let kv = self.kv.as_mut().unwrap();
                let grown = kv.tables[slot].grow_to(shared_n + new_need, &kv.pages);
                assert!(grown, "free-page check precedes grow");
                if eligible {
                    self.stats.prefix_lookups += 1;
                    if covered > 0 {
                        self.stats.prefix_hits += 1;
                        self.stats.shared_prompt_pages += shared_n as u64;
                    }
                }
                self.stats.prompt_pages_charged += new_need as u64;
                return Ok(true);
            }
            if self.shed_one_for_pages() {
                continue;
            }
            if self.slots.iter().any(|s| !s.is_idle()) {
                // in-flight work will release pages; drop the shared
                // mapping (the retry re-looks it up) and retry later
                if shared_n > 0 {
                    let kv = self.kv.as_mut().unwrap();
                    kv.tables[slot].release_all(&kv.pages);
                }
                return Ok(false);
            }
            if shared_n > 0 {
                // last resort: cannibalize this admission's own shared
                // mapping — its pages drop to refcount 1 and become
                // reclaimable by the radix rung next time around
                let kv = self.kv.as_mut().unwrap();
                kv.tables[slot].release_all(&kv.pages);
                covered = 0;
                continue;
            }
            bail!(
                "unified page pool too small: admission needs {new_need} pages, \
                 {free} free and nothing left to shed"
            );
        }
    }

    /// The asynchronous half of the adapter swap path: drain finished
    /// background reads into the cache (adoption) and issue speculative
    /// reads for requests waiting in the queue, so their disk I/O overlaps
    /// with the decode work of the requests occupying the slots.
    fn pump_prefetch(&mut self) -> Result<()> {
        if !self.memory.prefetch_enabled() {
            return Ok(());
        }
        let now = self.clock.now();
        let min_age = self.backend.adapter_load_cost_s();
        if self.clock.is_virtual() {
            // virtual time must stay deterministic: any read whose modeled
            // latency has elapsed is settled (blocking for its wall-clock-µs
            // completion), so adoption depends only on the virtual clock
            self.memory.settle_prefetch(min_age, now);
        } else {
            self.memory.poll_prefetch();
        }
        // Adopt reads whose modeled load latency is fully covered: they
        // become ordinary residents, visible to adapter selection, at zero
        // remaining cost. Early-needed reads are instead claimed (and their
        // remainder charged) in `ensure_loaded`. The bank upload happens on
        // the engine thread either way — adoption merely moves it earlier;
        // and because the planner below only speculates on adapters queued
        // requests have named, or scored by the same router selection will
        // consult (the head-router guard stands mismatched guesses down),
        // an adopted upload is one a request would pay at claim anyway.
        while let Some((id, claim)) = self.memory.take_ready_prefetch(min_age, now) {
            self.stats.adapter_loads += 1;
            self.stats.prefetch_hits += 1;
            let view = self.memory.quant_view(id).expect("adopted prefetch");
            self.backend
                .load_adapter_overlapped(claim.resident.bank_slot, &view, claim.covered_s)?;
        }
        if self.queue.is_empty() {
            return Ok(());
        }
        // Inspect the head of the queue (bounded window — deeper entries
        // will still be waiting next iteration). Requests are copied out of
        // the queue (TraceRequest is 6 machine words, no heap) so the shared
        // speculation policy can borrow the engine mutably.
        let window = (2 * self.slots.len()).max(4).min(self.queue.len());
        for qi in 0..window {
            if !self.memory.prefetch_has_capacity() {
                // depth cap reached: don't burn router scoring on requests
                // that cannot be issued anyway; they retry once reads drain
                break;
            }
            let req = self.queue[qi].clone();
            self.plan_request_prefetch(&req, now);
        }
        Ok(())
    }

    fn process_new_slots(&mut self, budget: &mut usize) -> Result<()> {
        for i in 0..self.slots.len() {
            if self.slots[i].state != SlotState::AdapterSelection {
                continue;
            }
            // --- Algorithm 1 ---
            // Move the prompt out of the slot instead of cloning it twice
            // (once for the router, once for prefill); restored below.
            let prompt = RouterPrompt {
                tokens: std::mem::take(&mut self.slots[i].prompt),
                latent_task: Some(self.slots[i].true_adapter as usize),
            };
            let explicit = self.slots[i].explicit_adapter;
            // a selection deferred by pool backpressure is reused on retry —
            // its router pass was already charged exactly once
            let selection = match self.deferred_selection[i].take() {
                Some(s) => s,
                None if explicit.is_none() => {
                    // the router forward pass costs one prompt decode (§4.1)
                    self.stats.router_passes += 1;
                    let head = self.backend.router_pass(&prompt.tokens)?;
                    match head {
                        Some(raw) => {
                            self.router_head_active = true;
                            // map head outputs onto logical adapter ids (the
                            // head width is a static artifact property; the
                            // adapter set size comes from the configured
                            // router)
                            let n_adapters = self.router.scores(&prompt).len();
                            let mapper = crate::router::pjrt::HeadScoreMapper::identity(
                                n_adapters,
                                raw.len(),
                            );
                            let snap = crate::router::pjrt::SnapshotRouter {
                                scores: mapper.expand(&raw),
                            };
                            select_adapter(&prompt, None, &snap, &self.memory, self.cfg.top_k)
                        }
                        None => select_adapter(
                            &prompt,
                            None,
                            self.router.as_ref(),
                            &self.memory,
                            self.cfg.top_k,
                        ),
                    }
                }
                None => select_adapter(
                    &prompt,
                    explicit,
                    self.router.as_ref(),
                    &self.memory,
                    self.cfg.top_k,
                ),
            };
            // Deferred loads normally wait for decode to free a pin (or, in
            // paged mode, pages). One state cannot resolve that way: nothing
            // is pinned, cached or speculative, so every page is held by
            // admitted slots' KV reservations and no decode will ever run —
            // several fresh admissions can starve each other's adapter
            // blocks. Break it by preempting the newest *other* slot until
            // this one loads; if this slot is the last one standing and
            // still cannot fit its block beside its own KV, the pool is
            // simply too small (a sizing bug, not a transient).
            let loaded = loop {
                match self.ensure_loaded(&selection)? {
                    Some(b) => break Some(b),
                    None => {
                        let freeable = self.memory.pinned_count() > 0
                            || self.memory.resident_count() > 0
                            || self.memory.prefetch_outstanding() > 0;
                        if freeable {
                            break None; // in-flight decode will release it
                        }
                        // the manager has nothing left to shed, but radix-
                        // held prefix pages (refcount 1) are invisible to
                        // it — reclaim those before resorting to preemption
                        // so a cached prefix can never starve a block load
                        if let Some(kv) = &mut self.kv {
                            if kv.prefix.reclaim_one(&kv.pages) {
                                self.stats.prefix_reclaims += 1;
                                continue;
                            }
                        }
                        match self.preempt_victim(i) {
                            Some(v) => self.preempt_slot(v)?,
                            None => bail!(
                                "unified page pool too small: adapter block \
                                 cannot fit beside one request's KV"
                            ),
                        }
                    }
                }
            };
            let Some(bank_slot) = loaded else {
                // put the prompt back, remember the selection, and retry
                // next iteration once decode completes a request
                self.slots[i].prompt = prompt.tokens;
                self.deferred_selection[i] = Some(selection);
                continue;
            };
            // pin for the lifetime of the request: the bank slot now feeds
            // this slot's decode rows and must not be evicted underneath it
            self.memory.pin(selection.adapter);
            let auto = selection.auto;
            let cached = selection.cached;
            self.slots[i].adapter_selected(selection.adapter, bank_slot, cached, auto);

            // --- prompt processing ---
            let row = self.slots[i].row;
            // §Prefix sharing: positions the shared chain already holds are
            // skipped; the uncovered suffix is computed and its KV entries
            // written through the page table (private pages only — the
            // chain covers everything below `covered` by construction)
            let covered = if let Some(kv) = &mut self.kv {
                let covered = kv.tables[i]
                    .shared_positions()
                    .min(prompt.tokens.len());
                for (pos, &tok) in prompt.tokens.iter().enumerate().skip(covered) {
                    kv.tables[i].write_pos(pos, kv.page_tokens, kv_entry(tok, pos), &kv.pages);
                }
                covered
            } else {
                0
            };
            // §Chunked prefill: when the uncovered suffix exceeds this
            // tick's remaining budget, process only a budget-sized chunk and
            // park the slot in `Prefilling` — later ticks resume it via
            // `pump_prefill`, interleaved with decode. KV entries were all
            // written above (pages are reserved at admission); only the
            // backend compute is deferred.
            let suffix = prompt.tokens.len() - covered;
            if suffix > *budget {
                let chunk = *budget;
                if chunk > 0 {
                    self.backend.prefill_chunk(
                        row,
                        &prompt.tokens[covered..covered + chunk],
                        covered,
                        bank_slot,
                    )?;
                }
                self.slots[i].prompt = prompt.tokens;
                self.slots[i].prefill_progress(covered + chunk);
                *budget = 0;
                continue;
            }
            // a full-prefix hit (suffix == 0) still costs one decode step on
            // the backend; charge it one token of budget
            *budget = budget.saturating_sub(suffix.max(1));
            let first = if covered > 0 {
                self.backend
                    .prefill_with_cached_prefix(row, &prompt.tokens, bank_slot, covered)?
            } else {
                self.backend.prefill(row, &prompt.tokens, bank_slot)?
            };
            self.slots[i].prompt = prompt.tokens;
            self.finish_prefill(i, first)?;
        }
        Ok(())
    }

    /// One rung of the page-pressure shed ladder, shared by admission and
    /// the decode fault path so the two sides can never diverge: reclaim a
    /// cached prefix page nobody maps first (refcount 1 — one prefill
    /// recomputes it, the cheapest thing to lose), then shrink the adapter
    /// cache (coldest unpinned resident — a disk reload), then reclaim one
    /// speculative prefetch block. The order is load-bearing for the
    /// preempt-and-recompute determinism guarantee.
    fn shed_one_for_pages(&mut self) -> bool {
        if let Some(kv) = &mut self.kv {
            if kv.prefix.reclaim_one(&kv.pages) {
                self.stats.prefix_reclaims += 1;
                return true;
            }
        }
        self.memory.evict_one_for_pressure().is_some() || self.memory.reclaim_one_speculative()
    }

    /// Return slot `i`'s KV pages to the unified pool (completion or
    /// preemption). No-op when unpaged.
    fn release_kv_pages(&mut self, i: usize) {
        if let Some(kv) = &mut self.kv {
            kv.tables[i].release_all(&kv.pages);
        }
    }

    /// The preemption victim under page pressure: with `cfg.qos`, any Batch
    /// slot is victimized before any Interactive one (Batch exists to
    /// absorb pressure); within a class — and with QoS off — the *newest*
    /// non-idle slot (latest admission instant; slot index breaks ties)
    /// other than `exclude` loses: it has the least recompute to lose and,
    /// having been admitted last, the weakest claim on the pool.
    fn preempt_victim(&self, exclude: usize) -> Option<usize> {
        let mut best: Option<(bool, f64, usize)> = None;
        for (j, s) in self.slots.iter().enumerate() {
            if j == exclude || s.is_idle() {
                continue;
            }
            let batch = self.cfg.qos && s.record.qos == QosClass::Batch;
            let better = match best {
                None => true,
                Some((bb, t, bj)) => {
                    (batch && !bb)
                        || (batch == bb
                            && (s.record.scheduled > t
                                || (s.record.scheduled == t && j > bj)))
                }
            };
            if better {
                best = Some((batch, s.record.scheduled, j));
            }
        }
        best.map(|(_, _, j)| j)
    }

    /// Preempt-and-requeue slot `j` (last-resort page-pressure handling):
    /// free its KV pages and pins, rebuild its `TraceRequest`, and push it
    /// to the *front* of the queue so it re-admits as soon as pages exist.
    /// Recompute is deterministic — the regenerated prompt and the resumed
    /// decode are pure functions of the request and the engine state, so
    /// the same trace + seed reproduces the same tokens and event order.
    fn preempt_slot(&mut self, j: usize) -> Result<()> {
        let (req, state, adapter, row) = {
            let s = &self.slots[j];
            debug_assert!(!s.is_idle(), "preempt of idle slot");
            (
                TraceRequest {
                    id: s.request_id,
                    arrival_s: s.record.arrival,
                    true_adapter: s.true_adapter,
                    explicit_adapter: s.explicit_adapter,
                    input_tokens: s.record.input_tokens.max(1),
                    output_tokens: s.target_tokens,
                    qos: s.record.qos,
                    deadline_s: (s.record.deadline_s > 0.0).then_some(s.record.deadline_s),
                },
                s.state,
                s.adapter,
                s.row,
            )
        };
        match state {
            SlotState::Generation
            | SlotState::PromptProcessing
            | SlotState::Prefilling { .. } => {
                // a mid-prefill slot holds the same pin + row as a decoding
                // one; its chunk progress is simply dropped — re-admission
                // recomputes the suffix deterministically
                self.memory.unpin(adapter);
                self.backend.release_row(row)?;
            }
            SlotState::AdapterSelection => {
                // a deferred selection's router pass is re-run (and
                // re-charged) at re-admission — preemption is rare enough
                // that simplicity wins over caching the selection
                self.deferred_selection[j] = None;
            }
            SlotState::Idle => unreachable!("checked non-idle above"),
        }
        self.slots[j].abort();
        self.scratch.plan_dirty = true;
        self.release_kv_pages(j);
        let rid = req.id;
        self.events.emit(rid, EngineEvent::Preempted);
        self.queue.push_front(req);
        self.stats.preemptions += 1;
        self.events.emit(rid, EngineEvent::Requeued);
        Ok(())
    }

    /// Before a decode step, make every generating slot's KV table cover
    /// its next position. Page-pressure ladder: take a free page (hit or
    /// fault) → shrink the adapter cache (coldest unpinned evicted) → drop
    /// speculative prefetch blocks → preempt-and-requeue the newest other
    /// slot. Errors only when a single remaining request cannot fit — a
    /// pool-sizing bug.
    fn ensure_kv_for_decode(&mut self) -> Result<()> {
        if self.kv.is_none() {
            return Ok(());
        }
        let mut i = 0;
        while i < self.slots.len() {
            if self.slots[i].state != SlotState::Generation {
                i += 1;
                continue;
            }
            // positions after this step: prompt + generated so far + the
            // token this step writes
            let positions = self.slots[i].prompt_len + self.slots[i].generated + 1;
            loop {
                let kv = self.kv.as_mut().unwrap();
                match kv.tables[i].ensure_positions(positions, kv.page_tokens, &kv.pages)? {
                    KvEnsure::Fits => {
                        self.stats.kv_appends += 1;
                        break;
                    }
                    KvEnsure::Grew => {
                        self.stats.kv_appends += 1;
                        self.stats.kv_page_faults += 1;
                        break;
                    }
                    KvEnsure::NoPage => {
                        if self.shed_one_for_pages() {
                            continue;
                        }
                        let Some(victim) = self.preempt_victim(i) else {
                            bail!(
                                "unified page pool too small: slot {i} cannot \
                                 grow KV with nothing left to shed"
                            );
                        };
                        self.preempt_slot(victim)?;
                    }
                }
            }
            i += 1;
        }
        Ok(())
    }

    /// Make the selected adapter resident + uploaded; returns its bank slot,
    /// or None when the load must be deferred (every pool block pinned).
    /// Order: cache hit → claim an outstanding prefetch (paying only the
    /// uncovered remainder of the load) → synchronous zero-copy load.
    fn ensure_loaded(&mut self, sel: &Selection) -> Result<Option<usize>> {
        let id = sel.adapter;
        if let Some(slot) = self.memory.peek_slot(id) {
            // resident (possibly via an adopted prefetch): plain hit — but
            // route through ensure_resident to maintain recency + stats
            let r = self.memory.ensure_resident(id)?;
            debug_assert!(r.is_hit());
            debug_assert_eq!(r.resident().bank_slot, slot);
            return Ok(Some(slot));
        }
        let now = self.clock.now();
        if let Some(claim) = self.memory.take_prefetched(id, now) {
            self.stats.adapter_loads += 1;
            self.stats.prefetch_hits += 1;
            let view = self.memory.quant_view(id).expect("claimed prefetch");
            self.backend.load_adapter_overlapped(
                claim.resident.bank_slot,
                &view,
                claim.covered_s,
            )?;
            return Ok(Some(claim.resident.bank_slot));
        }
        match self.memory.ensure_resident(id)? {
            Residency::Hit(r) => Ok(Some(r.bank_slot)),
            Residency::Loaded { resident, .. } => {
                self.stats.adapter_loads += 1;
                let view = self.memory.quant_view(id).expect("just loaded");
                self.backend.load_adapter(resident.bank_slot, &view)?;
                Ok(Some(resident.bank_slot))
            }
            Residency::Deferred => Ok(None),
        }
    }

    /// One batched decode step. Returns whether any work happened.
    /// Steady state allocates nothing: every buffer lives in `scratch` and
    /// the KV page tables grow only off the preallocated free list.
    fn decode_tick(&mut self) -> Result<bool> {
        // paged mode: every generating row secures its next KV position
        // first (may shed adapters or preempt the newest slot)
        self.ensure_kv_for_decode()?;
        self.scratch.rows.clear();
        self.scratch.slot_of_row.clear();
        for i in 0..self.slots.len() {
            let s = &self.slots[i];
            if s.state != SlotState::Generation {
                continue;
            }
            // paged attention reads/writes go *through the page table*: the
            // input token's KV entry lands at this step's position (the
            // first decode write into a shared tail COW-forks it), and the
            // probe folds entries read back through the table — shared and
            // private pages are bit-identical, and a page freed while
            // mapped corrupts the token stream instead of passing silently
            let write_pos = s.prompt_len + s.generated;
            let (token, row, bank_slot) = (s.last_token, s.row, s.bank_slot);
            let kv_probe = if let Some(kv) = &mut self.kv {
                let forked = kv.tables[i].write_pos(
                    write_pos,
                    kv.page_tokens,
                    kv_entry(token, write_pos),
                    &kv.pages,
                );
                if forked {
                    self.stats.cow_forks += 1;
                }
                let first = kv.tables[i].read_pos(0, kv.page_tokens, &kv.pages);
                let last = kv.tables[i].read_pos(write_pos, kv.page_tokens, &kv.pages);
                splitmix64(first ^ last.rotate_left(1))
            } else {
                0
            };
            self.scratch.rows.push(DecodeRow {
                row,
                token,
                pos: write_pos as u32,
                bank_slot,
                kv_probe,
            });
            self.scratch.slot_of_row.push(i);
        }
        let scratch = &mut self.scratch;
        if scratch.rows.is_empty() {
            return Ok(false);
        }
        // §3.4: group rows by adapter (u-batches) before the backend call.
        // The plan is a pure function of (bank_slot, slot membership), both
        // of which only change when a slot enters or leaves Generation — so
        // it is rebuilt only when `plan_dirty` was set by such a transition
        // (pinned by the `batcher/plan reuse` bench entry).
        let rebuilt = scratch.plan.rebuild_if(&scratch.rows, scratch.plan_dirty);
        scratch.plan_dirty = false;
        #[cfg(debug_assertions)]
        if !rebuilt {
            let fresh = crate::coordinator::batcher::UBatchPlan::build(&scratch.rows);
            debug_assert_eq!(fresh.order, scratch.plan.order, "stale cached u-batch plan");
            debug_assert_eq!(fresh.groups, scratch.plan.groups, "stale cached u-batch plan");
        }
        #[cfg(not(debug_assertions))]
        let _ = rebuilt;
        self.stats.decode_steps += 1;
        self.stats.decode_rows += scratch.rows.len() as u64;
        self.stats.ubatch_groups += scratch.plan.n_groups() as u64;
        scratch.plan.gather_into(&scratch.rows, &mut scratch.sorted);
        self.backend
            .decode_step_into(&scratch.sorted, &mut scratch.toks_sorted)?;
        scratch
            .plan
            .scatter_into(&scratch.toks_sorted, &mut scratch.toks);
        let now = self.local_now();
        self.scratch.itl.clear();
        for k in 0..self.scratch.slot_of_row.len() {
            let slot_idx = self.scratch.slot_of_row[k];
            let tok = self.scratch.toks[k];
            self.stats.token_checksum =
                self.stats.token_checksum.rotate_left(1) ^ tok as u64;
            let rid = self.slots[slot_idx].request_id;
            self.scratch.itl.push((
                (now - self.slots[slot_idx].last_token_at).max(0.0),
                self.slots[slot_idx].record.qos,
            ));
            let done = self.slots[slot_idx].token_generated(tok, now);
            self.events.emit(
                rid,
                EngineEvent::Token {
                    index: (self.slots[slot_idx].generated - 1) as u32,
                    token: tok,
                    t: now,
                },
            );
            if done {
                let row = self.slots[slot_idx].row;
                let adapter = self.slots[slot_idx].adapter;
                let rec = self.slots[slot_idx].release();
                self.scratch.plan_dirty = true;
                self.memory.unpin(adapter);
                self.backend.release_row(row)?;
                self.release_kv_pages(slot_idx);
                self.recorder.complete(&rec);
                self.events.emit(rid, EngineEvent::Done { t: now });
            }
        }
        self.recorder.record_itl_batch(&self.scratch.itl);
        Ok(true)
    }

    /// Capacities of every per-tick scratch buffer — a steady-state decode
    /// loop must leave these untouched (no per-tick heap allocation).
    pub fn scratch_footprint(&self) -> [usize; 9] {
        [
            self.scratch.rows.capacity(),
            self.scratch.slot_of_row.capacity(),
            self.scratch.plan.order.capacity(),
            self.scratch.plan.inverse.capacity(),
            self.scratch.plan.groups.capacity(),
            self.scratch.sorted.capacity(),
            self.scratch.toks_sorted.capacity(),
            self.scratch.toks.capacity(),
            self.scratch.itl.capacity(),
        ]
    }

    /// Benchmark/test hook: put `rows` slots directly into Generation on
    /// adapter 0 with `target_tokens` to produce, bypassing the queue.
    #[doc(hidden)]
    pub fn bench_fill_generating(&mut self, rows: usize, target_tokens: usize) -> Result<()> {
        let sel = Selection {
            adapter: 0,
            cached: false,
            auto: false,
            candidates: Vec::new(),
        };
        let bank = self
            .ensure_loaded(&sel)?
            .expect("bench engine has no pinned adapters yet");
        for i in 0..rows.min(self.slots.len()) {
            if !self.slots[i].is_idle() {
                continue;
            }
            self.slots[i].admit(i as u64 + 1, vec![1, 2, 3, 4], Some(0), 0, target_tokens, 0.0, 0.0);
            self.memory.pin(0);
            self.slots[i].adapter_selected(0, bank, true, false);
            self.slots[i].prompt_done(1, 0.0);
            self.scratch.plan_dirty = true;
        }
        Ok(())
    }

    /// Benchmark/test hook: run one decode tick (see `bench_fill_generating`).
    #[doc(hidden)]
    pub fn decode_tick_once(&mut self) -> Result<bool> {
        self.decode_tick()
    }
}

/// Deterministic synthetic prompt for a trace request (token values don't
/// affect scheduling; the *length* does). Task-banded like
/// `TaskWorld::sample_prompt` so the PJRT router head sees structure — and,
/// like real multi-tenant traffic, the first ~3/4 of every prompt is the
/// adapter's *system/task preamble* (a pure function of the adapter), so
/// same-adapter requests share a long common prefix: the prefix cache's
/// operating regime (DESIGN.md §Prefix sharing). The per-request tail keeps
/// prompts distinct end-to-end.
pub fn synth_prompt(req: &TraceRequest, max_len: usize) -> Vec<u32> {
    let mut out = Vec::new();
    synth_prompt_into(req, max_len, &mut out);
    out
}

/// [`synth_prompt`] into a caller-owned buffer (cleared first) — the
/// cluster's prefix-affinity hint hashes one prompt per dispatch and must
/// not allocate at steady state.
pub fn synth_prompt_into(req: &TraceRequest, max_len: usize, out: &mut Vec<u32>) {
    let len = req.input_tokens.clamp(1, max_len);
    let sys = len - len / 4;
    let step = |h: &mut u64| {
        *h = h.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (1 + (req.true_adapter * 97) as u64 + (*h >> 33) % 50) as u32
    };
    out.clear();
    out.reserve(len);
    let mut hs = 0x5eedu64 ^ req.true_adapter.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    for _ in 0..sys {
        out.push(step(&mut hs));
    }
    let mut hr = 0x5eedu64 ^ req.id;
    for _ in sys..len {
        out.push(step(&mut hr));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::{AdapterStore, LoraShape};
    use crate::backend::devices::DeviceProfile;
    use crate::backend::sim::SimBackend;
    use crate::config::{ModelSetting, WorkloadConfig};
    use crate::memory::CachePolicy;
    use crate::quant::QuantType;
    use crate::router::confidence::{TaskModelRouter, TaskWorld};
    use crate::util::time::VirtualClock;
    use crate::workload::generate;

    const SHAPE: LoraShape = LoraShape {
        n_layers: 2,
        d_model: 16,
        rank: 4,
    };

    fn mk_engine_cfg(
        n_adapters: usize,
        slots: usize,
        engine: EngineKind,
        prefetch: bool,
        tag: &str,
    ) -> EdgeLoraEngine {
        let dir = std::env::temp_dir().join(format!(
            "elra_engine_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = AdapterStore::create(&dir, SHAPE, QuantType::Q8_0).unwrap();
        store.populate_synthetic(n_adapters).unwrap();
        let store = Arc::new(store);
        let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
        let cache_cap = 8usize.min(n_adapters).max(2);
        let backend = SimBackend::new(
            DeviceProfile::agx_orin(),
            ModelSetting::s3(),
            clock.clone(),
            slots,
            cache_cap,
            None,
        )
        .unwrap();
        let memory = AdapterMemoryManager::new(store, cache_cap, CachePolicy::Lru);
        let world = TaskWorld::synthetic(n_adapters, 4, 1);
        let router = TaskModelRouter::new(world.acc.clone(), 0.95, 2);
        EdgeLoraEngine::new(
            Box::new(backend),
            memory,
            Box::new(router),
            clock,
            ServerConfig {
                slots,
                top_k: 3,
                cache_capacity: Some(cache_cap),
                engine,
                prefetch,
                ..ServerConfig::default()
            },
        )
    }

    fn mk_engine(
        n_adapters: usize,
        slots: usize,
        engine: EngineKind,
        tag: &str,
    ) -> EdgeLoraEngine {
        mk_engine_cfg(n_adapters, slots, engine, true, tag)
    }

    fn short_trace(n_adapters: usize, rate: f64, dur: f64) -> Trace {
        generate(&WorkloadConfig {
            n_adapters,
            rate,
            duration_s: dur,
            input_range: (8, 32),
            output_range: (4, 16),
            ..WorkloadConfig::default()
        })
    }

    #[test]
    fn completes_every_request() {
        let mut e = mk_engine(10, 4, EngineKind::EdgeLora, "complete");
        let trace = short_trace(10, 2.0, 30.0);
        let n = trace.len() as u64;
        let summary = e.run_trace(&trace).unwrap();
        assert_eq!(summary.requests, n, "no request may be lost");
        assert!(summary.throughput_rps > 0.0);
        assert!(summary.avg_latency_s > 0.0);
        assert!(summary.avg_first_token_s <= summary.avg_latency_s);
    }

    #[test]
    fn batching_occurs_under_load() {
        // offered load well above single-slot capacity ⇒ slots fill up and
        // decode steps carry multiple rows (batch LoRA inference engaged)
        let mut e = mk_engine(4, 8, EngineKind::EdgeLora, "batch");
        let trace = short_trace(4, 60.0, 10.0);
        e.run_trace(&trace).unwrap();
        assert!(
            e.stats.mean_batch() > 1.5,
            "mean batch {} too small under 60 req/s",
            e.stats.mean_batch()
        );
    }

    #[test]
    fn no_aas_uses_true_adapter_and_skips_router() {
        let mut e = mk_engine(10, 4, EngineKind::EdgeLoraNoAas, "noaas");
        let trace = short_trace(10, 1.0, 20.0);
        e.run_trace(&trace).unwrap();
        assert_eq!(e.stats.router_passes, 0);
    }

    #[test]
    fn aas_runs_router_per_auto_request() {
        let mut e = mk_engine(10, 4, EngineKind::EdgeLora, "aas");
        let trace = short_trace(10, 1.0, 20.0);
        let n = trace.len() as u64;
        e.run_trace(&trace).unwrap();
        assert_eq!(e.stats.router_passes, n);
    }

    #[test]
    fn cache_hit_rate_rises_with_locality() {
        let run = |alpha: f64| {
            let mut e = mk_engine(32, 4, EngineKind::EdgeLoraNoAas, &format!("loc{alpha}"));
            let trace = generate(&WorkloadConfig {
                n_adapters: 32,
                alpha,
                rate: 2.0,
                duration_s: 60.0,
                input_range: (8, 16),
                output_range: (4, 8),
                ..WorkloadConfig::default()
            });
            e.run_trace(&trace).unwrap().cache_hit_rate
        };
        let low = run(0.1);
        let high = run(3.0);
        assert!(high > low, "hit rate: alpha3 {high} vs alpha0.1 {low}");
    }

    #[test]
    fn warm_cache_preloads() {
        let mut e = mk_engine(10, 4, EngineKind::EdgeLora, "warm");
        e.warm_cache(0..8).unwrap();
        assert_eq!(e.memory().resident_count(), 8);
    }

    #[test]
    fn more_slots_more_throughput() {
        // overload: a single slot cannot drain the queue within the trace,
        // so the run stretches past the nominal duration and throughput
        // (n / actual span) drops — Table 14's mechanism.
        let run = |slots: usize| {
            let mut e = mk_engine(8, slots, EngineKind::EdgeLoraNoAas, &format!("sl{slots}"));
            let trace = short_trace(8, 40.0, 20.0);
            e.run_trace(&trace).unwrap()
        };
        let t1 = run(1);
        let t8 = run(8);
        assert!(
            t8.throughput_rps > t1.throughput_rps,
            "slots 8 {} vs 1 {}",
            t8.throughput_rps,
            t1.throughput_rps
        );
        assert!(t8.avg_latency_s < t1.avg_latency_s);
    }

    #[test]
    fn empty_trace_is_fine() {
        let mut e = mk_engine(4, 2, EngineKind::EdgeLora, "empty");
        let trace = Trace {
            requests: vec![],
            duration_s: 1.0,
            n_adapters: 4,
        };
        let s = e.run_trace(&trace).unwrap();
        assert_eq!(s.requests, 0);
    }

    /// Low-locality overload trace: many distinct adapters, enough offered
    /// load that the queue stays populated (prefetch's operating regime).
    fn low_locality_trace(n_adapters: usize, seed: u64) -> Trace {
        generate(&WorkloadConfig {
            n_adapters,
            alpha: 0.1,
            rate: 20.0,
            duration_s: 20.0,
            input_range: (8, 24),
            output_range: (6, 16),
            auto_select_fraction: 0.0,
            seed,
            ..WorkloadConfig::default()
        })
    }

    #[test]
    fn prefetch_loses_no_requests_and_raises_hit_rate() {
        // adapters ≫ cache (64 vs 8), α=0.1: nearly every request misses
        // without prefetch. With prefetch the queued requests' adapters are
        // adopted before scheduling, so selection sees them resident.
        let trace = low_locality_trace(64, 0x5eed1);
        let mut on = mk_engine_cfg(64, 2, EngineKind::EdgeLoraNoAas, true, "pfon");
        let s_on = on.run_trace(&trace).unwrap();
        let mut off = mk_engine_cfg(64, 2, EngineKind::EdgeLoraNoAas, false, "pfoff");
        let s_off = off.run_trace(&trace).unwrap();

        // equal correctness: every request completes, same tokens generated
        assert_eq!(s_on.requests, trace.len() as u64);
        assert_eq!(s_off.requests, trace.len() as u64);
        assert_eq!(s_on.total_output_tokens, s_off.total_output_tokens);

        assert!(on.stats.prefetch_issued > 0, "prefetch must engage");
        assert!(on.stats.prefetch_hits > 0, "prefetches must be used");
        assert!(
            s_on.cache_hit_rate > s_off.cache_hit_rate,
            "prefetch hit rate {} must beat off {}",
            s_on.cache_hit_rate,
            s_off.cache_hit_rate
        );
        assert!(
            s_on.avg_first_token_s < s_off.avg_first_token_s,
            "prefetch first-token {} must beat off {}",
            s_on.avg_first_token_s,
            s_off.avg_first_token_s
        );
        assert_eq!(off.stats.prefetch_issued, 0);
    }

    #[test]
    fn prefetch_stats_flow_to_memory_stats() {
        let trace = low_locality_trace(64, 0x5eed2);
        let mut e = mk_engine_cfg(64, 2, EngineKind::EdgeLoraNoAas, true, "pfstats");
        e.run_trace(&trace).unwrap();
        let m = e.memory().stats();
        assert_eq!(m.prefetch_hits, e.stats.prefetch_hits);
        assert!(m.prefetch_issued >= e.stats.prefetch_hits);
        assert_eq!(m.prefetch_issued, e.stats.prefetch_issued);
    }

    #[test]
    fn steppable_api_drains_all_requests() {
        let mut e = mk_engine(8, 4, EngineKind::EdgeLoraNoAas, "steppable");
        let trace = short_trace(8, 20.0, 5.0);
        let n = trace.len() as u64;
        assert!(n > 0);
        // burst admission: everything arrives at t=0; the steppable API
        // alone (no run_trace loop) must drain it
        for r in trace.requests.iter().cloned() {
            e.push_request(TraceRequest { arrival_s: 0.0, ..r });
        }
        assert!(e.has_work());
        assert_eq!(e.queue_len() + e.active_slots(), n as usize);
        e.drain().unwrap();
        assert!(!e.has_work());
        assert_eq!(e.active_slots(), 0);
        assert_eq!(e.recorder.completed(), n);
    }

    #[test]
    fn steal_newest_takes_queue_tail_and_loses_nothing() {
        let mut e = mk_engine(8, 2, EngineKind::EdgeLoraNoAas, "steal");
        let trace = short_trace(8, 20.0, 5.0);
        let n = trace.len();
        assert!(n >= 3, "need a few requests, got {n}");
        for r in trace.requests.iter().cloned() {
            e.push_request(TraceRequest { arrival_s: 0.0, ..r });
        }
        let qlen = e.queue_len();
        let stolen = e.steal_newest().unwrap();
        assert_eq!(stolen.id, trace.requests.last().unwrap().id);
        assert_eq!(e.queue_len(), qlen - 1);
        e.drain().unwrap();
        assert_eq!(e.recorder.completed(), n as u64 - 1);
        assert!(e.steal_newest().is_none(), "drained queue has nothing to steal");
    }

    /// Paged engine on the sim backend: S3 geometry, `page_tokens` KV
    /// positions per page, `n_pages` total, 2 modeled pages per adapter
    /// block.
    fn mk_paged_engine(
        n_adapters: usize,
        slots: usize,
        cache_cap: usize,
        n_pages: usize,
        page_tokens: usize,
        prefetch: bool,
        tag: &str,
    ) -> EdgeLoraEngine {
        let dir = std::env::temp_dir().join(format!(
            "elra_engpg_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = AdapterStore::create(&dir, SHAPE, QuantType::Q8_0).unwrap();
        store.populate_synthetic(n_adapters).unwrap();
        let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
        let backend = SimBackend::new(
            DeviceProfile::agx_orin(),
            ModelSetting::s3(),
            clock.clone(),
            slots,
            cache_cap,
            None,
        )
        .unwrap();
        let kv_tok = ModelSetting::s3().kv_bytes_per_token();
        let shared = SharedPages::new(n_pages, kv_tok * page_tokens);
        let memory = AdapterMemoryManager::new_paged(
            Arc::new(store),
            cache_cap,
            CachePolicy::Lru,
            shared,
            2,
        );
        let world = TaskWorld::synthetic(n_adapters, 4, 1);
        let router = TaskModelRouter::new(world.acc.clone(), 0.95, 2);
        EdgeLoraEngine::new(
            Box::new(backend),
            memory,
            Box::new(router),
            clock,
            ServerConfig {
                slots,
                top_k: 3,
                cache_capacity: Some(cache_cap),
                engine: EngineKind::EdgeLoraNoAas,
                prefetch,
                ..ServerConfig::default()
            },
        )
    }

    fn burst_trace(n: u64, n_adapters: u64, input: usize, output: usize) -> Trace {
        Trace {
            requests: (0..n)
                .map(|i| TraceRequest {
                    id: i,
                    arrival_s: 0.0,
                    true_adapter: i % n_adapters,
                    explicit_adapter: Some(i % n_adapters),
                    input_tokens: input,
                    output_tokens: output,
                    qos: QosClass::Interactive,
                    deadline_s: None,
                })
                .collect(),
            duration_s: 1.0,
            n_adapters: n_adapters as usize,
        }
    }

    #[test]
    fn paged_engine_completes_pays_per_page_and_releases_kv() {
        // generous pool: no preemption, but KV grows page-by-page
        let mut e = mk_paged_engine(8, 4, 4, 256, 4, true, "pgok");
        assert!(e.paged());
        assert_eq!(e.total_pages(), 256);
        let trace = burst_trace(12, 8, 8, 20);
        let s = e.run_trace(&trace).unwrap();
        assert_eq!(s.requests, 12, "paged engine must lose nothing");
        assert!(e.stats.kv_appends > 0, "decode must account KV appends");
        assert!(e.stats.kv_page_faults > 0, "20-token outputs cross pages");
        assert_eq!(e.stats.preemptions, 0, "generous pool never preempts");
        assert_eq!(e.kv_pages_in_use(), 0, "completed requests release KV");
        // page conservation: everything not held by resident/speculative
        // adapter blocks or the prefix radix is back on the free list
        let held = (e.memory().resident_count() + e.memory().prefetch_outstanding()) * 2;
        assert_eq!(e.free_pages() + held + e.prefix_pages_held(), 256);
        // the burst repeats adapters with identical task preambles, so the
        // radix must have been consulted and hit at least once
        assert!(e.stats.prefix_lookups > 0);
        assert!(e.stats.prefix_hits > 0, "repeat adapters must share prefixes");
    }

    #[test]
    fn paged_engine_preempts_under_pressure_and_loses_nothing() {
        // 12 pages, 3 slots, 24-token outputs: a full request needs 8 KV
        // pages + its 2-page adapter block, so concurrent slots must shed
        // adapters first and then preempt the newest slot
        let mut e = mk_paged_engine(4, 3, 2, 12, 4, false, "pgtight");
        let trace = burst_trace(6, 4, 8, 24);
        let s = e.run_trace(&trace).unwrap();
        assert_eq!(s.requests, 6, "preempted requests must be re-served");
        assert!(
            e.stats.preemptions > 0,
            "12-page pool with 3 growing slots must preempt"
        );
        assert!(e.memory().stats().evictions > 0, "cache shrinks before preempting");
        assert_eq!(e.kv_pages_in_use(), 0);
        assert!(!e.has_work());
    }

    fn qreq(id: u64, qos: QosClass) -> TraceRequest {
        TraceRequest {
            id,
            arrival_s: 0.0,
            true_adapter: 0,
            explicit_adapter: Some(0),
            input_tokens: 4,
            output_tokens: 64,
            qos,
            deadline_s: None,
        }
    }

    #[test]
    fn wfq_admission_prioritizes_interactive_but_never_starves_batch() {
        // queue: one Batch at the very front, then five Interactive. WFQ
        // must skip past the Batch head while Interactive's virtual finish
        // time is cheaper, then grant Batch its floor (1 in 5 admissions at
        // batch_weight 0.25) before the last Interactive — priority without
        // starvation, arrival order preserved within each class.
        let mut e = mk_engine(4, 1, EngineKind::EdgeLoraNoAas, "wfq");
        e.push_request(qreq(1, QosClass::Batch));
        for id in 2..=6 {
            e.push_request(qreq(id, QosClass::Interactive));
        }
        let mut order = Vec::new();
        while !e.queue.is_empty() {
            let qi = e.next_queue_index();
            let r = e.queue.remove(qi).unwrap();
            match r.qos {
                QosClass::Interactive => e.served_interactive += 1,
                QosClass::Batch => e.served_batch += 1,
            }
            order.push(r.id);
        }
        assert_eq!(order, vec![2, 3, 4, 5, 1, 6]);
    }

    #[test]
    fn preemption_victimizes_batch_before_interactive() {
        // Tilt the WFQ counter so Batch wins the *first* admission (slot 0)
        // and the two Interactive requests land in slots 1-2. All three
        // share one admission instant, so the pre-QoS "newest slot loses"
        // tie-break alone would pick slot 2 — an Interactive. With QoS on,
        // the Batch slot must lose first regardless of admission recency.
        let mut e = mk_engine(4, 3, EngineKind::EdgeLoraNoAas, "qosvictim");
        e.served_interactive = 100;
        e.push_request(qreq(1, QosClass::Batch));
        e.push_request(qreq(2, QosClass::Interactive));
        e.push_request(qreq(3, QosClass::Interactive));
        e.step().unwrap();
        assert_eq!(e.active_slots(), 3);
        assert_eq!(e.slots[0].record.qos, QosClass::Batch);
        let v = e.preempt_victim(usize::MAX).expect("non-idle slots exist");
        assert_eq!(v, 0, "Batch is victimized before Interactive");
        e.cfg.qos = false;
        assert_eq!(
            e.preempt_victim(usize::MAX),
            Some(2),
            "without QoS the newest slot (index tie-break) loses"
        );
    }

    #[test]
    fn paged_kv_append_steady_state_is_allocation_free() {
        let mut e = mk_paged_engine(4, 4, 4, 512, 16, false, "pgalloc");
        // warm one short trace, then saturate decode: KV tables keep
        // growing off the free list without any table reallocating
        let trace = burst_trace(6, 4, 8, 8);
        e.run_trace(&trace).unwrap();
        e.bench_fill_generating(4, 200).unwrap();
        e.decode_tick_once().unwrap();
        let scratch = e.scratch_footprint();
        let kv = e.kv_footprint();
        assert!(!kv.is_empty());
        for _ in 0..150 {
            e.decode_tick_once().unwrap();
        }
        assert_eq!(scratch, e.scratch_footprint(), "decode tick allocated");
        assert_eq!(kv, e.kv_footprint(), "KV append path allocated");
        assert!(e.stats.kv_page_faults > 0, "growth happened through pages");
    }

    #[test]
    fn unpaged_engine_reports_no_pages() {
        let e = mk_engine(4, 2, EngineKind::EdgeLora, "nopg");
        assert!(!e.paged());
        assert_eq!(e.total_pages(), 0);
        assert_eq!(e.free_pages(), 0);
        assert!(e.kv_footprint().is_empty());
    }

    #[test]
    fn submit_streams_lifecycle_events_bit_identical_to_push_request() {
        let trace = short_trace(8, 20.0, 5.0);
        let n = trace.len();
        assert!(n > 2);
        // reference: the fire-and-forget contract, nobody listening
        let mut a = mk_engine(8, 4, EngineKind::EdgeLoraNoAas, "ev_ref");
        for r in trace.requests.iter().cloned() {
            a.push_request(TraceRequest { arrival_s: 0.0, ..r });
        }
        a.drain().unwrap();
        // streamed: same burst through submit, with a tap + per-request subs
        let mut b = mk_engine(8, 4, EngineKind::EdgeLoraNoAas, "ev_sub");
        let bus = b.events();
        let tap = bus.tap();
        let per: Vec<_> = trace
            .requests
            .iter()
            .map(|r| (r.id, bus.subscribe(r.id)))
            .collect();
        for r in trace.requests.iter().cloned() {
            let id = b.submit(TraceRequest { arrival_s: 0.0, ..r });
            assert_eq!(id, r.id);
        }
        b.drain().unwrap();
        // observation must not perturb generation: identical checksums
        assert_eq!(b.stats.token_checksum, a.stats.token_checksum);
        // the tap's Token events, folded in emission order, ARE the checksum
        let mut fold = 0u64;
        for (_, ev) in tap.try_iter() {
            if let EngineEvent::Token { token, .. } = ev {
                fold = fold.rotate_left(1) ^ token as u64;
            }
        }
        assert_eq!(fold, b.stats.token_checksum, "stream lost or reordered tokens");
        // every per-request stream is ordered and complete
        for (_, rx) in per {
            let evs: Vec<EngineEvent> = rx.try_iter().collect();
            assert!(matches!(evs[0], EngineEvent::Queued { .. }), "{evs:?}");
            assert!(matches!(evs[1], EngineEvent::Admitted { .. }), "{evs:?}");
            let idx: Vec<u32> = evs
                .iter()
                .filter_map(|e| match e {
                    EngineEvent::Token { index, .. } => Some(*index),
                    _ => None,
                })
                .collect();
            assert!(!idx.is_empty());
            assert_eq!(idx, (0..idx.len() as u32).collect::<Vec<_>>());
            assert!(matches!(evs.last(), Some(EngineEvent::Done { .. })), "{evs:?}");
        }
    }

    #[test]
    fn cancel_releases_queue_slot_and_pins() {
        let mut e = mk_engine(8, 2, EngineKind::EdgeLoraNoAas, "cancel");
        let trace = short_trace(8, 20.0, 5.0);
        let n = trace.len();
        assert!(n >= 4);
        let bus = e.events();
        let tap = bus.tap();
        for r in trace.requests.iter().cloned() {
            e.submit(TraceRequest { arrival_s: 0.0, ..r });
        }
        // cancel one straight out of the queue (never admitted)
        let queued_victim = trace.requests.last().unwrap().id;
        assert!(e.cancel(queued_victim).unwrap());
        // step until some other request is mid-generation, then cancel it
        let mut all: Vec<(u64, EngineEvent)> = tap.try_iter().collect();
        let mut gen_victim = None;
        while gen_victim.is_none() {
            e.step().unwrap();
            for (id, ev) in tap.try_iter() {
                if gen_victim.is_none()
                    && matches!(ev, EngineEvent::Token { index: 0, .. })
                {
                    gen_victim = Some(id);
                }
                all.push((id, ev));
            }
        }
        let v = gen_victim.unwrap();
        assert!(e.cancel(v).unwrap(), "mid-generation cancel");
        assert!(!e.cancel(v).unwrap(), "second cancel is a no-op");
        assert!(!e.cancel(12345).unwrap(), "unknown id");
        e.drain().unwrap();
        all.extend(tap.try_iter());
        assert_eq!(e.stats.cancelled, 2);
        assert_eq!(e.recorder.completed(), n as u64 - 2);
        assert_eq!(e.active_slots(), 0);
        assert_eq!(e.memory().pinned_count(), 0, "cancel must unpin");
        // each cancelled stream ends at Cancelled, with nothing after
        for victim in [queued_victim, v] {
            let evs: Vec<&EngineEvent> =
                all.iter().filter(|(id, _)| *id == victim).map(|(_, e)| e).collect();
            assert!(matches!(evs.last(), Some(EngineEvent::Cancelled)), "{evs:?}");
            assert!(!evs.iter().any(|e| matches!(e, EngineEvent::Done { .. })));
        }
        assert!(
            all.iter()
                .filter(|(id, _)| *id == queued_victim)
                .all(|(_, e)| !matches!(e, EngineEvent::Token { .. })),
            "a queue-cancelled request must emit no tokens"
        );
    }

    #[test]
    fn paged_cancel_mid_generation_frees_all_pages() {
        let mut e = mk_paged_engine(4, 3, 2, 64, 4, false, "pgcancel");
        let trace = burst_trace(6, 4, 8, 24);
        let bus = e.events();
        let tap = bus.tap();
        for r in trace.requests.iter().cloned() {
            e.submit(r);
        }
        // step until two requests are generating, then cancel the first
        let mut generating: Vec<u64> = Vec::new();
        while generating.len() < 2 {
            e.step().unwrap();
            for (id, ev) in tap.try_iter() {
                if matches!(ev, EngineEvent::Token { index: 0, .. }) {
                    generating.push(id);
                }
            }
        }
        assert!(e.cancel(generating[0]).unwrap());
        e.drain().unwrap();
        assert_eq!(e.recorder.completed(), 5);
        assert_eq!(e.stats.cancelled, 1);
        assert_eq!(e.kv_pages_in_use(), 0, "cancelled KV pages must free");
        assert_eq!(e.memory().pinned_count(), 0);
        // page conservation: free + resident/speculative blocks + radix
        // pages == capacity
        let held = (e.memory().resident_count() + e.memory().prefetch_outstanding()) * 2;
        assert_eq!(
            e.free_pages() + held + e.prefix_pages_held(),
            64,
            "cancel leaked pages"
        );
    }

    #[test]
    fn registry_pin_and_purge_lifecycle() {
        let mut e = mk_engine(16, 2, EngineKind::EdgeLoraNoAas, "registry");
        assert!(e.pin_adapter(5).unwrap());
        assert!(e.registry_pinned(5));
        assert!(e.pin_adapter(5).unwrap(), "pin is idempotent");
        // churn the cache well past capacity: the pinned adapter survives
        let trace = short_trace(16, 10.0, 10.0);
        e.run_trace(&trace).unwrap();
        assert!(e.memory().is_resident(5), "registry pin must survive churn");
        assert!(e.unpin_adapter(5));
        assert!(!e.unpin_adapter(5), "unpin is one-shot");
        assert!(!e.registry_pinned(5));
        // purge drops residency; a purge of a non-resident id is a no-op
        assert!(e.purge_adapter(5).unwrap());
        assert!(!e.memory().is_resident(5));
        assert!(!e.purge_adapter(5).unwrap());
        assert_eq!(e.memory().pinned_count(), 0);
    }

    #[test]
    fn decode_tick_steady_state_allocates_nothing() {
        let mut e = mk_engine(4, 8, EngineKind::EdgeLoraNoAas, "scratch");
        // warm: one full trace grows every scratch buffer to the slot count
        let trace = short_trace(4, 60.0, 5.0);
        e.run_trace(&trace).unwrap();
        let warm = e.scratch_footprint();
        // steady state: saturated decode ticks must not grow any buffer
        e.bench_fill_generating(8, 10_000).unwrap();
        for _ in 0..200 {
            assert!(e.decode_tick_once().unwrap());
        }
        assert_eq!(warm, e.scratch_footprint(), "per-tick allocation detected");
    }

    // --- chunked prefill (DESIGN.md §Chunked prefill & hot-path) ---

    /// 4-slot unpaged engine with an 8k context cap (room for a 4k prompt)
    /// and an explicit chunk budget; explicit adapters, no prefetch, so the
    /// only clock charges are prefill and decode.
    fn mk_longprompt_engine(chunk_tokens: usize, tag: &str) -> EdgeLoraEngine {
        let dir = std::env::temp_dir().join(format!(
            "elra_chunk_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = AdapterStore::create(&dir, SHAPE, QuantType::Q8_0).unwrap();
        store.populate_synthetic(4).unwrap();
        let store = Arc::new(store);
        let clock: Arc<VirtualClock> = Arc::new(VirtualClock::new());
        let backend = SimBackend::new(
            DeviceProfile::agx_orin(),
            ModelSetting::s3(),
            clock.clone(),
            4,
            4,
            None,
        )
        .unwrap()
        .with_max_seq(8192);
        let memory = AdapterMemoryManager::new(store, 4, CachePolicy::Lru);
        let world = TaskWorld::synthetic(4, 4, 1);
        let router = TaskModelRouter::new(world.acc.clone(), 0.95, 2);
        EdgeLoraEngine::new(
            Box::new(backend),
            memory,
            Box::new(router),
            clock,
            ServerConfig {
                slots: 4,
                top_k: 3,
                cache_capacity: Some(4),
                engine: EngineKind::EdgeLoraNoAas,
                prefetch: false,
                prefill_chunk_tokens: chunk_tokens,
                ..ServerConfig::default()
            },
        )
    }

    fn chunk_req(id: u64, input: usize, output: usize) -> TraceRequest {
        TraceRequest {
            id,
            arrival_s: 0.0,
            true_adapter: 0,
            explicit_adapter: Some(0),
            input_tokens: input,
            output_tokens: output,
            qos: QosClass::Interactive,
            deadline_s: None,
        }
    }

    /// Run the long-prompt admission scenario: 3 residents decode steadily,
    /// then a 4k-prompt single-output-token request arrives. Returns per-
    /// request `(token, t)` streams, the admission window `[t0, t1]` (submit
    /// → long-request Done), and the preemption count. `preempt_past`
    /// preempts the long request once its prefill offset passes the value
    /// (mid-prefill restart must stay deterministic).
    fn run_long_prompt(
        chunk_cfg: usize,
        resident_out: usize,
        preempt_past: Option<usize>,
        tag: &str,
    ) -> (
        std::collections::BTreeMap<u64, Vec<(u32, f64)>>,
        f64,
        f64,
        u64,
    ) {
        let mut e = mk_longprompt_engine(chunk_cfg, tag);
        let bus = e.events();
        let tap = bus.tap();
        let mut streams: std::collections::BTreeMap<u64, Vec<(u32, f64)>> =
            std::collections::BTreeMap::new();
        e.begin();
        for a in 0..3u64 {
            e.submit(chunk_req(a + 1, 16, resident_out));
        }
        // warm until all three residents decode steadily (bounded: even a
        // 1-token chunk budget admits 3×16 prompt tokens within 60 ticks)
        for _ in 0..80 {
            e.step().unwrap();
            for (id, ev) in tap.try_iter() {
                if let EngineEvent::Token { token, t, .. } = ev {
                    streams.entry(id).or_default().push((token, t));
                }
            }
            if (1..=3).all(|id| streams.get(&id).is_some_and(|s| s.len() >= 10)) {
                break;
            }
        }
        assert!(
            (1..=3).all(|id| streams.get(&id).is_some_and(|s| s.len() >= 10)),
            "residents failed to reach steady decode during warmup"
        );
        let t0 = e.local_now();
        e.submit(chunk_req(9, 4096, 1));
        let mut preempted = false;
        let mut long_done = f64::NAN;
        while e.has_work() {
            if let Some(past) = preempt_past {
                if !preempted {
                    let hit = e.slots.iter().position(|s| {
                        matches!(s.state, SlotState::Prefilling { next_offset } if next_offset >= past)
                    });
                    if let Some(j) = hit {
                        e.preempt_slot(j).unwrap();
                        preempted = true;
                    }
                }
            }
            e.step().unwrap();
            for (id, ev) in tap.try_iter() {
                match ev {
                    EngineEvent::Token { token, t, .. } => {
                        streams.entry(id).or_default().push((token, t));
                    }
                    EngineEvent::Done { t } if id == 9 => long_done = t,
                    _ => {}
                }
            }
        }
        assert!(long_done.is_finite(), "long request must complete");
        (streams, t0, long_done, e.stats.preemptions)
    }

    /// Worst resident inter-token gap whose *later* token lands in
    /// `(t0, t1]` — the admission window tail metric (deterministic sim, so
    /// the max IS the p99).
    fn max_resident_gap(
        streams: &std::collections::BTreeMap<u64, Vec<(u32, f64)>>,
        t0: f64,
        t1: f64,
    ) -> f64 {
        let mut worst = 0.0f64;
        for id in 1..=3u64 {
            let toks = &streams[&id];
            for w in toks.windows(2) {
                if w[1].1 > t0 && w[1].1 <= t1 {
                    worst = worst.max(w[1].1 - w[0].1);
                }
            }
        }
        assert!(worst > 0.0, "no resident tokens inside the window");
        worst
    }

    #[test]
    fn chunked_prefill_keeps_decode_itl_flat() {
        // chunk sized so one chunk costs ≤15% of a 3-row decode step — the
        // interleaved gap then stays within the 1.2x flatness bound
        let tm = crate::backend::devices::TimingModel::new(
            &DeviceProfile::agx_orin(),
            &ModelSetting::s3(),
            None,
        );
        let baseline = tm.decode_step_s(3);
        let chunk = ((0.15 * baseline / tm.prefill_s(1)) as usize).max(1);
        // residents must outlive the whole chunked prefill (plus warmup)
        let resident_out = 4096usize.div_ceil(chunk) + 150;

        // window end extends past Done by two decode steps: the resident
        // tokens of the long request's final tick land just *after* its
        // Done timestamp (prefill spends before decode within a tick)
        let (chunked, t0, t1, _) =
            run_long_prompt(chunk, resident_out, None, "itl_chunk");
        let gap = max_resident_gap(&chunked, t0, t1 + 2.5 * baseline);
        assert!(
            gap <= 1.2 * baseline,
            "chunked admission gap {gap:.4}s vs baseline ITL {baseline:.4}s"
        );

        // monolithic prefill of the same prompt stalls residents for the
        // whole 4k prefill — the regression chunking exists to prevent
        let (mono, m0, m1, _) = run_long_prompt(0, resident_out, None, "itl_mono");
        let mono_gap = max_resident_gap(&mono, m0, m1 + 2.5 * baseline);
        assert!(
            mono_gap > 3.0 * baseline,
            "monolithic gap {mono_gap:.4}s should dwarf baseline {baseline:.4}s"
        );

        // bit-identity: every request's token stream is identical under
        // chunked and monolithic prefill (timestamps differ; values cannot)
        let values = |s: &std::collections::BTreeMap<u64, Vec<(u32, f64)>>, id: u64| {
            s[&id].iter().map(|&(tok, _)| tok).collect::<Vec<u32>>()
        };
        for id in [1u64, 2, 3, 9] {
            assert_eq!(
                values(&chunked, id),
                values(&mono, id),
                "request {id}: chunked stream diverged from monolithic"
            );
        }

        // ...including under mid-prefill preemption: the restarted suffix
        // recomputes deterministically
        let (pre, _, _, preemptions) =
            run_long_prompt(chunk, resident_out, Some(1000), "itl_pre");
        assert_eq!(preemptions, 1, "exactly one mid-prefill preemption");
        for id in [1u64, 2, 3, 9] {
            assert_eq!(
                values(&chunked, id),
                values(&pre, id),
                "request {id}: stream changed across mid-prefill preemption"
            );
        }
    }

    #[test]
    fn default_chunk_cap_never_chunks_paper_workloads() {
        // default cap (512) exceeds the sim's max prompt (max_seq/2 = 256),
        // so every existing trace prefills monolithically — checksum parity
        // with an explicitly-monolithic engine pins the no-op
        let trace = short_trace(6, 8.0, 15.0);
        let mut def = mk_engine(6, 4, EngineKind::EdgeLoraNoAas, "defcap");
        def.run_trace(&trace).unwrap();
        let mut mono = mk_engine_cfg(6, 4, EngineKind::EdgeLoraNoAas, true, "monocap");
        mono.cfg.prefill_chunk_tokens = 0;
        mono.run_trace(&trace).unwrap();
        assert_eq!(def.stats.token_checksum, mono.stats.token_checksum);
        assert_eq!(def.stats.decode_steps, mono.stats.decode_steps);
    }
}
