//! Adaptive adapter selection — Algorithm 1 of the paper.
//!
//! Given a prompt:
//!  1. explicit adapter id ⇒ use it (bypass).
//!  2. otherwise ask the router for confidence scores, take the top-k
//!     candidate set A′,
//!  3. walk A′ in descending confidence; the first candidate already in the
//!     memory cache wins (zero load cost),
//!  4. if none is cached, load the top-scored candidate.
//!
//! This module is pure decision logic: it inspects cache residency through
//! a read-only view and reports what to do; the engine performs the actual
//! load + bank upload and charges the router pass's compute cost.

use crate::adapters::AdapterId;
use crate::router::{AdapterRouter, RouterPrompt};

/// Read-only residency view (implemented by the memory manager).
pub trait ResidencyView {
    fn is_resident(&self, id: AdapterId) -> bool;
}

impl ResidencyView for crate::memory::AdapterMemoryManager {
    fn is_resident(&self, id: AdapterId) -> bool {
        self.is_resident(id)
    }
}

/// Outcome of the selection decision.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Selection {
    pub adapter: AdapterId,
    /// candidate was already in the memory cache
    pub cached: bool,
    /// adaptive path taken (false = explicit bypass)
    pub auto: bool,
    /// the top-k candidate set the router produced (empty for explicit)
    pub candidates: Vec<AdapterId>,
}

/// Algorithm 1. `router_paid` lets the caller know a router forward pass is
/// required (the engine charges one prompt-decode's compute for it).
pub fn select_adapter(
    prompt: &RouterPrompt,
    explicit: Option<AdapterId>,
    router: &dyn AdapterRouter,
    residency: &dyn ResidencyView,
    top_k: usize,
) -> Selection {
    // Line 1–2: explicit bypass.
    if let Some(id) = explicit {
        return Selection {
            adapter: id,
            cached: residency.is_resident(id),
            auto: false,
            candidates: Vec::new(),
        };
    }
    // Lines 8–9: scores → top-k candidate set A′.
    let candidates = router.top_k(prompt, top_k.max(1));
    assert!(!candidates.is_empty(), "router returned no candidates");
    // Lines 10–12: first cached candidate in descending confidence.
    for &c in &candidates {
        if residency.is_resident(c) {
            return Selection {
                adapter: c,
                cached: true,
                auto: true,
                candidates,
            };
        }
    }
    // Lines 13–14: none cached — load the highest-scored.
    Selection {
        adapter: candidates[0],
        cached: false,
        auto: true,
        candidates,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::AdapterRouter;
    use std::collections::BTreeSet;

    struct FixedRouter(Vec<f32>);
    impl AdapterRouter for FixedRouter {
        fn scores(&self, _p: &RouterPrompt) -> Vec<f32> {
            self.0.clone()
        }
    }

    struct SetView(BTreeSet<AdapterId>);
    impl ResidencyView for SetView {
        fn is_resident(&self, id: AdapterId) -> bool {
            self.0.contains(&id)
        }
    }

    fn prompt() -> RouterPrompt {
        RouterPrompt {
            tokens: vec![1, 2],
            latent_task: None,
        }
    }

    #[test]
    fn explicit_bypasses_router() {
        let router = FixedRouter(vec![0.9, 0.1]);
        let view = SetView([5].into_iter().collect());
        let s = select_adapter(&prompt(), Some(5), &router, &view, 3);
        assert_eq!(s.adapter, 5);
        assert!(!s.auto);
        assert!(s.cached);
        assert!(s.candidates.is_empty());
    }

    #[test]
    fn prefers_cached_candidate_over_higher_score() {
        // scores: 3 > 1 > 0 > 2; cache holds {1}; top-3 = [3,1,0] → pick 1.
        let router = FixedRouter(vec![0.5, 0.7, 0.1, 0.9]);
        let view = SetView([1].into_iter().collect());
        let s = select_adapter(&prompt(), None, &router, &view, 3);
        assert_eq!(s.adapter, 1);
        assert!(s.cached && s.auto);
        assert_eq!(s.candidates, vec![3, 1, 0]);
    }

    #[test]
    fn loads_top_scored_when_none_cached() {
        let router = FixedRouter(vec![0.5, 0.7, 0.1, 0.9]);
        let view = SetView(BTreeSet::new());
        let s = select_adapter(&prompt(), None, &router, &view, 2);
        assert_eq!(s.adapter, 3);
        assert!(!s.cached);
    }

    #[test]
    fn cached_outside_top_k_is_ignored() {
        // cache holds {2} but 2 is not in top-2 — Algorithm 1 only checks A′.
        let router = FixedRouter(vec![0.5, 0.7, 0.1, 0.9]);
        let view = SetView([2].into_iter().collect());
        let s = select_adapter(&prompt(), None, &router, &view, 2);
        assert_eq!(s.adapter, 3);
        assert!(!s.cached);
    }

    #[test]
    fn descending_order_among_cached() {
        // both 1 and 0 cached; 1 scores higher → pick 1.
        let router = FixedRouter(vec![0.7, 0.8, 0.1]);
        let view = SetView([0, 1].into_iter().collect());
        let s = select_adapter(&prompt(), None, &router, &view, 3);
        assert_eq!(s.adapter, 1);
    }

    #[test]
    fn top_k_one() {
        let router = FixedRouter(vec![0.2, 0.9]);
        let view = SetView([0].into_iter().collect());
        let s = select_adapter(&prompt(), None, &router, &view, 1);
        // k=1: only candidate is 1, not cached → load it (0's residency moot)
        assert_eq!(s.adapter, 1);
        assert!(!s.cached);
    }
}
