//! Q4_0 block quantization (ggml layout): 32 values per block, one f16
//! scale + 16 bytes of packed 4-bit quants. `q = round(x/d) + 8` with
//! `d = -max|x| / 8` sign convention folded into the scale (we use the
//! simpler symmetric form `d = amax/7` with offset 8, preserving the wire
//! *size*; absolute layouts differ across ggml versions anyway and nothing
//! downstream depends on bit-compatibility, only on size + error bounds).

use crate::quant::{f16_bits_to_f32, f32_to_f16_bits, BLOCK};

/// Bytes per block: 2 (f16 scale) + 16 (packed nibbles).
pub const BLOCK_BYTES: usize = 2 + BLOCK / 2;

pub fn storage_bytes(n: usize) -> usize {
    n.div_ceil(BLOCK) * BLOCK_BYTES
}

pub fn quantize(values: &[f32]) -> Vec<u8> {
    let n_blocks = values.len().div_ceil(BLOCK);
    let mut out = Vec::with_capacity(n_blocks * BLOCK_BYTES);
    for b in 0..n_blocks {
        let chunk = &values[b * BLOCK..((b + 1) * BLOCK).min(values.len())];
        let amax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let d = amax / 7.0;
        let inv = if d > 0.0 { 1.0 / d } else { 0.0 };
        out.extend_from_slice(&f32_to_f16_bits(d).to_le_bytes());
        for i in 0..BLOCK / 2 {
            let enc = |j: usize| -> u8 {
                let x = chunk.get(j).copied().unwrap_or(0.0);
                // signed 4-bit: [-7, 7] biased to [1, 15]; 8 = zero
                ((x * inv).round().clamp(-7.0, 7.0) as i8 + 8) as u8
            };
            out.push(enc(2 * i) | (enc(2 * i + 1) << 4));
        }
    }
    out
}

/// One full block: fixed-size in/out arrays so every loop has a constant
/// trip count and zero bounds checks, and the nibble unpack runs as two
/// planar stride-1 passes (low lanes, high lanes) instead of interleaved
/// scalar stores — the shape LLVM autovectorizes into widening byte→f32
/// lane ops with a broadcast scale multiply.
#[inline]
fn dequant_block(packed: &[u8; BLOCK / 2], d: f32, ob: &mut [f32; BLOCK]) {
    let mut lo = [0.0f32; BLOCK / 2];
    let mut hi = [0.0f32; BLOCK / 2];
    for i in 0..BLOCK / 2 {
        lo[i] = ((packed[i] & 0x0f) as i32 - 8) as f32;
        hi[i] = ((packed[i] >> 4) as i32 - 8) as f32;
    }
    for i in 0..BLOCK / 2 {
        ob[2 * i] = lo[i] * d;
        ob[2 * i + 1] = hi[i] * d;
    }
}

/// Dequantize into a caller-provided slice (`out.len()` values). Full blocks
/// unpack two nibbles per byte with no per-element bounds test — the
/// bank-upload hot loop of an adapter swap.
pub fn dequantize_into(bytes: &[u8], out: &mut [f32]) {
    let n = out.len();
    let full = n / BLOCK;
    for (blk, ob) in bytes
        .chunks_exact(BLOCK_BYTES)
        .take(full)
        .zip(out.chunks_exact_mut(BLOCK))
    {
        let d = f16_bits_to_f32(u16::from_le_bytes([blk[0], blk[1]]));
        let packed: &[u8; BLOCK / 2] = blk[2..].try_into().unwrap();
        dequant_block(packed, d, ob.try_into().unwrap());
    }
    let rem = n - full * BLOCK;
    if rem > 0 {
        let base = full * BLOCK_BYTES;
        let d = f16_bits_to_f32(u16::from_le_bytes([bytes[base], bytes[base + 1]]));
        let ob = &mut out[full * BLOCK..];
        for i in 0..rem {
            let byte = bytes[base + 2 + i / 2];
            let nib = if i % 2 == 0 { byte & 0x0f } else { byte >> 4 };
            ob[i] = (nib as i32 - 8) as f32 * d;
        }
    }
}

/// Dequantize `n` values from Q4_0 blocks (allocating wrapper).
pub fn dequantize(bytes: &[u8], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    dequantize_into(bytes, &mut out);
    out
}

/// Worst-case absolute error: half a 4-bit step of the block max.
pub fn error_bound(block_amax: f32) -> f32 {
    block_amax * (0.5 / 7.0 + 1.0 / 2048.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_vec(n: usize, scale: f32, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| (rng.next_f32() - 0.5) * 2.0 * scale).collect()
    }

    #[test]
    fn roundtrip_error_within_bound() {
        let xs = rand_vec(128, 2.0, 7);
        let back = dequantize(&quantize(&xs), xs.len());
        for (bi, chunk) in xs.chunks(BLOCK).enumerate() {
            let amax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let bound = error_bound(amax);
            for (i, &x) in chunk.iter().enumerate() {
                let d = back[bi * BLOCK + i];
                assert!((x - d).abs() <= bound, "{x} vs {d} (bound {bound})");
            }
        }
    }

    #[test]
    fn four_bits_is_lossier_than_eight() {
        let xs = rand_vec(256, 1.0, 9);
        let e4: f32 = dequantize(&quantize(&xs), 256)
            .iter()
            .zip(&xs)
            .map(|(a, b)| (a - b).abs())
            .sum();
        let e8: f32 = crate::quant::q8_0::dequantize(
            &crate::quant::q8_0::quantize(&xs),
            256,
        )
        .iter()
        .zip(&xs)
        .map(|(a, b)| (a - b).abs())
        .sum();
        assert!(e4 > e8 * 2.0, "q4 err {e4} vs q8 err {e8}");
    }

    #[test]
    fn storage_is_half_of_q8() {
        let n = 4096;
        assert!(storage_bytes(n) * 17 == crate::quant::q8_0::storage_bytes(n) * 9);
    }

    #[test]
    fn odd_tail() {
        let xs = rand_vec(37, 1.0, 11);
        assert_eq!(dequantize(&quantize(&xs), 37).len(), 37);
    }

    /// Independent per-element reference decoder (no shared code with the
    /// block-loop `dequantize_into`) — guards the wire layout itself,
    /// including low-nibble-first packing.
    fn oracle(bytes: &[u8], n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let base = (i / BLOCK) * BLOCK_BYTES;
                let d = f16_bits_to_f32(u16::from_le_bytes([bytes[base], bytes[base + 1]]));
                let byte = bytes[base + 2 + (i % BLOCK) / 2];
                let nib = if i % 2 == 0 { byte & 0x0f } else { byte >> 4 };
                (nib as i32 - 8) as f32 * d
            })
            .collect()
    }

    #[test]
    fn dequantize_into_matches_independent_oracle() {
        for n in [1usize, 31, 32, 37, 64, 129] {
            let xs = rand_vec(n, 2.0, 100 + n as u64);
            let q = quantize(&xs);
            let expect = oracle(&q, n);
            assert_eq!(dequantize(&q, n), expect, "vec path n={n}");
            let mut via_slice = vec![f32::NAN; n];
            dequantize_into(&q, &mut via_slice);
            assert_eq!(via_slice, expect, "slice path n={n}");
        }
    }

    #[test]
    fn zeros_exact() {
        let xs = vec![0.0f32; 32];
        assert_eq!(dequantize(&quantize(&xs), 32), xs);
    }
}
