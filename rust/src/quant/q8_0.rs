//! Q8_0 block quantization (ggml layout): 32 values per block, one f16
//! scale + 32 signed-byte quants. `q = round(x / d)` with `d = max|x| / 127`.

use crate::quant::{f16_bits_to_f32, f32_to_f16_bits, BLOCK};

/// Bytes per block on the wire: 2 (f16 scale) + 32 (i8 quants).
pub const BLOCK_BYTES: usize = 2 + BLOCK;

pub fn storage_bytes(n: usize) -> usize {
    n.div_ceil(BLOCK) * BLOCK_BYTES
}

/// Quantize to Q8_0 blocks. The tail block is zero-padded.
pub fn quantize(values: &[f32]) -> Vec<u8> {
    let n_blocks = values.len().div_ceil(BLOCK);
    let mut out = Vec::with_capacity(n_blocks * BLOCK_BYTES);
    for b in 0..n_blocks {
        let chunk = &values[b * BLOCK..((b + 1) * BLOCK).min(values.len())];
        let amax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let d = amax / 127.0;
        let inv = if d > 0.0 { 1.0 / d } else { 0.0 };
        out.extend_from_slice(&f32_to_f16_bits(d).to_le_bytes());
        for i in 0..BLOCK {
            let x = chunk.get(i).copied().unwrap_or(0.0);
            let q = (x * inv).round().clamp(-127.0, 127.0) as i8;
            out.push(q as u8);
        }
    }
    out
}

/// One full block: fixed-size in/out arrays — constant trip count, zero
/// bounds checks — so the i8→f32 widening + broadcast scale multiply
/// autovectorizes into straight SIMD lanes.
#[inline]
fn dequant_block(quants: &[u8; BLOCK], d: f32, ob: &mut [f32; BLOCK]) {
    for i in 0..BLOCK {
        ob[i] = quants[i] as i8 as f32 * d;
    }
}

/// Dequantize into a caller-provided slice (`out.len()` values). The full
/// blocks run branch-free (no per-element bounds test, no Vec growth) — this
/// is the bank-upload hot loop of an adapter swap.
pub fn dequantize_into(bytes: &[u8], out: &mut [f32]) {
    let n = out.len();
    let full = n / BLOCK;
    for (blk, ob) in bytes
        .chunks_exact(BLOCK_BYTES)
        .take(full)
        .zip(out.chunks_exact_mut(BLOCK))
    {
        let d = f16_bits_to_f32(u16::from_le_bytes([blk[0], blk[1]]));
        let quants: &[u8; BLOCK] = blk[2..].try_into().unwrap();
        dequant_block(quants, d, ob.try_into().unwrap());
    }
    let rem = n - full * BLOCK;
    if rem > 0 {
        let base = full * BLOCK_BYTES;
        let d = f16_bits_to_f32(u16::from_le_bytes([bytes[base], bytes[base + 1]]));
        let ob = &mut out[full * BLOCK..];
        for i in 0..rem {
            ob[i] = bytes[base + 2 + i] as i8 as f32 * d;
        }
    }
}

/// Dequantize `n` values from Q8_0 blocks (allocating wrapper).
pub fn dequantize(bytes: &[u8], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    dequantize_into(bytes, &mut out);
    out
}

/// Worst-case relative error of a Q8_0 round trip: half a quantization step
/// relative to the block max, plus the f16 scale error (~2^-11).
pub fn error_bound(block_amax: f32) -> f32 {
    block_amax * (0.5 / 127.0 + 1.0 / 2048.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_vec(n: usize, scale: f32, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| (rng.next_f32() - 0.5) * 2.0 * scale).collect()
    }

    #[test]
    fn roundtrip_error_within_bound() {
        let xs = rand_vec(256, 3.0, 1);
        let q = quantize(&xs);
        let back = dequantize(&q, xs.len());
        for (bi, chunk) in xs.chunks(BLOCK).enumerate() {
            let amax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let bound = error_bound(amax);
            for (i, &x) in chunk.iter().enumerate() {
                let d = back[bi * BLOCK + i];
                assert!(
                    (x - d).abs() <= bound,
                    "block {bi} idx {i}: {x} vs {d} bound {bound}"
                );
            }
        }
    }

    #[test]
    fn storage_size_exact() {
        assert_eq!(quantize(&rand_vec(64, 1.0, 2)).len(), storage_bytes(64));
        assert_eq!(quantize(&rand_vec(33, 1.0, 3)).len(), storage_bytes(33));
        assert_eq!(storage_bytes(33), 2 * BLOCK_BYTES);
    }

    #[test]
    fn zeros_roundtrip_exact() {
        let xs = vec![0.0f32; 64];
        assert_eq!(dequantize(&quantize(&xs), 64), xs);
    }

    #[test]
    fn tail_block_handled() {
        let xs = rand_vec(40, 1.0, 4);
        let back = dequantize(&quantize(&xs), 40);
        assert_eq!(back.len(), 40);
    }

    /// Independent per-element reference decoder (no shared code with the
    /// block-loop `dequantize_into`) — guards the wire layout itself.
    fn oracle(bytes: &[u8], n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let base = (i / BLOCK) * BLOCK_BYTES;
                let d = f16_bits_to_f32(u16::from_le_bytes([bytes[base], bytes[base + 1]]));
                bytes[base + 2 + i % BLOCK] as i8 as f32 * d
            })
            .collect()
    }

    #[test]
    fn dequantize_into_matches_independent_oracle() {
        for n in [1usize, 31, 32, 33, 64, 257] {
            let xs = rand_vec(n, 2.0, n as u64);
            let q = quantize(&xs);
            let expect = oracle(&q, n);
            assert_eq!(dequantize(&q, n), expect, "vec path n={n}");
            let mut via_slice = vec![f32::NAN; n];
            dequantize_into(&q, &mut via_slice);
            assert_eq!(via_slice, expect, "slice path n={n}");
        }
    }

    #[test]
    fn preserves_sign_and_extremes() {
        let mut xs = vec![0.0f32; 32];
        xs[0] = 5.0;
        xs[1] = -5.0;
        let back = dequantize(&quantize(&xs), 32);
        assert!((back[0] - 5.0).abs() < 0.05);
        assert!((back[1] + 5.0).abs() < 0.05);
    }
}
