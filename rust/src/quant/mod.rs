//! Block quantization in the llama.cpp wire layouts the paper's adapter
//! configurations use (Table 2: Q8_0 for S1 adapters, Q4_0 for S2/S3).
//!
//! Adapters are stored on disk quantized and dequantized into the memory
//! pool when loaded — quantization is what makes a rank-32 8B-scale adapter
//! small enough to hold thousands of them on an edge device's disk.

pub mod q4_0;
pub mod q8_0;

/// Quantization formats supported by the adapter store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantType {
    F32,
    Q8_0,
    Q4_0,
}

impl QuantType {
    pub fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "F32" => Some(Self::F32),
            "Q8_0" => Some(Self::Q8_0),
            "Q4_0" => Some(Self::Q4_0),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::F32 => "F32",
            Self::Q8_0 => "Q8_0",
            Self::Q4_0 => "Q4_0",
        }
    }

    /// Stored bytes for `n` f32 values (n must be block-aligned for quantized
    /// types; the store pads).
    pub fn storage_bytes(&self, n: usize) -> usize {
        match self {
            Self::F32 => n * 4,
            Self::Q8_0 => q8_0::storage_bytes(n),
            Self::Q4_0 => q4_0::storage_bytes(n),
        }
    }

    pub fn quantize(&self, values: &[f32]) -> Vec<u8> {
        match self {
            Self::F32 => values.iter().flat_map(|v| v.to_le_bytes()).collect(),
            Self::Q8_0 => q8_0::quantize(values),
            Self::Q4_0 => q4_0::quantize(values),
        }
    }

    pub fn dequantize(&self, bytes: &[u8], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n];
        self.dequantize_into(bytes, &mut out);
        out
    }

    /// Dequantize into a caller-provided slice (`out.len()` values) with no
    /// allocation — the adapter-swap hot path dequantizes straight from the
    /// pool block into the backend's bank staging buffer.
    pub fn dequantize_into(&self, bytes: &[u8], out: &mut [f32]) {
        match self {
            Self::F32 => {
                for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
                    *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
            }
            Self::Q8_0 => q8_0::dequantize_into(bytes, out),
            Self::Q4_0 => q4_0::dequantize_into(bytes, out),
        }
    }
}

/// Elements per quantization block (shared by Q8_0 and Q4_0, as in ggml).
pub const BLOCK: usize = 32;

/// f16 encode/decode for block scales (ggml stores scales as IEEE half).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x7f_ffff;
    if exp == 0xff {
        // inf/nan
        return sign | 0x7c00 | if mant != 0 { 0x200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unbiased >= -14 {
        // normal half
        let half_exp = (unbiased + 15) as u16;
        let half_mant = (mant >> 13) as u16;
        // round-to-nearest-even on the dropped bits
        let round = (mant >> 12) & 1;
        let out = (half_exp << 10) | half_mant;
        return sign | (out + round as u16);
    }
    if unbiased >= -24 {
        // subnormal half: value = m · 2^(unbiased-23), half ulp = 2^-24,
        // so half_mant = m · 2^(unbiased+1) = m >> (-unbiased - 1).
        let m = mant | 0x80_0000;
        let shift = (-unbiased - 1) as u32;
        let half_mant = (m >> shift) as u16;
        return sign | half_mant;
    }
    sign // underflow to zero
}

pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            sign | (((127 - 15 + e + 1) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_common_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, 1e-4, -3.1415] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            let tol = (v.abs() * 1e-3).max(1e-6);
            assert!((back - v).abs() <= tol, "{v} -> {back}");
        }
    }

    #[test]
    fn f16_overflow_is_inf() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e30)).is_infinite());
    }

    #[test]
    fn quant_type_names() {
        for q in [QuantType::F32, QuantType::Q8_0, QuantType::Q4_0] {
            assert_eq!(QuantType::from_name(q.name()), Some(q));
        }
        assert_eq!(QuantType::from_name("q8_0"), Some(QuantType::Q8_0));
        assert_eq!(QuantType::from_name("nope"), None);
    }

    #[test]
    fn storage_sizes() {
        // Q8_0: 32 vals -> 2 (scale) + 32 bytes; Q4_0: 2 + 16.
        assert_eq!(QuantType::Q8_0.storage_bytes(32), 34);
        assert_eq!(QuantType::Q4_0.storage_bytes(32), 18);
        assert_eq!(QuantType::F32.storage_bytes(32), 128);
        // compression ratios the paper's configs rely on
        assert!(QuantType::Q4_0.storage_bytes(4096) * 7 < QuantType::F32.storage_bytes(4096));
    }
}
