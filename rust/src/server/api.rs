//! JSON completion API over the HTTP server — the llama.cpp-server-style
//! front-end the paper's node client talks to.
//!
//! Endpoints:
//!   GET  /health               → slot occupancy + metrics snapshot
//!   GET  /cluster              → per-replica occupancy + dispatch counters
//!                                (`serve-sim`, DESIGN.md §Cluster)
//!   POST /v1/completions       → {"prompt_tokens":[...], "max_tokens":N,
//!                                 "adapter": optional id}
//!
//! The API layer owns request parsing/validation and a bounded admission
//! queue; the engine behind it is driven by a dedicated serving thread.

use crate::metrics::Summary;
use crate::util::json::{Json, ObjBuilder};

/// A parsed completion request.
#[derive(Debug, Clone, PartialEq)]
pub struct CompletionRequest {
    pub prompt_tokens: Vec<u32>,
    pub max_tokens: usize,
    pub adapter: Option<u64>,
}

#[derive(Debug, thiserror::Error)]
pub enum ApiError {
    #[error("invalid json: {0}")]
    BadJson(String),
    #[error("{0}")]
    BadRequest(String),
}

pub fn parse_completion(body: &[u8]) -> Result<CompletionRequest, ApiError> {
    let text = std::str::from_utf8(body)
        .map_err(|e| ApiError::BadJson(e.to_string()))?;
    let j = Json::parse(text).map_err(|e| ApiError::BadJson(e.to_string()))?;
    let prompt_tokens = j
        .get("prompt_tokens")
        .and_then(Json::as_arr)
        .ok_or_else(|| ApiError::BadRequest("missing prompt_tokens".into()))?
        .iter()
        .map(|v| {
            v.as_i64()
                .filter(|&t| t >= 0)
                .map(|t| t as u32)
                .ok_or_else(|| ApiError::BadRequest("bad token id".into()))
        })
        .collect::<Result<Vec<u32>, _>>()?;
    if prompt_tokens.is_empty() {
        return Err(ApiError::BadRequest("empty prompt".into()));
    }
    let max_tokens = j
        .get("max_tokens")
        .and_then(Json::as_usize)
        .unwrap_or(16)
        .clamp(1, 4096);
    let adapter = j.get("adapter").and_then(Json::as_i64).map(|a| a as u64);
    Ok(CompletionRequest {
        prompt_tokens,
        max_tokens,
        adapter,
    })
}

/// Completion response payload.
pub fn completion_response(
    request_id: u64,
    adapter: u64,
    auto_selected: bool,
    tokens: &[u32],
    first_token_s: f64,
    total_s: f64,
) -> String {
    ObjBuilder::new()
        .num("id", request_id as f64)
        .num("adapter", adapter as f64)
        .bool("auto_selected", auto_selected)
        .val(
            "tokens",
            Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        )
        .num("first_token_s", first_token_s)
        .num("total_s", total_s)
        .build()
        .to_string()
}

/// /health payload from a metrics summary.
pub fn health_response(summary: &Summary, idle_slots: usize, total_slots: usize) -> String {
    ObjBuilder::new()
        .str("status", "ok")
        .num("idle_slots", idle_slots as f64)
        .num("total_slots", total_slots as f64)
        .num("completed_requests", summary.requests as f64)
        .num("throughput_rps", summary.throughput_rps)
        .num("avg_latency_s", summary.avg_latency_s)
        .num("avg_first_token_s", summary.avg_first_token_s)
        .num("slo_attainment", summary.slo_attainment)
        .build()
        .to_string()
}

/// One replica's row in the /cluster payload.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaStatus {
    pub queue: usize,
    pub active_slots: usize,
    pub resident_adapters: usize,
    pub clock_s: f64,
    pub dispatched: u64,
    /// unified-paging shard accounting (0/0 when the replica is unpaged)
    pub free_pages: usize,
    pub total_pages: usize,
    /// KV pages currently mapped by this shard's active slots
    pub kv_pages: usize,
    /// requests preempted-and-requeued under page pressure on this shard
    pub preemptions: u64,
    /// admissions deferred for lack of pages (queue-growth diagnostic)
    pub admission_deferrals: u64,
}

/// /cluster payload: per-replica occupancy plus cluster dispatch counters.
pub fn cluster_status_response(replicas: &[ReplicaStatus], steals: u64) -> String {
    let rows = replicas
        .iter()
        .enumerate()
        .map(|(i, r)| {
            ObjBuilder::new()
                .num("replica", i as f64)
                .num("queue", r.queue as f64)
                .num("active_slots", r.active_slots as f64)
                .num("resident_adapters", r.resident_adapters as f64)
                .num("clock_s", r.clock_s)
                .num("dispatched", r.dispatched as f64)
                .num("free_pages", r.free_pages as f64)
                .num("total_pages", r.total_pages as f64)
                .num("kv_pages", r.kv_pages as f64)
                .num("preemptions", r.preemptions as f64)
                .num("admission_deferrals", r.admission_deferrals as f64)
                .build()
        })
        .collect();
    ObjBuilder::new()
        .num("replicas", replicas.len() as f64)
        .num("steals", steals as f64)
        .val("shards", Json::Arr(rows))
        .build()
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_request() {
        let req = parse_completion(
            br#"{"prompt_tokens":[1,2,3],"max_tokens":8,"adapter":5}"#,
        )
        .unwrap();
        assert_eq!(req.prompt_tokens, vec![1, 2, 3]);
        assert_eq!(req.max_tokens, 8);
        assert_eq!(req.adapter, Some(5));
    }

    #[test]
    fn adapter_optional_and_defaults() {
        let req = parse_completion(br#"{"prompt_tokens":[7]}"#).unwrap();
        assert_eq!(req.adapter, None);
        assert_eq!(req.max_tokens, 16);
    }

    #[test]
    fn rejects_bad_payloads() {
        assert!(parse_completion(b"not json").is_err());
        assert!(parse_completion(br#"{"max_tokens":4}"#).is_err());
        assert!(parse_completion(br#"{"prompt_tokens":[]}"#).is_err());
        assert!(parse_completion(br#"{"prompt_tokens":[-1]}"#).is_err());
    }

    #[test]
    fn response_is_valid_json() {
        let s = completion_response(7, 3, true, &[10, 20], 0.25, 1.5);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("auto_selected").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn health_is_valid_json() {
        let s = health_response(&Summary::empty(), 3, 8);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("idle_slots").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("status").unwrap().as_str(), Some("ok"));
    }

    #[test]
    fn cluster_status_is_valid_json() {
        let s = cluster_status_response(
            &[
                ReplicaStatus {
                    queue: 2,
                    active_slots: 4,
                    resident_adapters: 8,
                    clock_s: 1.5,
                    dispatched: 10,
                    free_pages: 100,
                    total_pages: 128,
                    kv_pages: 12,
                    preemptions: 1,
                    admission_deferrals: 3,
                },
                ReplicaStatus {
                    queue: 0,
                    active_slots: 1,
                    resident_adapters: 3,
                    clock_s: 0.5,
                    dispatched: 4,
                    free_pages: 0,
                    total_pages: 0,
                    kv_pages: 0,
                    preemptions: 0,
                    admission_deferrals: 0,
                },
            ],
            7,
        );
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("replicas").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("steals").unwrap().as_usize(), Some(7));
        let shards = j.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].get("queue").unwrap().as_usize(), Some(2));
        assert_eq!(shards[1].get("dispatched").unwrap().as_usize(), Some(4));
        assert_eq!(shards[0].get("free_pages").unwrap().as_usize(), Some(100));
        assert_eq!(shards[0].get("total_pages").unwrap().as_usize(), Some(128));
        assert_eq!(shards[0].get("kv_pages").unwrap().as_usize(), Some(12));
        assert_eq!(shards[0].get("preemptions").unwrap().as_usize(), Some(1));
        assert_eq!(
            shards[0].get("admission_deferrals").unwrap().as_usize(),
            Some(3)
        );
    }
}
