//! JSON completion API over the HTTP server — the llama.cpp-server-style
//! front-end the paper's node client talks to (DESIGN.md §Serving API).
//!
//! Endpoints:
//!   GET    /health                  → slot occupancy + metrics snapshot
//!   GET    /cluster                 → per-replica occupancy + dispatch
//!                                     counters (`serve-sim`, §Cluster)
//!   POST   /v1/completions          → {"prompt_tokens":[...],
//!                                      "max_tokens":N, "adapter": opt id,
//!                                      "stream": opt bool}
//!                                     "stream": true answers with SSE over
//!                                     chunked transfer-encoding, one frame
//!                                     per EngineEvent
//!   POST   /v1/requests/{id}/cancel → cancel a queued/in-flight request
//!   GET    /v1/adapters             → registry listing (residency/pins)
//!   POST   /v1/adapters             → register {"id":N, "path": opt file}
//!   DELETE /v1/adapters/{id}        → drain + evict everywhere + scrub
//!   POST   /v1/adapters/{id}/pin    → fleet-wide registry pin
//!   POST   /v1/adapters/{id}/unpin  → release the registry pin
//!
//! This module owns the wire formats (parse/serialize only); routing and
//! engine plumbing live in `server::service`.

use crate::coordinator::EngineEvent;
use crate::metrics::{ClassSummary, Summary};
use crate::util::json::{Json, ObjBuilder};
use crate::workload::QosClass;

/// A parsed completion request.
#[derive(Debug, Clone, PartialEq)]
pub struct CompletionRequest {
    pub prompt_tokens: Vec<u32>,
    pub max_tokens: usize,
    pub adapter: Option<u64>,
    /// stream the response as SSE instead of one JSON body
    pub stream: bool,
    /// service class (`"qos": "interactive" | "batch"`; defaults to
    /// Interactive — a class-less request behaves like the pre-QoS system)
    pub qos: QosClass,
    /// optional TTFT deadline (`"deadline_ms"`; 0 or absent = none)
    pub deadline_s: Option<f64>,
}

#[derive(Debug, thiserror::Error)]
pub enum ApiError {
    #[error("invalid json: {0}")]
    BadJson(String),
    #[error("{0}")]
    BadRequest(String),
}

pub fn parse_completion(body: &[u8]) -> Result<CompletionRequest, ApiError> {
    let text = std::str::from_utf8(body)
        .map_err(|e| ApiError::BadJson(e.to_string()))?;
    let j = Json::parse(text).map_err(|e| ApiError::BadJson(e.to_string()))?;
    let prompt_tokens = j
        .get("prompt_tokens")
        .and_then(Json::as_arr)
        .ok_or_else(|| ApiError::BadRequest("missing prompt_tokens".into()))?
        .iter()
        .map(|v| {
            v.as_i64()
                .filter(|&t| t >= 0)
                .map(|t| t as u32)
                .ok_or_else(|| ApiError::BadRequest("bad token id".into()))
        })
        .collect::<Result<Vec<u32>, _>>()?;
    if prompt_tokens.is_empty() {
        return Err(ApiError::BadRequest("empty prompt".into()));
    }
    let max_tokens = j
        .get("max_tokens")
        .and_then(Json::as_usize)
        .unwrap_or(16)
        .clamp(1, 4096);
    // a negative id must be rejected, not wrapped through `as u64` into a
    // huge bogus adapter id
    let adapter = match j.get("adapter") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_i64()
                .filter(|&a| a >= 0)
                .ok_or_else(|| {
                    ApiError::BadRequest("adapter must be a non-negative integer".into())
                })? as u64,
        ),
    };
    let stream = j.get("stream").and_then(Json::as_bool).unwrap_or(false);
    let qos = match j.get("qos") {
        None | Some(Json::Null) => QosClass::Interactive,
        Some(v) => v
            .as_str()
            .and_then(QosClass::from_name)
            .ok_or_else(|| {
                ApiError::BadRequest("qos must be \"interactive\" or \"batch\"".into())
            })?,
    };
    // deadline_ms: 0 or absent means "no deadline"; negative is invalid
    let deadline_s = match j.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let ms = v.as_i64().filter(|&d| d >= 0).ok_or_else(|| {
                ApiError::BadRequest("deadline_ms must be a non-negative integer".into())
            })?;
            (ms > 0).then(|| ms as f64 / 1000.0)
        }
    };
    Ok(CompletionRequest {
        prompt_tokens,
        max_tokens,
        adapter,
        stream,
        qos,
        deadline_s,
    })
}

/// Parse a `POST /v1/adapters` body: `{"id": N, "path": optional source
/// file}`. Without a path the registry synthesizes the adapter's weights.
pub fn parse_register(body: &[u8]) -> Result<(u64, Option<String>), ApiError> {
    let text = std::str::from_utf8(body).map_err(|e| ApiError::BadJson(e.to_string()))?;
    let j = Json::parse(text).map_err(|e| ApiError::BadJson(e.to_string()))?;
    let id = j
        .get("id")
        .and_then(Json::as_i64)
        .filter(|&a| a >= 0)
        .ok_or_else(|| ApiError::BadRequest("id must be a non-negative integer".into()))?
        as u64;
    let path = j.get("path").and_then(Json::as_str).map(String::from);
    Ok((id, path))
}

/// Completion response payload.
pub fn completion_response(
    request_id: u64,
    adapter: u64,
    auto_selected: bool,
    tokens: &[u32],
    first_token_s: f64,
    total_s: f64,
) -> String {
    ObjBuilder::new()
        .num("id", request_id as f64)
        .num("adapter", adapter as f64)
        .bool("auto_selected", auto_selected)
        .val(
            "tokens",
            Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        )
        .num("first_token_s", first_token_s)
        .num("total_s", total_s)
        .build()
        .to_string()
}

/// One SSE frame for a lifecycle event: `event: <name>\ndata: <json>\n\n`.
/// The `data` object always carries the request id; timestamps are
/// engine-relative seconds.
pub fn event_frame(request_id: u64, ev: &EngineEvent) -> String {
    let b = ObjBuilder::new().num("id", request_id as f64);
    let b = match *ev {
        EngineEvent::Queued { replica } => b.num("replica", replica as f64),
        EngineEvent::Admitted { replica, t } => b.num("replica", replica as f64).num("t", t),
        EngineEvent::Truncated { target } => b.num("target", target as f64),
        EngineEvent::Token { index, token, t } => b
            .num("index", index as f64)
            .num("token", token as f64)
            .num("t", t),
        EngineEvent::Preempted | EngineEvent::Requeued | EngineEvent::Cancelled => b,
        EngineEvent::Rehomed { from, to } => {
            b.num("from", from as f64).num("to", to as f64)
        }
        EngineEvent::Done { t } => b.num("t", t),
        EngineEvent::Shed { reason } => b.str("reason", reason.name()),
    };
    format!("event: {}\ndata: {}\n\n", ev.name(), b.build())
}

/// One adapter's row in the `GET /v1/adapters` listing.
#[derive(Debug, Clone)]
pub struct AdapterRow {
    pub id: u64,
    /// shards where the adapter is currently resident
    pub resident_shards: Vec<usize>,
    /// registry pin held on at least one shard
    pub pinned: bool,
    /// completed requests served with this adapter
    pub requests: u64,
}

/// `GET /v1/adapters` payload.
pub fn adapters_response(rows: &[AdapterRow]) -> String {
    let arr = rows
        .iter()
        .map(|r| {
            ObjBuilder::new()
                .num("id", r.id as f64)
                .val(
                    "resident_shards",
                    Json::Arr(
                        r.resident_shards
                            .iter()
                            .map(|&s| Json::Num(s as f64))
                            .collect(),
                    ),
                )
                .bool("pinned", r.pinned)
                .num("requests", r.requests as f64)
                .build()
        })
        .collect();
    ObjBuilder::new()
        .num("adapters", rows.len() as f64)
        .val("rows", Json::Arr(arr))
        .build()
        .to_string()
}

/// One replica's liveness row in the /health payload.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaHealth {
    /// health-ladder state name: alive/degraded/suspect/dead, or
    /// draining/retired while the autoscaler winds the shard down
    pub state: &'static str,
    /// seconds since the shard's last heartbeat at the observation frontier
    pub heartbeat_age_s: f64,
}

/// Per-class percentile block shared by /health and /cluster (DESIGN.md
/// §QoS & overload).
fn class_block(c: &ClassSummary) -> Json {
    ObjBuilder::new()
        .num("completed", c.completed as f64)
        .num("p50_ttft_s", c.p50_ttft_s)
        .num("p99_ttft_s", c.p99_ttft_s)
        .num("p50_itl_s", c.p50_itl_s)
        .num("p99_itl_s", c.p99_itl_s)
        .num("slo_attainment", c.slo_attainment)
        .build()
}

/// /health payload from a metrics summary plus per-replica liveness.
/// `status` degrades to "degraded" when any shard left the Alive state.
pub fn health_response(
    summary: &Summary,
    idle_slots: usize,
    total_slots: usize,
    replicas: &[ReplicaHealth],
) -> String {
    let all_alive = replicas.iter().all(|r| r.state == "alive");
    let rows = replicas
        .iter()
        .enumerate()
        .map(|(i, r)| {
            ObjBuilder::new()
                .num("replica", i as f64)
                .str("state", r.state)
                .num("heartbeat_age_s", r.heartbeat_age_s)
                .build()
        })
        .collect();
    ObjBuilder::new()
        .str("status", if all_alive { "ok" } else { "degraded" })
        .val("replicas", Json::Arr(rows))
        .num("idle_slots", idle_slots as f64)
        .num("total_slots", total_slots as f64)
        .num("completed_requests", summary.requests as f64)
        .num("throughput_rps", summary.throughput_rps)
        .num("avg_latency_s", summary.avg_latency_s)
        .num("avg_first_token_s", summary.avg_first_token_s)
        .num("slo_attainment", summary.slo_attainment)
        .num("p50_ttft_s", summary.p50_ttft_s)
        .num("p99_ttft_s", summary.p99_ttft_s)
        .num("p50_itl_s", summary.p50_itl_s)
        .num("p99_itl_s", summary.p99_itl_s)
        .val("interactive", class_block(&summary.interactive))
        .val("batch", class_block(&summary.batch))
        .num("shed_rate_limit", summary.shed_rate_limit as f64)
        .num("shed_deadline", summary.shed_deadline as f64)
        .build()
        .to_string()
}

/// One replica's row in the /cluster payload.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaStatus {
    /// health/lifecycle state name (alive/degraded/suspect/dead/
    /// draining/retired)
    pub state: &'static str,
    /// times this shard was healed back from a kill
    pub restarts: u64,
    /// requests this shard re-dispatched away after a peer died
    pub rehomed_requests: u64,
    pub queue: usize,
    pub active_slots: usize,
    pub resident_adapters: usize,
    pub clock_s: f64,
    pub dispatched: u64,
    /// unified-paging shard accounting (0/0 when the replica is unpaged)
    pub free_pages: usize,
    pub total_pages: usize,
    /// KV pages currently mapped by this shard's active slots
    pub kv_pages: usize,
    /// requests preempted-and-requeued under page pressure on this shard
    pub preemptions: u64,
    /// admissions deferred for lack of pages (queue-growth diagnostic)
    pub admission_deferrals: u64,
    /// requests cancelled on this shard (queue or slot)
    pub cancelled: u64,
    /// prompt pages the prefix radix currently holds (DESIGN.md §Prefix
    /// sharing; 0 when unpaged or sharing is off)
    pub prefix_pages: usize,
    /// admissions that mapped a cached prompt prefix on this shard
    pub prefix_hits: u64,
    /// prefix hit rate over sharing-eligible admissions
    pub prefix_hit_rate: f64,
    /// cumulative prompt pages mapped shared instead of allocated
    pub shared_kv_pages: u64,
}

/// /cluster payload: per-replica occupancy plus cluster dispatch counters
/// and the cluster-wide per-class QoS percentiles.
pub fn cluster_status_response(
    replicas: &[ReplicaStatus],
    steals: u64,
    summary: &Summary,
) -> String {
    let rows = replicas
        .iter()
        .enumerate()
        .map(|(i, r)| {
            ObjBuilder::new()
                .num("replica", i as f64)
                .str("state", r.state)
                .num("restarts", r.restarts as f64)
                .num("rehomed_requests", r.rehomed_requests as f64)
                .num("queue", r.queue as f64)
                .num("active_slots", r.active_slots as f64)
                .num("resident_adapters", r.resident_adapters as f64)
                .num("clock_s", r.clock_s)
                .num("dispatched", r.dispatched as f64)
                .num("free_pages", r.free_pages as f64)
                .num("total_pages", r.total_pages as f64)
                .num("kv_pages", r.kv_pages as f64)
                .num("preemptions", r.preemptions as f64)
                .num("admission_deferrals", r.admission_deferrals as f64)
                .num("cancelled", r.cancelled as f64)
                .num("prefix_pages", r.prefix_pages as f64)
                .num("prefix_hits", r.prefix_hits as f64)
                .num("prefix_hit_rate", r.prefix_hit_rate)
                .num("shared_kv_pages", r.shared_kv_pages as f64)
                .build()
        })
        .collect();
    ObjBuilder::new()
        .num("replicas", replicas.len() as f64)
        .num("steals", steals as f64)
        .val("interactive", class_block(&summary.interactive))
        .val("batch", class_block(&summary.batch))
        .num("shed_rate_limit", summary.shed_rate_limit as f64)
        .num("shed_deadline", summary.shed_deadline as f64)
        .val("shards", Json::Arr(rows))
        .build()
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_request() {
        let req = parse_completion(
            br#"{"prompt_tokens":[1,2,3],"max_tokens":8,"adapter":5}"#,
        )
        .unwrap();
        assert_eq!(req.prompt_tokens, vec![1, 2, 3]);
        assert_eq!(req.max_tokens, 8);
        assert_eq!(req.adapter, Some(5));
    }

    #[test]
    fn adapter_optional_and_defaults() {
        let req = parse_completion(br#"{"prompt_tokens":[7]}"#).unwrap();
        assert_eq!(req.adapter, None);
        assert_eq!(req.max_tokens, 16);
        assert!(!req.stream, "stream defaults off");
        assert_eq!(req.qos, QosClass::Interactive, "class-less = interactive");
        assert_eq!(req.deadline_s, None);
        let req = parse_completion(br#"{"prompt_tokens":[7],"stream":true}"#).unwrap();
        assert!(req.stream);
    }

    #[test]
    fn qos_and_deadline_parse_and_validate() {
        let req = parse_completion(
            br#"{"prompt_tokens":[1],"qos":"batch","deadline_ms":1500}"#,
        )
        .unwrap();
        assert_eq!(req.qos, QosClass::Batch);
        assert_eq!(req.deadline_s, Some(1.5));
        // case-insensitive class names; explicit null = default
        let req =
            parse_completion(br#"{"prompt_tokens":[1],"qos":"Interactive"}"#).unwrap();
        assert_eq!(req.qos, QosClass::Interactive);
        let req = parse_completion(
            br#"{"prompt_tokens":[1],"qos":null,"deadline_ms":null}"#,
        )
        .unwrap();
        assert_eq!((req.qos, req.deadline_s), (QosClass::Interactive, None));
        // a zero deadline means "none", not "instantly impossible"
        let req =
            parse_completion(br#"{"prompt_tokens":[1],"deadline_ms":0}"#).unwrap();
        assert_eq!(req.deadline_s, None);
        // bad class names and negative deadlines are 400s, not defaults
        assert!(parse_completion(br#"{"prompt_tokens":[1],"qos":"vip"}"#).is_err());
        assert!(parse_completion(br#"{"prompt_tokens":[1],"qos":3}"#).is_err());
        assert!(
            parse_completion(br#"{"prompt_tokens":[1],"deadline_ms":-4}"#).is_err()
        );
    }

    #[test]
    fn rejects_bad_payloads() {
        assert!(parse_completion(b"not json").is_err());
        assert!(parse_completion(br#"{"max_tokens":4}"#).is_err());
        assert!(parse_completion(br#"{"prompt_tokens":[]}"#).is_err());
        assert!(parse_completion(br#"{"prompt_tokens":[-1]}"#).is_err());
    }

    #[test]
    fn negative_adapter_is_rejected_not_wrapped() {
        // regression: `as_i64 … as u64` silently wrapped -5 into a huge id
        let err = parse_completion(br#"{"prompt_tokens":[1],"adapter":-5}"#)
            .expect_err("negative adapter must 400");
        assert!(matches!(err, ApiError::BadRequest(_)), "{err}");
        assert!(err.to_string().contains("non-negative"), "{err}");
        // non-integer adapters are rejected the same way
        assert!(parse_completion(br#"{"prompt_tokens":[1],"adapter":"x"}"#).is_err());
        // an explicit null means "not set"
        let req = parse_completion(br#"{"prompt_tokens":[1],"adapter":null}"#).unwrap();
        assert_eq!(req.adapter, None);
    }

    #[test]
    fn register_payload_roundtrip_and_validation() {
        let (id, path) = parse_register(br#"{"id":42}"#).unwrap();
        assert_eq!((id, path), (42, None));
        let (id, path) = parse_register(br#"{"id":7,"path":"/tmp/a.elra"}"#).unwrap();
        assert_eq!(id, 7);
        assert_eq!(path.as_deref(), Some("/tmp/a.elra"));
        assert!(parse_register(br#"{"id":-1}"#).is_err());
        assert!(parse_register(br#"{"path":"x"}"#).is_err());
        assert!(parse_register(b"junk").is_err());
    }

    #[test]
    fn event_frames_are_well_formed_sse() {
        let frames = [
            event_frame(3, &EngineEvent::Queued { replica: 1 }),
            event_frame(3, &EngineEvent::Admitted { replica: 1, t: 0.5 }),
            event_frame(3, &EngineEvent::Token { index: 0, token: 42, t: 0.6 }),
            event_frame(3, &EngineEvent::Done { t: 1.0 }),
            event_frame(3, &EngineEvent::Cancelled),
            event_frame(3, &EngineEvent::Rehomed { from: 2, to: 0 }),
            event_frame(
                3,
                &EngineEvent::Shed { reason: crate::coordinator::ShedReason::RateLimit },
            ),
        ];
        for f in &frames {
            assert!(f.starts_with("event: "), "{f}");
            assert!(f.ends_with("\n\n"), "{f}");
            let data = f.lines().nth(1).unwrap().strip_prefix("data: ").unwrap();
            let j = Json::parse(data).unwrap();
            assert_eq!(j.get("id").unwrap().as_usize(), Some(3));
        }
        assert!(frames[2].starts_with("event: token\n"));
        let data = frames[2].lines().nth(1).unwrap().strip_prefix("data: ").unwrap();
        let j = Json::parse(data).unwrap();
        assert_eq!(j.get("token").unwrap().as_usize(), Some(42));
        assert_eq!(j.get("index").unwrap().as_usize(), Some(0));
        assert!(frames[5].starts_with("event: rehomed\n"));
        let data = frames[5].lines().nth(1).unwrap().strip_prefix("data: ").unwrap();
        let j = Json::parse(data).unwrap();
        assert_eq!(j.get("from").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("to").unwrap().as_usize(), Some(0));
        assert!(frames[6].starts_with("event: shed\n"));
        let data = frames[6].lines().nth(1).unwrap().strip_prefix("data: ").unwrap();
        let j = Json::parse(data).unwrap();
        assert_eq!(j.get("reason").unwrap().as_str(), Some("rate_limit"));
    }

    #[test]
    fn adapters_listing_is_valid_json() {
        let s = adapters_response(&[
            AdapterRow {
                id: 0,
                resident_shards: vec![0, 1],
                pinned: true,
                requests: 9,
            },
            AdapterRow {
                id: 7,
                resident_shards: vec![],
                pinned: false,
                requests: 0,
            },
        ]);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("adapters").unwrap().as_usize(), Some(2));
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("resident_shards").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(rows[0].get("pinned").unwrap().as_bool(), Some(true));
        assert_eq!(rows[1].get("requests").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn response_is_valid_json() {
        let s = completion_response(7, 3, true, &[10, 20], 0.25, 1.5);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("auto_selected").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn health_is_valid_json() {
        let live = [
            ReplicaHealth { state: "alive", heartbeat_age_s: 0.0 },
            ReplicaHealth { state: "alive", heartbeat_age_s: 0.1 },
        ];
        let s = health_response(&Summary::empty(), 3, 8, &live);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("idle_slots").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("status").unwrap().as_str(), Some("ok"));
        // per-class QoS blocks + shed counters always present
        let inter = j.get("interactive").unwrap();
        assert_eq!(inter.get("completed").unwrap().as_usize(), Some(0));
        assert!(j.get("batch").unwrap().get("p99_ttft_s").is_some());
        assert_eq!(j.get("shed_rate_limit").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("shed_deadline").unwrap().as_usize(), Some(0));
        let rows = j.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("state").unwrap().as_str(), Some("alive"));
        assert_eq!(rows[1].get("heartbeat_age_s").unwrap().as_f64(), Some(0.1));

        // any non-alive shard degrades the top-level status
        let hurt = [
            ReplicaHealth { state: "alive", heartbeat_age_s: 0.0 },
            ReplicaHealth { state: "dead", heartbeat_age_s: 4.0 },
        ];
        let s = health_response(&Summary::empty(), 3, 8, &hurt);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("status").unwrap().as_str(), Some("degraded"));
        let rows = j.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(rows[1].get("state").unwrap().as_str(), Some("dead"));
    }

    #[test]
    fn cluster_status_is_valid_json() {
        let mut sum = Summary::empty();
        sum.shed_rate_limit = 3;
        sum.shed_deadline = 1;
        let s = cluster_status_response(
            &[
                ReplicaStatus {
                    state: "alive",
                    restarts: 0,
                    rehomed_requests: 0,
                    queue: 2,
                    active_slots: 4,
                    resident_adapters: 8,
                    clock_s: 1.5,
                    dispatched: 10,
                    free_pages: 100,
                    total_pages: 128,
                    kv_pages: 12,
                    preemptions: 1,
                    admission_deferrals: 3,
                    cancelled: 2,
                    prefix_pages: 6,
                    prefix_hits: 4,
                    prefix_hit_rate: 0.5,
                    shared_kv_pages: 18,
                },
                ReplicaStatus {
                    state: "dead",
                    restarts: 1,
                    rehomed_requests: 5,
                    queue: 0,
                    active_slots: 1,
                    resident_adapters: 3,
                    clock_s: 0.5,
                    dispatched: 4,
                    free_pages: 0,
                    total_pages: 0,
                    kv_pages: 0,
                    preemptions: 0,
                    admission_deferrals: 0,
                    cancelled: 0,
                    prefix_pages: 0,
                    prefix_hits: 0,
                    prefix_hit_rate: 0.0,
                    shared_kv_pages: 0,
                },
            ],
            7,
            &sum,
        );
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("replicas").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("steals").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("shed_rate_limit").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("shed_deadline").unwrap().as_usize(), Some(1));
        assert!(j.get("interactive").unwrap().get("slo_attainment").is_some());
        let shards = j.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].get("queue").unwrap().as_usize(), Some(2));
        assert_eq!(shards[1].get("dispatched").unwrap().as_usize(), Some(4));
        assert_eq!(shards[0].get("free_pages").unwrap().as_usize(), Some(100));
        assert_eq!(shards[0].get("total_pages").unwrap().as_usize(), Some(128));
        assert_eq!(shards[0].get("kv_pages").unwrap().as_usize(), Some(12));
        assert_eq!(shards[0].get("preemptions").unwrap().as_usize(), Some(1));
        assert_eq!(
            shards[0].get("admission_deferrals").unwrap().as_usize(),
            Some(3)
        );
        assert_eq!(shards[0].get("cancelled").unwrap().as_usize(), Some(2));
        assert_eq!(shards[0].get("prefix_pages").unwrap().as_usize(), Some(6));
        assert_eq!(shards[0].get("prefix_hits").unwrap().as_usize(), Some(4));
        assert_eq!(
            shards[0].get("shared_kv_pages").unwrap().as_usize(),
            Some(18)
        );
        assert_eq!(shards[1].get("prefix_hit_rate").unwrap().as_usize(), Some(0));
        assert_eq!(shards[0].get("state").unwrap().as_str(), Some("alive"));
        assert_eq!(shards[1].get("state").unwrap().as_str(), Some("dead"));
        assert_eq!(shards[1].get("restarts").unwrap().as_usize(), Some(1));
        assert_eq!(
            shards[1].get("rehomed_requests").unwrap().as_usize(),
            Some(5)
        );
    }
}
