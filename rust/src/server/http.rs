//! Minimal HTTP/1.1 server (no hyper/tokio in the offline vendor set):
//! blocking listener + thread-pool dispatch, enough of RFC 7230 for a JSON
//! API — request line, headers, Content-Length bodies, keep-alive off.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::util::threadpool::ThreadPool;

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: impl Into<Vec<u8>>) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: body.into(),
        }
    }

    pub fn text(status: u16, body: impl Into<Vec<u8>>) -> Self {
        Self {
            status,
            content_type: "text/plain",
            body: body.into(),
        }
    }

    fn status_line(&self) -> &'static str {
        match self.status {
            200 => "200 OK",
            400 => "400 Bad Request",
            404 => "404 Not Found",
            405 => "405 Method Not Allowed",
            429 => "429 Too Many Requests",
            500 => "500 Internal Server Error",
            503 => "503 Service Unavailable",
            _ => "200 OK",
        }
    }
}

/// Parse one HTTP request from a stream.
pub fn parse_request(stream: &mut dyn Read) -> Result<Request> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).context("reading request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().context("missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version}");
    }
    let mut headers = HashMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).context("reading header")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':').context("bad header")?;
        headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
    }
    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse())
        .transpose()
        .context("bad content-length")?
        .unwrap_or(0);
    if len > 16 << 20 {
        bail!("body too large");
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).context("reading body")?;
    Ok(Request {
        method,
        path,
        headers,
        body,
    })
}

pub fn write_response(stream: &mut dyn Write, resp: &Response) -> Result<()> {
    write!(
        stream,
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status_line(),
        resp.content_type,
        resp.body.len()
    )?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

/// A handler maps requests to responses (must be thread-safe).
pub type Handler = Arc<dyn Fn(Request) -> Response + Send + Sync>;

/// Blocking HTTP server with a shutdown flag.
pub struct HttpServer {
    listener: TcpListener,
    pool: ThreadPool,
    handler: Handler,
    shutdown: Arc<AtomicBool>,
}

impl HttpServer {
    pub fn bind(addr: &str, workers: usize, handler: Handler) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Self {
            listener,
            pool: ThreadPool::new(workers),
            handler,
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    /// Serve until the shutdown flag is set. Uses a 200 ms accept timeout to
    /// poll the flag.
    pub fn serve(&self) -> Result<()> {
        self.listener.set_nonblocking(true)?;
        while !self.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let handler = Arc::clone(&self.handler);
                    self.pool.execute(move || {
                        let _ = handle_connection(stream, handler);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => {
                    log::warn!("accept error: {e}");
                }
            }
        }
        self.pool.wait_idle();
        Ok(())
    }
}

fn handle_connection(mut stream: TcpStream, handler: Handler) -> Result<()> {
    stream.set_nodelay(true).ok();
    let req = match parse_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let resp = Response::json(
                400,
                format!("{{\"error\":\"{e}\"}}").into_bytes(),
            );
            write_response(&mut stream, &resp)?;
            return Ok(());
        }
    };
    let resp = handler(req);
    write_response(&mut stream, &resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"a\":1}";
        let req = parse_request(&mut Cursor::new(raw.to_vec())).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.body, b"{\"a\":1}");
        assert_eq!(req.headers.get("host").map(String::as_str), Some("x"));
    }

    #[test]
    fn parses_get_without_body() {
        let raw = b"GET /health HTTP/1.1\r\n\r\n";
        let req = parse_request(&mut Cursor::new(raw.to_vec())).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_request(&mut Cursor::new(b"not http\r\n\r\n".to_vec())).is_err());
        assert!(parse_request(&mut Cursor::new(
            b"GET / HTTP/1.1\r\nContent-Length: nope\r\n\r\n".to_vec()
        ))
        .is_err());
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response::json(200, b"{\"ok\":true}".to_vec());
        let mut out = Vec::new();
        write_response(&mut out, &resp).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 11"));
        assert!(s.ends_with("{\"ok\":true}"));
    }

    #[test]
    fn end_to_end_over_tcp() {
        let handler: Handler = Arc::new(|req: Request| {
            Response::json(200, format!("{{\"path\":\"{}\"}}", req.path).into_bytes())
        });
        let server = Arc::new(HttpServer::bind("127.0.0.1:0", 2, handler).unwrap());
        let addr = server.local_addr().unwrap();
        let flag = server.shutdown_flag();
        let srv = Arc::clone(&server);
        let t = std::thread::spawn(move || srv.serve().unwrap());

        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"GET /health HTTP/1.1\r\n\r\n")
            .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("\"path\":\"/health\""), "{buf}");

        flag.store(true, Ordering::SeqCst);
        t.join().unwrap();
    }
}
