//! Minimal HTTP/1.1 server (no hyper/tokio in the offline vendor set):
//! blocking listener + thread-pool dispatch, enough of RFC 7230 for a JSON
//! API — request line, headers, Content-Length bodies — plus chunked
//! transfer-encoding responses for the SSE streaming path (DESIGN.md
//! §Serving API): a handler may answer with [`Reply::Stream`], which hands
//! the connection to a closure that writes SSE frames through a
//! [`ChunkSink`] and can detect client disconnect between frames.
//!
//! Connection reuse is *opt-in*: the default stays one-request-per-
//! connection with `Connection: close`, because every existing client of
//! this server reads to EOF. A client that sends an explicit
//! `Connection: keep-alive` request header gets the connection back for the
//! next request — pipelining included, since the request reader is buffered
//! per-connection, not per-request — with the slow-loris read deadline
//! re-armed for each request and a hard cap of
//! [`MAX_KEEPALIVE_REQUESTS`] requests per connection so one client cannot
//! squat a worker thread forever. Streaming (SSE) replies always close:
//! the chunked stream is terminated by EOF semantics on the client side.

use std::collections::HashMap;
use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::ObjBuilder;
use crate::util::threadpool::ThreadPool;

/// Largest accepted request body. Completion payloads are ≤ 4096 token ids;
/// anything bigger is rejected with 413 before the body is read.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Default wall-clock budget for reading one request (head + body). A
/// client trickling bytes slower than this — a slow-loris — gets 408 and
/// the worker thread back (`lingering_close` already bounds the drain side).
/// On a kept-alive connection the deadline re-arms per request, so it also
/// bounds how long an idle keep-alive connection holds its worker.
pub const DEFAULT_READ_DEADLINE: Duration = Duration::from_secs(5);

/// Requests served per kept-alive connection before the server forces
/// `Connection: close` — bounds worker-thread occupancy per client.
pub const MAX_KEEPALIVE_REQUESTS: usize = 32;

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// emit a `Retry-After: N` header (machine-retryable 429/503 answers —
    /// shed, rate-limited, or transiently unpinnable requests)
    pub retry_after_s: Option<u64>,
}

impl Response {
    pub fn json(status: u16, body: impl Into<Vec<u8>>) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: body.into(),
            retry_after_s: None,
        }
    }

    /// `{"error": msg}` with proper JSON escaping.
    pub fn error(status: u16, msg: &str) -> Self {
        Self::json(
            status,
            ObjBuilder::new().str("error", msg).build().to_string().into_bytes(),
        )
    }

    pub fn text(status: u16, body: impl Into<Vec<u8>>) -> Self {
        Self {
            status,
            content_type: "text/plain",
            body: body.into(),
            retry_after_s: None,
        }
    }

    /// Attach a `Retry-After` hint (seconds, floored to 1).
    pub fn retry_after(mut self, secs: u64) -> Self {
        self.retry_after_s = Some(secs.max(1));
        self
    }

    fn status_line(&self) -> &'static str {
        match self.status {
            200 => "200 OK",
            201 => "201 Created",
            400 => "400 Bad Request",
            404 => "404 Not Found",
            405 => "405 Method Not Allowed",
            408 => "408 Request Timeout",
            409 => "409 Conflict",
            413 => "413 Payload Too Large",
            429 => "429 Too Many Requests",
            500 => "500 Internal Server Error",
            503 => "503 Service Unavailable",
            _ => "200 OK",
        }
    }
}

/// Why a request could not be parsed — carries the HTTP status the reply
/// must use (413 for an oversized body, 400 for everything malformed).
#[derive(Debug, thiserror::Error)]
#[error("{msg}")]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl HttpError {
    fn bad(msg: impl Into<String>) -> Self {
        Self { status: 400, msg: msg.into() }
    }
}

/// Classify a read failure: a deadline expiry (slow-loris guard) is 408 so
/// the client knows the *transfer* was too slow, not the request malformed.
fn read_err(what: &str, e: io::Error) -> HttpError {
    if matches!(e.kind(), io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock) {
        HttpError {
            status: 408,
            msg: format!("timed out {what} — request read deadline exceeded"),
        }
    } else {
        HttpError::bad(format!("{what}: {e}"))
    }
}

/// Wall-clock deadline enforcement for the request-read side: each `read`
/// re-arms the socket timeout with the time remaining, so the *sum* of all
/// reads is bounded — a per-read timeout alone would let a slow-loris
/// client trickle one byte per interval and hold the worker forever. Owns
/// a `try_clone` of the connection (the write side keeps the original), so
/// a per-connection `BufReader` can persist across kept-alive requests —
/// the deadline is re-armed between requests by resetting `deadline`.
struct DeadlineReader {
    stream: TcpStream,
    deadline: Instant,
}

impl Read for DeadlineReader {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let now = Instant::now();
        if now >= self.deadline {
            return Err(io::Error::new(
                io::ErrorKind::TimedOut,
                "request read deadline exceeded",
            ));
        }
        self.stream.set_read_timeout(Some(self.deadline - now))?;
        self.stream.read(buf)
    }
}

/// Parse one HTTP request from a stream.
pub fn parse_request(stream: &mut dyn Read) -> Result<Request, HttpError> {
    let mut reader = BufReader::new(stream);
    parse_request_buffered(&mut reader)?.ok_or_else(|| HttpError::bad("missing method"))
}

/// Parse one request from a persistent per-connection reader. `Ok(None)`
/// is clean EOF at a request boundary — how a keep-alive client says it is
/// done (no bytes of a next request yet), distinct from every malformed or
/// truncated-mid-request case, which stays an error.
fn parse_request_buffered(
    reader: &mut impl BufRead,
) -> Result<Option<Request>, HttpError> {
    let mut line = String::new();
    let first = reader
        .read_line(&mut line)
        .map_err(|e| read_err("reading request line", e))?;
    if first == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| HttpError::bad("missing method"))?.to_string();
    let path = parts.next().ok_or_else(|| HttpError::bad("missing path"))?.to_string();
    let version = parts.next().ok_or_else(|| HttpError::bad("missing version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::bad(format!("unsupported version {version}")));
    }
    let mut headers = HashMap::new();
    loop {
        let mut h = String::new();
        reader
            .read_line(&mut h)
            .map_err(|e| read_err("reading header", e))?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':').ok_or_else(|| HttpError::bad("bad header"))?;
        headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
    }
    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse())
        .transpose()
        .map_err(|_| HttpError::bad("bad content-length"))?
        .unwrap_or(0);
    if len > MAX_BODY_BYTES {
        return Err(HttpError {
            status: 413,
            msg: format!("body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte limit"),
        });
    }
    let mut body = vec![0u8; len];
    reader
        .read_exact(&mut body)
        .map_err(|e| read_err("reading body", e))?;
    Ok(Some(Request {
        method,
        path,
        headers,
        body,
    }))
}

pub fn write_response(stream: &mut dyn Write, resp: &Response) -> Result<()> {
    write_response_conn(stream, resp, false)
}

/// Like [`write_response`] but with the connection disposition explicit:
/// `keep = true` advertises `Connection: keep-alive` instead of `close`.
pub fn write_response_conn(stream: &mut dyn Write, resp: &Response, keep: bool) -> Result<()> {
    write!(
        stream,
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        resp.status_line(),
        resp.content_type,
        resp.body.len(),
        if keep { "keep-alive" } else { "close" }
    )?;
    if let Some(secs) = resp.retry_after_s {
        write!(stream, "Retry-After: {secs}\r\n")?;
    }
    stream.write_all(b"\r\n")?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

/// Response head of an SSE stream (status committed before the first event).
pub fn write_stream_head(stream: &mut dyn Write) -> io::Result<()> {
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n\
          Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
    )?;
    stream.flush()
}

/// One chunked-transfer-encoding chunk: `<len hex>\r\n<data>\r\n`.
pub fn write_chunk(w: &mut dyn Write, data: &[u8]) -> io::Result<()> {
    if data.is_empty() {
        return Ok(()); // an empty chunk would terminate the stream
    }
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data)?;
    w.write_all(b"\r\n")?;
    w.flush()
}

/// The streaming half of a connection: chunked writes plus client-disconnect
/// detection, handed to a [`Reply::Stream`] closure.
pub struct ChunkSink {
    stream: TcpStream,
    dead: bool,
}

impl ChunkSink {
    fn new(stream: TcpStream) -> Self {
        Self { stream, dead: false }
    }

    /// Write one chunk. False = the client is gone (connection reset/closed);
    /// the sink goes dead and further sends are no-ops.
    pub fn send(&mut self, data: &[u8]) -> bool {
        if self.dead {
            return false;
        }
        if write_chunk(&mut self.stream, data).is_err() {
            self.dead = true;
        }
        !self.dead
    }

    /// Poll for client disconnect without blocking: a closed peer surfaces
    /// as EOF (or an error) on a non-blocking read. Bytes the client sends
    /// mid-stream are discarded — the request was fully read already.
    pub fn client_gone(&mut self) -> bool {
        if self.dead {
            return true;
        }
        if self.stream.set_nonblocking(true).is_err() {
            self.dead = true;
            return true;
        }
        let mut buf = [0u8; 256];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.stream.set_nonblocking(false).is_err() {
            self.dead = true;
        }
        self.dead
    }

    /// Terminate the chunked stream (`0\r\n\r\n`) and hand the socket back
    /// for the lingering close.
    fn finish(mut self) -> TcpStream {
        if !self.dead {
            let _ = self.stream.write_all(b"0\r\n\r\n");
            let _ = self.stream.flush();
        }
        self.stream
    }
}

/// A handler's answer: one buffered response, or a streaming closure that
/// drives the connection (SSE over chunked encoding).
pub enum Reply {
    Full(Response),
    Stream(Box<dyn FnOnce(&mut ChunkSink) + Send>),
}

impl From<Response> for Reply {
    fn from(r: Response) -> Self {
        Reply::Full(r)
    }
}

/// A handler maps requests to replies (must be thread-safe).
pub type Handler = Arc<dyn Fn(Request) -> Reply + Send + Sync>;

/// Blocking HTTP server with a shutdown flag.
pub struct HttpServer {
    listener: TcpListener,
    pool: ThreadPool,
    handler: Handler,
    shutdown: Arc<AtomicBool>,
    read_deadline: Duration,
}

impl HttpServer {
    pub fn bind(addr: &str, workers: usize, handler: Handler) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Self {
            listener,
            pool: ThreadPool::new(workers),
            handler,
            shutdown: Arc::new(AtomicBool::new(false)),
            read_deadline: DEFAULT_READ_DEADLINE,
        })
    }

    /// Override the request-read deadline (slow-loris guard; tests shrink it).
    pub fn set_read_deadline(&mut self, d: Duration) {
        self.read_deadline = d.max(Duration::from_millis(1));
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    /// Serve until the shutdown flag is set. Uses a 200 ms accept timeout to
    /// poll the flag.
    pub fn serve(&self) -> Result<()> {
        self.listener.set_nonblocking(true)?;
        while !self.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let handler = Arc::clone(&self.handler);
                    let deadline = self.read_deadline;
                    self.pool.execute(move || {
                        let _ = handle_connection(stream, handler, deadline);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => {
                    log::warn!("accept error: {e}");
                }
            }
        }
        self.pool.wait_idle();
        Ok(())
    }
}

/// The server is one-request-per-connection and says so (`Connection:
/// close` on every response), but an HTTP/1.1 client may have optimistically
/// pipelined a second request before reading the first response. Closing the
/// socket with that unread input still buffered makes the kernel send RST,
/// which can discard the response in flight — the classic way a well-behaved
/// pipelining client "hangs" on a one-shot server. So: half-close the write
/// side first (FIN after the response), then drain and discard whatever the
/// client already sent until it closes or a short timeout elapses.
fn lingering_close(mut stream: TcpStream) {
    let _ = stream.shutdown(std::net::Shutdown::Write);
    // fast path: pipelined bytes, if any, were written before the client
    // read our response, so they are already in the receive buffer. A
    // non-blocking probe costs nothing for the (typical) client with no
    // pending input — the worker thread is not pinned behind well-behaved
    // connections.
    if stream.set_nonblocking(true).is_err() {
        return;
    }
    let mut buf = [0u8; 512];
    match stream.read(&mut buf) {
        Err(e) if e.kind() == io::ErrorKind::WouldBlock => return, // nothing pipelined
        Ok(n) if n > 0 => {} // pipelined input: drain it below
        _ => return,         // EOF or hard error: the client is done
    }
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(250)));
    // hard deadline on the whole drain: the per-read timeout alone would
    // let a client trickling one byte per interval pin this worker thread
    // indefinitely (slowloris). Past the deadline the socket just drops —
    // the response is long flushed by then.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(1);
    while std::time::Instant::now() < deadline
        && matches!(stream.read(&mut buf), Ok(n) if n > 0)
    {}
}

fn handle_connection(
    mut stream: TcpStream,
    handler: Handler,
    read_deadline: Duration,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // accepted sockets can inherit the listener's non-blocking mode; every
    // path here (request parse, response write, lingering drain) wants
    // blocking semantics — the streaming sink polls disconnect explicitly
    stream.set_nonblocking(false).ok();
    // read side: a try_clone of the socket behind one per-connection
    // BufReader, so a pipelined next request buffered during this parse is
    // not dropped on the floor between kept-alive requests
    let read_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return Ok(()),
    };
    let mut reader = BufReader::new(DeadlineReader {
        stream: read_half,
        deadline: Instant::now() + read_deadline,
    });
    let mut served = 0usize;
    loop {
        // the whole request (head + body) must arrive within the deadline:
        // a slow-loris connection is answered 408 and released, not held
        // open. Re-armed per request on kept-alive connections.
        reader.get_mut().deadline = Instant::now() + read_deadline;
        let parsed = parse_request_buffered(&mut reader);
        // the deadline's socket timeout must not leak into the response
        // write or the streaming path
        stream.set_read_timeout(None).ok();
        let req = match parsed {
            Ok(Some(r)) => r,
            Ok(None) if served > 0 => {
                // clean EOF between kept-alive requests: the client is done
                lingering_close(stream);
                return Ok(());
            }
            Ok(None) => {
                // connected and sent nothing at all
                write_response(&mut stream, &Response::error(400, "missing method"))?;
                lingering_close(stream);
                return Ok(());
            }
            Err(e) if e.status == 408 && served > 0 => {
                // an idle kept-alive connection is reaped silently — there
                // is no half-read request to answer for
                lingering_close(stream);
                return Ok(());
            }
            Err(e) => {
                write_response(&mut stream, &Response::error(e.status, &e.msg))?;
                lingering_close(stream);
                return Ok(());
            }
        };
        served += 1;
        // connection reuse is opt-in (existing clients read to EOF): only
        // an explicit request header keeps the connection, and only below
        // the per-connection request cap
        let keep = served < MAX_KEEPALIVE_REQUESTS
            && req
                .headers
                .get("connection")
                .map_or(false, |v| v.eq_ignore_ascii_case("keep-alive"));
        match handler(req) {
            Reply::Full(resp) => {
                write_response_conn(&mut stream, &resp, keep)?;
                if !keep {
                    lingering_close(stream);
                    return Ok(());
                }
            }
            Reply::Stream(f) => {
                // SSE streams own the connection to the end — the chunked
                // terminator is the last thing the client sees
                write_stream_head(&mut stream)?;
                let mut sink = ChunkSink::new(stream);
                f(&mut sink);
                lingering_close(sink.finish());
                return Ok(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"a\":1}";
        let req = parse_request(&mut Cursor::new(raw.to_vec())).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.body, b"{\"a\":1}");
        assert_eq!(req.headers.get("host").map(String::as_str), Some("x"));
    }

    #[test]
    fn parses_get_without_body() {
        let raw = b"GET /health HTTP/1.1\r\n\r\n";
        let req = parse_request(&mut Cursor::new(raw.to_vec())).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_request(&mut Cursor::new(b"not http\r\n\r\n".to_vec())).is_err());
        let err = parse_request(&mut Cursor::new(
            b"GET / HTTP/1.1\r\nContent-Length: nope\r\n\r\n".to_vec(),
        ))
        .unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn oversized_body_is_413_not_400() {
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        let err = parse_request(&mut Cursor::new(raw.into_bytes())).unwrap_err();
        assert_eq!(err.status, 413, "{err}");
        // exactly at the limit is fine (parse then fails on the short body,
        // which is a 400, not a 413)
        let raw = format!("POST /x HTTP/1.1\r\nContent-Length: {MAX_BODY_BYTES}\r\n\r\n");
        let err = parse_request(&mut Cursor::new(raw.into_bytes())).unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response::json(200, b"{\"ok\":true}".to_vec());
        let mut out = Vec::new();
        write_response(&mut out, &resp).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 11"));
        assert!(s.ends_with("{\"ok\":true}"));
    }

    #[test]
    fn error_response_escapes_json() {
        let resp = Response::error(400, "bad \"quote\"");
        let body = String::from_utf8(resp.body).unwrap();
        assert_eq!(body, r#"{"error":"bad \"quote\""}"#);
        assert_eq!(Response::error(413, "x").status_line(), "413 Payload Too Large");
        assert_eq!(Response::error(405, "x").status_line(), "405 Method Not Allowed");
        assert_eq!(Response::error(409, "x").status_line(), "409 Conflict");
        assert_eq!(Response::error(201, "x").status_line(), "201 Created");
        assert_eq!(Response::error(408, "x").status_line(), "408 Request Timeout");
        assert_eq!(Response::error(429, "x").status_line(), "429 Too Many Requests");
    }

    #[test]
    fn retry_after_header_is_emitted_and_floored() {
        let resp = Response::error(429, "rate limited").retry_after(7);
        let mut out = Vec::new();
        write_response(&mut out, &resp).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{s}");
        assert!(s.contains("\r\nRetry-After: 7\r\n"), "{s}");
        // zero would tell clients "retry immediately" — floored to 1
        assert_eq!(Response::error(503, "x").retry_after(0).retry_after_s, Some(1));
        // absent by default
        let mut out = Vec::new();
        write_response(&mut out, &Response::json(200, b"{}".to_vec())).unwrap();
        assert!(!String::from_utf8(out).unwrap().contains("Retry-After"));
    }

    #[test]
    fn slow_loris_request_times_out_with_408() {
        let handler: Handler =
            Arc::new(|_req: Request| Response::json(200, b"{}".to_vec()).into());
        let mut server = HttpServer::bind("127.0.0.1:0", 2, handler).unwrap();
        server.set_read_deadline(Duration::from_millis(200));
        let server = Arc::new(server);
        let addr = server.local_addr().unwrap();
        let flag = server.shutdown_flag();
        let srv = Arc::clone(&server);
        let t = std::thread::spawn(move || srv.serve().unwrap());

        // dribble an incomplete request head and then stall — the server
        // must answer 408 within the deadline instead of holding the worker
        let start = Instant::now();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET /health HTT").unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 408 Request Timeout"), "{buf}");
        assert!(
            start.elapsed() < Duration::from_secs(3),
            "the 408 must arrive promptly, took {:?}",
            start.elapsed()
        );

        // a well-formed request on the same server still succeeds
        let mut ok = TcpStream::connect(addr).unwrap();
        ok.write_all(b"GET /health HTTP/1.1\r\n\r\n").unwrap();
        let mut buf = String::new();
        ok.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 200 OK"), "{buf}");

        flag.store(true, Ordering::SeqCst);
        t.join().unwrap();
    }

    #[test]
    fn chunk_encoding_matches_rfc7230() {
        let mut out = Vec::new();
        write_chunk(&mut out, b"event: token\n\n").unwrap();
        assert_eq!(out, b"e\r\nevent: token\n\n\r\n");
        // empty payloads are suppressed, not emitted as a terminator
        let mut out2 = Vec::new();
        write_chunk(&mut out2, b"").unwrap();
        assert!(out2.is_empty());
        let mut head = Vec::new();
        write_stream_head(&mut head).unwrap();
        let s = String::from_utf8(head).unwrap();
        assert!(s.contains("Transfer-Encoding: chunked"));
        assert!(s.contains("text/event-stream"));
    }

    #[test]
    fn end_to_end_over_tcp() {
        let handler: Handler = Arc::new(|req: Request| {
            Response::json(200, format!("{{\"path\":\"{}\"}}", req.path).into_bytes()).into()
        });
        let server = Arc::new(HttpServer::bind("127.0.0.1:0", 2, handler).unwrap());
        let addr = server.local_addr().unwrap();
        let flag = server.shutdown_flag();
        let srv = Arc::clone(&server);
        let t = std::thread::spawn(move || srv.serve().unwrap());

        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"GET /health HTTP/1.1\r\n\r\n")
            .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("\"path\":\"/health\""), "{buf}");

        flag.store(true, Ordering::SeqCst);
        t.join().unwrap();
    }

    #[test]
    fn pipelined_second_request_does_not_destroy_the_first_response() {
        // a client that optimistically pipelines two requests must still
        // receive the full first response + clean EOF (no RST from closing
        // with unread input), and the advertised Connection: close
        let handler: Handler = Arc::new(|req: Request| {
            Response::json(200, format!("{{\"path\":\"{}\"}}", req.path).into_bytes()).into()
        });
        let server = Arc::new(HttpServer::bind("127.0.0.1:0", 2, handler).unwrap());
        let addr = server.local_addr().unwrap();
        let flag = server.shutdown_flag();
        let srv = Arc::clone(&server);
        let t = std::thread::spawn(move || srv.serve().unwrap());

        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"GET /first HTTP/1.1\r\n\r\nGET /second HTTP/1.1\r\n\r\n")
            .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap(); // returns ⇒ no hang, no RST
        assert!(buf.contains("\"path\":\"/first\""), "{buf}");
        assert!(buf.contains("Connection: close"), "{buf}");
        assert_eq!(
            buf.matches("HTTP/1.1 ").count(),
            1,
            "one-request-per-connection must answer exactly once: {buf}"
        );

        flag.store(true, Ordering::SeqCst);
        t.join().unwrap();
    }

    #[test]
    fn keepalive_serves_pipelined_requests_on_one_connection() {
        let handler: Handler = Arc::new(|req: Request| {
            Response::json(200, format!("{{\"path\":\"{}\"}}", req.path).into_bytes()).into()
        });
        let server = Arc::new(HttpServer::bind("127.0.0.1:0", 2, handler).unwrap());
        let addr = server.local_addr().unwrap();
        let flag = server.shutdown_flag();
        let srv = Arc::clone(&server);
        let t = std::thread::spawn(move || srv.serve().unwrap());

        // two requests written back-to-back before reading anything: the
        // first opts into keep-alive, the second closes. Both must be
        // answered, in order, on the one connection.
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(
                b"GET /first HTTP/1.1\r\nConnection: keep-alive\r\n\r\n\
                  GET /second HTTP/1.1\r\nConnection: close\r\n\r\n",
            )
            .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert_eq!(buf.matches("HTTP/1.1 200 OK").count(), 2, "{buf}");
        assert!(buf.contains("\"path\":\"/first\""), "{buf}");
        assert!(buf.contains("\"path\":\"/second\""), "{buf}");
        let first_resp = &buf[..buf.find("/second").unwrap()];
        assert!(first_resp.contains("Connection: keep-alive"), "{buf}");
        assert!(buf.contains("Connection: close"), "{buf}");
        let p1 = buf.find("\"path\":\"/first\"").unwrap();
        let p2 = buf.find("\"path\":\"/second\"").unwrap();
        assert!(p1 < p2, "responses must arrive in request order: {buf}");

        // without the opt-in header the old contract still holds: exactly
        // one response, Connection: close (pinned again by the pipelining
        // test above)
        flag.store(true, Ordering::SeqCst);
        t.join().unwrap();
    }

    #[test]
    fn keepalive_connection_ends_cleanly_when_client_stops_sending() {
        let handler: Handler =
            Arc::new(|_req: Request| Response::json(200, b"{}".to_vec()).into());
        let mut server = HttpServer::bind("127.0.0.1:0", 2, handler).unwrap();
        // short deadline so the idle-connection reap is what ends the test,
        // fast, if the client-side shutdown path regresses
        server.set_read_deadline(Duration::from_millis(300));
        let server = Arc::new(server);
        let addr = server.local_addr().unwrap();
        let flag = server.shutdown_flag();
        let srv = Arc::clone(&server);
        let t = std::thread::spawn(move || srv.serve().unwrap());

        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"GET /a HTTP/1.1\r\nConnection: keep-alive\r\n\r\n")
            .unwrap();
        // stop sending: the server must either see our FIN (clean EOF) or
        // reap the idle connection at the deadline — silently, with no
        // trailing 408 garbage after the valid response
        let _ = stream.shutdown(std::net::Shutdown::Write);
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert_eq!(buf.matches("HTTP/1.1 ").count(), 1, "{buf}");
        assert!(buf.starts_with("HTTP/1.1 200 OK"), "{buf}");
        assert!(!buf.contains("408"), "idle reap must be silent: {buf}");

        flag.store(true, Ordering::SeqCst);
        t.join().unwrap();
    }

    #[test]
    fn streaming_reply_delivers_chunked_frames_over_tcp() {
        let handler: Handler = Arc::new(|_req: Request| {
            Reply::Stream(Box::new(|sink: &mut ChunkSink| {
                assert!(sink.send(b"event: a\ndata: {}\n\n"));
                assert!(sink.send(b"event: b\ndata: {}\n\n"));
            }))
        });
        let server = Arc::new(HttpServer::bind("127.0.0.1:0", 2, handler).unwrap());
        let addr = server.local_addr().unwrap();
        let flag = server.shutdown_flag();
        let srv = Arc::clone(&server);
        let t = std::thread::spawn(move || srv.serve().unwrap());

        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET /stream HTTP/1.1\r\n\r\n").unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("Transfer-Encoding: chunked"), "{buf}");
        assert!(buf.contains("event: a"), "{buf}");
        assert!(buf.contains("event: b"), "{buf}");
        assert!(buf.ends_with("0\r\n\r\n"), "terminated: {buf}");

        flag.store(true, Ordering::SeqCst);
        t.join().unwrap();
    }

    #[test]
    fn sink_detects_client_disconnect() {
        use std::sync::mpsc::channel;
        let (tx, rx) = channel();
        let handler: Handler = Arc::new(move |_req: Request| {
            let tx = tx.clone();
            Reply::Stream(Box::new(move |sink: &mut ChunkSink| {
                assert!(sink.send(b"event: a\ndata: {}\n\n"));
                // wait until the peer has definitely closed
                for _ in 0..100 {
                    if sink.client_gone() {
                        tx.send(true).unwrap();
                        return;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                tx.send(false).unwrap();
            }))
        });
        let server = Arc::new(HttpServer::bind("127.0.0.1:0", 2, handler).unwrap());
        let addr = server.local_addr().unwrap();
        let flag = server.shutdown_flag();
        let srv = Arc::clone(&server);
        let t = std::thread::spawn(move || srv.serve().unwrap());

        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET /stream HTTP/1.1\r\n\r\n").unwrap();
        // read the head + first frame, then hang up mid-stream
        let mut buf = [0u8; 64];
        let _ = stream.read(&mut buf).unwrap();
        drop(stream);
        assert!(
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap(),
            "server must observe the disconnect"
        );
        flag.store(true, Ordering::SeqCst);
        t.join().unwrap();
    }
}
