//! Serving front-end: hand-rolled HTTP/1.1 server (chunked SSE streaming
//! included), the JSON wire formats, and the cluster service that routes
//! the request-lifecycle + adapter-registry API onto a `ClusterEngine`
//! (DESIGN.md §Serving API).

pub mod api;
pub mod http;
pub mod service;

pub use api::{parse_completion, CompletionRequest};
pub use http::{ChunkSink, Handler, HttpServer, Reply, Request, Response};
pub use service::ClusterService;
