//! Serving front-end: hand-rolled HTTP/1.1 server + the JSON completion API
//! (the role llama.cpp's server + node client play in the paper's artifact).

pub mod api;
pub mod http;

pub use api::{parse_completion, CompletionRequest};
pub use http::{Handler, HttpServer, Request, Response};
