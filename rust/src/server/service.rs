//! Long-lived cluster serving front-end (DESIGN.md §Serving API): routes
//! the HTTP surface onto a [`ClusterEngine`] — streamed and one-shot
//! completions, request cancellation, and the dynamic adapter registry.
//!
//! Serving model: the cluster sits behind one mutex. A one-shot completion
//! holds it for a full `serve_one` (dispatch → quiesce). A *streamed*
//! completion instead interleaves `step_once` with event delivery, taking
//! the lock once per scheduler step — so a cancel arriving on another
//! connection (or a client disconnect, polled between frames) lands
//! between steps and releases the slot/pages/pins deterministically.
//! Several streaming connections pump the same cluster cooperatively:
//! every `step_once` advances the globally earliest replica, whoever calls
//! it, and each connection only forwards its own request's events.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::adapters::AdapterStore;
use crate::cluster::{ClusterEngine, Dispatched};
use crate::coordinator::{EngineEvent, EventBus, EventRx, ShedReason};
use crate::metrics::Recorder;
use crate::net::RemoteCluster;
use crate::server::api;
use crate::server::http::{ChunkSink, Handler, Reply, Request, Response};
use crate::util::json::ObjBuilder;
use crate::workload::TraceRequest;

/// The serving back-end behind the HTTP surface: either the in-process
/// cluster (one engine per replica, single process) or the socket router
/// (one engine per worker *process*, DESIGN.md §Distributed serving). The
/// HTTP routes, SSE framing, and registry semantics are identical either
/// way — that symmetry is the solo-equivalence guarantee made structural.
pub enum AnyCluster {
    Local(ClusterEngine),
    Remote(RemoteCluster),
}

impl AnyCluster {
    fn makespan_s(&self) -> f64 {
        match self {
            AnyCluster::Local(c) => c.makespan_s(),
            AnyCluster::Remote(c) => c.makespan_s(),
        }
    }

    /// Admission + dispatch. The in-process path is infallible (sheds are
    /// data, not errors); the socket path can fail on I/O plumbing.
    fn try_dispatch(&mut self, req: TraceRequest) -> Result<Dispatched> {
        match self {
            AnyCluster::Local(c) => Ok(c.try_dispatch(req)),
            AnyCluster::Remote(c) => c.try_dispatch(req),
        }
    }

    fn try_serve_one(&mut self, req: TraceRequest) -> Result<Dispatched> {
        match self {
            AnyCluster::Local(c) => c.try_serve_one(req),
            AnyCluster::Remote(c) => c.try_serve_one(req),
        }
    }

    fn step_once(&mut self) -> Result<bool> {
        match self {
            AnyCluster::Local(c) => c.step_once(),
            AnyCluster::Remote(c) => c.step_once(),
        }
    }

    fn cancel(&mut self, id: u64) -> Result<bool> {
        match self {
            AnyCluster::Local(c) => c.cancel(id),
            AnyCluster::Remote(c) => c.cancel(id),
        }
    }

    fn quiesce(&mut self) -> Result<()> {
        match self {
            AnyCluster::Local(c) => c.quiesce(),
            AnyCluster::Remote(c) => c.quiesce(),
        }
    }

    fn trim_logs(&mut self) {
        match self {
            AnyCluster::Local(c) => c.trim_logs(),
            AnyCluster::Remote(c) => c.trim_logs(),
        }
    }

    fn recorder(&self) -> &Recorder {
        match self {
            AnyCluster::Local(c) => &c.recorder,
            AnyCluster::Remote(c) => &c.recorder,
        }
    }

    fn residency(&self, id: u64) -> Vec<usize> {
        match self {
            AnyCluster::Local(c) => c.residency(id),
            AnyCluster::Remote(c) => c.residency(id),
        }
    }

    fn registry_pinned(&self, id: u64) -> bool {
        match self {
            AnyCluster::Local(c) => c.registry_pinned(id),
            AnyCluster::Remote(c) => c.registry_pinned(id),
        }
    }

    fn pin_adapter(&mut self, id: u64) -> Result<usize> {
        match self {
            AnyCluster::Local(c) => c.pin_adapter(id),
            AnyCluster::Remote(c) => c.pin_adapter(id),
        }
    }

    fn unpin_adapter(&mut self, id: u64) -> usize {
        match self {
            AnyCluster::Local(c) => c.unpin_adapter(id),
            AnyCluster::Remote(c) => c.unpin_adapter(id),
        }
    }

    fn purge_adapter(&mut self, id: u64) -> Result<usize> {
        match self {
            AnyCluster::Local(c) => c.purge_adapter(id),
            AnyCluster::Remote(c) => c.purge_adapter(id),
        }
    }

    fn n_shards(&self) -> usize {
        match self {
            AnyCluster::Local(c) => c.n_replicas(),
            AnyCluster::Remote(c) => c.n_workers(),
        }
    }

    /// Shard-naming diagnosis carried in an Unreachable shed's 503 body.
    fn unreachable_detail(&self) -> String {
        match self {
            AnyCluster::Local(c) => {
                let states: Vec<String> = (0..c.n_replicas())
                    .map(|i| format!("shard {i} {}", c.replica_state_name(i)))
                    .collect();
                format!("no routable replica — {}", states.join(", "))
            }
            AnyCluster::Remote(c) => c.unreachable_detail(),
        }
    }
}

/// The HTTP-facing wrapper around one cluster: shared by every connection
/// thread; owns request-id allocation and the event/registry plumbing.
pub struct ClusterService {
    cluster: Mutex<AnyCluster>,
    events: Arc<EventBus>,
    store: Arc<AdapterStore>,
    next_id: AtomicU64,
    /// synthetic-tenant modulus for auto-select requests (the sim router
    /// profiles against this latent-task range)
    n_adapters: u64,
}

/// What happened when one event was forwarded to the client.
enum Forward {
    Sent,
    Terminal,
    ClientGone,
}

impl ClusterService {
    pub fn new(cluster: ClusterEngine, n_adapters: usize) -> Arc<Self> {
        let events = cluster.events();
        let store = cluster.store();
        Arc::new(Self {
            cluster: Mutex::new(AnyCluster::Local(cluster)),
            events,
            store,
            next_id: AtomicU64::new(1),
            n_adapters: n_adapters.max(1) as u64,
        })
    }

    /// Mount the same HTTP surface on a socket fleet: the router process
    /// calls this with a connected [`RemoteCluster`].
    pub fn new_remote(cluster: RemoteCluster, n_adapters: usize) -> Arc<Self> {
        let events = cluster.events();
        let store = cluster.store();
        Arc::new(Self {
            cluster: Mutex::new(AnyCluster::Remote(cluster)),
            events,
            store,
            next_id: AtomicU64::new(1),
            n_adapters: n_adapters.max(1) as u64,
        })
    }

    /// Take the cluster lock, recovering from poison: a panicking
    /// connection thread must not wedge every other client behind a
    /// `PoisonError`, and the cluster state is step-consistent (each step
    /// completes or the request is shed), so the data under a poisoned
    /// lock is still well-formed.
    fn lock_cluster(&self) -> std::sync::MutexGuard<'_, AnyCluster> {
        self.cluster
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// The connection handler to mount on an [`HttpServer`]
    /// (routing table in the `server::api` module docs).
    pub fn handler(self: &Arc<Self>) -> Handler {
        let svc = Arc::clone(self);
        Arc::new(move |req: Request| Self::route(&svc, req))
    }

    fn route(svc: &Arc<Self>, req: Request) -> Reply {
        let method = req.method.as_str();
        match req.path.as_str() {
            "/health" => match method {
                "GET" => svc.health().into(),
                _ => method_not_allowed(),
            },
            "/cluster" => match method {
                "GET" => svc.cluster_status().into(),
                _ => method_not_allowed(),
            },
            "/v1/completions" => match method {
                "POST" => Self::completions(svc, &req),
                _ => method_not_allowed(),
            },
            "/v1/adapters" => match method {
                "GET" => svc.list_adapters().into(),
                "POST" => svc.register_adapter(&req.body).into(),
                _ => method_not_allowed(),
            },
            p => {
                if let Some((id, tail)) = adapter_subroute(p) {
                    return match (method, tail) {
                        ("DELETE", "") => svc.delete_adapter(id).into(),
                        ("POST", "pin") => svc.pin_adapter(id).into(),
                        ("POST", "unpin") => svc.unpin_adapter(id).into(),
                        (_, "" | "pin" | "unpin") => method_not_allowed(),
                        _ => not_found(),
                    };
                }
                if let Some(id) = cancel_subroute(p) {
                    return match method {
                        "POST" => svc.cancel_request_http(id).into(),
                        _ => method_not_allowed(),
                    };
                }
                not_found()
            }
        }
    }

    // --- completions -----------------------------------------------------

    fn completions(svc: &Arc<Self>, req: &Request) -> Reply {
        let parsed = match api::parse_completion(&req.body) {
            Ok(p) => p,
            Err(e) => return Response::error(400, &e.to_string()).into(),
        };
        // the registry is the source of truth: an unregistered (or deleted)
        // adapter id is 404, never an engine error mid-flight
        if let Some(a) = parsed.adapter {
            if !svc.store.contains(a) {
                return Response::error(404, &format!("unknown adapter {a}")).into();
            }
        }
        let id = svc.next_id.fetch_add(1, Ordering::SeqCst);
        // size the channel to the request: the blocking path buffers every
        // event until quiescence, and the deterministic-recompute guarantee
        // means the *final* emission is a contiguous 0..n token stream — a
        // request-sized buffer can therefore never truncate the response,
        // no matter how many re-emitted prefixes overflow coalescing drops
        let rx = svc
            .events
            .subscribe_with_capacity(id, parsed.max_tokens + 64);
        let treq = TraceRequest {
            id,
            arrival_s: 0.0, // stamped from the cluster clock at dispatch
            true_adapter: parsed.adapter.unwrap_or(id % svc.n_adapters),
            explicit_adapter: parsed.adapter,
            input_tokens: parsed.prompt_tokens.len(),
            output_tokens: parsed.max_tokens,
            qos: parsed.qos,
            deadline_s: parsed.deadline_s,
        };
        if parsed.stream {
            let svc = Arc::clone(svc);
            Reply::Stream(Box::new(move |sink| {
                svc.stream_completion(sink, rx, id, treq);
            }))
        } else {
            svc.blocking_completion(rx, id, treq, parsed.adapter)
        }
    }

    /// One-shot path: serve to quiescence under the lock, then rebuild the
    /// response from the request's own event stream — tokens plus its real
    /// first-token/total latency (not fleet averages).
    fn blocking_completion(
        &self,
        rx: EventRx,
        id: u64,
        mut treq: TraceRequest,
        adapter: Option<u64>,
    ) -> Reply {
        let (arrival, served) = {
            let mut c = self.lock_cluster();
            // re-check under the lock: a DELETE may have unregistered the
            // adapter between the fast-path check and here (deletes mutate
            // the store while holding this lock)
            if let Some(a) = treq.explicit_adapter {
                if !self.store.contains(a) {
                    drop(c);
                    self.events.unsubscribe(id);
                    return Response::error(404, &format!("unknown adapter {a}")).into();
                }
            }
            let arrival = c.makespan_s();
            treq.arrival_s = arrival;
            (arrival, c.try_serve_one(treq))
        };
        self.events.unsubscribe(id);
        let served = match served {
            Ok(d) => d,
            Err(e) => return Response::error(500, &format!("{e:#}")).into(),
        };
        // QoS admission shed: machine-retryable, with a Retry-After hint —
        // 429 when the tenant's token bucket is empty, 503 when the
        // queueing-delay estimate says the deadline is already lost or no
        // shard is routable (the latter names every shard and its state,
        // so the operator learns *which* workers are down from the body)
        if let Dispatched::Shed { reason, retry_after_s } = served {
            let (status, msg) = match reason {
                ShedReason::RateLimit => (429, format!("request shed: {}", reason.name())),
                ShedReason::Deadline => (503, format!("request shed: {}", reason.name())),
                ShedReason::Unreachable => {
                    let detail = self.lock_cluster().unreachable_detail();
                    (503, format!("request shed: {}: {detail}", reason.name()))
                }
            };
            return Response::error(status, &msg).retry_after(retry_after_s).into();
        }
        let mut tokens: Vec<u32> = Vec::new();
        let (mut first_t, mut done_t) = (arrival, arrival);
        let mut seen_first = false;
        for ev in rx.try_iter() {
            match ev {
                EngineEvent::Token { index, token, t } => {
                    if !seen_first && index == 0 {
                        first_t = t;
                        seen_first = true;
                    }
                    // preempt-and-recompute re-emits earlier indices with
                    // bit-identical tokens — append only the frontier
                    if index as usize == tokens.len() {
                        tokens.push(token);
                    }
                }
                EngineEvent::Done { t } => done_t = t,
                _ => {}
            }
        }
        Response::json(
            200,
            api::completion_response(
                id,
                adapter.unwrap_or(0),
                adapter.is_none(),
                &tokens,
                (first_t - arrival).max(0.0),
                (done_t - arrival).max(0.0),
            )
            .into_bytes(),
        )
        .into()
    }

    /// Streaming path: dispatch, then alternate one scheduler step with
    /// event delivery until the request's terminal event. Client disconnect
    /// (polled between frames, or a failed chunk write) cancels the request.
    fn stream_completion(
        &self,
        sink: &mut ChunkSink,
        rx: EventRx,
        id: u64,
        mut treq: TraceRequest,
    ) {
        {
            let mut c = self.lock_cluster();
            // same under-the-lock registration re-check as the one-shot path
            if let Some(a) = treq.explicit_adapter {
                if !self.store.contains(a) {
                    drop(c);
                    let frame = format!(
                        "event: error\ndata: {}\n\n",
                        ObjBuilder::new()
                            .num("id", id as f64)
                            .str("error", format!("unknown adapter {a}"))
                            .build()
                    );
                    let _ = sink.send(frame.as_bytes());
                    self.events.unsubscribe(id);
                    return;
                }
            }
            treq.arrival_s = c.makespan_s();
            // a QoS shed emits the terminal `shed` SSE frame through the
            // subscribed event stream below — no special-casing needed here
            let _ = c.try_dispatch(treq);
        }
        let mut next_index = 0u32;
        'serve: loop {
            // deliver everything buffered before stepping again
            while let Ok(ev) = rx.try_recv() {
                match self.forward(sink, id, ev, &mut next_index) {
                    Forward::Sent => {}
                    Forward::Terminal => break 'serve,
                    Forward::ClientGone => {
                        self.cancel_quietly(id);
                        break 'serve;
                    }
                }
            }
            if sink.client_gone() {
                self.cancel_quietly(id);
                break;
            }
            let stepped = {
                let mut c = self.lock_cluster();
                c.step_once()
            };
            match stepped {
                Ok(true) => {}
                Ok(false) => {
                    // cluster idle: our terminal event may still be in
                    // flight from another connection's stepping — wait
                    // briefly, then conclude the stream is over
                    match rx.recv_timeout(Duration::from_millis(100)) {
                        Ok(ev) => match self.forward(sink, id, ev, &mut next_index) {
                            Forward::Sent => {}
                            Forward::Terminal => break,
                            Forward::ClientGone => {
                                self.cancel_quietly(id);
                                break;
                            }
                        },
                        Err(_) => break,
                    }
                }
                Err(e) => {
                    let frame = format!(
                        "event: error\ndata: {}\n\n",
                        ObjBuilder::new()
                            .num("id", id as f64)
                            .str("error", format!("{e:#}"))
                            .build()
                    );
                    let _ = sink.send(frame.as_bytes());
                    break;
                }
            }
        }
        self.events.unsubscribe(id);
        self.lock_cluster().trim_logs();
    }

    fn forward(
        &self,
        sink: &mut ChunkSink,
        id: u64,
        ev: EngineEvent,
        next_index: &mut u32,
    ) -> Forward {
        // deterministic recompute after a preemption replays earlier token
        // indices bit-identically — the client must not see them twice
        if let EngineEvent::Token { index, .. } = ev {
            if index < *next_index {
                return Forward::Sent;
            }
            *next_index = index + 1;
        }
        if !sink.send(api::event_frame(id, &ev).as_bytes()) {
            return Forward::ClientGone;
        }
        if ev.is_terminal() {
            Forward::Terminal
        } else {
            Forward::Sent
        }
    }

    /// Cancel without a response surface (disconnect path).
    fn cancel_quietly(&self, id: u64) {
        let mut c = self.lock_cluster();
        let _ = c.cancel(id);
    }

    // --- request lifecycle -----------------------------------------------

    fn cancel_request_http(&self, id: u64) -> Response {
        let mut c = self.lock_cluster();
        match c.cancel(id) {
            Ok(true) => Response::json(
                200,
                ObjBuilder::new()
                    .num("id", id as f64)
                    .bool("cancelled", true)
                    .build()
                    .to_string()
                    .into_bytes(),
            ),
            Ok(false) => Response::error(404, &format!("no in-flight request {id}")),
            Err(e) => Response::error(500, &format!("{e:#}")),
        }
    }

    // --- status ----------------------------------------------------------

    fn health(&self) -> Response {
        let c = self.lock_cluster();
        let summary = c.recorder().summarize(None);
        let (idle, total, live) = match &*c {
            AnyCluster::Local(c) => {
                let idle = c
                    .replicas()
                    .iter()
                    .map(|r| r.engine.slot_count() - r.engine.active_slots())
                    .sum();
                let total = c.replicas().iter().map(|r| r.engine.slot_count()).sum();
                let live: Vec<api::ReplicaHealth> = (0..c.n_replicas())
                    .map(|i| api::ReplicaHealth {
                        state: c.replica_state_name(i),
                        heartbeat_age_s: c.heartbeat_age_s(i),
                    })
                    .collect();
                (idle, total, live)
            }
            AnyCluster::Remote(c) => {
                // slot occupancy from the last gossiped scoreboards — the
                // wall-clock heartbeat age doubles as the staleness signal
                let idle: usize = (0..c.n_workers())
                    .map(|i| {
                        let b = c.board(i);
                        b.slots.saturating_sub(b.active) as usize
                    })
                    .sum();
                let total: usize =
                    (0..c.n_workers()).map(|i| c.board(i).slots as usize).sum();
                let live: Vec<api::ReplicaHealth> = (0..c.n_workers())
                    .map(|i| api::ReplicaHealth {
                        state: c.link_state_name(i),
                        heartbeat_age_s: c.heartbeat_age_s(i),
                    })
                    .collect();
                (idle, total, live)
            }
        };
        Response::json(
            200,
            api::health_response(&summary, idle, total, &live).into_bytes(),
        )
    }

    fn cluster_status(&self) -> Response {
        let c = self.lock_cluster();
        let summary = c.recorder().summarize(None);
        let (rows, steals) = match &*c {
            AnyCluster::Local(c) => {
                let rows: Vec<api::ReplicaStatus> = c
                    .replicas()
                    .iter()
                    .zip(&c.dispatched)
                    .enumerate()
                    .map(|(i, (r, &dispatched))| api::ReplicaStatus {
                        state: c.replica_state_name(i),
                        restarts: c.restarts[i],
                        rehomed_requests: c.rehomed[i],
                        queue: r.engine.queue_len(),
                        active_slots: r.engine.active_slots(),
                        resident_adapters: r.engine.memory().resident_count(),
                        clock_s: r.clock.now(),
                        dispatched,
                        free_pages: r.engine.free_pages(),
                        total_pages: r.engine.total_pages(),
                        kv_pages: r.engine.kv_pages_in_use(),
                        preemptions: r.engine.stats.preemptions,
                        admission_deferrals: r.engine.stats.kv_admission_deferrals,
                        cancelled: r.engine.stats.cancelled,
                        prefix_pages: r.engine.prefix_pages_held(),
                        prefix_hits: r.engine.stats.prefix_hits,
                        prefix_hit_rate: r.engine.prefix_hit_rate(),
                        shared_kv_pages: r.engine.stats.shared_prompt_pages,
                    })
                    .collect();
                (rows, c.steals)
            }
            AnyCluster::Remote(c) => {
                // the same rows, reconstructed from gossip: every counter a
                // worker exports in its scoreboard maps onto one column, so
                // `GET /cluster` reads identically against a socket fleet
                let rows: Vec<api::ReplicaStatus> = (0..c.n_workers())
                    .map(|i| {
                        let b = c.board(i);
                        api::ReplicaStatus {
                            state: c.link_state_name(i),
                            restarts: 0,
                            rehomed_requests: c.rehomed[i],
                            queue: b.queue as usize,
                            active_slots: b.active as usize,
                            resident_adapters: b.resident.len(),
                            clock_s: b.clock_s,
                            dispatched: c.dispatched[i],
                            free_pages: b.free_pages as usize,
                            total_pages: b.total_pages as usize,
                            kv_pages: b.kv_pages as usize,
                            preemptions: b.preemptions,
                            admission_deferrals: b.admission_deferrals,
                            cancelled: b.cancelled,
                            prefix_pages: b.prefix_pages as usize,
                            prefix_hits: b.prefix_hits,
                            prefix_hit_rate: if b.prefix_lookups > 0 {
                                b.prefix_hits as f64 / b.prefix_lookups as f64
                            } else {
                                0.0
                            },
                            shared_kv_pages: b.shared_kv_pages,
                        }
                    })
                    .collect();
                (rows, c.steals)
            }
        };
        Response::json(
            200,
            api::cluster_status_response(&rows, steals, &summary).into_bytes(),
        )
    }

    // --- adapter registry ------------------------------------------------

    fn list_adapters(&self) -> Response {
        let c = self.lock_cluster();
        let counts = c.recorder().per_adapter_counts();
        let rows: Vec<api::AdapterRow> = self
            .store
            .ids()
            .into_iter()
            .map(|id| api::AdapterRow {
                id,
                resident_shards: c.residency(id),
                pinned: c.registry_pinned(id),
                requests: counts.get(&(id as usize)).copied().unwrap_or(0),
            })
            .collect();
        Response::json(200, api::adapters_response(&rows).into_bytes())
    }

    fn register_adapter(&self, body: &[u8]) -> Response {
        let (id, path) = match api::parse_register(body) {
            Ok(v) => v,
            Err(e) => return Response::error(400, &e.to_string()),
        };
        // registry mutations serialize on the cluster lock (like DELETE), so
        // two concurrent registers of one id cannot both report 201
        let mut c = self.lock_cluster();
        if self.store.contains(id) {
            return Response::error(409, &format!("adapter {id} already registered"));
        }
        if let AnyCluster::Remote(rc) = &mut *c {
            // each worker owns its own store; a router-local file path means
            // nothing on their filesystems — only synthetic registration
            // (deterministic per id, so every copy is byte-identical) works
            if path.is_some() {
                return Response::error(
                    400,
                    "file import is not supported in distributed mode; \
                     POST without a path to register a synthetic adapter",
                );
            }
            if let Err(e) = self.store.put_synthetic(id) {
                return Response::error(400, &format!("{e:#}"));
            }
            return match rc.register_adapter(id) {
                Ok(n) => Response::json(
                    201,
                    ObjBuilder::new()
                        .num("id", id as f64)
                        .bool("registered", true)
                        .bool("synthetic", true)
                        .num("workers", n as f64)
                        .build()
                        .to_string()
                        .into_bytes(),
                ),
                Err(e) => Response::error(500, &format!("{e:#}")),
            };
        }
        let result = match &path {
            Some(p) => self.store.import(id, p),
            None => self.store.put_synthetic(id),
        };
        match result {
            Ok(()) => Response::json(
                201,
                ObjBuilder::new()
                    .num("id", id as f64)
                    .bool("registered", true)
                    .bool("synthetic", path.is_none())
                    .build()
                    .to_string()
                    .into_bytes(),
            ),
            Err(e) => Response::error(400, &format!("{e:#}")),
        }
    }

    /// `DELETE /v1/adapters/{id}`: drain in-flight users (quiesce), evict
    /// from every shard's cache/bank/prefetcher, scrub the dispatch
    /// scoreboard, then unregister the file — subsequent requests for the
    /// id are 404 at parse-adjacent validation.
    fn delete_adapter(&self, id: u64) -> Response {
        // check, drain, purge AND unregister under one lock acquisition, so
        // no completion can pass its registration check, then watch the file
        // vanish (or reload a purged adapter from a file about to go)
        let purged = {
            let mut c = self.lock_cluster();
            if !self.store.contains(id) {
                return Response::error(404, &format!("unknown adapter {id}"));
            }
            if let Err(e) = c.quiesce() {
                return Response::error(500, &format!("{e:#}"));
            }
            let purged = match c.purge_adapter(id) {
                Ok(n) => n,
                Err(e) => return Response::error(409, &format!("{e:#}")),
            };
            if let Err(e) = self.store.remove(id) {
                return Response::error(500, &format!("{e:#}"));
            }
            purged
        };
        Response::json(
            200,
            ObjBuilder::new()
                .num("id", id as f64)
                .bool("deleted", true)
                .num("purged_shards", purged as f64)
                .build()
                .to_string()
                .into_bytes(),
        )
    }

    fn pin_adapter(&self, id: u64) -> Response {
        let mut c = self.lock_cluster();
        if !self.store.contains(id) {
            return Response::error(404, &format!("unknown adapter {id}"));
        }
        let replicas = c.n_shards();
        match c.pin_adapter(id) {
            Ok(0) => Response::error(503, "no replica could pin right now — retry")
                .retry_after(1),
            Ok(n) => Response::json(
                200,
                ObjBuilder::new()
                    .num("id", id as f64)
                    .num("pinned_shards", n as f64)
                    .num("replicas", replicas as f64)
                    .build()
                    .to_string()
                    .into_bytes(),
            ),
            Err(e) => Response::error(500, &format!("{e:#}")),
        }
    }

    fn unpin_adapter(&self, id: u64) -> Response {
        let mut c = self.lock_cluster();
        if !self.store.contains(id) {
            return Response::error(404, &format!("unknown adapter {id}"));
        }
        let n = c.unpin_adapter(id);
        Response::json(
            200,
            ObjBuilder::new()
                .num("id", id as f64)
                .num("unpinned_shards", n as f64)
                .build()
                .to_string()
                .into_bytes(),
        )
    }
}

fn not_found() -> Reply {
    Response::error(404, "not found").into()
}

fn method_not_allowed() -> Reply {
    Response::error(405, "method not allowed").into()
}

/// `/v1/adapters/{id}[/{tail}]` → (id, tail). Non-numeric ids fall through
/// to 404.
fn adapter_subroute(path: &str) -> Option<(u64, &str)> {
    let rest = path.strip_prefix("/v1/adapters/")?;
    let (id_str, tail) = match rest.split_once('/') {
        Some((a, b)) => (a, b),
        None => (rest, ""),
    };
    id_str.parse().ok().map(|id| (id, tail))
}

/// `/v1/requests/{id}/cancel` → id.
fn cancel_subroute(path: &str) -> Option<u64> {
    path.strip_prefix("/v1/requests/")?
        .strip_suffix("/cancel")?
        .parse()
        .ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subroutes_parse() {
        assert_eq!(adapter_subroute("/v1/adapters/42"), Some((42, "")));
        assert_eq!(adapter_subroute("/v1/adapters/42/pin"), Some((42, "pin")));
        assert_eq!(adapter_subroute("/v1/adapters/42/unpin"), Some((42, "unpin")));
        assert_eq!(adapter_subroute("/v1/adapters/x"), None);
        assert_eq!(adapter_subroute("/v1/adapter/42"), None);
        assert_eq!(cancel_subroute("/v1/requests/9/cancel"), Some(9));
        assert_eq!(cancel_subroute("/v1/requests/9"), None);
        assert_eq!(cancel_subroute("/v1/requests/x/cancel"), None);
    }
}
