//! EdgeLoRA — an efficient multi-tenant LLM serving system for edge devices.
//!
//! Reproduction of Shen et al., "EdgeLoRA: An Efficient Multi-Tenant LLM
//! Serving System on Edge Devices" (MobiSys '25).
//!
//! Three-layer architecture:
//!  * L3 (this crate): request routing, slot state machine, adaptive adapter
//!    selection, heterogeneous memory management, batch scheduling.
//!  * L2 (python/compile/model.py): JAX transformer forward with batched
//!    LoRA, lowered AOT to HLO text artifacts.
//!  * L1 (python/compile/kernels/): Pallas BGMV (batched gather matmul)
//!    kernels implementing batch LoRA inference.
//!
//! Python never runs on the request path: the Rust binary loads the
//! AOT-compiled HLO artifacts through PJRT (`runtime`) and serves requests.

// Unsafe is opt-in per site: the two remaining blocks (raw `signal(2)` in
// net/node.rs, the `Send` impl for the PJRT backend) each carry an
// explicit `#[allow(unsafe_code)]` + `// SAFETY:` argument. Everything
// else — including the whole memory subsystem — is safe code by
// construction (DESIGN.md §Static analysis).
#![deny(unsafe_code)]

pub mod adapters;
pub mod analysis;
pub mod backend;
pub mod cli;
pub mod baseline;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod experiments;
pub mod memory;
pub mod metrics;
pub mod net;
pub mod router;
pub mod runtime;
pub mod server;
pub mod quant;
pub mod util;
pub mod workload;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
