//! Router-side core of multi-node serving (DESIGN.md §Distributed
//! serving): [`RemoteCluster`] owns the [`Dispatcher`], the wall-clock
//! health ladder, and one framed TCP link per worker node, and presents
//! the same serving surface [`ClusterEngine`](crate::cluster::ClusterEngine)
//! presents in-process — so `server::ClusterService` mounts either behind
//! the identical HTTP routes.
//!
//! Event flow: workers free-run and stream every request-lifecycle event
//! back as `Event` frames; the router re-emits them on its own
//! [`EventBus`] (SSE consumers subscribe there, exactly as in-process) and
//! *reconstructs* per-request records for its `Recorder` from the stream —
//! guarded by a `finished` set so a rehome/steal replay can never double-
//! count a completion. Sim tokens are pure functions of request content,
//! so a replay re-emits bit-identical `(index, token)` pairs and the
//! monotone `index == tokens` frontier check keeps the reconstruction
//! exact.
//!
//! Health: the in-process cluster detects death by frozen *virtual*
//! clocks; across real sockets the signal is wall-clock staleness of the
//! last received frame — Alive → Suspect (unroutable) after
//! [`SUSPECT_AFTER`], Suspect → Dead after [`DEAD_AFTER`], any frame
//! recovers Suspect → Alive. A connection error or EOF is immediately
//! Dead. Dead links rehome their in-flight requests onto live workers in
//! `(qos, arrival, id)` order; a worker that drains gracefully hands its
//! backlog over in a `Draining` frame instead and skips the ladder.

use std::collections::{BTreeMap, BTreeSet};
use std::io;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::adapters::AdapterStore;
use crate::cluster::{ClusterConfig, Dispatched, Dispatcher, TokenBucket};
use crate::coordinator::{synth_prompt_into, EngineEvent, EventBus, ShedReason};
use crate::memory::boundary_hashes;
use crate::metrics::{Recorder, RequestRecord, Summary};
use crate::net::proto::{
    Conn, Frame, NodeScoreboard, OP_DELETE, OP_PIN, OP_REGISTER, OP_UNPIN, PROTO_VERSION,
};
use crate::workload::{Trace, TraceRequest};

/// Wall-clock staleness thresholds of the link health ladder. A healthy
/// idle node heartbeats every ~50 ms, so Suspect carries a 20× margin.
pub const SUSPECT_AFTER: Duration = Duration::from_millis(1000);
pub const DEAD_AFTER: Duration = Duration::from_millis(3000);

/// `Retry-After` seconds a router-side Unreachable shed advertises: long
/// enough for the Dead→rehome or operator restart to land, short enough
/// that clients re-probe a healing fleet promptly.
const RETRY_AFTER_UNREACHABLE: u64 = 2;

/// Wall watchdogs: a one-shot completion and a fleet quiesce must finish
/// within these or the caller gets an error instead of a hang.
const SERVE_WATCHDOG: Duration = Duration::from_secs(60);
const QUIESCE_WATCHDOG: Duration = Duration::from_secs(60);

/// Registry RPC broadcast timeout (Pin/Unpin/Register/Delete round trip).
const OP_TIMEOUT: Duration = Duration::from_secs(10);

/// How long `connect` retries dialing a worker that is still binding.
const DIAL_TIMEOUT: Duration = Duration::from_secs(10);

/// A donor queue must exceed this before the router issues a remote steal.
const STEAL_MIN_QUEUE: u32 = 2;

/// Link health/lifecycle state (names align with the in-process ladder so
/// `GET /cluster` reads the same either way).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkState {
    Alive,
    Suspect,
    Dead,
    /// drained (graceful shutdown or standby scale-down) — unroutable,
    /// backlog already handed back
    Draining,
}

/// One worker link: the framed connection, the last gossiped scoreboard,
/// and the health-ladder bookkeeping.
struct WorkerLink {
    addr: String,
    conn: Option<Conn>,
    state: LinkState,
    board: NodeScoreboard,
    last_rx: Instant,
    /// whether dispatch may target this link when Alive (false for
    /// standby workers until activated, and after a drain)
    serving: bool,
    /// configured as standby capacity (activated under queue pressure)
    standby: bool,
    /// when an activated standby last held work (scale-down timer)
    busy_until: Instant,
}

/// Router-side view of one in-flight request (recorder reconstruction +
/// rehome bookkeeping).
struct Flight {
    req: TraceRequest,
    shard: usize,
    scheduled: f64,
    first_token: f64,
    last_token_t: f64,
    /// contiguous token frontier — replayed indices below it are dropped
    /// from the reconstruction (consumers dedup the same way)
    tokens: u32,
}

/// Aggregate outcome of a socket-cluster run (the remote analogue of
/// `ClusterReport`, carrying only what crosses the wire).
#[derive(Debug, Clone)]
pub struct RemoteReport {
    pub summary: Summary,
    pub makespan_s: f64,
    pub dispatched: Vec<u64>,
    pub steals: u64,
    pub rehomed_total: u64,
    pub shed_total: u64,
    pub prefix_hits: u64,
    pub prefix_lookups: u64,
    /// routes decided by a prefix-hash hit (the affinity ablation column)
    pub prefix_overrides: u64,
}

/// The router's cluster handle: N worker links behind one dispatcher.
pub struct RemoteCluster {
    links: Vec<WorkerLink>,
    dispatcher: Dispatcher,
    cfg: ClusterConfig,
    events: Arc<EventBus>,
    pub recorder: Recorder,
    store: Arc<AdapterStore>,
    inflight: BTreeMap<u64, Flight>,
    finished: BTreeSet<u64>,
    buckets: BTreeMap<u64, TokenBucket>,
    /// router-side registry pin view (nodes hold the actual pins)
    pinned: BTreeSet<u64>,
    /// (donor, thief) of the one steal RPC allowed in flight
    steal_pending: Option<(usize, usize)>,
    /// collected registry acks awaiting a broadcast's tally
    acks: Vec<(u8, u64, u64, usize)>,
    pub dispatched: Vec<u64>,
    pub rehomed: Vec<u64>,
    pub steals: u64,
    pub rehomed_total: u64,
    pub shed_total: u64,
    /// KV page geometry from the handshake (0 disables prefix hints —
    /// unpaged fleet or heterogeneous geometry)
    page_tokens: usize,
    max_prompt: usize,
    n_adapters: usize,
    prompt_buf: Vec<u32>,
    hash_buf: Vec<u64>,
    load_buf: Vec<usize>,
}

impl RemoteCluster {
    /// Dial and handshake every worker. `workers` is in shard order — the
    /// node started as `--shard i` must be the i-th address (the handshake
    /// enforces it). The last `standby` workers start unroutable and are
    /// activated under queue pressure. The store is the router's own copy
    /// of the (deterministic, synthetic) adapter registry.
    pub fn connect(
        workers: &[String],
        standby: usize,
        cfg: ClusterConfig,
        store: Arc<AdapterStore>,
        n_adapters: usize,
    ) -> Result<Self> {
        let n = workers.len();
        anyhow::ensure!(n > 0, "router needs at least one worker");
        anyhow::ensure!(standby < n, "at least one worker must start serving");
        let mut dispatcher =
            Dispatcher::new(n, cfg.policy, cfg.vnodes).with_page_weight(cfg.page_weight);
        let mut links = Vec::with_capacity(n);
        let mut page_tokens = usize::MAX;
        let mut max_prompt = 0usize;
        for (i, addr) in workers.iter().enumerate() {
            let mut conn = dial(addr)?;
            conn.send(&Frame::Hello {
                version: PROTO_VERSION,
                shard: i as u32,
                peers: n as u32,
            })
            .with_context(|| format!("handshaking shard {i} ({addr})"))?;
            let (pt, mp) = await_hello_ack(&mut conn, i)?;
            // prefix hints need the whole fleet on one geometry; otherwise
            // hashes computed here would never match any node's radix
            page_tokens = if page_tokens == usize::MAX || page_tokens == pt {
                pt
            } else {
                0
            };
            max_prompt = max_prompt.max(mp);
            let standby_link = i >= n - standby;
            if standby_link {
                dispatcher.set_routable(i, false);
            }
            links.push(WorkerLink {
                addr: addr.clone(),
                conn: Some(conn),
                state: LinkState::Alive,
                board: NodeScoreboard::default(),
                last_rx: Instant::now(),
                serving: !standby_link,
                standby: standby_link,
                busy_until: Instant::now(),
            });
        }
        if page_tokens == usize::MAX {
            page_tokens = 0;
        }
        Ok(Self {
            links,
            dispatcher,
            cfg,
            events: Arc::new(EventBus::new()),
            recorder: Recorder::new(),
            store,
            inflight: BTreeMap::new(),
            finished: BTreeSet::new(),
            buckets: BTreeMap::new(),
            pinned: BTreeSet::new(),
            steal_pending: None,
            acks: Vec::new(),
            dispatched: vec![0; n],
            rehomed: vec![0; n],
            steals: 0,
            rehomed_total: 0,
            shed_total: 0,
            page_tokens,
            max_prompt,
            n_adapters,
            prompt_buf: Vec::new(),
            hash_buf: Vec::new(),
            load_buf: Vec::new(),
        })
    }

    pub fn events(&self) -> Arc<EventBus> {
        Arc::clone(&self.events)
    }

    pub fn store(&self) -> Arc<AdapterStore> {
        Arc::clone(&self.store)
    }

    pub fn n_workers(&self) -> usize {
        self.links.len()
    }

    /// Observation frontier: the furthest worker virtual clock gossiped so
    /// far (drives arrival stamping and report durations, like the
    /// in-process makespan).
    pub fn makespan_s(&self) -> f64 {
        self.links
            .iter()
            .map(|l| l.board.clock_s)
            .fold(0.0, f64::max)
    }

    pub fn link_state_name(&self, i: usize) -> &'static str {
        match self.links[i].state {
            LinkState::Alive if !self.links[i].serving && self.links[i].standby => "standby",
            LinkState::Alive => "alive",
            LinkState::Suspect => "suspect",
            LinkState::Dead => "dead",
            LinkState::Draining => "draining",
        }
    }

    pub fn heartbeat_age_s(&self, i: usize) -> f64 {
        self.links[i].last_rx.elapsed().as_secs_f64()
    }

    pub fn board(&self, i: usize) -> &NodeScoreboard {
        &self.links[i].board
    }

    /// Shards whose gossiped resident set holds `id` (registry listing).
    pub fn residency(&self, id: u64) -> Vec<usize> {
        self.links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.board.resident.contains(&id))
            .map(|(i, _)| i)
            .collect()
    }

    pub fn registry_pinned(&self, id: u64) -> bool {
        self.pinned.contains(&id)
    }

    fn any_routable(&self) -> bool {
        (0..self.links.len()).any(|i| self.dispatcher.is_routable(i))
    }

    /// Shard-naming diagnosis for an Unreachable shed's error body.
    pub fn unreachable_detail(&self) -> String {
        let parts: Vec<String> = self
            .links
            .iter()
            .enumerate()
            .map(|(i, l)| format!("shard {i} ({}) {}", l.addr, self.link_state_name(i)))
            .collect();
        format!("no routable worker — {}", parts.join(", "))
    }

    // ── pumping: frames in, state machine forward ─────────────────────────

    /// Drain every link's socket, apply scoreboards, re-emit events, run
    /// the health ladder. Returns whether any frame arrived.
    pub fn pump(&mut self) -> Result<bool> {
        let mut any = false;
        for i in 0..self.links.len() {
            let polled = match &mut self.links[i].conn {
                Some(c) => c.poll(),
                None => continue,
            };
            let frames = match polled {
                Ok(f) => f,
                Err(e) => {
                    self.fail_link(i, &e.to_string())?;
                    continue;
                }
            };
            if frames.is_empty() {
                continue;
            }
            any = true;
            self.links[i].last_rx = Instant::now();
            if self.links[i].state == LinkState::Suspect {
                // any frame proves life; serving intent decides routability
                self.links[i].state = LinkState::Alive;
                self.dispatcher.set_routable(i, self.links[i].serving);
            }
            for frame in frames {
                self.on_frame(i, frame)?;
            }
        }
        self.health_sweep()?;
        Ok(any)
    }

    fn on_frame(&mut self, shard: usize, frame: Frame) -> Result<()> {
        match frame {
            Frame::Scoreboard { shard: s, board } => {
                if s as usize != shard {
                    log::warn!("router: shard {shard} gossiped as shard {s}; dropping");
                    return Ok(());
                }
                self.apply_board(shard, board);
            }
            Frame::Event { id, ev } => self.on_event(shard, id, ev),
            Frame::StealAck { reqs } => self.on_steal_ack(shard, reqs)?,
            Frame::Draining { reqs } => {
                // graceful handover: the worker evacuated — rehome its
                // backlog now and take it out of rotation without the ladder
                log::info!(
                    "router: shard {shard} draining, rehoming {} requests",
                    reqs.len()
                );
                self.links[shard].state = LinkState::Draining;
                self.links[shard].serving = false;
                self.dispatcher.set_routable(shard, false);
                self.dispatcher.publish(shard, []);
                self.dispatcher.publish_pages(shard, 0);
                self.dispatcher.publish_prefixes(shard, []);
                self.rehome(shard, reqs)?;
            }
            Frame::OpAck { op, adapter, val } => self.acks.push((op, adapter, val, shard)),
            Frame::Bye => {
                let was_draining = self.links[shard].state == LinkState::Draining;
                self.links[shard].conn = None;
                if !was_draining {
                    self.fail_link(shard, "peer said Bye with work outstanding")?;
                }
            }
            other => {
                log::warn!("router: unexpected frame from shard {shard}: {other:?}");
            }
        }
        Ok(())
    }

    fn apply_board(&mut self, shard: usize, board: NodeScoreboard) {
        self.dispatcher
            .publish(shard, board.resident.iter().copied());
        self.dispatcher
            .publish_pages(shard, board.free_pages as usize);
        if self.cfg.prefix_affinity && self.links.len() > 1 {
            self.dispatcher
                .publish_prefixes(shard, board.prefix_hashes.iter().copied());
        }
        if board.queue > 0 || board.active > 0 {
            self.links[shard].busy_until = Instant::now();
        }
        self.links[shard].board = board;
    }

    /// Re-emit one worker event on the router bus and fold it into the
    /// recorder reconstruction. The `finished` guard makes terminal events
    /// idempotent: a false-Dead worker whose request was already rehomed
    /// and completed elsewhere cannot double-count.
    fn on_event(&mut self, _shard: usize, id: u64, ev: EngineEvent) {
        self.events.emit(id, ev);
        if self.finished.contains(&id) {
            return;
        }
        match ev {
            EngineEvent::Admitted { t, .. } => {
                if let Some(fl) = self.inflight.get_mut(&id) {
                    fl.scheduled = t;
                }
            }
            EngineEvent::Token { index, t, .. } => {
                if let Some(fl) = self.inflight.get_mut(&id) {
                    if index == fl.tokens {
                        if index == 0 {
                            fl.first_token = t;
                            self.recorder
                                .record_ttft((t - fl.req.arrival_s).max(0.0), fl.req.qos);
                        } else {
                            self.recorder
                                .record_itl((t - fl.last_token_t).max(0.0), fl.req.qos);
                        }
                        fl.last_token_t = t;
                        fl.tokens += 1;
                    }
                }
            }
            EngineEvent::Done { t } => {
                if let Some(fl) = self.inflight.remove(&id) {
                    self.finished.insert(id);
                    self.recorder.complete(&RequestRecord {
                        id,
                        adapter: fl.req.explicit_adapter.unwrap_or(fl.req.true_adapter) as usize,
                        arrival: fl.req.arrival_s,
                        scheduled: fl.scheduled,
                        first_token: fl.first_token,
                        finished: t,
                        input_tokens: fl.req.input_tokens,
                        output_tokens: fl.tokens as usize,
                        cache_hit: false,
                        auto_selected: fl.req.explicit_adapter.is_none(),
                        qos: fl.req.qos,
                        deadline_s: fl.req.deadline_s.unwrap_or(0.0),
                    });
                }
            }
            EngineEvent::Cancelled => {
                if self.inflight.remove(&id).is_some() {
                    self.finished.insert(id);
                }
            }
            EngineEvent::Shed { reason } => {
                if self.inflight.remove(&id).is_some() {
                    self.finished.insert(id);
                    self.recorder.record_shed(reason);
                    self.shed_total += 1;
                }
            }
            _ => {}
        }
    }

    // ── health ladder + failure handling ──────────────────────────────────

    fn health_sweep(&mut self) -> Result<()> {
        for i in 0..self.links.len() {
            if self.links[i].conn.is_none() {
                continue;
            }
            let age = self.links[i].last_rx.elapsed();
            match self.links[i].state {
                LinkState::Alive if age > SUSPECT_AFTER => {
                    log::warn!(
                        "router: shard {i} ({}) silent for {age:?} — Suspect",
                        self.links[i].addr
                    );
                    self.links[i].state = LinkState::Suspect;
                    self.dispatcher.set_routable(i, false);
                }
                LinkState::Suspect if age > DEAD_AFTER => {
                    self.fail_link(i, "heartbeat timeout")?;
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Declare a link Dead: tear the connection down, scrub its dispatch
    /// state, rehome its in-flight requests. Draining links were already
    /// evacuated — their flights moved with the `Draining` frame.
    fn fail_link(&mut self, i: usize, why: &str) -> Result<()> {
        if self.links[i].state == LinkState::Dead {
            return Ok(());
        }
        let was_draining = self.links[i].state == LinkState::Draining;
        log::warn!("router: shard {i} ({}) is dead: {why}", self.links[i].addr);
        self.links[i].conn = None;
        self.links[i].state = LinkState::Dead;
        self.links[i].serving = false;
        self.dispatcher.set_routable(i, false);
        self.dispatcher.publish(i, []);
        self.dispatcher.publish_pages(i, 0);
        self.dispatcher.publish_prefixes(i, []);
        if self
            .steal_pending
            .map_or(false, |(d, t)| d == i || t == i)
        {
            self.steal_pending = None;
        }
        if !was_draining {
            let orphans: Vec<TraceRequest> = self
                .inflight
                .values()
                .filter(|f| f.shard == i)
                .map(|f| f.req.clone())
                .collect();
            self.rehome(i, orphans)?;
        }
        Ok(())
    }

    /// Re-dispatch requests off shard `from` onto live workers, in
    /// `(qos, arrival, id)` order — Interactive work re-enters live queues
    /// first, deterministic within a class. No live worker ⇒ the request
    /// sheds Unreachable (terminal, counted) rather than queue into a
    /// black hole.
    fn rehome(&mut self, from: usize, mut reqs: Vec<TraceRequest>) -> Result<()> {
        reqs.sort_by(|a, b| {
            a.qos
                .cmp(&b.qos)
                .then(a.arrival_s.total_cmp(&b.arrival_s))
                .then(a.id.cmp(&b.id))
        });
        for req in reqs {
            let id = req.id;
            match self.route_live(&req) {
                Some(to) => {
                    if let Some(fl) = self.inflight.get_mut(&id) {
                        fl.shard = to;
                    }
                    self.rehomed[from] += 1;
                    self.rehomed_total += 1;
                    self.events.emit(id, EngineEvent::Rehomed { from, to });
                    self.links[to].board.queue += 1;
                    self.send_to(to, Frame::Submit { req })?;
                }
                None => {
                    self.inflight.remove(&id);
                    self.finished.insert(id);
                    self.events
                        .emit(id, EngineEvent::Shed { reason: ShedReason::Unreachable });
                    self.recorder.record_shed(ShedReason::Unreachable);
                    self.shed_total += 1;
                }
            }
        }
        Ok(())
    }

    fn send_to(&mut self, i: usize, frame: Frame) -> Result<()> {
        let res = match &mut self.links[i].conn {
            Some(c) => c.send(&frame),
            None => Err(io::Error::new(
                io::ErrorKind::NotConnected,
                "link already down",
            )),
        };
        if let Err(e) = res {
            self.fail_link(i, &e.to_string())?;
        }
        Ok(())
    }

    // ── dispatch ──────────────────────────────────────────────────────────

    /// Routing decision over the gossiped scoreboards (loads, resident
    /// sets, free pages, prefix hashes). `None` when no worker is routable.
    fn route_live(&mut self, req: &TraceRequest) -> Option<usize> {
        if !self.any_routable() {
            return None;
        }
        let key = req.explicit_adapter.unwrap_or(req.true_adapter);
        self.load_buf.clear();
        self.load_buf
            .extend(self.links.iter().map(|l| (l.board.queue + l.board.active) as usize));
        let prefix = self.prefix_hint(req);
        Some(
            self.dispatcher
                .route_with_prefix(key, req.id, &self.load_buf, prefix),
        )
    }

    /// First-page boundary hash of the request's prompt — same gates as
    /// the in-process cluster (≥ 2 workers, feature on, somebody gossiped
    /// a hash, explicit adapter), plus an agreed page geometry from the
    /// handshake. The router hashes the prompt exactly as every node's
    /// radix does, so a hit here is a guaranteed radix hit there (modulo
    /// eviction races, which just cost the hint nothing).
    fn prefix_hint(&mut self, req: &TraceRequest) -> Option<u64> {
        if !self.cfg.prefix_affinity
            || self.links.len() < 2
            || self.page_tokens == 0
            || !self.dispatcher.any_prefixes()
        {
            return None;
        }
        let adapter = req.explicit_adapter?;
        synth_prompt_into(req, self.max_prompt, &mut self.prompt_buf);
        boundary_hashes(adapter, &self.prompt_buf, self.page_tokens, &mut self.hash_buf);
        self.hash_buf.first().copied()
    }

    fn shed_edge(&mut self, id: u64, reason: ShedReason) {
        self.events.emit(id, EngineEvent::Shed { reason });
        self.recorder.record_shed(reason);
        self.shed_total += 1;
        self.finished.insert(id);
    }

    /// Admission + dispatch: Unreachable shed when no worker is routable
    /// (satellite: the 503 + `Retry-After` path), then the same QoS ladder
    /// as in-process (token bucket, deadline feasibility over the gossiped
    /// EWMA), then route + Submit.
    pub fn try_dispatch(&mut self, req: TraceRequest) -> Result<Dispatched> {
        self.pump()?;
        if !self.any_routable() {
            self.activate_standby();
        }
        if !self.any_routable() {
            self.shed_edge(req.id, ShedReason::Unreachable);
            return Ok(Dispatched::Shed {
                reason: ShedReason::Unreachable,
                retry_after_s: RETRY_AFTER_UNREACHABLE,
            });
        }
        if self.cfg.qos.enabled && self.cfg.qos.tenant_rate > 0.0 {
            let bucket = self
                .buckets
                .entry(req.explicit_adapter.unwrap_or(req.true_adapter))
                .or_insert_with(|| {
                    TokenBucket::new(self.cfg.qos.tenant_rate, self.cfg.qos.tenant_burst)
                });
            if !bucket.try_take(req.arrival_s) {
                let retry_after_s = bucket.retry_after_s();
                self.shed_edge(req.id, ShedReason::RateLimit);
                return Ok(Dispatched::Shed {
                    reason: ShedReason::RateLimit,
                    retry_after_s,
                });
            }
        }
        let i = match self.route_live(&req) {
            Some(i) => i,
            None => {
                self.shed_edge(req.id, ShedReason::Unreachable);
                return Ok(Dispatched::Shed {
                    reason: ShedReason::Unreachable,
                    retry_after_s: RETRY_AFTER_UNREACHABLE,
                });
            }
        };
        if self.cfg.qos.enabled {
            if let Some(d) = req.deadline_s {
                // remote variant of the deadline feasibility check: the
                // gossiped EWMA and whole-queue depth (the class-ahead
                // split does not cross the wire — strictly conservative)
                let b = &self.links[i].board;
                let ewma = b.ewma_ttft_s;
                let slots = b.slots.max(1) as f64;
                let predicted = ewma * (1.0 + b.queue as f64 / slots);
                if ewma > 0.0 && predicted > d * self.cfg.qos.deadline_slack {
                    self.shed_edge(req.id, ShedReason::Deadline);
                    return Ok(Dispatched::Shed {
                        reason: ShedReason::Deadline,
                        retry_after_s: (predicted - d).ceil().max(1.0) as u64,
                    });
                }
            }
        }
        self.dispatched[i] += 1;
        self.inflight.insert(
            req.id,
            Flight {
                shard: i,
                scheduled: req.arrival_s,
                first_token: req.arrival_s,
                last_token_t: req.arrival_s,
                tokens: 0,
                req: req.clone(),
            },
        );
        // optimistic load bump so a dispatch burst spreads before the next
        // gossip round lands
        self.links[i].board.queue += 1;
        self.links[i].busy_until = Instant::now();
        self.send_to(i, Frame::Submit { req })?;
        Ok(Dispatched::To(i))
    }

    /// One-shot serving: dispatch, then pump to this request's terminal
    /// event under a wall watchdog.
    pub fn try_serve_one(&mut self, req: TraceRequest) -> Result<Dispatched> {
        let id = req.id;
        let served = self.try_dispatch(req)?;
        if let Dispatched::Shed { .. } = served {
            return Ok(served);
        }
        let deadline = Instant::now() + SERVE_WATCHDOG;
        while !self.finished.contains(&id) {
            if !self.pump()? {
                std::thread::sleep(Duration::from_millis(1));
            }
            self.rebalance()?;
            if Instant::now() > deadline {
                bail!("request {id} did not finish within {SERVE_WATCHDOG:?}");
            }
        }
        Ok(served)
    }

    /// Streaming-path driver (the remote `step_once`): pump frames, run
    /// the steal/standby governors. `Ok(false)` means idle — nothing in
    /// flight and no frame moved.
    pub fn step_once(&mut self) -> Result<bool> {
        let any = self.pump()?;
        self.rebalance()?;
        self.scale_down_idle_standby()?;
        Ok(any || !self.inflight.is_empty())
    }

    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        self.pump()?;
        let shard = match self.inflight.get(&id) {
            Some(f) => f.shard,
            None => return Ok(false),
        };
        self.send_to(shard, Frame::Cancel { id })?;
        Ok(true)
    }

    /// Pump until nothing is in flight and every live worker reports an
    /// empty queue and no active slots.
    pub fn quiesce(&mut self) -> Result<()> {
        let deadline = Instant::now() + QUIESCE_WATCHDOG;
        loop {
            let any = self.pump()?;
            self.rebalance()?;
            let idle = self.inflight.is_empty()
                && self.links.iter().all(|l| {
                    l.conn.is_none() || (l.board.queue == 0 && l.board.active == 0)
                });
            if idle {
                return Ok(());
            }
            if Instant::now() > deadline {
                bail!(
                    "quiesce watchdog: {} requests still in flight",
                    self.inflight.len()
                );
            }
            if !any {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }

    /// Keep per-request bookkeeping bounded on the long-lived serving path.
    pub fn trim_logs(&mut self) {
        if self.finished.len() > 65536 {
            self.finished.clear();
        }
        self.acks.clear();
    }

    // ── remote work stealing ──────────────────────────────────────────────

    /// The remote analogue of in-process queue rebalancing: when a
    /// routable worker sits queue-empty while another's gossiped backlog
    /// exceeds the steal threshold, ask the donor to hand half its queue
    /// over (`Steal` → `StealAck`) and re-submit the stolen requests to
    /// the thief. One steal RPC in flight at a time.
    fn rebalance(&mut self) -> Result<()> {
        if !self.cfg.stealing || self.links.len() < 2 || self.steal_pending.is_some() {
            return Ok(());
        }
        let mut donor: Option<(usize, u32)> = None;
        let mut thief: Option<usize> = None;
        for i in 0..self.links.len() {
            if !self.dispatcher.is_routable(i) || self.links[i].conn.is_none() {
                continue;
            }
            let b = &self.links[i].board;
            if b.queue >= STEAL_MIN_QUEUE.max(self.cfg.steal_threshold as u32)
                && donor.map_or(true, |(_, q)| b.queue > q)
            {
                donor = Some((i, b.queue));
            }
            if b.queue == 0 && b.active < b.slots && thief.is_none() {
                thief = Some(i);
            }
        }
        if let (Some((d, q)), Some(t)) = (donor, thief) {
            if d != t {
                self.steal_pending = Some((d, t));
                self.send_to(d, Frame::Steal { max: (q / 2).max(1) })?;
            }
        }
        Ok(())
    }

    fn on_steal_ack(&mut self, shard: usize, reqs: Vec<TraceRequest>) -> Result<()> {
        let thief = match self.steal_pending.take() {
            Some((d, t)) if d == shard => t,
            _ => {
                // stale ack (donor died and recovered the slot) — requests
                // must not be lost: rehome them like an evacuation
                return self.rehome(shard, reqs);
            }
        };
        for req in reqs {
            let id = req.id;
            if !self.dispatcher.is_routable(thief) {
                // thief died while the RPC was in flight
                return self.rehome(shard, vec![req]);
            }
            if let Some(fl) = self.inflight.get_mut(&id) {
                fl.shard = thief;
            }
            self.steals += 1;
            self.events
                .emit(id, EngineEvent::Rehomed { from: shard, to: thief });
            self.links[thief].board.queue += 1;
            self.send_to(thief, Frame::Submit { req })?;
        }
        Ok(())
    }

    // ── standby autoscaling ───────────────────────────────────────────────

    /// Activate one standby worker: on total unreachability (failover) or
    /// when the fleet's gossiped backlog exceeds twice its serving slots
    /// (pressure). Called from the dispatch path.
    fn activate_standby(&mut self) {
        let pressure: u32 = self.links.iter().map(|l| l.board.queue).sum();
        let serving_slots: u32 = self
            .links
            .iter()
            .filter(|l| l.serving)
            .map(|l| l.board.slots.max(1))
            .sum();
        let need = !self.any_routable() || pressure > serving_slots.max(1) * 2;
        if !need {
            return;
        }
        self.scale_out();
    }

    /// Activate the next inactive standby worker and start routing to it.
    /// The pressure-gated path ([`Self::try_dispatch`]) and operator- or
    /// experiment-initiated scale-outs (`bench-table --table distributed`)
    /// share this. Returns false when no standby is available.
    pub fn scale_out(&mut self) -> bool {
        for i in 0..self.links.len() {
            let l = &mut self.links[i];
            if l.standby && !l.serving && l.conn.is_some() && l.state == LinkState::Alive {
                log::info!("router: activating standby shard {i} ({})", l.addr);
                l.serving = true;
                l.busy_until = Instant::now();
                self.dispatcher.set_routable(i, true);
                return true;
            }
        }
        false
    }

    /// Wind an activated standby back down once it has sat idle: `Drain`
    /// it (the node evacuates — usually nothing — and keeps serving the
    /// link) and stop routing to it.
    fn scale_down_idle_standby(&mut self) -> Result<()> {
        for i in 0..self.links.len() {
            let l = &self.links[i];
            if l.standby
                && l.serving
                && l.state == LinkState::Alive
                && l.board.queue == 0
                && l.board.active == 0
                && l.busy_until.elapsed() > Duration::from_secs(2)
            {
                log::info!("router: draining idle standby shard {i} ({})", l.addr);
                self.links[i].serving = false;
                self.dispatcher.set_routable(i, false);
                self.send_to(i, Frame::Drain)?;
                // the Draining answer is empty (it was idle) and flips the
                // state to Draining; reactivation re-marks it serving
                return Ok(());
            }
        }
        Ok(())
    }

    // ── registry RPC broadcasts ───────────────────────────────────────────

    /// Broadcast one registry op to every connected worker and tally the
    /// acks (sum of each node's `val`). Workers that die mid-RPC are
    /// excluded from the wait rather than timing the whole op out.
    fn broadcast_op(&mut self, frame: Frame, op: u8, adapter: u64) -> Result<u64> {
        self.acks
            .retain(|&(o, a, _, _)| !(o == op && a == adapter));
        let mut waiting = vec![false; self.links.len()];
        for i in 0..self.links.len() {
            if self.links[i].conn.is_some() && self.links[i].state != LinkState::Dead {
                self.send_to(i, frame.clone())?;
                waiting[i] = self.links[i].conn.is_some();
            }
        }
        let deadline = Instant::now() + OP_TIMEOUT;
        let mut total = 0u64;
        let mut got = vec![false; self.links.len()];
        loop {
            self.pump()?;
            let mut j = 0;
            while j < self.acks.len() {
                let (o, a, val, s) = self.acks[j];
                if o == op && a == adapter {
                    total += val;
                    got[s] = true;
                    self.acks.remove(j);
                } else {
                    j += 1;
                }
            }
            let done = (0..self.links.len())
                .all(|i| !waiting[i] || got[i] || self.links[i].conn.is_none());
            if done {
                return Ok(total);
            }
            if Instant::now() > deadline {
                bail!("registry op {op} on adapter {adapter} timed out");
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Fleet-wide registry pin; returns how many workers hold it.
    pub fn pin_adapter(&mut self, id: u64) -> Result<usize> {
        let n = self.broadcast_op(Frame::Pin { adapter: id }, OP_PIN, id)?;
        if n > 0 {
            self.pinned.insert(id);
        }
        Ok(n as usize)
    }

    /// Release fleet pins; returns how many existed.
    pub fn unpin_adapter(&mut self, id: u64) -> usize {
        self.pinned.remove(&id);
        self.broadcast_op(Frame::Unpin { adapter: id }, OP_UNPIN, id)
            .unwrap_or(0) as usize
    }

    /// Materialize a synthetic adapter on every worker (deterministic per
    /// id, so the fleet's copies are byte-identical to the router's).
    pub fn register_adapter(&mut self, id: u64) -> Result<usize> {
        Ok(self.broadcast_op(Frame::Register { adapter: id }, OP_REGISTER, id)? as usize)
    }

    /// Fleet-wide purge (the caller quiesced first); returns how many
    /// workers held residency.
    pub fn purge_adapter(&mut self, id: u64) -> Result<usize> {
        self.pinned.remove(&id);
        let n = self.broadcast_op(Frame::Delete { adapter: id }, OP_DELETE, id)?;
        self.dispatcher.scrub(id);
        Ok(n as usize)
    }

    pub fn n_adapters(&self) -> usize {
        self.n_adapters
    }

    // ── trace replay + reporting (bench/e2e surface) ──────────────────────

    /// Replay a whole trace through the socket fleet and quiesce. Arrivals
    /// keep their trace stamps — workers advance their virtual clocks to
    /// them on Submit, exactly like the in-process dispatch path.
    /// Replay a trace, pacing submissions on the wall clock so scoreboard
    /// and prefix-hash gossip flows between dispatches exactly as it would
    /// for live traffic. Pacing never changes token *content* — nodes pace
    /// themselves on their own virtual clocks and tokens are a pure
    /// function of the request — it only lets placement see fresh boards.
    pub fn run_trace(&mut self, trace: &Trace) -> Result<RemoteReport> {
        let t0 = Instant::now();
        for req in &trace.requests {
            while t0.elapsed().as_secs_f64() < req.arrival_s {
                self.pump()?;
                std::thread::sleep(Duration::from_millis(1));
            }
            let _ = self.try_dispatch(req.clone())?;
        }
        self.quiesce()?;
        Ok(self.report())
    }

    pub fn report(&self) -> RemoteReport {
        let makespan = self.makespan_s();
        RemoteReport {
            summary: self.recorder.summarize(Some(makespan.max(1e-9))),
            makespan_s: makespan,
            dispatched: self.dispatched.clone(),
            steals: self.steals,
            rehomed_total: self.rehomed_total,
            shed_total: self.shed_total,
            prefix_hits: self.links.iter().map(|l| l.board.prefix_hits).sum(),
            prefix_lookups: self.links.iter().map(|l| l.board.prefix_lookups).sum(),
            prefix_overrides: self.dispatcher.prefix_overrides,
        }
    }

    /// Send `Bye` on every live link (thread-hosted workers go back to
    /// `accept`; process workers idle until killed).
    pub fn close(&mut self) {
        for i in 0..self.links.len() {
            let _ = self.send_to(i, Frame::Bye);
            self.links[i].conn = None;
        }
    }

    /// Test hook: force every link Suspect/unroutable so the
    /// all-workers-down 503 path can be pinned without real timeouts.
    #[doc(hidden)]
    pub fn force_all_unroutable(&mut self) {
        for i in 0..self.links.len() {
            self.links[i].state = LinkState::Suspect;
            self.links[i].serving = false;
            self.links[i].standby = false;
            self.dispatcher.set_routable(i, false);
        }
    }
}

impl Drop for RemoteCluster {
    fn drop(&mut self) {
        self.close();
    }
}

/// Dial one worker, retrying while it binds.
fn dial(addr: &str) -> Result<Conn> {
    let deadline = Instant::now() + DIAL_TIMEOUT;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(Conn::new(s)?),
            Err(e) => {
                if Instant::now() > deadline {
                    return Err(e).with_context(|| format!("dialing worker {addr}"));
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Await the handshake reply; returns (page_tokens, max_prompt).
fn await_hello_ack(conn: &mut Conn, shard: usize) -> Result<(usize, usize)> {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        for frame in conn.poll()? {
            match frame {
                Frame::HelloAck { version, page_tokens, max_prompt, .. } => {
                    anyhow::ensure!(
                        version == PROTO_VERSION,
                        "shard {shard} speaks v{version}, router speaks v{PROTO_VERSION}"
                    );
                    return Ok((page_tokens as usize, max_prompt as usize));
                }
                other => bail!("shard {shard}: expected HelloAck, got {other:?}"),
            }
        }
        if Instant::now() > deadline {
            bail!("shard {shard}: no HelloAck within 5s");
        }
        std::thread::sleep(Duration::from_millis(1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::devices::DeviceProfile;
    use crate::config::{EngineKind, ModelSetting, ServerConfig, WorkloadConfig};
    use crate::experiments::harness::{mk_store, ClusterSpec, ExperimentSpec};
    use crate::memory::CachePolicy;
    use crate::net::node::NodeServer;
    use crate::workload::QosClass;
    use std::sync::atomic::{AtomicBool, Ordering};

    fn tiny_spec(n: usize) -> ClusterSpec {
        ClusterSpec {
            base: ExperimentSpec {
                model: ModelSetting::s1(),
                device: DeviceProfile::agx_orin(),
                engine: EngineKind::EdgeLora,
                server: ServerConfig {
                    engine: EngineKind::EdgeLora,
                    slots: 2,
                    ..ServerConfig::default()
                },
                workload: WorkloadConfig {
                    n_adapters: 4,
                    duration_s: 1.0,
                    ..WorkloadConfig::default()
                },
                tdp_watts: None,
                cache_policy: CachePolicy::Lru,
                router_acc: 0.95,
            },
            devices: vec![DeviceProfile::agx_orin(); n],
            cluster: ClusterConfig::default(),
        }
    }

    fn req(id: u64, adapter: u64) -> TraceRequest {
        TraceRequest {
            id,
            arrival_s: id as f64 * 0.01,
            true_adapter: adapter,
            explicit_adapter: Some(adapter),
            input_tokens: 8,
            output_tokens: 4,
            qos: QosClass::Interactive,
            deadline_s: None,
        }
    }

    /// Spawn `n` thread-hosted workers; returns (addrs, stops, joins).
    fn spawn_workers(
        spec: &ClusterSpec,
        n: usize,
    ) -> (Vec<String>, Vec<Arc<AtomicBool>>, Vec<std::thread::JoinHandle<()>>) {
        let mut addrs = Vec::new();
        let mut stops = Vec::new();
        let mut joins = Vec::new();
        for shard in 0..n {
            let node = NodeServer::bind(spec, shard, "127.0.0.1:0").unwrap();
            addrs.push(node.local_addr().unwrap().to_string());
            stops.push(node.stop_handle());
            joins.push(std::thread::spawn(move || node.serve().unwrap()));
        }
        (addrs, stops, joins)
    }

    fn stop_workers(stops: Vec<Arc<AtomicBool>>, joins: Vec<std::thread::JoinHandle<()>>) {
        for s in &stops {
            s.store(true, Ordering::SeqCst);
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn serves_requests_and_reconstructs_records_over_sockets() {
        let spec = tiny_spec(2);
        let (addrs, stops, joins) = spawn_workers(&spec, 2);
        let store = mk_store(&spec.base, "router_t1").unwrap();
        let mut rc =
            RemoteCluster::connect(&addrs, 0, spec.cluster.clone(), store, 4).unwrap();
        for i in 0..6u64 {
            let d = rc.try_serve_one(req(i, i % 4)).unwrap();
            assert!(matches!(d, Dispatched::To(_)), "request {i} must dispatch");
        }
        assert_eq!(rc.recorder.completed(), 6, "every request completes once");
        let report = rc.report();
        assert_eq!(report.summary.requests, 6);
        assert!(report.makespan_s > 0.0, "worker clocks must have advanced");
        assert_eq!(report.dispatched.iter().sum::<u64>(), 6);
        rc.close();
        stop_workers(stops, joins);
    }

    #[test]
    fn unreachable_fleet_sheds_with_retry_after_and_names_shards() {
        let spec = tiny_spec(2);
        let (addrs, stops, joins) = spawn_workers(&spec, 2);
        let store = mk_store(&spec.base, "router_t2").unwrap();
        let mut rc =
            RemoteCluster::connect(&addrs, 0, spec.cluster.clone(), store, 4).unwrap();
        rc.force_all_unroutable();
        match rc.try_dispatch(req(1, 1)).unwrap() {
            Dispatched::Shed { reason, retry_after_s } => {
                assert_eq!(reason, ShedReason::Unreachable);
                assert!(retry_after_s >= 1, "must carry a Retry-After hint");
            }
            other => panic!("expected Unreachable shed, got {other:?}"),
        }
        let detail = rc.unreachable_detail();
        assert!(detail.contains("shard 0"), "detail names shard 0: {detail}");
        assert!(detail.contains("shard 1"), "detail names shard 1: {detail}");
        assert!(detail.contains("suspect"), "detail names the state: {detail}");
        assert_eq!(rc.report().summary.shed_unreachable, 1);
        // frames from the (actually alive) workers recover the ladder
        let deadline = Instant::now() + Duration::from_secs(10);
        while !rc.any_routable() {
            rc.pump().unwrap();
            assert!(Instant::now() < deadline, "heartbeats must recover Suspect");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(matches!(rc.try_dispatch(req(2, 1)).unwrap(), Dispatched::To(_)));
        rc.quiesce().unwrap();
        rc.close();
        stop_workers(stops, joins);
    }

    #[test]
    fn registry_broadcast_reaches_every_worker() {
        let spec = tiny_spec(2);
        let (addrs, stops, joins) = spawn_workers(&spec, 2);
        let store = mk_store(&spec.base, "router_t3").unwrap();
        let mut rc =
            RemoteCluster::connect(&addrs, 0, spec.cluster.clone(), store, 4).unwrap();
        assert_eq!(rc.register_adapter(77).unwrap(), 2, "both nodes materialize");
        let pinned = rc.pin_adapter(77).unwrap();
        assert!(pinned >= 1, "at least one node pins (got {pinned})");
        assert!(rc.registry_pinned(77));
        assert_eq!(rc.unpin_adapter(77), pinned);
        assert!(!rc.registry_pinned(77));
        let purged = rc.purge_adapter(77).unwrap();
        assert!(purged <= 2, "purge reports residency count (got {purged})");
        rc.close();
        stop_workers(stops, joins);
    }
}
