//! Multi-node serving over real sockets (DESIGN.md §Distributed serving):
//! the length-prefixed wire protocol ([`proto`]), the worker process
//! wrapping one engine replica behind it ([`node`]), and the router-side
//! cluster handle that owns dispatch, health, and standby scaling across N
//! worker links ([`router`]). The in-process `cluster::ClusterEngine` stays
//! the single-process fast path; this module is the same scheduling brain
//! split across processes.

pub mod node;
pub mod proto;
pub mod router;

pub use node::{install_signal_handlers, shutdown_requested, NodeServer};
pub use proto::{Conn, Frame, NodeScoreboard, WireError, MAX_FRAME_BYTES, PROTO_VERSION};
pub use router::{LinkState, RemoteCluster, RemoteReport, DEAD_AFTER, SUSPECT_AFTER};
